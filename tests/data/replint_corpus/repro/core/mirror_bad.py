"""Corpus: mirror-sync violations — raw buffer writes outside the owner."""


def clobber(dev, state, arr):
    dev._sky = arr                         # BAD: direct write
    dev._t2s.remove(0.5)                   # BAD: mutator through _t2s
    state._dirty.clear()                   # BAD: mutator through _dirty
    del dev._lp                            # BAD: delete
    dev._sky._steps[0] += 1.0              # BAD: augassign through _sky
