"""Head padding for TPU-friendly attention sharding (§Perf, DESIGN.md §8.3).

Several assigned archs have head counts that don't divide the model mesh
axis (llava 56q/8kv, qwen2 14q/2kv, smollm 9q/3kv on model=16), so the
baseline divisibility rules *replicate* all attention weights and the KV
cache — for decode that makes attention weights the dominant per-device HBM
stream and invites GSPMD to invent catastrophic cache re-shards.

The fix is the standard TPU trick: pad the head axes so they divide the
mesh —

  kv' = lcm(n_kv_heads, multiple)      (each orig kv head duplicated
                                        r = kv'/n_kv_heads times)
  g   = n_heads // n_kv_heads          (GQA group)
  g'  = ceil(g / r)                    (queries per padded kv slot)
  h'  = kv' * g'

Padded kv slot ``j`` holds a copy of original kv head ``j // r``; its query
slots ``l in [0, g')`` hold original query head ``(j//r)*g + (j%r)*g' + l``
(zero-weights when that index walks off the original group).  Because the
padded wq rows AND the matching wo rows are zero, the transformed model is
numerically identical to the original (the uniform softmax a zero query
produces is annihilated by the zero output-projection row).

``pad_heads_config`` transforms the config (for abstract lowering);
``pad_attn_params`` transforms real parameter trees (so serving engines can
load unpadded checkpoints); both are validated for exact equivalence in
tests/test_head_padding.py.
"""
from __future__ import annotations

import math
from dataclasses import replace

import jax
import jax.numpy as jnp

from .config import ModelConfig


def padded_head_counts(n_heads: int, n_kv_heads: int,
                       multiple: int) -> tuple[int, int]:
    """(h', kv') after padding so ``multiple | kv'`` and ``multiple | h'``."""
    kv_p = math.lcm(n_kv_heads, multiple)
    r = kv_p // n_kv_heads
    g = n_heads // n_kv_heads
    g_p = -(-g // r)                     # ceil
    return kv_p * g_p, kv_p


def pad_heads_config(cfg: ModelConfig, multiple: int) -> ModelConfig:
    """Padded-head variant of ``cfg`` (no-op if already divisible or MLA)."""
    if cfg.mla is not None:
        return cfg                        # MLA shares one latent cache
    if cfg.n_heads % multiple == 0 and cfg.n_kv_heads % multiple == 0:
        return cfg
    if cfg.n_heads % cfg.n_kv_heads != 0:
        return cfg
    h_p, kv_p = padded_head_counts(cfg.n_heads, cfg.n_kv_heads, multiple)
    return replace(cfg, n_heads=h_p, n_kv_heads=kv_p,
                   head_dim=cfg.resolved_head_dim)


def _q_slot_map(h: int, kv: int, h_p: int, kv_p: int) -> list[int]:
    """padded q slot -> original q head index (or -1 for a zero slot)."""
    r = kv_p // kv
    g = h // kv
    g_p = h_p // kv_p
    out = []
    for j in range(kv_p):
        i, c = divmod(j, r)
        for l in range(g_p):
            src = c * g_p + l
            out.append(i * g + src if src < g else -1)
    return out

def _pad_attn_leaf_dict(p: dict, h: int, kv: int, h_p: int, kv_p: int,
                        hd: int) -> dict:
    """Pad one attention param dict {wq, wk, wv, wo[, bq, bk, bv]}.

    Leading (stacked-layer) axes are preserved; head axes are addressed
    from the right.
    """
    r = kv_p // kv
    qmap = _q_slot_map(h, kv, h_p, kv_p)
    out = dict(p)

    def pad_q(w):                         # [..., d, h, hd] -> [..., d, h', hd]
        base = jnp.zeros(w.shape[:-2] + (h_p, hd), w.dtype)
        cols = [base[..., s, :] if src < 0 else w[..., src, :]
                for s, src in enumerate(qmap)]
        return jnp.stack(cols, axis=-2)

    def pad_q_bias(b):                    # [..., h, hd] -> [..., h', hd]
        zero = jnp.zeros(b.shape[:-2] + (hd,), b.dtype)
        cols = [zero if src < 0 else b[..., src, :] for src in qmap]
        return jnp.stack(cols, axis=-2)

    out["wq"] = pad_q(p["wq"])
    out["wk"] = jnp.repeat(p["wk"], r, axis=-2)
    out["wv"] = jnp.repeat(p["wv"], r, axis=-2)
    # wo [..., h*hd, d] -> unflatten, place rows per qmap, reflatten
    wo = p["wo"]
    wo_h = wo.reshape(wo.shape[:-2] + (h, hd, wo.shape[-1]))
    zero_row = jnp.zeros(wo_h.shape[:-3] + (hd, wo.shape[-1]), wo.dtype)
    rows = [zero_row if src < 0 else wo_h[..., src, :, :] for src in qmap]
    out["wo"] = jnp.stack(rows, axis=-3).reshape(
        wo.shape[:-2] + (h_p * hd, wo.shape[-1]))
    if "bq" in p:
        out["bq"] = pad_q_bias(p["bq"])
        out["bk"] = jnp.repeat(p["bk"], r, axis=-2)
        out["bv"] = jnp.repeat(p["bv"], r, axis=-2)
    return out


def pad_attn_params(params, cfg: ModelConfig, cfg_p: ModelConfig):
    """Transform an unpadded parameter tree to the padded-head layout."""
    if cfg_p.n_heads == cfg.n_heads and cfg_p.n_kv_heads == cfg.n_kv_heads:
        return params
    h, kv = cfg.n_heads, cfg.n_kv_heads
    h_p, kv_p = cfg_p.n_heads, cfg_p.n_kv_heads
    hd = cfg.resolved_head_dim
    out = jax.tree_util.tree_map(lambda x: x, params)   # shallow copy tree

    def visit(stage_params, stage):
        sp = dict(stage_params)
        for i, ld in enumerate(stage.pattern):
            lp = dict(sp[f"p{i}"])
            if ld.mixer == "attn":
                lp["mixer"] = _pad_attn_leaf_dict(lp["mixer"], h, kv,
                                                  h_p, kv_p, hd)
            if ld.cross_attn:
                lp["cross"] = _pad_attn_leaf_dict(lp["cross"], h, kv,
                                                  h_p, kv_p, hd)
            sp[f"p{i}"] = lp
        return sp

    for i, st in enumerate(cfg.stages):
        out[f"dec{i}"] = visit(out[f"dec{i}"], st)
    for i, st in enumerate(cfg.encoder_stages):
        key = f"enc{i}"
        if key in out:
            out[key] = visit(out[key], st)
    return out
