from . import attention, common, ffn, mamba, mla, xlstm  # noqa: F401
