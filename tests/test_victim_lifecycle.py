"""The full victim lifecycle, across both victim policies and both
eviction paths (vectorized plane / scalar reference):

* preempt -> reallocation success (victim re-enters ALLOCATED elsewhere),
* preempt -> reallocation failure (victim FAILED, counted),
* preempt -> the HP admission itself fails (the PR 5 stranded-victim
  bugfix: victims must STILL get the reallocation pass),

including link-slot cancellation and the ``preempt_count`` /
``preempted_by_cores`` accounting.
"""
import pytest

from repro.core.calendar import NetworkState
from repro.core.network import NetworkConfig
from repro.core.scheduler import PreemptionAwareScheduler
from repro.core.task import LowPriorityRequest, Priority, Task, TaskState

PARAMS = [(pol, plane) for pol in ("farthest_deadline", "weakest_set")
          for plane in (True, False)]


def make(n_devices=2, policy="farthest_deadline", plane=True):
    state = NetworkState(n_devices)
    net = NetworkConfig()
    sched = PreemptionAwareScheduler(state, net, preemption=True,
                                     victim_policy=policy,
                                     preemption_plane=plane)
    return state, net, sched


def hp_task(dev=0, deadline=3.0, frame=0):
    return Task(priority=Priority.HIGH, source_device=dev, deadline=deadline,
                frame_id=frame)


def admitted_lp(sched, dev=0, deadline=60.0, frame=0):
    """One LP task admitted through the scheduler (so it owns link slots)."""
    req = LowPriorityRequest(source_device=dev, deadline=deadline,
                             frame_id=frame, n_tasks=1)
    req.make_tasks()
    res = sched.allocate_low_priority(req, 0.0)
    assert len(res.allocations) == 1
    return req.tasks[0], res.allocations[0]


def link_tags(state):
    return [s.tag for s in state.link.reservations()]


@pytest.mark.parametrize("policy,plane", PARAMS)
def test_preempt_then_realloc_success(policy, plane):
    state, net, sched = make(3, policy, plane)
    # fill the source device so the victim's request offloads to device 1;
    # device 2 stays free as the reallocation target
    blocker = Task(priority=Priority.LOW, source_device=0, deadline=200.0,
                   frame_id=9)
    state.devices[0].reserve(0.0, 100.0, 4, blocker)
    filler2 = Task(priority=Priority.LOW, source_device=2, deadline=200.0,
                   frame_id=7)
    state.devices[2].reserve(0.0, 100.0, 2, filler2)   # keep dev1 least-loaded
    victim, alloc = admitted_lp(sched, dev=0, deadline=60.0)
    assert alloc.offloaded and alloc.device == 1
    assert ("xfer", victim.task_id) in link_tags(state)
    # saturate device 1's remaining cores over the victim's slot
    filler = Task(priority=Priority.LOW, source_device=1, deadline=55.0,
                  frame_id=8)
    state.devices[1].reserve(alloc.t_start, alloc.t_end, 2, filler)

    res = sched.allocate_high_priority(hp_task(dev=1), 0.0)
    assert res.success and victim in res.preempted
    assert victim.state == TaskState.ALLOCATED      # reallocated in time
    assert victim.preempt_count == 1
    assert sched.metrics.preemptions >= 1
    assert sched.metrics.preempted_by_cores[alloc.cores] >= 1
    assert sched.metrics.realloc_success >= 1
    # stale pending link traffic cancelled, replacement slots recorded
    tags = link_tags(state)
    assert ("xfer", victim.task_id) not in tags or \
        any(r.task is victim for r in res.reallocations)
    assert any(r.task is victim for r in res.reallocations)
    new_alloc = next(r for r in res.reallocations if r.task is victim)
    assert new_alloc.t_end <= victim.deadline
    assert ("update", victim.task_id) in tags


@pytest.mark.parametrize("policy,plane", PARAMS)
def test_preempt_then_realloc_failure(policy, plane):
    state, net, sched = make(1, policy, plane)   # nowhere to offload
    # two cores stay busy for a long horizon with NON-preemptable (HP)
    # work, so after the eviction the new HP slot leaves no 2-core window
    # for the victim
    for i in range(2):
        background = Task(priority=Priority.HIGH, source_device=0,
                          deadline=200.0, frame_id=9 + i)
        state.devices[0].reserve(0.0, 100.0, 1, background)
    victim, alloc = admitted_lp(sched, dev=0, deadline=18.5)
    assert not alloc.offloaded
    hp = hp_task(dev=0, deadline=3.0)
    res = sched.allocate_high_priority(hp, 0.0)
    assert res.success and victim in res.preempted
    assert victim.state == TaskState.FAILED
    assert sched.metrics.realloc_failure == 1
    assert sched.metrics.realloc_success == 0
    assert not res.reallocations
    # no pending link traffic left for the dead victim
    assert ("update", victim.task_id) not in link_tags(state)


@pytest.mark.parametrize("policy,plane", PARAMS)
def test_failed_hp_admission_still_reallocates_victims(policy, plane):
    """The stranded-victim regression (PR 5 headline bugfix): when the HP
    admission fails AFTER evicting victims — here the preempt message eats
    the only early link gap, pushing the re-derived window past the HP
    deadline — the victims must still get the reallocation pass instead of
    being left in PREEMPTED forever."""
    state, net, sched = make(2, policy, plane)
    msg_dur = net.slot(net.msg.hp_alloc)
    pre_dur = net.slot(net.msg.preempt)
    # link: free gap fits ONE hp_alloc message, then jammed until t=5
    gap = msg_dur + 0.5 * pre_dur
    state.link.reserve(gap, 5.0, "jam")
    # the victim holds all four cores of device 0 over the HP window
    victim = Task(priority=Priority.LOW, source_device=0, deadline=40.0,
                  frame_id=1)
    victim.state = TaskState.ALLOCATED
    state.devices[0].reserve(0.0, 15.0, 4, victim)

    hp = hp_task(dev=0, deadline=1.5)
    res = sched.allocate_high_priority(hp, 0.0)
    # the eviction happened, then the re-derived window missed the deadline
    assert not res.success
    assert res.preempted == [victim]
    assert victim.preempt_count == 1
    # THE FIX: the victim is not stranded in PREEMPTED — it got a
    # reallocation attempt before its own (still-far) deadline
    assert victim.state == TaskState.ALLOCATED
    assert sched.metrics.realloc_success == 1
    assert len(res.reallocations) == 1
    new_alloc = res.reallocations[0]
    assert new_alloc.task is victim
    assert new_alloc.t_end <= victim.deadline


@pytest.mark.parametrize("policy,plane", PARAMS)
def test_failed_hp_admission_realloc_failure_counted(policy, plane):
    """Same stranded scenario, but the victim's own deadline is too tight
    to re-place: it must transition to FAILED (not PREEMPTED) and count as
    a reallocation failure."""
    state, net, sched = make(1, policy, plane)
    msg_dur = net.slot(net.msg.hp_alloc)
    pre_dur = net.slot(net.msg.preempt)
    state.link.reserve(msg_dur + 0.5 * pre_dur, 5.0, "jam")
    victim = Task(priority=Priority.LOW, source_device=0, deadline=16.0,
                  frame_id=1)
    victim.state = TaskState.ALLOCATED
    state.devices[0].reserve(0.0, 15.0, 4, victim)

    res = sched.allocate_high_priority(hp_task(dev=0, deadline=1.5), 0.0)
    assert not res.success
    assert res.preempted == [victim]
    assert victim.state == TaskState.FAILED
    assert sched.metrics.realloc_failure == 1
    assert not res.reallocations


@pytest.mark.parametrize("policy,plane", PARAMS)
def test_failed_hp_admission_nonlp_blockers(policy, plane):
    """The OTHER failed-after-preemption path: every conflicting LP task
    was evicted but non-preemptable HP reservations still block the
    window.  Victims must get the reallocation pass here too."""
    state, net, sched = make(2, policy, plane)
    dev = state.devices[0]
    # four HP reservations saturate the early part of every candidate
    # window for a long horizon
    for i in range(4):
        blocker = Task(priority=Priority.HIGH, source_device=0,
                       deadline=50.0, frame_id=10 + i)
        dev.reserve(0.0, 30.0, 1, blocker)
    # an LP victim also overlaps the window (over-subscribed on purpose;
    # reserve() does not admission-check)
    victim = Task(priority=Priority.LOW, source_device=0, deadline=40.0,
                  frame_id=1)
    victim.state = TaskState.ALLOCATED
    dev.reserve(0.0, 15.0, 2, victim)

    res = sched.allocate_high_priority(hp_task(dev=0, deadline=2.0), 0.0)
    assert not res.success
    assert res.preempted == [victim]
    # reallocated on the idle device 1 before its deadline
    assert victim.state == TaskState.ALLOCATED
    assert sched.metrics.realloc_success == 1
    assert len(res.reallocations) == 1 and res.reallocations[0].offloaded


@pytest.mark.parametrize("plane", [True, False])
def test_weakest_set_health_updates_during_eviction_chain(plane):
    """Two conflicting victims from the SAME request: after the first
    eviction the sibling's set health drops, which must be visible to the
    next ranking round — on both eviction paths (the plane maintains the
    health column incrementally)."""
    state, net, sched = make(1, "weakest_set", plane)
    dev = state.devices[0]
    # request A: 2 tasks, both on this device, farther deadlines
    req_a = LowPriorityRequest(source_device=0, deadline=90.0, frame_id=1,
                               n_tasks=2)
    req_a.make_tasks()
    # request B: 2 tasks, one healthy here, one sibling healthy elsewhere,
    # nearer deadline
    req_b = LowPriorityRequest(source_device=0, deadline=80.0, frame_id=2,
                               n_tasks=2)
    req_b.make_tasks()
    sched._requests[req_a.request_id] = req_a
    sched._requests[req_b.request_id] = req_b
    for t in req_a.tasks + req_b.tasks:
        t.state = TaskState.ALLOCATED
    # dev: A0 + A1 + B0 hold 2 cores each over the window (6/4 —
    # over-subscribed on purpose; two evictions needed before 1 core fits)
    dev.reserve(0.0, 50.0, 2, req_a.tasks[0])
    dev.reserve(0.0, 50.0, 2, req_a.tasks[1])
    dev.reserve(0.0, 50.0, 2, req_b.tasks[0])

    res = sched.allocate_high_priority(hp_task(dev=0, deadline=3.0), 0.0)
    assert res.success
    # round 1: all healths are 1.0 -> farthest deadline wins (A, 90.0);
    # round 2: A's health fell to 1/2 < B's 1.0 -> the A sibling goes next,
    # NOT the nearer-deadline B task
    assert [t.request_id for t in res.preempted[:2]] == \
        [req_a.request_id, req_a.request_id]
    assert req_b.tasks[0].state == TaskState.ALLOCATED  # kept its slot...
    assert state.devices[0].get(req_b.tasks[0]) is not None
