"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304.  xLSTM[7:1] ratio:
super-block of 7 mLSTM + 1 sLSTM, repeated 6 times.  Blocks carry their own
up/down projections, so there is no separate FFN (d_ff=0).
"""
from __future__ import annotations

from dataclasses import replace

from ..models.config import LayerDef, ModelConfig, StageDef, XLSTMConfig


def _superblock() -> tuple[LayerDef, ...]:
    return tuple(
        LayerDef(mixer="mlstm" if i < 7 else "slstm", ffn="none")
        for i in range(8)
    )


CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    stages=(StageDef(_superblock(), 6),),
    xlstm=XLSTMConfig(),
    tie_embeddings=True,
    source="arXiv:2405.04517",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG, n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
        vocab_size=512,
        stages=(StageDef(
            (LayerDef("mlstm", "none"), LayerDef("slstm", "none")), 1),),
    )
