"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596].

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206.  Encoder-decoder:
12 encoder + 12 decoder layers (n_layers counts the decoder stack).  The
mel-spectrogram + conv feature extractor frontend is a STUB per the brief:
``input_specs()`` supplies precomputed frame embeddings (dim 1024) which the
model consumes through a learned projector.
"""
from __future__ import annotations

from dataclasses import replace

from ..models.config import LayerDef, ModelConfig, StageDef

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    stages=(StageDef((LayerDef("attn", "dense", cross_attn=True),), 12),),
    encoder_stages=(StageDef((LayerDef("attn", "dense"),), 12),),
    modality="audio",
    modality_embed_dim=1024,          # stub-provided audio frame embeddings
    source="arXiv:2308.11596",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=512,
        stages=(StageDef((LayerDef("attn", "dense", cross_attn=True),), 2),),
        encoder_stages=(StageDef((LayerDef("attn", "dense"),), 2),),
        modality_embed_dim=64,
    )
