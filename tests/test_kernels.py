"""Kernel sweeps: shapes x dtypes, assert_allclose against the jnp oracles
(interpret mode executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.halo_conv2d.ops import halo_conv_block
from repro.kernels.halo_conv2d.ref import conv_block_ref

TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def tol(dtype):
    return TOLS[jnp.bfloat16] if dtype == jnp.bfloat16 else TOLS[jnp.float32]


# --------------------------------------------------------------------------- #
# halo conv                                                                   #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("hw,ch,n_layers,tiles", [
    ((16, 16), 8, 1, (2, 2)),
    ((16, 16), 8, 3, (2, 2)),
    ((8, 24), 4, 2, (2, 4)),
    ((32, 32), 16, 2, (4, 4)),
    ((16, 16), 8, 2, (1, 1)),
])
def test_halo_conv_matches_ref(hw, ch, n_layers, tiles):
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (2, *hw, ch))
    ws = tuple(0.2 * jax.random.normal(jax.random.PRNGKey(i + 1),
                                       (3, 3, ch, ch))
               for i in range(n_layers))
    y = halo_conv_block(x, ws, tiles=tiles)
    yr = conv_block_ref(x, list(ws))
    assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4, rtol=1e-4)


def test_halo_conv_tiling_invariance():
    """The paper's property: results identical across core configurations."""
    k = jax.random.PRNGKey(3)
    x = jax.random.normal(k, (1, 16, 16, 8))
    ws = tuple(0.2 * jax.random.normal(jax.random.PRNGKey(i), (3, 3, 8, 8))
               for i in range(2))
    y1 = halo_conv_block(x, ws, tiles=(1, 2))   # "2-core"
    y2 = halo_conv_block(x, ws, tiles=(2, 2))   # "4-core"
    assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------- #
# flash attention                                                             #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,d,bq,bk", [
    (128, 64, 64, 64),
    (256, 32, 128, 64),
    (256, 128, 64, 128),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 96), (False, 0)])
def test_flash_attention_sweep(dtype, t, d, bq, bk, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    shape = (2, 2, t, d)
    q = jax.random.normal(ks[0], shape, dtype)
    k = jax.random.normal(ks[1], shape, dtype)
    v = jax.random.normal(ks[2], shape, dtype)
    y = flash_attention(q, k, v, causal=causal, window=window, bq=bq, bk=bk)
    yr = attention_ref(q, k, v, causal=causal, window=window)
    assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                    atol=tol(dtype), rtol=tol(dtype))


# --------------------------------------------------------------------------- #
# decode attention                                                            #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("h,kv,s,block_s", [
    (8, 2, 256, 64),       # GQA 4:1
    (4, 4, 128, 128),      # MHA
    (16, 1, 512, 128),     # MQA
])
def test_decode_attention_sweep(dtype, h, kv, s, block_s):
    d = 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, h, d), dtype)
    kc = jax.random.normal(ks[1], (2, s, kv, d), dtype)
    vc = jax.random.normal(ks[2], (2, s, kv, d), dtype)
    fill = int(0.75 * s)
    positions = jnp.where(jnp.arange(s) < fill, jnp.arange(s),
                          -1)[None].repeat(2, 0)
    pos = jnp.int32(fill - 1)
    y = decode_attention(q, kc, vc, positions, pos, block_s=block_s)
    yr = decode_attention_ref(q, kc, vc, positions, pos)
    assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                    atol=tol(dtype), rtol=tol(dtype))


def test_decode_attention_rotating_window():
    """Rotating (mod-S) cache slots with a sliding window mask."""
    d, h, kv, s = 32, 4, 2, 128
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, h, d))
    kc = jax.random.normal(ks[1], (1, s, kv, d))
    vc = jax.random.normal(ks[2], (1, s, kv, d))
    # cache holds positions 200-327 at slots (p % 128)
    pos_abs = jnp.arange(200, 200 + s)
    slots = pos_abs % s
    positions = jnp.zeros((1, s), jnp.int32).at[0, slots].set(pos_abs)
    pos = jnp.int32(327)
    y = decode_attention(q, kc, vc, positions, pos, window=100, block_s=64)
    yr = decode_attention_ref(q, kc, vc, positions, pos, window=100)
    assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------- #
# sLSTM scan (recurrent-matrix-resident kernel, §Perf pair 2)                 #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("dtype,b,t,h,dh,block_t", [
    (jnp.float32, 2, 32, 2, 16, 8),
    (jnp.float32, 1, 40, 1, 32, 16),     # ragged: 40 % 16 != 0
    (jnp.float32, 3, 16, 4, 8, 16),      # single block
    (jnp.bfloat16, 2, 24, 2, 16, 8),
])
def test_slstm_scan_sweep(dtype, b, t, h, dh, block_t):
    from repro.kernels.slstm_scan.kernel import slstm_scan
    from repro.kernels.slstm_scan.ref import slstm_scan_ref
    k = jax.random.split(jax.random.PRNGKey(0), 3)
    wx = (0.5 * jax.random.normal(k[0], (b, t, 4, h, dh))).astype(dtype)
    r = (dh ** -0.5 * jax.random.normal(k[1], (4, h, dh, dh))).astype(dtype)
    bias = (0.1 * jax.random.normal(k[2], (4, h, dh))).astype(jnp.float32)
    got = slstm_scan(wx, r, bias, block_t=block_t, interpret=True)
    want = slstm_scan_ref(wx, r, bias)
    assert got.shape == want.shape == (b, t, h, dh)
    assert_allclose(np.asarray(got), np.asarray(want), atol=tol(dtype),
                    rtol=tol(dtype))


def test_slstm_kernel_matches_model_layer():
    """The kernel reproduces the model's sLSTM hidden states end-to-end
    (wx built from the layer's own input projection)."""
    from repro.configs import get_smoke_config
    from repro.kernels.slstm_scan.kernel import slstm_scan
    from repro.models.layers import xlstm as X
    cfg = get_smoke_config("xlstm-1.3b")
    p = X.slstm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model))
    wx = jnp.einsum("btd,dghk->btghk", x, p["w"])
    hs = slstm_scan(wx, p["r"], p["b"], block_t=4, interpret=True)
    # reference: the model's own scan (hidden states pre-groupnorm)
    b_, t_ = x.shape[:2]
    hh = cfg.n_heads
    dh = cfg.d_model // hh
    state = (jnp.zeros((b_, hh, dh)), jnp.zeros((b_, hh, dh)),
             jnp.ones((b_, hh, dh)), jnp.zeros((b_, hh, dh)))
    outs = []
    for i in range(t_):
        state = X._slstm_step(p, state, wx[:, i])
        outs.append(state[0])
    want = jnp.stack(outs, axis=1)
    assert_allclose(np.asarray(hs), np.asarray(want), atol=2e-5, rtol=2e-4)
