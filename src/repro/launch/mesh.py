"""Production mesh builders.

Functions, not module-level constants, so importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    data = max(1, n // model_axis)
    return jax.make_mesh((data, model_axis), ("data", "model"))
