"""Differential testing: the skyline calendars must answer exactly like the
seed's O(n) reference implementation on randomized reservation sequences.

Contract under test (see calendar.py module docstring): after ``gc(now)``
both implementations are only queried with windows at or after ``now`` —
that is how the scheduler uses them (it garbage-collects to controller time
before probing).
"""
import random

import pytest

from repro.core.calendar import DeviceCalendar, LinkCalendar, NetworkState
from repro.core.calendar_reference import (
    ReferenceDeviceCalendar,
    ReferenceLinkCalendar,
    ReferenceNetworkState,
)


@pytest.mark.parametrize("seed", range(40))
def test_device_calendar_equivalence(seed):
    rng = random.Random(seed)
    new = DeviceCalendar(0, 4)
    ref = ReferenceDeviceCalendar(0, 4)
    live = []
    now = 0.0
    for op in range(80):
        c = rng.random()
        if c < 0.45 or not live:
            t1 = now + rng.uniform(0, 30)
            dur = rng.uniform(0.05, 10)
            cores = rng.choice([1, 2, 4])
            tag = (seed, op)
            new.reserve(t1, t1 + dur, cores, tag)
            ref.reserve(t1, t1 + dur, cores, tag)
            live.append(tag)
        elif c < 0.60:
            tag = live.pop(rng.randrange(len(live)))
            assert (new.release(tag) is None) == (ref.release(tag) is None)
        elif c < 0.70:
            tag = rng.choice(live)
            r = ref.get(tag)
            t_end = rng.uniform(r.t1 - 1.0, r.t2 + 1.0)
            new.truncate(tag, t_end)
            ref.truncate(tag, t_end)
            if ref.get(tag) is None:
                live.remove(tag)
        elif c < 0.80:
            now += rng.uniform(0, 10)
            new.gc(now)
            ref.gc(now)
            live = [t for t in live if ref.get(t) is not None]
        # queries, always at/after the gc horizon
        q1 = now + rng.uniform(0, 40)
        q2 = q1 + rng.uniform(0.01, 20)
        assert new.max_usage(q1, q2) == ref.max_usage(q1, q2)
        assert new.free_cores(q1, q2) == ref.free_cores(q1, q2)
        for cores in (1, 2, 4):
            assert new.fits(q1, q2, cores) == ref.fits(q1, q2, cores)
        assert new.load(q1, q2) == pytest.approx(ref.load(q1, q2), abs=1e-6)
        assert new.completion_times(q1, q2) == ref.completion_times(q1, q2)
        assert len(new) == len(ref)


@pytest.mark.parametrize("seed", range(40))
def test_link_calendar_equivalence(seed):
    rng = random.Random(10_000 + seed)
    new = LinkCalendar()
    ref = ReferenceLinkCalendar()
    pairs = []
    now = 0.0
    for op in range(80):
        c = rng.random()
        if c < 0.60 or not pairs:
            dur = rng.uniform(0.01, 3.0)
            nb = now + rng.uniform(0, 20)
            a = new.reserve_earliest(dur, nb, op)
            b = ref.reserve_earliest(dur, nb, op)
            assert a.t1 == pytest.approx(b.t1, abs=1e-12)
            pairs.append((a, b))
        elif c < 0.75:
            a, b = pairs.pop(rng.randrange(len(pairs)))
            new.cancel(a)
            ref.cancel(b)
        elif c < 0.85:
            now += rng.uniform(0, 8)
            new.gc(now)
            ref.gc(now)
            pairs = [(a, b) for a, b in pairs if b.t2 > now]
        q = now + rng.uniform(0, 30)
        dur = rng.uniform(0.01, 4.0)
        assert new.earliest_slot(dur, q) == pytest.approx(
            ref.earliest_slot(dur, q), abs=1e-12
        )
        assert len(new) == len(ref)


@pytest.mark.parametrize("seed", range(10))
def test_network_state_completion_times_equivalence(seed):
    rng = random.Random(77 + seed)
    n_dev = rng.randint(2, 6)
    new = NetworkState(n_dev)
    ref = ReferenceNetworkState(n_dev)
    for i in range(60):
        d = rng.randrange(n_dev)
        t1 = rng.uniform(0, 50)
        dur = rng.uniform(0.1, 10)
        cores = rng.choice([1, 2, 4])
        new.devices[d].reserve(t1, t1 + dur, cores, i)
        ref.devices[d].reserve(t1, t1 + dur, cores, i)
    for _ in range(20):
        a = rng.uniform(0, 60)
        b = a + rng.uniform(0, 30)
        assert new.completion_times(a, b) == ref.completion_times(a, b)
        assert list(new.iter_completion_times(a, b)) == ref.completion_times(a, b)
    assert new.total_allocated_tasks() == ref.total_allocated_tasks()
