"""Pure-jnp oracle for the sLSTM scan kernel — mirrors
``repro.models.layers.xlstm._slstm_step`` semantics exactly."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def slstm_scan_ref(wx: jax.Array, r: jax.Array, b: jax.Array) -> jax.Array:
    """wx [B,T,4,H,dh], r [4,H,dh,dh], b [4,H,dh] -> hs [B,T,H,dh] f32."""
    bsz, t, _, h, dh = wx.shape
    state = (
        jnp.zeros((bsz, h, dh), jnp.float32),
        jnp.zeros((bsz, h, dh), jnp.float32),
        jnp.ones((bsz, h, dh), jnp.float32),
        jnp.zeros((bsz, h, dh), jnp.float32),
    )
    rf = r.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    def step(state, wx_t):
        hh, c, n, m = state
        rec = jnp.einsum("bhk,ghkj->bghj", hh, rf)
        pre = wx_t.astype(jnp.float32) + rec + bf
        i_pre, f_pre, z_pre, o_pre = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
        logf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(logf + m, i_pre)
        i_eff = jnp.exp(i_pre - m_new)
        f_eff = jnp.exp(logf + m - m_new)
        z = jnp.tanh(z_pre)
        o = jax.nn.sigmoid(o_pre)
        c_new = f_eff * c + i_eff * z
        n_new = jnp.maximum(f_eff * n + i_eff, 1e-6)
        h_new = o * c_new / n_new
        return (h_new, c_new, n_new, m_new), h_new

    _, hs = jax.lax.scan(step, state, wx.swapaxes(0, 1))
    return hs.swapaxes(0, 1)                              # [B, T, H, dh]
