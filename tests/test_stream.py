"""Streaming serving engine: validation, backpressure, shedding, determinism.

Covers the DESIGN.md §14 subsystem end to end at test scale: the submit
boundary rejects malformed requests naming the field; a saturated
admission queue sheds according to the configured policy; the degrade
policy pins LP work to its minimum core configuration; identical seeds
reproduce identical virtual-time outcomes; and the probe plane persists
(dirty-mark refreshed, never rebuilt) across admission windows.
"""
import math

import pytest

from repro.core.calendar import NetworkState
from repro.core.network import NetworkConfig
from repro.core.scheduler import PreemptionAwareScheduler
from repro.core.task import (
    LowPriorityRequest,
    Priority,
    reset_id_counters,
)
from repro.serving.stream import (
    AdmissionQueue,
    Backpressure,
    StreamArrival,
    StreamingEngine,
    StreamRequest,
    create_shed_policy,
    registered_shed_policies,
    validate_submission,
)
from repro.sim.openended import FirehoseConfig, firehose


# --------------------------------------------------------------------- #
# Submit-boundary validation                                            #
# --------------------------------------------------------------------- #
def _valid(**over):
    kw = dict(priority=Priority.HIGH, deadline=5.0, now=0.0,
              n_tasks=1, max_new_tokens=32, task_type=None,
              spec=NetworkConfig().spec)
    kw.update(over)
    return kw


def test_valid_submission_passes():
    validate_submission(**_valid())


@pytest.mark.parametrize("field,value,match", [
    ("deadline", float("nan"), "deadline is NaN"),
    ("deadline", float("inf"), "deadline must be finite"),
    ("deadline", -1.0, "in the past"),
    ("deadline", "soon", "deadline must be a number"),
    ("n_tasks", 0, "n_tasks"),
    ("n_tasks", -2, "n_tasks"),
    ("n_tasks", 1.5, "n_tasks"),
    ("max_new_tokens", 0, "max_new_tokens"),
    ("max_new_tokens", -5, "max_new_tokens"),
    ("priority", "high", "priority"),
    ("task_type", "no_such_model", "unknown task_type 'no_such_model'"),
])
def test_invalid_submission_names_the_field(field, value, match):
    with pytest.raises(ValueError, match=match):
        validate_submission(**_valid(**{field: value}))


def test_past_deadline_is_relative_to_now():
    validate_submission(**_valid(deadline=5.0, now=4.0))
    with pytest.raises(ValueError, match="in the past"):
        validate_submission(**_valid(deadline=5.0, now=5.0))


def test_engine_offer_validates_at_the_boundary():
    eng = StreamingEngine(2, queue_capacity=8)
    with pytest.raises(ValueError, match="deadline is NaN"):
        eng.offer(StreamRequest(priority=Priority.HIGH,
                                deadline=float("nan")))
    with pytest.raises(ValueError, match="unknown task_type"):
        eng.offer(StreamRequest(priority=Priority.LOW, deadline=9.0,
                                task_type="bogus"))
    # nothing was accounted for the rejected offers
    assert eng.telemetry.offered == 0
    assert eng.metrics.hp_generated == 0 and eng.metrics.lp_generated == 0


# --------------------------------------------------------------------- #
# Queue, backpressure and shed policies                                 #
# --------------------------------------------------------------------- #
def _hp(deadline=100.0, rid=None):
    return StreamRequest(priority=Priority.HIGH, deadline=deadline, rid=rid)


def _lp(deadline=100.0, n_tasks=2, rid=None):
    return StreamRequest(priority=Priority.LOW, deadline=deadline,
                         n_tasks=n_tasks, rid=rid)


def test_admission_queue_validates_configuration():
    with pytest.raises(ValueError, match="capacity"):
        AdmissionQueue(capacity=0)
    with pytest.raises(ValueError, match="soft_watermark"):
        AdmissionQueue(capacity=4, soft_watermark=1.5)


def test_unknown_shed_policy_lists_options():
    with pytest.raises(ValueError, match="reject_newest"):
        create_shed_policy("nope")
    assert set(registered_shed_policies()) >= {
        "reject_newest", "reject_cheapest", "degrade"}


def test_backpressure_progression_accepted_soft_shed():
    eng = StreamingEngine(2, queue_capacity=4, soft_watermark=0.75,
                          shed="reject_newest")
    assert eng.offer(_hp()) is Backpressure.ACCEPTED
    assert eng.offer(_hp()) is Backpressure.ACCEPTED
    assert eng.offer(_hp()) is Backpressure.SOFT      # depth 3 >= 0.75*4
    assert eng.offer(_hp()) is Backpressure.SOFT      # full at depth 4
    shed_me = _hp()
    assert eng.offer(shed_me) is Backpressure.SHED
    assert shed_me.state == "shed"
    assert shed_me.shed_reason == "queue_full"
    assert eng.metrics.hp_shed == 1
    assert eng.telemetry.shed_queue_full == 1
    assert eng.queue.live == 4


def test_reject_newest_sheds_the_incoming_request():
    eng = StreamingEngine(2, queue_capacity=2, shed="reject_newest")
    first, second, third = _hp(), _hp(), _hp()
    eng.offer(first), eng.offer(second)
    assert eng.offer(third) is Backpressure.SHED
    assert third.state == "shed"
    assert first.state == "queued" and second.state == "queued"


def test_reject_cheapest_prefers_lp_then_cost_then_newest():
    eng = StreamingEngine(2, queue_capacity=3, shed="reject_cheapest")
    hp, lp_big, lp_small = _hp(), _lp(n_tasks=4), _lp(n_tasks=1)
    eng.offer(hp), eng.offer(lp_big), eng.offer(lp_small)
    incoming = _hp()
    assert eng.offer(incoming) is Backpressure.SOFT   # queued: a victim shed
    assert lp_small.state == "shed"                   # LP < HP, then min cost
    assert hp.state == "queued" and lp_big.state == "queued"
    assert incoming.state == "queued"
    # among equals the newest is shed
    eng2 = StreamingEngine(2, queue_capacity=2, shed="reject_cheapest")
    a, b = _lp(n_tasks=2), _lp(n_tasks=2)
    eng2.offer(a), eng2.offer(b)
    c = _lp(n_tasks=2)
    eng2.offer(c)
    assert c.state == "shed"                          # newest of the equals
    assert a.state == "queued" and b.state == "queued"


def test_degrade_policy_downgrades_queued_lp_at_the_watermark():
    eng = StreamingEngine(2, queue_capacity=4, soft_watermark=0.5,
                          shed="degrade")
    lp1, hp1 = _lp(), _hp()
    eng.offer(lp1)
    assert lp1.degraded is False
    eng.offer(hp1)                                    # depth 2 hits watermark
    assert lp1.degraded is True                       # queued LP downgraded
    assert hp1.degraded is False                      # HP never degraded
    assert eng.metrics.lp_degraded == 1
    assert eng.telemetry.degraded == 1
    # full queue: incoming LP is degraded, then cheapest-shed kicks in
    eng.offer(_lp()), eng.offer(_lp())
    incoming = _lp(n_tasks=1)
    eng.offer(incoming)
    assert incoming.degraded is True
    assert incoming.state == "shed"                   # it was the cheapest


def test_degraded_task_is_pinned_to_minimum_core_configuration():
    # unit check of the scheduler hook the degrade policy leans on: the
    # upgrade pass skips degraded tasks, so an empty network still
    # allocates core_options[0]
    for degraded, want in ((False, 4), (True, 2)):
        reset_id_counters()
        net = NetworkConfig()
        sched = PreemptionAwareScheduler(NetworkState(2, capacity=4), net)
        req = LowPriorityRequest(source_device=0, deadline=100.0,
                                 frame_id=0, n_tasks=1)
        for t in req.make_tasks():
            t.degraded = degraded
        res = sched.allocate_low_priority(req, 0.0)
        assert [a.cores for a in res.allocations] == [want]


def test_expired_requests_are_shed_at_the_window_not_admitted():
    eng = StreamingEngine(2, queue_capacity=8, window=1.0)
    doomed = _hp(deadline=0.5)        # dies before the first window flush
    alive = _hp(deadline=100.0)
    eng.offer(doomed, now=0.0)
    eng.offer(alive, now=0.0)
    eng.q.now = 1.0
    eng.flush_window(1.0)
    assert doomed.state == "shed" and doomed.shed_reason == "expired"
    assert eng.telemetry.shed_expired == 1
    assert alive.state == "admitted"
    assert eng.metrics.hp_shed == 1


def test_window_budget_defers_excess_work():
    eng = StreamingEngine(2, queue_capacity=16, window_budget=2)
    for _ in range(5):
        eng.offer(_hp())
    admitted = eng.flush_window(0.5)
    assert admitted == 2
    assert eng.queue.live == 3        # the rest waits for the next window


# --------------------------------------------------------------------- #
# End-to-end: overload runs, accounting, determinism, plane reuse       #
# --------------------------------------------------------------------- #
def _overload_run(shed: str, seed: int = 9, limit: int = 1200):
    """Paper-profile tasks at a rate 4 devices cannot sustain: guarantees
    queue-full shedding, preemption and deadline misses."""
    reset_id_counters()
    eng = StreamingEngine(4, queue_capacity=16, shed=shed, window=0.5,
                          keep_done=limit)
    cfg = FirehoseConfig(n_devices=4, rate=40.0, seed=seed)
    report = eng.run(firehose(cfg, limit=limit))
    return eng, report


@pytest.mark.parametrize("shed", sorted(registered_shed_policies()))
def test_overload_sheds_and_still_partitions_exactly(shed):
    eng, report = _overload_run(shed)
    m = eng.metrics
    assert m.hp_shed + m.lp_shed > 0, "overload run must shed"
    assert m.hp_generated == (m.hp_completed + m.hp_failed_alloc
                              + m.hp_failed_runtime + m.hp_shed)
    assert m.lp_generated == (m.lp_completed + m.lp_failed_alloc
                              + m.lp_failed_runtime + m.realloc_failure
                              + m.lp_shed)
    assert report["unresolved"] == 0
    assert report["in_flight"] == 0 and report["queued"] == 0
    s = report["metrics"]
    assert s["hp_shed"] == m.hp_shed and s["lp_shed"] == m.lp_shed
    # every offered request reached exactly one terminal request state
    states = {"done", "failed", "shed"}
    assert all(r.state in states for r in eng.done)
    assert len(eng.done) == eng.telemetry.offered


def test_degrade_run_degrades_under_pressure():
    eng, _ = _overload_run("degrade")
    assert eng.metrics.lp_degraded > 0
    assert eng.telemetry.degraded == eng.metrics.lp_degraded


_WALL_KEYS = {"t_hp_initial_ms", "t_hp_preempt_ms", "t_lp_alloc_ms",
              "t_realloc_ms"}


def _virtual_view(report):
    """The report minus wall-clock quantities (which legitimately vary)."""
    return {
        "metrics": {k: v for k, v in report["metrics"].items()
                    if k not in _WALL_KEYS},
        "telemetry": {k: v for k, v in report["telemetry"].items()
                      if k != "admission_latency_s"},
        "unresolved": report["unresolved"],
    }


def test_open_ended_trace_is_seed_deterministic():
    _, r1 = _overload_run("degrade", seed=21)
    _, r2 = _overload_run("degrade", seed=21)
    _, r3 = _overload_run("degrade", seed=22)
    assert _virtual_view(r1) == _virtual_view(r2)
    assert _virtual_view(r1) != _virtual_view(r3)


def test_probe_plane_persists_across_windows():
    reset_id_counters()
    eng = StreamingEngine(4, queue_capacity=64, window=0.5)
    plane = eng.policy.state.probe_plane()
    windows = []
    eng.run(firehose(FirehoseConfig(n_devices=4, rate=20.0, seed=1),
                     limit=300),
            on_window=lambda e: windows.append(
                e.policy.state.probe_plane() is plane))
    assert len(windows) > 5
    assert all(windows), "probe plane was rebuilt instead of refreshed"


def test_e2e_latency_includes_queueing_delay():
    eng, report = _overload_run("reject_newest")
    e2e = report["telemetry"]["e2e_latency_s"]
    if e2e["count"]:
        assert e2e["p50"] > 0.0
        assert math.isfinite(e2e["max"])


def test_underload_run_completes_everything():
    reset_id_counters()
    eng = StreamingEngine(8, queue_capacity=256, window=0.5)
    cfg = FirehoseConfig(n_devices=8, rate=2.0, lp_fraction=0.3, seed=5)
    report = eng.run(firehose(cfg, limit=120))
    t = report["telemetry"]
    assert t["shed_total"] == 0
    m = eng.metrics
    assert m.hp_generated == m.hp_completed + m.hp_failed_alloc \
        + m.hp_failed_runtime
    assert "hp_shed" not in report["metrics"], \
        "shed keys must stay absent when nothing was shed"


def test_request_from_arrival_derives_profile_deadlines():
    eng = StreamingEngine(2)
    prof = eng.net.profile(None)
    hp = eng.request_from_arrival(
        StreamArrival(t=3.0, device=1, priority=Priority.HIGH))
    assert hp.deadline == pytest.approx(prof.hp_deadline(3.0))
    lp = eng.request_from_arrival(
        StreamArrival(t=3.0, device=1, priority=Priority.LOW, n_tasks=3,
                      rel_deadline=7.0))
    assert lp.deadline == pytest.approx(10.0)
    assert lp.n_tasks == 3
    lp2 = eng.request_from_arrival(
        StreamArrival(t=0.0, device=0, priority=Priority.LOW))
    assert lp2.deadline == pytest.approx(
        prof.lp_deadline if prof.lp_deadline is not None
        else eng.default_lp_deadline)


# --------------------------------------------------------------------- #
# Watermark hysteresis: the rising edge must re-arm after a full drain  #
# --------------------------------------------------------------------- #
def test_on_pressure_rearms_after_full_drain_sawtooth():
    """Sawtooth load: fill past the watermark, drain to empty, fill again.

    ``on_pressure`` is a rising-edge signal — it must fire exactly once
    per excursion above the soft watermark, and the falling-edge reset in
    ``flush_window`` must re-arm it so the SECOND rising edge fires too
    (a stuck ``_soft`` latch would silently disable degrade-style
    policies for the rest of a long run).
    """
    eng = StreamingEngine(2, queue_capacity=4, soft_watermark=0.75,
                          window=0.5)
    edges = []
    eng.shed_policy.on_pressure = (
        lambda queue, engine: edges.append(queue.live))

    # cycle 1: depth 3 crosses the watermark (0.75 * 4 = 3)
    for _ in range(3):
        eng.offer(_hp())
    assert edges == [3], "first rising edge must fire exactly once"
    eng.offer(_hp())                       # still soft: no second firing
    assert edges == [3]
    eng.flush_window(0.25)                 # full drain -> falling edge
    assert eng.queue.live == 0

    # cycle 2: the second excursion must fire again
    for _ in range(3):
        eng.offer(_hp())
    assert edges == [3, 3], "hysteresis failed to re-arm after a drain"
    eng.flush_window(0.5)
    assert eng.queue.live == 0

    # cycle 3: and keeps re-arming on every subsequent sawtooth
    for _ in range(4):
        eng.offer(_hp())
    assert edges == [3, 3, 3]
