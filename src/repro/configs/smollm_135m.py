"""smollm-135m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
"""
from __future__ import annotations

from dataclasses import replace

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    arch_type="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG, n_layers=2, d_model=96, n_heads=3, n_kv_heads=3, d_ff=256,
        vocab_size=512, stages=(),
    )
