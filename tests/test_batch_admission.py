"""Behaviour of `allocate_low_priority_batch` (DESIGN.md §4.3)."""
import pytest

from repro.core.calendar import NetworkState
from repro.core.network import NetworkConfig
from repro.core.scheduler import PreemptionAwareScheduler
from repro.core.task import LowPriorityRequest, TaskState, reset_id_counters


def make(n_devices=4):
    state = NetworkState(n_devices)
    net = NetworkConfig()
    return state, net, PreemptionAwareScheduler(state, net)


def lp_request(dev=0, deadline=30.0, n=1, frame=0):
    req = LowPriorityRequest(source_device=dev, deadline=deadline,
                             frame_id=frame, n_tasks=n)
    req.make_tasks()
    return req


def test_batch_empty():
    _, _, sched = make()
    assert sched.allocate_low_priority_batch([], 0.0) == []


def test_batch_single_request_matches_sequential():
    """A batch of one request on an empty network behaves like the
    sequential path (same counts, devices may legitimately differ only
    when loads tie — with one request they don't)."""
    reset_id_counters()
    _, _, s1 = make()
    r1 = lp_request(dev=1, deadline=40.0, n=3)
    seq = s1.allocate_low_priority(r1, 0.0)

    reset_id_counters()
    _, _, s2 = make()
    r2 = lp_request(dev=1, deadline=40.0, n=3)
    [bat] = s2.allocate_low_priority_batch([r2], 0.0)

    assert len(seq.allocations) == len(bat.allocations) == 3
    assert [a.device for a in seq.allocations] == [a.device for a in bat.allocations]
    assert [a.cores for a in seq.allocations] == [a.cores for a in bat.allocations]
    assert [a.t_start for a in seq.allocations] == [a.t_start for a in bat.allocations]


def test_batch_results_positional_and_complete():
    _, _, sched = make()
    reqs = [lp_request(dev=i % 4, deadline=120.0, n=1 + i % 4, frame=i)
            for i in range(10)]
    results = sched.allocate_low_priority_batch(reqs, 0.0)
    assert len(results) == len(reqs)
    for req, res in zip(reqs, results):
        assert len(res.allocations) + len(res.failed) == req.n_tasks
        for a in res.allocations:
            assert a.task in req.tasks
            assert a.task.state == TaskState.ALLOCATED
        for t in res.failed:
            assert t in req.tasks and t.state == TaskState.FAILED


def test_batch_respects_deadlines_and_capacity():
    state, net, sched = make(n_devices=2)
    # both devices fully blocked until t=100
    state.devices[0].reserve(0.0, 100.0, 4, "blk0")
    state.devices[1].reserve(0.0, 100.0, 4, "blk1")
    tight = lp_request(dev=0, deadline=50.0, n=2, frame=0)      # hopeless
    loose = lp_request(dev=1, deadline=200.0, n=2, frame=1)     # fits at 100+
    res_tight, res_loose = sched.allocate_low_priority_batch([tight, loose], 0.0)
    assert res_tight.failed == tight.tasks
    assert len(res_loose.allocations) == 2
    for a in res_loose.allocations:
        assert a.t_start >= 100.0
        assert a.t_end <= 200.0 + 1e-9


def test_batch_edf_order_across_requests():
    """With capacity for only one task in the early window, the request
    with the earlier deadline wins it even when submitted last."""
    state, net, sched = make(n_devices=1)
    # leave room for exactly one 2-core task before t=100
    state.devices[0].reserve(0.0, 100.0, 2, "blk")
    late = lp_request(dev=0, deadline=150.0, n=1, frame=0)
    early = lp_request(dev=0, deadline=30.0, n=1, frame=1)
    res_late, res_early = sched.allocate_low_priority_batch([late, early], 0.0)
    assert len(res_early.allocations) == 1          # EDF winner
    assert res_early.allocations[0].t_end <= 30.0 + 1e-9
    assert len(res_late.allocations) == 1           # allocated later is fine
    assert res_late.allocations[0].t_end <= 150.0 + 1e-9


def test_batch_uses_completions_created_by_batch():
    """Later tasks may start at completion points the batch itself created
    (the dynamic time-point heap)."""
    state, net, sched = make(n_devices=1)
    # 2 cores permanently gone; each task needs 2 cores -> strictly serial
    state.devices[0].reserve(0.0, 1000.0, 2, "blk")
    reqs = [lp_request(dev=0, deadline=200.0, n=1, frame=i) for i in range(3)]
    results = sched.allocate_low_priority_batch(reqs, 0.0)
    allocs = sorted(a.t_start for r in results for a in r.allocations)
    assert len(allocs) == 3
    for a, b in zip(allocs, allocs[1:]):
        assert b >= a + net.lp_proc_time(2) - 1e-6   # stacked back-to-back


def test_batch_registers_requests_for_set_health():
    _, _, sched = make()
    req = lp_request(dev=0, deadline=40.0, n=2)
    sched.allocate_low_priority_batch([req], 0.0)
    assert sched._requests[req.request_id] is req


def test_batch_metrics_amortised_per_request():
    _, _, sched = make()
    reqs = [lp_request(dev=i % 4, deadline=60.0, n=1, frame=i) for i in range(5)]
    sched.allocate_low_priority_batch(reqs, 0.0)
    assert len(sched.metrics.t_lp_alloc) == 5


def test_batch_works_on_reference_calendars():
    """The batch path must degrade gracefully on calendars without skyline
    queries (no lazy grid, no hints) — same admissions, just slower."""
    from repro.core.calendar_reference import ReferenceNetworkState

    reset_id_counters()
    _, _, sched = make(n_devices=2)
    reqs = [lp_request(dev=i % 2, deadline=200.0, n=2, frame=i) for i in range(4)]
    new_counts = [len(r.allocations)
                  for r in sched.allocate_low_priority_batch(reqs, 0.0)]

    reset_id_counters()
    net = NetworkConfig()
    ref_sched = PreemptionAwareScheduler(ReferenceNetworkState(2), net)
    reqs = [lp_request(dev=i % 2, deadline=200.0, n=2, frame=i) for i in range(4)]
    ref_counts = [len(r.allocations)
                  for r in ref_sched.allocate_low_priority_batch(reqs, 0.0)]
    assert ref_counts == new_counts


def test_batch_many_requests_all_within_capacity():
    state, net, sched = make(n_devices=8)
    reqs = [lp_request(dev=i % 8, deadline=400.0, n=1 + i % 4, frame=i)
            for i in range(40)]
    results = sched.allocate_low_priority_batch(reqs, 0.0)
    n_tasks = sum(r.n_tasks for r in reqs)
    allocated = sum(len(r.allocations) for r in results)
    failed = sum(len(r.failed) for r in results)
    assert allocated + failed == n_tasks
    assert allocated > 0
    # capacity invariant across every device
    for dev in state.devices:
        pts = sorted({r.t1 for r in dev.reservations()}
                     | {r.t2 for r in dev.reservations()})
        for t1, t2 in zip(pts, pts[1:]):
            if t1 + 2e-9 < t2:
                assert dev.max_usage(t1 + 1e-9, t2 - 1e-9) <= dev.capacity
