"""Scenario replays: the policy-API refactor must not change behaviour.

``tests/data/golden_scenarios.json`` holds ``Metrics.summary()`` for every
entry in ``SCENARIOS`` (at a reduced frame count), captured from the
pre-refactor backends (``SchedulerBackend`` / ``WorkstealerBackend`` with
their bespoke admission loops).  The unified ``SchedulingPolicy`` path must
reproduce each summary exactly — decisions, preemptions, completions,
core-allocation histograms, all of it (wall-clock timing fields excluded).

Regenerate (only when behaviour is *intentionally* changed) through the
helper, which prints a reviewable structured diff::

    PYTHONPATH=src python tests/regen_golden.py            # regen + diff
    PYTHONPATH=src python tests/regen_golden.py --check    # diff only
"""
import importlib.util
import json
from dataclasses import replace
from pathlib import Path

import pytest

from repro.core.policy import registered_policies
from repro.sim import SCENARIOS, ScenarioConfig, run_scenario
from repro.sim.experiment import MIXED_SCENARIOS

GOLDEN = Path(__file__).parent / "data" / "golden_scenarios.json"


def _regen_helper():
    """Load tests/regen_golden.py whether or not ``tests`` is a package."""
    spec = importlib.util.spec_from_file_location(
        "regen_golden", Path(__file__).parent / "regen_golden.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod

#: Every golden-replayed scenario: the paper's Table-1 set (captured from
#: the pre-refactor backends) plus the heterogeneous-workload set (captured
#: when the workload-profile layer landed; the paper set must stay
#: bit-identical across BOTH refactors).
ALL_GOLDEN_SCENARIOS = {**SCENARIOS, **MIXED_SCENARIOS}


def _summary(metrics) -> dict:
    """Deterministic slice of Metrics.summary() (drop wall-clock timings)."""
    return {k: v for k, v in metrics.summary().items()
            if not k.startswith("t_")}


def regen() -> None:
    """Kept for the historic entry point; delegates to the diff-printing
    helper (tests/regen_golden.py)."""
    _regen_helper().regen()


@pytest.fixture(scope="module")
def golden():
    return json.loads(GOLDEN.read_text())


@pytest.mark.parametrize("name", sorted(ALL_GOLDEN_SCENARIOS))
def test_scenario_replay_matches_pre_refactor_golden(name, golden):
    cfg = replace(ALL_GOLDEN_SCENARIOS[name], n_frames=golden["n_frames"])
    assert _summary(run_scenario(cfg)) == golden["summaries"][name]


# --------------------------------------------------------------------- #
# Seed reproducibility: same config + seed -> identical summary, for     #
# EVERY registered policy (not just the paper's scenarios).              #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("policy", registered_policies())
def test_same_seed_reproduces_summary(policy):
    cfg = ScenarioConfig(f"repro_{policy}", "weighted_2", policy, True,
                         n_frames=80, seed=11)
    a = _summary(run_scenario(cfg))
    b = _summary(run_scenario(cfg))
    assert a == b


@pytest.mark.parametrize("policy", registered_policies())
def test_different_seed_differs_somewhere(policy):
    """Sanity companion: the reproducibility test isn't vacuous — changing
    the seed changes at least one outcome for every policy."""
    mk = lambda seed: _summary(run_scenario(
        ScenarioConfig(f"seed_{policy}", "weighted_2", policy, True,
                       n_frames=80, seed=seed)))
    assert mk(11) != mk(12)
