"""Pytree checkpointing: flat .npz payload + JSON manifest.

No external deps (orbax unavailable offline).  Leaves are addressed by their
jax.tree_util key-path string; restore validates structure against a
reference tree (shapes + dtypes) so partial/corrupt checkpoints fail loudly.

Durability contract:

* ``save`` stages the payload and manifest in a temporary sibling
  directory and swaps it into place with ``os.replace``, so an
  interrupted save can never leave a torn checkpoint (half-written
  payload, or new manifest next to old arrays) at ``path`` — whenever a
  checkpoint exists there, it is complete.  POSIX cannot exchange two
  directories atomically, so the overwrite path briefly parks the
  previous checkpoint at ``<path>.old.<pid>`` between two renames; a
  failed swap rolls the previous checkpoint back, and only a hard crash
  inside that window leaves ``path`` absent with the complete previous
  version recoverable from the ``.old`` sibling.
* ``restore`` refuses dtype mismatches by default — silently ``astype``-ing
  an integer/bool checkpoint leaf into a float reference corrupts state
  like RNG keys and step counters.  Pass ``cast=True`` to opt into
  converting every leaf to the reference dtype.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def _pid_alive(pid: int) -> bool:
    """Whether the pid a litter suffix names still runs (own pid counts)."""
    if pid == os.getpid():
        return True
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True         # exists, owned by someone else
    return True


def save(path: str, tree: Any, metadata: Optional[dict] = None) -> None:
    """Write the checkpoint via a staged temp dir + ``os.replace`` swap
    (see the module docstring for the exact durability guarantees)."""
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    # clear litter an earlier pid's interrupted save may have left beside
    # this checkpoint — but only from pids that are no longer alive (a
    # live pid's .tmp dir is a concurrent saver's staging area), and a
    # parked .old sibling only once a complete checkpoint exists at path
    # (it still holds a COMPLETE older version until then)
    base = os.path.basename(path)
    for entry in os.listdir(parent) if os.path.isdir(parent) else ():
        stale_tmp = entry.startswith(f"{base}.tmp.")
        stale_old = entry.startswith(f"{base}.old.") and os.path.isdir(path)
        suffix = entry.rsplit(".", 1)[-1]
        # only suffixes that are literal pids are OUR litter — anything
        # else (a user's `ckpt.old.bak`) is not ours to delete
        if (stale_tmp or stale_old) and suffix.isdigit() and \
                not _pid_alive(int(suffix)):
            shutil.rmtree(os.path.join(parent, entry), ignore_errors=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    try:
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "leaves": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in flat.items()
            },
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.isdir(path):
            # os.replace cannot overwrite a non-empty directory: park the
            # old checkpoint aside, swap the new one in, then drop the old.
            # If the swap itself fails, roll the previous checkpoint back
            # so `path` never stays empty on a survivable error.
            old = f"{path}.old.{os.getpid()}"
            if os.path.isdir(old):
                shutil.rmtree(old)
            os.replace(path, old)
            try:
                os.replace(tmp, path)
            except BaseException:
                os.replace(old, path)           # roll back the previous
                raise
            shutil.rmtree(old)
        else:
            os.replace(tmp, path)
    finally:
        if os.path.isdir(tmp):
            shutil.rmtree(tmp)


def load_metadata(path: str) -> dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["metadata"]


def restore(path: str, reference: Any, *, cast: bool = False) -> Any:
    """Restore into the structure of ``reference`` (a pytree of arrays or
    ShapeDtypeStructs).  Shape mismatches always raise; dtype mismatches
    raise a ``ValueError`` naming the leaf unless ``cast=True`` explicitly
    opts into converting leaves to the reference dtypes."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(reference)
    leaves = []
    for path_elems, ref in paths:
        key = jax.tree_util.keystr(path_elems)
        if key not in manifest["leaves"]:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != expected {ref.shape}")
        ref_dtype = np.dtype(ref.dtype)
        if arr.dtype != ref_dtype:
            if not cast:
                raise ValueError(
                    f"{key}: checkpoint dtype {arr.dtype} != expected "
                    f"{ref_dtype} (pass cast=True to convert explicitly)")
            arr = arr.astype(ref_dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def exists(path: str) -> bool:
    return os.path.exists(os.path.join(path, "manifest.json"))
