"""Static lint over the Pallas kernel sources: no bare-int ``pl.load``
indices.

This JAX version's interpret-mode discharge rule for ``pl.load`` rejects a
bare Python int inside the index tuple (``'int' object has no attribute
'shape'``) — the bug that broke all 18 flash-attention sweeps until the
index was rewritten as ``pl.ds(0, 1)`` + squeeze.  The grep below fails any
kernel that reintroduces the pattern, so the class cannot regress silently.
"""
import re
from pathlib import Path

import pytest

KERNELS_DIR = Path(__file__).parent.parent / "src" / "repro" / "kernels"

def _kernel_sources() -> list[Path]:
    return sorted(KERNELS_DIR.rglob("*.py"))


def test_kernel_sources_exist():
    assert _kernel_sources(), f"no kernel sources under {KERNELS_DIR}"


@pytest.mark.parametrize("path", _kernel_sources(),
                         ids=lambda p: str(p.relative_to(KERNELS_DIR)))
def test_no_bare_int_pl_load_indices(path):
    src = path.read_text()
    # Normalise whitespace so a call split across lines is still one match
    # target, then scan every pl.load/pl.store call's index tuple.
    flat = re.sub(r"\s+", " ", src)
    for m in re.finditer(r"pl\.(?:load|store|swap)\(", flat):
        # Walk the balanced parens of this call.
        depth, i = 0, m.end() - 1
        start = i
        while i < len(flat):
            if flat[i] == "(":
                depth += 1
            elif flat[i] == ")":
                depth -= 1
                if depth == 0:
                    break
            i += 1
        call = flat[start:i + 1]
        # Index tuple = the second top-level argument; none of its TOP-LEVEL
        # elements may be a bare int literal (ints inside pl.ds(0, 1) or
        # arithmetic like s * bk are fine — only a naked integer element
        # trips the interpret-mode discharge rule).
        bare = [e for e in _tuple_elements(_index_tuple(call))
                if re.fullmatch(r"-?\d+", e.strip())]
        assert not bare, (
            f"{path}: bare Python int {bare} inside a pl.load/pl.store index "
            f"tuple (use pl.ds(i, 1) + squeeze instead): {call!r}"
        )


def _index_tuple(call: str) -> str:
    """Extract the second top-level argument (the index tuple) of a
    ``pl.load(ref, (...))``-shaped call; '' when there is none."""
    depth = 0
    args_start = call.index("(") + 1
    second = ""
    arg_idx = 0
    i = args_start
    begin = i
    while i < len(call):
        c = call[i]
        if c == "(":
            depth += 1
        elif c == ")":
            if depth == 0:
                if arg_idx == 1:
                    second = call[begin:i]
                break
            depth -= 1
        elif c == "," and depth == 0:
            if arg_idx == 1:
                second = call[begin:i]
                break
            arg_idx += 1
            begin = i + 1
        i += 1
    return second


def _tuple_elements(tup: str) -> list[str]:
    """Split a ``(a, b, c)``-shaped source fragment into its top-level
    elements; a non-tuple fragment is returned as a single element."""
    tup = tup.strip()
    if not (tup.startswith("(") and tup.endswith(")")):
        return [tup] if tup else []
    inner = tup[1:-1]
    out, depth, begin = [], 0, 0
    for i, c in enumerate(inner):
        if c in "([{":
            depth += 1
        elif c in ")]}":
            depth -= 1
        elif c == "," and depth == 0:
            out.append(inner[begin:i])
            begin = i + 1
    tail = inner[begin:]
    if tail.strip():
        out.append(tail)
    return out
