"""AdamW + LR schedules, pure-JAX pytree implementation (no optax here)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(cfg: AdamWConfig, params: Any) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(
    cfg: AdamWConfig,
    params: Any,
    grads: Any,
    opt_state: dict,
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay (skip 1-d params: norms, biases)
        if p.ndim > 1:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (
            p_new.astype(p.dtype),
            m_new.astype(m.dtype),
            v_new.astype(v.dtype),
        )

    flat = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
