"""Pallas TPU kernel: halo-partitioned conv block (paper §3.2, TPU-native).

The paper tiles conv inputs across RPi cores and exchanges only tile borders
between consecutive conv layers.  TPU adaptation (DESIGN.md §8.5): tiles live
in VMEM; the halo exchange becomes the overlapping-tile gather done once in
HBM (ops.py), and the kernel processes a whole multi-conv block per tile
without leaving VMEM — the halo shrinks by one ring per 3x3 layer, exactly
the paper's expansion-border scheme.  Channel dims should be multiples of
128 so the per-tap matmuls hit the MXU.

Grid: (N, H_tiles, W_tiles).  BlockSpecs give each program one padded input
tile [th + 2r, tw + 2r, Cin] and one output tile [th, tw, Cout].
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv3x3_tile(x: jax.Array, w: jax.Array, leaky: float) -> jax.Array:
    """x [h+2, w+2, cin], w [3, 3, cin, cout] -> [h, w, cout] (VALID)."""
    h, wdt = x.shape[0] - 2, x.shape[1] - 2
    cout = w.shape[-1]
    acc = jnp.zeros((h * wdt, cout), jnp.float32)
    for di in range(3):
        for dj in range(3):
            patch = x[di : di + h, dj : dj + wdt, :].reshape(h * wdt, -1)
            acc += jnp.dot(patch, w[di, dj],
                           preferred_element_type=jnp.float32)
    acc = jnp.where(acc >= 0, acc, leaky * acc)
    return acc.reshape(h, wdt, cout)


def _halo_block_kernel(x_ref, *refs, n_layers: int, leaky: float):
    """x_ref: padded tile; refs = (w_0..w_{n-1}, out_ref)."""
    out_ref = refs[-1]
    w_refs = refs[:-1]
    x = x_ref[0].astype(jnp.float32)            # [th+2r, tw+2r, cin]
    for i in range(n_layers):
        x = _conv3x3_tile(x, w_refs[i][...].astype(jnp.float32), leaky)
    out_ref[0] = x.astype(out_ref.dtype)


@partial(jax.jit, static_argnames=("tile_h", "tile_w", "leaky", "interpret"))
def halo_conv_block_tiles(
    tiles: jax.Array,                    # [T, th + 2r, tw + 2r, Cin]
    weights: tuple[jax.Array, ...],      # n x [3, 3, C, C']
    *,
    tile_h: int,
    tile_w: int,
    leaky: float = 0.1,
    interpret: bool = True,
) -> jax.Array:
    n_layers = len(weights)
    r = n_layers                          # 3x3 conv: halo ring of 1 per layer
    t, ph, pw, cin = tiles.shape
    assert ph == tile_h + 2 * r and pw == tile_w + 2 * r
    cout = weights[-1].shape[-1]

    in_specs = [
        pl.BlockSpec((1, ph, pw, cin), lambda i: (i, 0, 0, 0)),
    ]
    for w in weights:
        in_specs.append(
            pl.BlockSpec(w.shape, lambda i, _s=w.shape: (0,) * len(_s)))
    out_spec = pl.BlockSpec((1, tile_h, tile_w, cout), lambda i: (i, 0, 0, 0))

    return pl.pallas_call(
        partial(_halo_block_kernel, n_layers=n_layers, leaky=leaky),
        grid=(t,),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((t, tile_h, tile_w, cout), tiles.dtype),
        interpret=interpret,
    )(tiles, *weights)
