"""Streaming serving engine: sustained-traffic admission with backpressure,
load shedding, and incremental SLO telemetry (DESIGN.md §14).

The closed-workload runtimes (``sim/experiment.py``, the one-shot
``serving/engine.py`` submit/run) materialise their whole workload up
front and post-process per-run lists at the end.  This module is the
open-ended counterpart: requests arrive as an (possibly infinite) stream,
are buffered in a **bounded admission queue** with explicit backpressure
signals, and are admitted in **rolling windows** through the same
:class:`~repro.core.policy.PolicyDispatcher` every other runtime uses —
one ``NetworkState`` (and therefore one dirty-mark-refreshed probe plane,
DESIGN.md §10) lives for the whole run, so window *k+1* reuses the plane
window *k* left behind instead of rebuilding it.

Memory is flat by construction: the queue is bounded, terminal requests
are dropped as soon as their last task resolves, ``Metrics`` latency
lists are swapped for :class:`~repro.core.telemetry.BoundedSeries`
sketches, and all telemetry lives in fixed-size structures
(``core/telemetry.py``).  ``benchmarks/soak.py`` pushes ≥10^6 requests
through a 1024-device network and gates on RSS staying flat.

Load shedding is pluggable (``@register_shed_policy``):

* ``reject_newest``  — queue full ⇒ the incoming request is shed.
* ``reject_cheapest`` — queue full ⇒ shed the least valuable queued work
  (LP before HP, then smallest estimated core-seconds, then newest).
* ``degrade`` — at the soft watermark, walk queued LP requests one rung
  down their task type's variant ladder (DESIGN.md §17; for ladder-free
  profiles the single legacy rung pins tasks to ``core_options[0]`` — the
  scheduler's upgrade pass skips them); a full queue still sheds like
  ``reject_cheapest``.

Backpressure is a three-state signal returned by :meth:`StreamingEngine.offer`:
``ACCEPTED`` (below the watermark), ``SOFT`` (queue above its high
watermark — slow down), ``SHED`` (the offered request was dropped).
"""
from __future__ import annotations

import enum
import itertools
import math
from collections import deque
from dataclasses import dataclass
from time import perf_counter
from typing import Any, Callable, Iterable, Optional

from ..core.metrics import Metrics
from ..core.network import NetworkConfig, resolve_network
from ..core.policy import (
    DispatchClient,
    PolicyDispatcher,
    create_policy,
    SchedulingPolicy,
)
from ..core.profiles import WorkloadSpec
from ..core.task import LowPriorityRequest, Priority, Task, TaskState
from ..core.telemetry import BoundedSeries, StreamTelemetry
from ..sim.events import EventQueue

_EPS = 1e-9


# ====================================================================== #
# Submit-boundary validation                                             #
# ====================================================================== #
def validate_submission(
    *,
    priority: Priority,
    deadline: float,
    now: float = 0.0,
    n_tasks: int = 1,
    max_new_tokens: Optional[int] = None,
    task_type: Optional[str] = None,
    spec: Optional[WorkloadSpec] = None,
) -> None:
    """Reject malformed submissions with a ``ValueError`` naming the field.

    Shared by the streaming engine's :meth:`StreamingEngine.offer` and the
    one-shot serving engine's ``submit`` — bad requests die at the boundary
    instead of corrupting calendars deep inside the event loop.
    """
    if not isinstance(priority, Priority):
        raise ValueError(
            f"priority must be a repro.core.task.Priority, got {priority!r}")
    if isinstance(deadline, bool) or not isinstance(deadline, (int, float)):
        raise ValueError(f"deadline must be a number, got {deadline!r}")
    if math.isnan(deadline):
        raise ValueError("deadline is NaN")
    if math.isinf(deadline):
        raise ValueError("deadline must be finite")
    if deadline <= now:
        raise ValueError(
            f"deadline {deadline:g} is in the past (now={now:g})")
    if not isinstance(n_tasks, int) or isinstance(n_tasks, bool) \
            or n_tasks < 1:
        raise ValueError(f"n_tasks must be a positive int, got {n_tasks!r}")
    if max_new_tokens is not None and (
            not isinstance(max_new_tokens, int)
            or isinstance(max_new_tokens, bool) or max_new_tokens < 1):
        raise ValueError(
            f"max_new_tokens must be a positive int, got {max_new_tokens!r}")
    if task_type is not None and spec is not None:
        try:
            spec.profile(task_type)
        except (KeyError, ValueError) as e:
            raise ValueError(f"unknown task_type {task_type!r}: {e}") from None


# ====================================================================== #
# Requests and backpressure                                              #
# ====================================================================== #
class Backpressure(enum.Enum):
    ACCEPTED = "accepted"    # queued below the high watermark
    SOFT = "soft"            # queued, but the queue is past its watermark
    SHED = "shed"            # the offered request was dropped


@dataclass(eq=False)
class StreamRequest:
    """One unit of streamed work: an HP task or an LP task set."""

    priority: Priority
    deadline: float                       # absolute virtual time
    home_device: int = 0
    n_tasks: int = 1                      # LP set size (HP: always 1 task)
    task_type: Optional[str] = None
    max_new_tokens: Optional[int] = None
    arrival: float = 0.0
    rid: Optional[int] = None             # assigned by the engine
    # lifecycle: queued -> admitted -> done | failed, or queued -> shed
    state: str = "queued"
    # Variant-ladder rung (DESIGN.md §17) the request is currently queued
    # at; the degrade shed policy walks it down, and admission stamps it
    # onto the request's tasks.  0 = full accuracy.
    variant: int = 0
    shed_reason: Optional[str] = None     # "queue_full" | "expired"
    est_cost: float = 0.0                 # estimated core-seconds (shedding)
    completed_at: float = -1.0
    _remaining: int = 0                   # live tasks still unresolved
    _failed: bool = False                 # any task failed / missed deadline

    @property
    def degraded(self) -> bool:
        """Deprecated one-bit view of the variant ladder (pre-ladder
        callers keep working): any rung below 0 counts as degraded."""
        return self.variant > 0

    @degraded.setter
    def degraded(self, flag: bool) -> None:
        self.variant = max(self.variant, 1) if flag else 0


@dataclass(frozen=True)
class StreamArrival:
    """A lightweight arrival record (what ``sim/openended.py`` yields).

    ``rel_deadline`` is relative to ``t``; ``None`` derives the deadline
    from the workload profile (HP: ``profile.hp_deadline``; LP: the
    profile's ``lp_deadline`` or the engine's default).
    """

    t: float
    device: int
    priority: Priority
    n_tasks: int = 1
    task_type: Optional[str] = None
    rel_deadline: Optional[float] = None


# ====================================================================== #
# Bounded admission queue                                                #
# ====================================================================== #
class AdmissionQueue:
    """FIFO queue with a hard capacity and a soft high watermark.

    Shed victims are removed *lazily*: :meth:`drop` only decrements the
    live count and the entry is skipped when a drain reaches it, so victim
    removal is O(1) regardless of queue depth.  Tombstones are bounded by
    one window's arrivals (every drain sweeps them out).
    """

    def __init__(self, capacity: int = 4096,
                 soft_watermark: float = 0.75) -> None:
        if capacity < 1:
            raise ValueError("AdmissionQueue capacity must be >= 1")
        if not (0.0 < soft_watermark <= 1.0):
            raise ValueError("soft_watermark must be in (0, 1]")
        self.capacity = capacity
        self.soft_level = max(1, int(capacity * soft_watermark))
        self._dq: deque[StreamRequest] = deque()
        self.live = 0

    def __len__(self) -> int:
        return self.live

    @property
    def full(self) -> bool:
        return self.live >= self.capacity

    @property
    def soft(self) -> bool:
        return self.live >= self.soft_level

    def push(self, req: StreamRequest) -> None:
        self._dq.append(req)
        self.live += 1

    def drop(self, req: StreamRequest) -> None:
        """Logically remove a victim (caller marks its state non-queued)."""
        self.live -= 1

    def pop_live(self) -> Optional[StreamRequest]:
        while self._dq:
            req = self._dq.popleft()
            if req.state == "queued":
                self.live -= 1
                return req
        return None

    def iter_live(self) -> Iterable[StreamRequest]:
        return (r for r in self._dq if r.state == "queued")


# ====================================================================== #
# Load-shedding policies                                                 #
# ====================================================================== #
_SHED_REGISTRY: dict[str, Callable[..., "ShedPolicy"]] = {}


def register_shed_policy(name: str):
    """Class decorator: make a shed policy constructible by name."""

    def deco(factory):
        if name in _SHED_REGISTRY:
            raise ValueError(f"shed policy {name!r} already registered")
        _SHED_REGISTRY[name] = factory
        factory.name = name
        return factory

    return deco


def registered_shed_policies() -> tuple[str, ...]:
    return tuple(sorted(_SHED_REGISTRY))


def create_shed_policy(name: str, **kwargs) -> "ShedPolicy":
    try:
        factory = _SHED_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown shed policy {name!r}; registered: "
            + ", ".join(registered_shed_policies())
        ) from None
    return factory(**kwargs)


class ShedPolicy:
    """What to drop (or downgrade) when the admission queue saturates."""

    name: str = "?"

    def on_pressure(self, queue: AdmissionQueue,
                    engine: "StreamingEngine") -> None:
        """The queue crossed its soft watermark (rising edge only)."""

    def pick_victim(self, queue: AdmissionQueue, incoming: StreamRequest,
                    engine: "StreamingEngine") -> StreamRequest:
        """The queue is full: return the request to shed — either
        ``incoming`` or a currently queued request."""
        raise NotImplementedError


@register_shed_policy("reject_newest")
class RejectNewest(ShedPolicy):
    """Tail drop: a full queue sheds the incoming request."""

    def pick_victim(self, queue, incoming, engine):
        return incoming


@register_shed_policy("reject_cheapest")
class RejectCheapest(ShedPolicy):
    """Shed the least valuable work: LP before HP, then the smallest
    estimated core-seconds, then the newest arrival."""

    @staticmethod
    def _key(req: StreamRequest):
        return (1 if req.priority == Priority.HIGH else 0,
                req.est_cost, -(req.rid or 0))

    def pick_victim(self, queue, incoming, engine):
        victim = incoming
        vkey = self._key(incoming)
        for r in queue.iter_live():
            k = self._key(r)
            if k < vkey:
                victim, vkey = r, k
        return victim


@register_shed_policy("degrade")
class DegradeThenReject(RejectCheapest):
    """Degrade before dropping: at the soft watermark every queued LP
    request steps one rung down its task type's variant ladder (DESIGN.md
    §17); a full queue degrades the incoming LP request too, then sheds
    like ``reject_cheapest``.

    :meth:`degrade` walks the real ladder: each call moves the request one
    rung deeper and re-estimates its shed cost at the new rung, so repeated
    pressure edges keep cutting until the ladder bottoms out.  For a
    ladder-free profile the single legacy rung pins the request's tasks to
    ``core_options[0]`` via ``Task.degraded`` (the scheduler's core-upgrade
    pass skips them) — exactly the pre-ladder behavior.
    """

    def degrade(self, req: StreamRequest, engine: "StreamingEngine") -> bool:
        prof = engine.net.profile(req.task_type)
        if req.variant + 1 < prof.n_variants:
            req.variant += 1
        elif prof.n_variants == 1 and req.variant == 0:
            req.variant = 1      # legacy pin: base stats at minimum cores
        else:
            return False         # ladder exhausted
        # re-estimate the shed cost at the admitted rung, so the
        # reject_cheapest fallback ranks degraded work by what it now costs
        rung = prof.variant_profile(req.variant)
        cores = rung.core_options[0]
        req.est_cost = req.n_tasks * rung.lp_slot_time(cores) * cores
        engine.telemetry.degraded += 1
        engine.metrics.lp_degraded += 1
        return True

    def on_pressure(self, queue, engine):
        for r in queue.iter_live():
            if r.priority == Priority.LOW:
                self.degrade(r, engine)

    def pick_victim(self, queue, incoming, engine):
        if incoming.priority == Priority.LOW:
            self.degrade(incoming, engine)
        return super().pick_victim(queue, incoming, engine)


# ====================================================================== #
# Dispatcher client: terminal bookkeeping without a final sweep          #
# ====================================================================== #
class _StreamClient(DispatchClient):
    def __init__(self, engine: "StreamingEngine") -> None:
        self.engine = engine

    def on_start(self, task: Task) -> None:
        hook = self.engine.compute_hook
        if hook is not None:
            hook(task)

    def on_hp_complete(self, task: Task) -> None:
        self.engine._task_terminal(task, ok=True)

    def on_lp_complete(self, task: Task) -> None:
        self.engine._task_terminal(task, ok=True)

    def on_admit_fail(self, task: Task) -> None:
        self.engine._task_terminal(task, ok=False)

    def on_late(self, task: Task) -> None:
        self.engine._task_terminal(task, ok=False)

    def on_device_lost(self, task: Task) -> None:
        # The orphan is transient, not terminal: recovery (or failure)
        # settles through the normal completion / admit-fail hooks.
        self.engine.telemetry.orphans_seen += 1


# ====================================================================== #
# The streaming engine                                                   #
# ====================================================================== #
class StreamingEngine:
    """Windowed streaming admission over the shared policy dispatcher.

    One instance holds one :class:`EventQueue`, one policy (and therefore
    one ``NetworkState`` whose probe plane persists across windows), one
    bounded :class:`AdmissionQueue` and one :class:`StreamTelemetry`.
    Requests enter through :meth:`offer` (returning a
    :class:`Backpressure` signal) or the :meth:`run` pump, which drains a
    source iterator window by window.

    Execution is exact-slot (``PolicyDispatcher(exact_slots=True)``):
    tasks complete at their reserved slot end, optionally invoking
    ``compute_hook`` at slot start — the jax engine mounts real decode
    work there; the soak benchmark leaves it ``None``.
    """

    def __init__(
        self,
        n_devices: int,
        *,
        net: Optional[NetworkConfig] = None,
        workload: str = "paper",
        policy: str = "scheduler",
        queue_capacity: int = 4096,
        soft_watermark: float = 0.75,
        shed: str = "reject_newest",
        window: float = 0.25,
        window_budget: Optional[int] = None,
        default_lp_deadline: float = 30.0,
        keep_done: int = 0,
        compute_hook: Optional[Callable[[Task], None]] = None,
        telemetry: Optional[StreamTelemetry] = None,
        policy_kwargs: Optional[dict] = None,
    ) -> None:
        if window <= 0.0:
            raise ValueError("window must be positive")
        if window_budget is not None and window_budget < 1:
            raise ValueError("window_budget must be >= 1 (or None)")
        self.net = resolve_network(net, workload)
        self.window = window
        self.window_budget = window_budget
        self.default_lp_deadline = default_lp_deadline
        self.compute_hook = compute_hook
        self.q = EventQueue()
        self.metrics = Metrics(scenario=f"stream_{policy}")
        # Open-ended run: cap the per-call latency lists with sketches so
        # metrics memory stays flat (telemetry.BoundedSeries is
        # list-compatible for the scheduler's appends).
        for f in ("t_hp_initial", "t_hp_preempt", "t_lp_alloc",
                  "t_realloc", "t_evict"):
            setattr(self.metrics, f, BoundedSeries())
        self.policy: SchedulingPolicy = create_policy(
            policy, n_devices=n_devices, net=self.net,
            metrics=self.metrics, **(policy_kwargs or {}))
        if self.policy.drives_execution:
            raise ValueError(
                f"policy {policy!r} drives its own execution model; the "
                "streaming engine supports slot-based policies only")
        self.dispatcher = PolicyDispatcher(
            self.policy, self.q, self.net, self.metrics,
            client=_StreamClient(self), exact_slots=True)
        # device calendars (churn drivers and tests read lifecycle off this;
        # None for policies without a NetworkState)
        self.state = getattr(self.policy, "state", None)
        self.queue = AdmissionQueue(queue_capacity, soft_watermark)
        self.shed_policy = create_shed_policy(shed)
        self.telemetry = telemetry if telemetry is not None \
            else StreamTelemetry()
        self.done: deque[StreamRequest] = deque(maxlen=max(keep_done, 1)) \
            if keep_done > 0 else deque(maxlen=0)
        self._by_task: dict[Task, StreamRequest] = {}
        self._rids = itertools.count()
        self._soft = False              # watermark hysteresis (rising edge)
        self.unresolved = 0             # safety valve; must stay 0

    # ------------------------------------------------------------------ #
    # Offer path                                                         #
    # ------------------------------------------------------------------ #
    def request_from_arrival(self, arr: StreamArrival) -> StreamRequest:
        """Materialise a :class:`StreamRequest` from an arrival record,
        deriving the absolute deadline from the workload profile."""
        prof = self.net.profile(arr.task_type)
        if arr.priority == Priority.HIGH:
            deadline = (arr.t + arr.rel_deadline
                        if arr.rel_deadline is not None
                        else prof.hp_deadline(arr.t))
            n_tasks = 1
        else:
            rel = arr.rel_deadline if arr.rel_deadline is not None else (
                prof.lp_deadline if prof.lp_deadline is not None
                else self.default_lp_deadline)
            deadline = arr.t + rel
            n_tasks = arr.n_tasks
        return StreamRequest(
            priority=arr.priority, deadline=deadline, home_device=arr.device,
            n_tasks=n_tasks, task_type=arr.task_type, arrival=arr.t)

    def offer(self, req: StreamRequest,
              now: Optional[float] = None) -> Backpressure:
        """Offer one request to the admission queue.

        Validates at the boundary (``ValueError`` names the offending
        field), accounts it as generated, and returns the backpressure
        signal the producer should react to.
        """
        t = self.q.now if now is None else now
        validate_submission(
            priority=req.priority, deadline=req.deadline, now=t,
            n_tasks=req.n_tasks, max_new_tokens=req.max_new_tokens,
            task_type=req.task_type, spec=self.net.spec)
        if req.rid is None:
            req.rid = next(self._rids)
        if req.arrival == 0.0 and t > 0.0:
            req.arrival = t
        prof = self.net.profile(req.task_type)
        if req.priority == Priority.HIGH:
            req.est_cost = prof.hp_slot_time
        else:
            cores = prof.core_options[0]
            req.est_cost = req.n_tasks * prof.lp_slot_time(cores) * cores
        m = self.metrics
        self.telemetry.offered += 1
        if req.priority == Priority.HIGH:
            m.hp_generated += 1
            m.count_type(req.task_type, "hp_generated")
        else:
            m.lp_generated += req.n_tasks
            m.lp_requests_total += 1
            m.count_type(req.task_type, "lp_generated", req.n_tasks)

        if self.queue.full:
            victim = self.shed_policy.pick_victim(self.queue, req, self)
            if victim is req:
                self._shed(req, "queue_full")
                return Backpressure.SHED
            self.queue.drop(victim)
            self._shed(victim, "queue_full")
            self.queue.push(req)
        else:
            self.queue.push(req)

        if self.queue.soft:
            if not self._soft:
                self._soft = True
                self.shed_policy.on_pressure(self.queue, self)
            self.telemetry.soft_signals += 1
            return Backpressure.SOFT
        return Backpressure.ACCEPTED

    def _shed(self, req: StreamRequest, reason: str) -> None:
        req.state = "shed"
        req.shed_reason = reason
        m = self.metrics
        if req.priority == Priority.HIGH:
            m.hp_shed += 1
            m.count_type(req.task_type, "hp_shed")
        else:
            m.lp_shed += req.n_tasks
            m.count_type(req.task_type, "lp_shed", req.n_tasks)
        if reason == "expired":
            self.telemetry.shed_expired += 1
        else:
            self.telemetry.shed_queue_full += 1
        self.telemetry.slo.record(req.task_type, attained=False)
        self.done.append(req)

    # ------------------------------------------------------------------ #
    # Window drain                                                       #
    # ------------------------------------------------------------------ #
    def flush_window(self, now: Optional[float] = None) -> int:
        """Drain (up to ``window_budget``) queued requests into the
        dispatcher at ``now``.  Returns the number admitted."""
        if now is None:
            now = self.q.now
        elif now > self.q.now:
            # direct callers (run() has already drained events to ``now``)
            self.q.now = now
        self.telemetry.windows += 1
        self.telemetry.queue_depth.sample(now, float(self.queue.live))
        budget = self.window_budget if self.window_budget is not None \
            else (1 << 62)
        hp_batch: list[tuple[StreamRequest, Task]] = []
        lp_batch: list[tuple[StreamRequest, LowPriorityRequest]] = []
        admitted = 0
        while self.queue.live and admitted < budget:
            req = self.queue.pop_live()
            if req is None:
                break
            if req.deadline <= now + _EPS:
                self._shed(req, "expired")
                continue
            admitted += 1
            req.state = "admitted"
            if req.priority == Priority.HIGH:
                task = Task(
                    priority=Priority.HIGH, source_device=req.home_device,
                    deadline=req.deadline, frame_id=req.rid,
                    task_type=req.task_type, created_at=req.arrival)
                req._remaining = 1
                self._by_task[task] = req
                hp_batch.append((req, task))
            else:
                lr = LowPriorityRequest(
                    source_device=req.home_device, deadline=req.deadline,
                    frame_id=req.rid, n_tasks=req.n_tasks,
                    created_at=req.arrival, task_type=req.task_type)
                tasks = lr.make_tasks()
                if req.variant:
                    for task in tasks:
                        task.variant = req.variant
                req._remaining = len(tasks)
                for task in tasks:
                    self._by_task[task] = req
                lp_batch.append((req, lr))
        if self.queue.live < self.queue.soft_level:
            self._soft = False
        tel = self.telemetry
        # HP first — they may preempt the LP work admitted the window
        # before, and the admission latency of each is a gated quantity.
        for req, task in hp_batch:
            t0 = perf_counter()
            dec = self.dispatcher.submit_hp(task)
            tel.admission.record(perf_counter() - t0)
            tel.admitted_hp += 1
            self._settle_failed_victims(dec)
        if lp_batch:
            t0 = perf_counter()
            self.dispatcher.submit_lp_batch([lr for _, lr in lp_batch])
            share = (perf_counter() - t0) / len(lp_batch)
            tel.admitted_lp += len(lp_batch)
            tel.admission.record_many([share] * len(lp_batch))
        return admitted

    def _settle_failed_victims(self, dec) -> None:
        # A preempting HP admission may strand a victim whose reallocation
        # failed; no completion event will ever fire for it, so its request
        # settles here (the dispatcher already counted realloc_failure).
        for victim in dec.preempted:
            if victim.state == TaskState.FAILED and victim in self._by_task:
                self._task_terminal(victim, ok=False)

    def _task_terminal(self, task: Task, ok: bool) -> None:
        req = self._by_task.pop(task, None)
        if req is None:
            return
        req._remaining -= 1
        if not ok:
            req._failed = True
        if req._remaining > 0:
            return
        now = self.q.now
        req.completed_at = now
        attained = not req._failed
        req.state = "done" if attained else "failed"
        if attained:
            self.telemetry.e2e.record(max(now - req.arrival, 0.0))
        self.telemetry.slo.record(req.task_type, attained)
        self.done.append(req)

    # ------------------------------------------------------------------ #
    # Device churn (DESIGN.md §16)                                       #
    # ------------------------------------------------------------------ #
    def fail_device(self, idx: int, now: Optional[float] = None):
        """Hard-fail a device at ``now``: orphan its in-flight work and
        drive recovery through the dispatcher (LP orphans re-placed or
        FAILED, HP orphans re-admitted ahead of the next window)."""
        t = self._advance(now)
        dec = self.dispatcher.device_lost(idx)
        tel = self.telemetry
        tel.devices_failed += 1
        tel.orphans_recovered += len(dec.reallocations)
        for alloc in dec.reallocations:
            tel.recovery_delay.record(max(alloc.t_start - t, 0.0))
        for task in dec.preempted:
            if task.priority == Priority.HIGH \
                    and task.state is not TaskState.FAILED:
                tel.orphans_recovered += 1
        return dec

    def drain_device(self, idx: int, now: Optional[float] = None) -> None:
        """Stop admitting onto a device; its in-flight work runs out."""
        self._advance(now)
        self.dispatcher.device_drained(idx)
        self.telemetry.devices_drained += 1

    def rejoin_device(self, idx: int, now: Optional[float] = None) -> None:
        """Bring a DOWN/DRAINING device back with a cleared calendar."""
        self._advance(now)
        self.dispatcher.device_rejoined(idx)
        self.telemetry.devices_rejoined += 1

    def _advance(self, now: Optional[float]) -> float:
        if now is not None and now > self.q.now:
            self.q.run(until=now)
            self.q.now = max(self.q.now, now)
        return self.q.now

    def _apply_churn_event(self, ev) -> None:
        """Apply one :class:`~repro.sim.churn.ChurnEvent` at its timestamp."""
        if ev.kind == "fail":
            self.fail_device(ev.device, now=ev.t)
        elif ev.kind == "drain":
            self.drain_device(ev.device, now=ev.t)
        elif ev.kind == "rejoin":
            self.rejoin_device(ev.device, now=ev.t)
        elif ev.kind == "link":
            # Time-varying link degradation: occupy the shared link for the
            # event's duration so concurrent offloads queue behind it.
            t = self._advance(ev.t)
            state = self.state
            if state is not None and ev.duration > 0.0:
                state.link.reserve(t, t + ev.duration, ("churn", ev.device))
        else:
            raise ValueError(f"unknown churn event kind {ev.kind!r}")

    # ------------------------------------------------------------------ #
    # The pump                                                           #
    # ------------------------------------------------------------------ #
    def run(
        self,
        source: Iterable,
        *,
        max_requests: Optional[int] = None,
        until: Optional[float] = None,
        on_window: Optional[Callable[["StreamingEngine"], None]] = None,
        churn: Optional[Iterable] = None,
    ) -> dict[str, Any]:
        """Pump a source of :class:`StreamArrival` / :class:`StreamRequest`
        through windowed admission until the source (or ``max_requests`` /
        ``until``) is exhausted and all admitted work has settled.

        ``on_window`` runs after every window flush (soak's RSS sampler).
        ``churn`` is an optional time-sorted stream of
        :class:`~repro.sim.churn.ChurnEvent` records applied at their
        timestamps as windows advance (``None`` — the default — executes
        zero churn code, so churn-free runs stay bit-identical).
        """
        it = iter(source)
        offered = 0
        churn_events = deque(churn) if churn is not None else None

        def pull():
            nonlocal offered
            if max_requests is not None and offered >= max_requests:
                return None
            nxt = next(it, None)
            if nxt is None:
                return None
            if not isinstance(nxt, StreamRequest):
                nxt = self.request_from_arrival(nxt)
            if until is not None and nxt.arrival >= until:
                return None
            offered += 1
            return nxt

        nxt = pull()
        while nxt is not None or self.queue.live:
            w_end = self.q.now + self.window
            if nxt is not None and not self.queue.live \
                    and nxt.arrival > w_end:
                # idle fast-forward: jump to the window holding the next
                # arrival instead of spinning empty windows
                w_end = nxt.arrival + self.window
            while nxt is not None and nxt.arrival <= w_end:
                self.offer(nxt, now=nxt.arrival)
                nxt = pull()
            if churn_events:
                # lifecycle events land at their exact timestamps: _advance
                # drains the event queue up to ev.t first, so completions
                # scheduled before the failure still fire before it
                while churn_events and churn_events[0].t <= w_end:
                    self._apply_churn_event(churn_events.popleft())
            self.q.run(until=w_end)
            self.q.now = max(self.q.now, w_end)
            self.flush_window(w_end)
            if on_window is not None:
                on_window(self)
        if churn_events:
            # events past the last arrival window still interleave with the
            # tail of admitted work draining below
            while churn_events:
                self._apply_churn_event(churn_events.popleft())
        self.q.run()
        self.dispatcher.finalize()
        if self._by_task:
            # must be unreachable: every admitted task resolves through a
            # client hook.  Counted (not asserted) so a soak surfaces it.
            self.unresolved += len(self._by_task)
            self._by_task.clear()
        return self.report()

    # ------------------------------------------------------------------ #
    def report(self) -> dict[str, Any]:
        return {
            "metrics": self.metrics.summary(),
            "telemetry": self.telemetry.snapshot(),
            "in_flight": len(self._by_task),
            "queued": self.queue.live,
            "unresolved": self.unresolved,
        }
