"""GQA/MHA attention with RoPE, optional QKV bias, sliding windows and a
position-tracked (optionally rotating) KV cache.

Cache layout: k/v [B, S, KV, D] with an int32 ``positions [B, S]`` slot map
(-1 = empty).  Full causal caches write slot ``pos``; sliding-window caches
write slot ``pos % window`` — the same attention code handles both because
masks are derived from the stored absolute positions, never from slot order.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..config import ModelConfig
from .common import apply_rope, dense_init, masked_softmax, rope_cos_sin, zeros


# --------------------------------------------------------------------------- #
# Params                                                                      #
# --------------------------------------------------------------------------- #


def attn_init(key, cfg: ModelConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d, cfg.n_heads, hd, dtype=dtype),
        "wk": dense_init(kk, d, cfg.n_kv_heads, hd, dtype=dtype),
        "wv": dense_init(kv, d, cfg.n_kv_heads, hd, dtype=dtype),
        "wo": dense_init(ko, cfg.n_heads * hd, d, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((cfg.n_heads, hd), dtype)
        p["bk"] = zeros((cfg.n_kv_heads, hd), dtype)
        p["bv"] = zeros((cfg.n_kv_heads, hd), dtype)
    return p


def attn_axes(cfg: ModelConfig) -> dict:
    a = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads_flat", "embed"),
    }
    if cfg.qkv_bias:
        a["bq"] = ("heads", "head_dim")
        a["bk"] = ("kv_heads", "head_dim")
        a["bv"] = ("kv_heads", "head_dim")
    return a


# --------------------------------------------------------------------------- #
# KV cache                                                                    #
# --------------------------------------------------------------------------- #


def init_kv_cache(batch: int, length: int, n_kv: int, head_dim: int, dtype) -> dict:
    return {
        "k": jnp.zeros((batch, length, n_kv, head_dim), dtype=dtype),
        "v": jnp.zeros((batch, length, n_kv, head_dim), dtype=dtype),
        "positions": jnp.full((batch, length), -1, dtype=jnp.int32),
    }


def kv_cache_axes() -> dict:
    return {
        "k": ("batch", "cache", "kv_heads", "head_dim"),
        "v": ("batch", "cache", "kv_heads", "head_dim"),
        "positions": ("batch", "cache"),
    }


def _write_slot(cache_len: int, pos: jax.Array, window: int) -> jax.Array:
    return jnp.where(window > 0, pos % cache_len, pos)


# --------------------------------------------------------------------------- #
# Core attention                                                              #
# --------------------------------------------------------------------------- #


def _gqa_scores_to_out(q, k, v, mask) -> jax.Array:
    """q [B,T,H,D], k/v [B,S,KV,D], mask [B|1, 1, T, S]."""
    b, t, h, d = q.shape
    kv = k.shape[2]
    group = h // kv
    q = q.reshape(b, t, kv, group, d)
    scale = jnp.asarray(d, jnp.float32) ** -0.5
    scores = jnp.einsum("btkgd,bskd->bkgts", q, k) * scale
    w = masked_softmax(scores, mask[:, :, None])      # [B,1,1,T,S] broadcast
    out = jnp.einsum("bkgts,bskd->btkgd", w.astype(v.dtype), v)
    return out.reshape(b, t, h, d)


def causal_mask(q_pos: jax.Array, k_pos: jax.Array, window: int) -> jax.Array:
    """q_pos [T], k_pos [S] (absolute) -> [T, S] bool."""
    m = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def _chunked_attention(q, k, v, q_pos, k_pos, window: int, chunk: int,
                       unroll: int | bool = 1) -> jax.Array:
    """Blocked full-seq attention: scan over query chunks so only a
    [B, H, chunk, S] score block is ever live (the jnp analogue of the
    flash_attention kernel — beyond-paper §Perf lever)."""
    b, t, h, d = q.shape
    n_pad = (-t) % chunk
    if n_pad:
        q = jnp.pad(q, [(0, 0), (0, n_pad), (0, 0), (0, 0)])
        q_pos = jnp.pad(q_pos, (0, n_pad), constant_values=-1)
    nb = q.shape[1] // chunk
    qb = q.reshape(b, nb, chunk, h, d).swapaxes(0, 1)
    pb = q_pos.reshape(nb, chunk)

    def blk(carry, inp):
        qi, qp = inp
        mask = causal_mask(qp, k_pos, window)[None, None]
        return carry, _gqa_scores_to_out(qi, k, v, mask)

    _, outs = jax.lax.scan(blk, 0, (qb, pb), unroll=unroll)
    out = outs.swapaxes(0, 1).reshape(b, nb * chunk, h, d)
    return out[:, :t]


def attn_apply(
    params: dict,
    x: jax.Array,                       # [B, T, d]
    cfg: ModelConfig,
    *,
    positions: jax.Array,               # [T] absolute positions
    causal: bool = True,
    window: int = 0,
    kv_x: Optional[jax.Array] = None,   # cross-attention source [B, S, d]
    kv_positions: Optional[jax.Array] = None,
    cache: Optional[dict] = None,       # decode: attend over cache
    rope: bool = True,
    chunk: int = 0,                     # blocked attention (0 = naive)
    inner_unroll: int | bool = 1,
) -> tuple[jax.Array, Optional[dict]]:
    b, t, d = x.shape
    hd = cfg.resolved_head_dim

    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]

    if rope:
        cos_q, sin_q = rope_cos_sin(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos_q, sin_q)

    if cache is None:
        src = x if kv_x is None else kv_x
        k = jnp.einsum("bsd,dhk->bshk", src, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", src, params["wv"])
        if "bk" in params:
            k = k + params["bk"]
            v = v + params["bv"]
        k_pos = positions if kv_x is None else kv_positions
        if rope and kv_x is None:
            cos_k, sin_k = rope_cos_sin(k_pos, hd, cfg.rope_theta)
            k = apply_rope(k, cos_k, sin_k)
        if causal and kv_x is None and chunk and t > chunk:
            return _chunked_attention(q, k, v, positions, k_pos, window,
                                      chunk, inner_unroll), None
        if causal and kv_x is None:
            mask = causal_mask(positions, k_pos, window)[None, None]
        else:
            mask = jnp.ones((1, 1, t, k.shape[1]), dtype=bool)
        return _gqa_scores_to_out(q, k, v, mask), None

    # ---- decode against the cache (T == 1) ------------------------------- #
    pos = positions[-1]                               # scalar current position
    cache_len = cache["k"].shape[1]
    new_cache = cache
    if kv_x is None:                                  # self-attention: write
        k_new = jnp.einsum("btd,dhk->bthk", x, params["wk"])
        v_new = jnp.einsum("btd,dhk->bthk", x, params["wv"])
        if "bk" in params:
            k_new = k_new + params["bk"]
            v_new = v_new + params["bv"]
        if rope:
            cos_k, sin_k = rope_cos_sin(positions, hd, cfg.rope_theta)
            k_new = apply_rope(k_new, cos_k, sin_k)
        slot = _write_slot(cache_len, pos, window)
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, 1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, 1),
            "positions": jax.lax.dynamic_update_slice_in_dim(
                cache["positions"],
                jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32),
                slot,
                1,
            ),
        }
    k, v, stored = new_cache["k"], new_cache["v"], new_cache["positions"]
    valid = (stored >= 0) & (stored <= pos)
    if window > 0:
        valid &= stored > pos - window
    mask = valid[:, None, None, :]                    # [B, 1, T=1, S]
    out = _gqa_scores_to_out(q, k, v, mask)
    return out, new_cache


def attn_out_project(params: dict, attn_out: jax.Array) -> jax.Array:
    b, t, h, d = attn_out.shape
    return jnp.einsum("bte,ed->btd", attn_out.reshape(b, t, h * d), params["wo"])


# --------------------------------------------------------------------------- #
# Cross-attention KV precompute (encoder-decoder prefill)                     #
# --------------------------------------------------------------------------- #


def cross_kv(params: dict, enc_out: jax.Array) -> dict:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"])
    if "bk" in params:
        k = k + params["bk"]
        v = v + params["bv"]
    return {"k": k, "v": v}


def cross_attend(params: dict, x: jax.Array, ckv: dict, cfg: ModelConfig) -> jax.Array:
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    b, t = x.shape[:2]
    mask = jnp.ones((b, 1, t, ckv["k"].shape[1]), dtype=bool)
    out = _gqa_scores_to_out(q, ckv["k"], ckv["v"], mask)
    return attn_out_project(params, out)
