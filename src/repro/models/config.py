"""Model configuration for the 10 assigned architectures.

A :class:`ModelConfig` fully describes one architecture as a sequence of
*stages*.  Each stage is a (pattern of layers) x (repeat count); repeated
stages are executed with ``jax.lax.scan`` over stacked parameters so the HLO
stays O(1) in depth (a 61-layer model must compile for 512 placeholder
devices on one CPU core).

Layer mixers supported: GQA/MHA attention (optional QKV bias, optional
sliding window), MLA (DeepSeek multi-head latent attention), Mamba selective
SSM, mLSTM and sLSTM (xLSTM).  FFNs: dense SwiGLU/GeLU MLP, MoE
(shared + routed top-k with capacity-based dispatch), or none.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional

# --------------------------------------------------------------------------- #
# Sub-configs                                                                 #
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int                       # routed experts
    top_k: int
    d_expert: int                        # per-expert FFN hidden size
    n_shared: int = 0                    # always-on shared experts
    capacity_factor: float = 1.25
    router: str = "softmax"              # "softmax" | "sigmoid" (DeepSeek-V3)
    router_aux_weight: float = 0.001     # load-balance aux loss weight


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention (arXiv:2405.04434 / 2412.19437)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0                 # 0 => direct q projection
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                     # 0 => ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    """mLSTM/sLSTM block dims (arXiv:2405.04517)."""

    proj_factor_mlstm: float = 2.0       # mLSTM up-projection
    conv_kernel: int = 4
    ffn_proj_factor: float = 1.3333      # post-sLSTM gated FFN


@dataclass(frozen=True)
class LayerDef:
    """One layer inside a stage pattern."""

    mixer: str                           # attn | mla | mamba | mlstm | slstm
    ffn: str                             # dense | moe | none
    cross_attn: bool = False             # decoder layer with cross-attention


@dataclass(frozen=True)
class StageDef:
    pattern: tuple[LayerDef, ...]
    repeats: int

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeats


# --------------------------------------------------------------------------- #
# ModelConfig                                                                 #
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                       # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0                    # 0 => d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # Sliding-window attention (tokens). 0 = full causal attention.  The
    # long_500k shape switches dense archs to `long_context_window`.
    sliding_window: int = 0
    long_context_window: int = 8192

    # Stage structure. Empty => homogeneous dense decoder derived from
    # n_layers (pattern [attn+dense] x n_layers).
    stages: tuple[StageDef, ...] = ()

    # Mixture-of-experts / MLA / SSM sub-configs (None when unused).
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # Encoder-decoder (audio): encoder stage list; 0 layers => decoder-only.
    encoder_stages: tuple[StageDef, ...] = ()

    # Modality frontend stubs (brief carve-out): embeddings arrive
    # precomputed with this dim; a learned projector maps them to d_model.
    modality: str = "text"               # text | vision | audio
    modality_embed_dim: int = 0          # dim of the stub-provided embeddings
    n_modality_tokens: int = 0           # prepended per sequence (vision)

    # DeepSeek-V3 multi-token prediction (optional extra head, training only)
    mtp_depth: int = 0

    # Beyond-paper §Perf lever: chunkwise-parallel mLSTM (linear-attention
    # chunk form).  0 = off -> naive T x T decay-masked parallel form.
    # Removes the quadratic decay/score matrices from HBM traffic and cuts
    # masked-out FLOPs; exactly equivalent to the naive form (same
    # stabiliser semantics) — see tests/test_layers_equivalence.py.
    mlstm_chunk: int = 0

    # Beyond-paper §Perf lever: blocked online-softmax attention for
    # full-sequence passes (0 = off -> naive T x T materialisation).  The
    # pure-JAX analogue of the flash_attention Pallas kernel; removes the
    # quadratic score tensor from HBM traffic.
    attn_chunk: int = 0

    # Numeric / padding policy
    param_dtype: str = "float32"
    activation_dtype: str = "float32"
    vocab_pad_multiple: int = 512        # pad embedding table so 16 | vocab

    source: str = ""                     # citation for the config

    # ------------------------------------------------------------------ #
    def __post_init__(self) -> None:
        if not self.stages:
            object.__setattr__(
                self,
                "stages",
                (StageDef((LayerDef("attn", "dense"),), self.n_layers),),
            )
        total = sum(s.n_layers for s in self.stages)
        if total != self.n_layers:
            raise ValueError(
                f"{self.name}: stages cover {total} layers != n_layers={self.n_layers}"
            )

    # ------------------------------------------------------------------ #
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_encoder_decoder(self) -> bool:
        return bool(self.encoder_stages)

    @property
    def n_encoder_layers(self) -> int:
        return sum(s.n_layers for s in self.encoder_stages)

    @property
    def mamba_d_inner(self) -> int:
        assert self.mamba is not None
        return self.mamba.expand * self.d_model

    @property
    def mamba_dt_rank(self) -> int:
        assert self.mamba is not None
        return self.mamba.dt_rank or max(1, math.ceil(self.d_model / 16))

    @property
    def uses_attention(self) -> bool:
        defs = [l for s in self.stages for l in s.pattern]
        return any(l.mixer in ("attn", "mla") for l in defs)

    @property
    def subquadratic_native(self) -> bool:
        """True when decode state is O(1) per token (SSM / hybrid-with-window)."""
        return self.arch_type in ("ssm", "hybrid")

    def with_sliding_window(self, window: int) -> "ModelConfig":
        return replace(self, sliding_window=window)

    def layer_defs(self) -> list[LayerDef]:
        out: list[LayerDef] = []
        for s in self.stages:
            out.extend(list(s.pattern) * s.repeats)
        return out

    # Parameter count (embedding + per-layer), for 6ND roofline numbers.
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads

        def attn_params() -> int:
            return d * hd * n_q + 2 * d * hd * n_kv + n_q * hd * d

        def mla_params() -> int:
            m = self.mla
            assert m is not None
            q_in = (
                d * m.q_lora_rank + m.q_lora_rank * n_q * (m.nope_head_dim + m.rope_head_dim)
                if m.q_lora_rank
                else d * n_q * (m.nope_head_dim + m.rope_head_dim)
            )
            kv = d * (m.kv_lora_rank + m.rope_head_dim)
            kv += m.kv_lora_rank * n_q * (m.nope_head_dim + m.v_head_dim)
            out = n_q * m.v_head_dim * d
            return q_in + kv + out

        def mamba_params() -> int:
            di, ds, dt = self.mamba_d_inner, self.mamba.d_state, self.mamba_dt_rank
            return (
                d * 2 * di                      # in_proj
                + di * self.mamba.d_conv        # conv
                + di * (dt + 2 * ds)            # x_proj
                + dt * di                       # dt_proj
                + di * ds                       # A
                + di                            # D
                + di * d                        # out_proj
            )

        def mlstm_params() -> int:
            di = int(self.xlstm.proj_factor_mlstm * d)
            return d * 2 * di + di * self.xlstm.conv_kernel + 3 * di * di // self.n_heads \
                + 3 * di + di * d

        def slstm_params() -> int:
            h = d
            per_head = (h // self.n_heads) ** 2
            return 4 * h * h + 4 * self.n_heads * per_head + \
                int(2 * self.xlstm.ffn_proj_factor * h * h)

        def ffn_params(kind: str) -> int:
            if kind == "dense":
                return 3 * d * self.d_ff
            if kind == "moe":
                m = self.moe
                assert m is not None
                routed = m.n_experts if not active_only else m.top_k
                shared = m.n_shared
                return 3 * d * m.d_expert * (routed + shared) + d * m.n_experts
            return 0

        total = 0
        mixers = {
            "attn": attn_params,
            "mla": mla_params,
            "mamba": mamba_params,
            "mlstm": mlstm_params,
            "slstm": slstm_params,
        }
        for ld in self.layer_defs():
            total += mixers[ld.mixer]()
            total += ffn_params(ld.ffn)
            if ld.cross_attn:
                total += attn_params()
            total += 2 * d                     # norms
        for s in self.encoder_stages:
            for ld in s.pattern * s.repeats:
                total += mixers[ld.mixer]() + ffn_params(ld.ffn) + 2 * d
        total += self.padded_vocab * d         # embedding
        if not self.tie_embeddings:
            total += self.padded_vocab * d     # lm head
        if self.modality_embed_dim:
            total += self.modality_embed_dim * d + d * d  # 2-layer projector
        return total
