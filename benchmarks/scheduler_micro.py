"""Scheduler micro-benchmarks: wall-clock admission latency vs network size.

The paper's §6.3 complexity discussion (HP ~ O(local tasks), LP ~ O(total
tasks^2)) is where the seed implementation stopped scaling.  This module
measures — rather than asserts — what the skyline-calendar rewrite
(DESIGN.md §2) buys:

* ``bench_scheduler_scaling``   — the original 4-device ladder (kept for
                                  benchmarks/run.py compatibility).
* ``bench_calendar_speedup``    — THE acceptance benchmark: identical
                                  pre-loaded networks (default 64 devices /
                                  5000 in-flight tasks) probed through the
                                  same ``PreemptionAwareScheduler`` backed by
                                  the seed calendars
                                  (``calendar_reference``) vs the skyline
                                  calendars; reports per-admission latency
                                  and the speedup ratio.
* ``bench_probe_plane``         — the PR 4 acceptance ladder: skyline
                                  admission latency at 64/256/1024 devices
                                  over 5k in-flight tasks, against the
                                  pinned PR 3 baselines.
* ``bench_batch_admission``     — sequential per-request admission vs
                                  ``allocate_low_priority_batch`` over the
                                  same burst.
* ``bench_preemption``          — the PR 5 acceptance ladder: HP admissions
                                  aimed at saturated devices (every probe
                                  walks the eviction + victim-reallocation
                                  path) through the vectorized preemption
                                  plane vs the scalar eviction loop, over
                                  identical states; hard-fails unless the
                                  two paths make bit-identical decisions.
                                  Also runs the ``preempt_storm`` scenario
                                  family end-to-end.
* ``bench_large_n``             — the sim/scenarios.py suite end-to-end:
                                  device ladder 4 -> 1024 (LARGE_N_TIERS),
                                  the three arrival families, and an HP:LP
                                  mix sweep.
* ``bench_policy_sweep``        — every policy in the registry
                                  (core/policy.py) runs one reduced scenario;
                                  a registry entry that cannot complete it
                                  fails the benchmark (and the CI smoke).

Run directly::

    PYTHONPATH=src python benchmarks/scheduler_micro.py [--quick] [--json PATH]

``--quick`` shrinks the workloads for CI smoke use (a scheduler-latency
regression still shows as a ratio, just with more noise).  ``--json PATH``
additionally writes the rows machine-readably (bench/config/metric/value
plus capture metadata) — the file committed as ``BENCH_4.json`` is one such
trajectory point, and CI uploads the per-run output as an artifact.

``PR3_BASELINE_US`` pins the pre-probe-plane admission latencies (commit
d91ade4) measured on the development container with this same benchmark;
``*_speedup_vs_pr3_x`` rows divide them by the current run.  They are
machine-specific reference points for the committed trajectory, NOT a CI
gate — the CI perf smoke gates on the in-run reference-vs-skyline ratio,
which is machine-independent.
"""
from __future__ import annotations

import argparse
import json
import platform
import time

from dataclasses import replace

from repro.core.calendar import NetworkState
from repro.core.calendar_reference import ReferenceNetworkState
from repro.core.network import NetworkConfig
from repro.core.policy import registered_policies
from repro.core.scheduler import PreemptionAwareScheduler
from repro.core.task import (
    LowPriorityRequest,
    Priority,
    Task,
    TaskState,
    reset_id_counters,
)
from repro.sim.experiment import MIXED_SCENARIOS, ScenarioConfig, run_scenario
from repro.sim.scenarios import (
    LARGE_N_TIERS,
    LargeNConfig,
    run_large_n,
    sweep_devices,
    sweep_mix,
)

Row = tuple[str, str, str, float]

#: Pre-probe-plane (PR 3, commit d91ade4) admission latencies, measured on
#: the development container with this benchmark's own protocol (identical
#: preload, warmed process, mean over the probe loop).  See module
#: docstring for how these are used.
PR3_BASELINE_US = {
    "64dev_5000tasks": {"hp": 52.0, "lp": 221.5},
    "256dev_5000tasks": {"hp": 224.8, "lp": 539.7},
    "1024dev_5000tasks": {"hp": 413.8, "lp": 1854.1},
}


def _loaded_state(n_devices: int, n_tasks: int, net: NetworkConfig):
    """A network with n_tasks LP reservations spread across devices/time."""
    state = NetworkState(n_devices)
    sched = PreemptionAwareScheduler(state, net, preemption=True)
    t = 0.0
    placed = 0
    while placed < n_tasks:
        req = LowPriorityRequest(source_device=placed % n_devices,
                                 deadline=t + 120.0, frame_id=placed,
                                 n_tasks=1)
        req.make_tasks()
        res = sched.allocate_low_priority(req, t)
        placed += 1
        if not res.allocations:
            t += 5.0
    return state, sched


def bench_scheduler_scaling(loads=(8, 32, 128), reps: int = 30) -> list[Row]:
    """Rows: (bench, load, metric, us_per_call)."""
    rows = []
    net = NetworkConfig()
    for load in loads:
        state, sched = _loaded_state(4, load, net)
        # HP allocation timing (fresh task each rep, rolled back after)
        t0 = time.perf_counter()
        for i in range(reps):
            task = Task(priority=Priority.HIGH, source_device=i % 4,
                        deadline=1e6, frame_id=i)
            res = sched.allocate_high_priority(task, 0.0)
            if res.allocation is not None:
                state.devices[task.device].release(task)
                for slot in res.allocation.link_slots:
                    state.link.cancel(slot)
        hp_us = (time.perf_counter() - t0) / reps * 1e6
        rows.append(("sched_micro", str(load), "hp_alloc_us", hp_us))

        t0 = time.perf_counter()
        for i in range(reps):
            req = LowPriorityRequest(source_device=i % 4, deadline=1e5,
                                     frame_id=i, n_tasks=1)
            req.make_tasks()
            sched.allocate_low_priority(req, 0.0)
        lp_us = (time.perf_counter() - t0) / reps * 1e6
        rows.append(("sched_micro", str(load), "lp_alloc_us", lp_us))
    return rows


# --------------------------------------------------------------------- #
# Reference vs skyline calendars on an identical pre-loaded network     #
# --------------------------------------------------------------------- #
def _preload(state, n_tasks: int, horizon: float, seed: int = 7) -> None:
    """Deterministically fill ``state`` with n_tasks in-flight reservations
    (identical content for either calendar implementation)."""
    import random

    rng = random.Random(seed)
    net = NetworkConfig()
    n_dev = len(state.devices)
    for i in range(n_tasks):
        dev = state.devices[rng.randrange(n_dev)]
        t1 = rng.uniform(0.0, horizon)
        cores = 2 if rng.random() < 0.8 else 4
        dur = net.lp_slot_time(cores) * rng.uniform(0.9, 1.1)
        task = Task(priority=Priority.LOW, source_device=dev.device,
                    deadline=t1 + 200.0, frame_id=i)
        task.state = task.state.ALLOCATED
        dev.reserve(t1, t1 + dur, cores, task)
        # every in-flight task also holds a state-update link slot
        state.link.reserve(t1 + dur, t1 + dur + net.slot(net.msg.state_update),
                           ("update", task.task_id))


def _probe_admissions(state, net: NetworkConfig, probes: int,
                      warmup: int = 12) -> tuple[float, float]:
    """Mean per-call wall time (us) for HP and single-task-LP admission.
    Every successful probe is rolled back so all probes see the same state;
    only the admission call itself is timed (rollback cost differs between
    the calendar implementations and is not admission latency).  A few
    untimed warmup probes first-touch caches and deferred structures for
    BOTH implementations, so the means measure steady-state latency."""
    sched = PreemptionAwareScheduler(state, net, preemption=False)

    def _one_hp(i: int) -> float:
        """One HP admission + rollback; returns the timed admission cost
        (warmup discards it, so warmed and measured state stay identical)."""
        task = Task(priority=Priority.HIGH,
                    source_device=i % len(state.devices),
                    deadline=1e6, frame_id=i)
        t0 = time.perf_counter()
        res = sched.allocate_high_priority(task, 0.0)
        dt = time.perf_counter() - t0
        if res.allocation is not None:
            state.devices[task.device].release(task)
            for slot in res.allocation.link_slots:
                state.link.cancel(slot)
        return dt

    def _one_lp(i: int) -> float:
        req = LowPriorityRequest(source_device=i % len(state.devices),
                                 deadline=120.0, frame_id=i, n_tasks=1)
        req.make_tasks()
        t0 = time.perf_counter()
        res = sched.allocate_low_priority(req, 0.0)
        dt = time.perf_counter() - t0
        for alloc in res.allocations:
            state.devices[alloc.device].release(alloc.task)
            for slot in alloc.link_slots:
                state.link.cancel(slot)
        return dt

    for i in range(warmup):
        _one_hp(i)
        _one_lp(i)
    hp_us = sum(_one_hp(i) for i in range(probes)) / probes * 1e6
    lp_us = sum(_one_lp(i) for i in range(probes)) / probes * 1e6
    return hp_us, lp_us


def bench_calendar_speedup(
    n_devices: int = 64, n_tasks: int = 5000, probes: int = 40
) -> list[Row]:
    """Acceptance benchmark: per-task admission latency, seed calendars vs
    skyline calendars, same 64-device / 5k-in-flight-task network."""
    net = NetworkConfig()
    horizon = 250.0 * (n_tasks / 5000.0) * (64.0 / max(n_devices, 1))
    rows: list[Row] = []
    label = f"{n_devices}dev_{n_tasks}tasks"

    reset_id_counters()
    ref = ReferenceNetworkState(n_devices)
    _preload(ref, n_tasks, horizon)
    ref_hp, ref_lp = _probe_admissions(ref, net, probes)

    reset_id_counters()
    new = NetworkState(n_devices)
    _preload(new, n_tasks, horizon)
    new_hp, new_lp = _probe_admissions(new, net, probes)

    rows.append(("calendar_speedup", label, "ref_hp_alloc_us", ref_hp))
    rows.append(("calendar_speedup", label, "new_hp_alloc_us", new_hp))
    rows.append(("calendar_speedup", label, "ref_lp_alloc_us", ref_lp))
    rows.append(("calendar_speedup", label, "new_lp_alloc_us", new_lp))
    rows.append(("calendar_speedup", label, "hp_speedup_x", ref_hp / max(new_hp, 1e-9)))
    rows.append(("calendar_speedup", label, "lp_speedup_x", ref_lp / max(new_lp, 1e-9)))
    pr3 = PR3_BASELINE_US.get(label)
    if pr3 is not None:
        rows.append(("calendar_speedup", label, "hp_speedup_vs_pr3_x",
                     pr3["hp"] / max(new_hp, 1e-9)))
        rows.append(("calendar_speedup", label, "lp_speedup_vs_pr3_x",
                     pr3["lp"] / max(new_lp, 1e-9)))
    return rows


def bench_probe_plane(probes: int = 60) -> list[Row]:
    """The probe-plane acceptance ladder: skyline-calendar admission latency
    at 64 / 256 / 1024 devices over the same 5k-task in-flight load (no
    reference side — the seed calendars take minutes per probe at 1024
    devices), compared against the pinned PR 3 numbers."""
    net = NetworkConfig()
    rows: list[Row] = []
    for n_devices in (64, 256, 1024):
        n_tasks = 5000
        horizon = 250.0 * (64.0 / n_devices)
        label = f"{n_devices}dev_{n_tasks}tasks"
        reset_id_counters()
        state = NetworkState(n_devices)
        _preload(state, n_tasks, horizon)
        hp, lp = _probe_admissions(state, net, probes)
        rows.append(("probe_plane", label, "hp_alloc_us", hp))
        rows.append(("probe_plane", label, "lp_alloc_us", lp))
        pr3 = PR3_BASELINE_US.get(label)
        if pr3 is not None:
            rows.append(("probe_plane", label, "hp_speedup_vs_pr3_x",
                         pr3["hp"] / max(hp, 1e-9)))
            rows.append(("probe_plane", label, "lp_speedup_vs_pr3_x",
                         pr3["lp"] / max(lp, 1e-9)))
    return rows


# --------------------------------------------------------------------- #
# Batch admission vs sequential admission over the same burst           #
# --------------------------------------------------------------------- #
def bench_batch_admission(n_devices: int = 64, n_requests: int = 200) -> list[Row]:
    net = NetworkConfig()
    label = f"{n_devices}dev_{n_requests}req"

    def burst():
        reqs = []
        for i in range(n_requests):
            r = LowPriorityRequest(source_device=i % n_devices, deadline=120.0,
                                   frame_id=i, n_tasks=1 + i % 4)
            r.make_tasks()
            reqs.append(r)
        return reqs

    reset_id_counters()
    sched = PreemptionAwareScheduler(NetworkState(n_devices), net)
    reqs = burst()
    t0 = time.perf_counter()
    seq_ok = sum(len(sched.allocate_low_priority(r, 0.0).allocations)
                 for r in reqs)
    seq_us = (time.perf_counter() - t0) / n_requests * 1e6

    reset_id_counters()
    sched = PreemptionAwareScheduler(NetworkState(n_devices), net)
    reqs = burst()
    t0 = time.perf_counter()
    results = sched.allocate_low_priority_batch(reqs, 0.0)
    batch_us = (time.perf_counter() - t0) / n_requests * 1e6
    batch_ok = sum(len(r.allocations) for r in results)

    return [
        ("batch_admission", label, "sequential_us_per_req", seq_us),
        ("batch_admission", label, "batch_us_per_req", batch_us),
        ("batch_admission", label, "batch_speedup_x", seq_us / max(batch_us, 1e-9)),
        ("batch_admission", label, "sequential_allocated", float(seq_ok)),
        ("batch_admission", label, "batch_allocated", float(batch_ok)),
    ]


# --------------------------------------------------------------------- #
# Preemption plane vs scalar eviction loop over identical saturated     #
# devices (the PR 5 acceptance ladder + CI bit-identity smoke)          #
# --------------------------------------------------------------------- #
def _saturated_state(n_devices: int, per_device: int, net: NetworkConfig):
    """Every device packed with ``per_device`` back-to-back 2-core LP
    reservations in two staggered lanes (4/4 cores busy at every instant).
    Each slot is a QUARTER of the HP window, so one admission has to chain
    several evictions before its window clears — the multi-victim case
    where the eviction loop's per-iteration cost shows.  Zero-laxity
    deadlines (== the slot end) make the per-victim reallocation attempt
    fast-fail the deadline pre-check identically — and cheaply — on both
    eviction paths, leaving the eviction loop itself as the measured
    quantity.  Mirrors are built up-front and the preload flushed, so the
    plane side runs in its steady state (a live controller maintains both
    incrementally from the first reservation; neither is admission
    latency)."""
    reset_id_counters()
    state = NetworkState(n_devices)
    for dev in state.devices:
        dev.lp_mirror()
    dur = net.hp_slot_time / 4.0
    for dev in state.devices:
        for lane in range(2):
            t = -lane * dur / 2.0
            for k in range(per_device // 2):
                task = Task(priority=Priority.LOW, source_device=dev.device,
                            deadline=t + dur, frame_id=k)
                task.state = TaskState.ALLOCATED
                dev.reserve(t, t + dur, 2, task)
                t += dur
        dev.fits(0.0, 0.1, 1)   # flush the buffered preload (untimed)
    return state


def _probe_preemptions_paired(plane_state, scalar_state, net: NetworkConfig,
                              probes: int, warmup: int = 6):
    """Drive the SAME HP admission stream through both eviction paths,
    alternating probe-by-probe so machine noise hits both sides equally
    (the paired ratio is the stable signal on shared runners).  Returns
    per-path mean admission time, mean eviction-loop time
    (``Metrics.t_evict`` — the phase the vectorized plane replaces), the
    two decision traces (bit-identity check) and the plane metrics.  The
    first ``warmup`` probes run untimed-in-effect: their admissions mutate
    both states identically but are excluded from the means."""
    scheds = {
        True: PreemptionAwareScheduler(plane_state, net, preemption=True,
                                       preemption_plane=True),
        False: PreemptionAwareScheduler(scalar_state, net, preemption=True,
                                        preemption_plane=False),
    }
    n = len(plane_state.devices)
    outcomes = {True: [], False: []}
    t_total = {True: 0.0, False: 0.0}
    for i in range(warmup + probes):
        for plane in (True, False):
            task = Task(priority=Priority.HIGH, source_device=i % n,
                        deadline=1e6, frame_id=i, task_id=10**7 + i)
            t0 = time.perf_counter()
            res = scheds[plane].allocate_high_priority(task, 0.0)
            dt = time.perf_counter() - t0
            if i >= warmup:
                t_total[plane] += dt
            outcomes[plane].append((
                res.success,
                tuple(t.task_id for t in res.preempted),
                tuple((a.task.task_id, a.device, round(a.t_start, 9))
                      for a in res.reallocations),
            ))
    for plane in (True, False):
        m = scheds[plane].metrics
        outcomes[plane].append(("metrics", m.preemptions, m.realloc_success,
                                m.realloc_failure))
        # t_evict gets one entry per admission that REACHED the eviction
        # branch; the warmup slice below is only aligned if every probe
        # did, so a partially-unsaturated workload fails loudly instead of
        # silently skewing the CI-gated ratio
        if len(m.t_evict) != warmup + probes:
            raise RuntimeError(
                f"only {len(m.t_evict)}/{warmup + probes} probes reached "
                "the eviction loop (workload no longer saturates every "
                "probed window)")
    evict_us = {
        plane: sum(scheds[plane].metrics.t_evict[warmup:]) / probes * 1e6
        for plane in (True, False)
    }
    return ({p: t_total[p] / probes * 1e6 for p in (True, False)},
            evict_us, outcomes, scheds[True].metrics)


def bench_preemption(quick: bool = False) -> list[Row]:
    """HP eviction latency, vectorized preemption plane vs the scalar loop,
    on identical saturated networks (64 / 256 / 1024 devices), plus the
    ``preempt_storm`` scenario family end-to-end.  Raises if the two
    eviction paths ever disagree on a decision."""
    # Fat link for the micro tiers: the paper's 16.3 MB/s AP congests after
    # a few dozen probes at a pinned ``now`` and the link ops (identical on
    # both paths) would drown the quantity under test — the eviction loop.
    # The storm scenarios below keep the paper link.
    net = NetworkConfig(throughput_bps=1e9, jitter_pad_s=0.0)
    rows: list[Row] = []
    tiers = ((64, 1024, 30), (256, 1024, 30)) if quick else \
            ((64, 1024, 40), (256, 1024, 40), (1024, 256, 24))
    for n_devices, per_device, probes in tiers:
        label = f"{n_devices}dev_{per_device}per"
        plane_state = _saturated_state(n_devices, per_device, net)
        scalar_state = _saturated_state(n_devices, per_device, net)
        warmup = 6
        alloc_us, evict_us, outcomes, m = _probe_preemptions_paired(
            plane_state, scalar_state, net, probes, warmup)
        if outcomes[True] != outcomes[False]:
            raise RuntimeError(
                f"preemption plane diverged from the scalar loop at {label}")
        if m.preemptions == 0:
            raise RuntimeError(
                f"bench_preemption at {label} triggered no preemptions "
                "(the workload no longer saturates the probed windows)")
        rows.append(("preemption", label, "scalar_hp_preempt_us",
                     alloc_us[False]))
        rows.append(("preemption", label, "plane_hp_preempt_us",
                     alloc_us[True]))
        rows.append(("preemption", label, "scalar_evict_us", evict_us[False]))
        rows.append(("preemption", label, "plane_evict_us", evict_us[True]))
        rows.append(("preemption", label, "hp_preempt_speedup_x",
                     alloc_us[False] / max(alloc_us[True], 1e-9)))
        rows.append(("preemption", label, "evict_speedup_x",
                     evict_us[False] / max(evict_us[True], 1e-9)))
        rows.append(("preemption", label, "preemptions_per_probe",
                     m.preemptions / (probes + warmup)))

    # end-to-end preemption-adversarial scenarios (plane on)
    for n in (16, 64) if quick else (64, 256):
        cfg = LargeNConfig(name=f"storm_n{n}", n_devices=n,
                           arrival="preempt_storm",
                           duration=20.0 if quick else 40.0)
        s = run_large_n(cfg)
        for k in ("hp_preempt_us_mean", "n_hp_preempt", "preemptions",
                  "realloc_success", "realloc_failure", "wall_s"):
            rows.append(("preemption", cfg.name, k, float(s[k])))

    # end-to-end decision equality on one full storm (plane vs scalar)
    cfg = LargeNConfig(name="storm_diff", n_devices=16,
                       arrival="preempt_storm", duration=20.0)
    drop = ("hp_alloc_us_mean", "hp_alloc_us_p99", "hp_preempt_us_mean",
            "lp_alloc_us_mean", "lp_alloc_us_p99", "wall_s")
    a = {k: v for k, v in run_large_n(cfg).items() if k not in drop}
    b = {k: v for k, v in
         run_large_n(cfg, preemption_plane=False).items() if k not in drop}
    if a != b:
        raise RuntimeError(
            f"preempt_storm decisions diverged between the plane and the "
            f"scalar loop: {a} != {b}")
    return rows


# --------------------------------------------------------------------- #
# Policy-registry sweep: every registered discipline must complete a    #
# small scenario (CI smoke gate for the unified SchedulingPolicy API)   #
# --------------------------------------------------------------------- #
def bench_policy_sweep(n_frames: int = 60) -> list[Row]:
    """Run one reduced scenario through EVERY entry in the policy registry,
    failing hard (non-zero exit) if any policy cannot complete it."""
    rows: list[Row] = []
    for name in registered_policies():
        cfg = ScenarioConfig(f"sweep_{name}", "uniform", name, True,
                             n_frames=n_frames, seed=3)
        t0 = time.perf_counter()
        m = run_scenario(cfg)
        wall = time.perf_counter() - t0
        if m.frames_total != n_frames * cfg.n_devices or m.hp_generated == 0:
            raise RuntimeError(
                f"policy {name!r} did not complete the sweep scenario "
                f"(frames={m.frames_total}, hp_generated={m.hp_generated})"
            )
        s = m.summary()
        rows.append(("policy_sweep", name, "frame_completion_pct",
                     s["frame_completion_pct"]))
        rows.append(("policy_sweep", name, "hp_completion_pct",
                     s["hp_completion_pct"]))
        rows.append(("policy_sweep", name, "lp_completion_pct",
                     s["lp_completion_pct"]))
        rows.append(("policy_sweep", name, "wall_s", wall))
    return rows


# --------------------------------------------------------------------- #
# Heterogeneous workloads (core/profiles.py): mixed-model scenarios     #
# --------------------------------------------------------------------- #
def bench_mixed_workload(n_frames: int = 60) -> list[Row]:
    """Run every mixed-model scenario (three profiles with distinct
    benchmark tables, transfer sizes and deadlines) end-to-end, plus a
    mixed large-N arrival stream; hard-fails if per-type accounting is
    missing (the profile layer would have silently fallen back to one
    model)."""
    rows: list[Row] = []
    for name, cfg in sorted(MIXED_SCENARIOS.items()):
        t0 = time.perf_counter()
        s = run_scenario(replace(cfg, n_frames=n_frames)).summary()
        wall = time.perf_counter() - t0
        types = s.get("task_types")
        if not types or len(types) < 2:
            raise RuntimeError(
                f"mixed scenario {name!r} did not produce per-type "
                f"accounting (task_types={types})"
            )
        rows.append(("mixed_workload", name, "frame_completion_pct",
                     s["frame_completion_pct"]))
        rows.append(("mixed_workload", name, "lp_completion_pct",
                     s["lp_completion_pct"]))
        for t, counts in types.items():
            done = counts.get("lp_completed", 0)
            alloc = counts.get("lp_allocated", 0)
            rows.append(("mixed_workload", name, f"lp_completed[{t}]",
                         float(done)))
            rows.append(("mixed_workload", name, f"lp_allocated[{t}]",
                         float(alloc)))
        rows.append(("mixed_workload", name, "wall_s", wall))

    cfg = LargeNConfig(name="mixed_large_n", n_devices=16, duration=20.0,
                       workload="mixed_edge")
    s = run_large_n(cfg, batch_window=0.25)
    for k in ("hp_admitted", "lp_allocated", "lp_failed",
              "lp_alloc_us_mean", "wall_s"):
        rows.append(("mixed_workload", cfg.name, k, float(s[k])))
    return rows


# --------------------------------------------------------------------- #
# Large-N scenario suite end-to-end                                     #
# --------------------------------------------------------------------- #
def bench_large_n(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    dur = 20.0 if quick else 120.0
    sizes = (16, 64, 256) if quick else LARGE_N_TIERS

    base = LargeNConfig(name="poisson", duration=dur)
    for cfg in sweep_devices(base, sizes):
        if cfg.n_devices >= 1024:            # 1024-dev tier: shorter stream,
            cfg = replace(cfg, duration=min(cfg.duration, 30.0))  # same rate
        s = run_large_n(cfg, batch_window=0.25)
        for k in ("hp_alloc_us_mean", "lp_alloc_us_mean", "lp_alloc_us_p99",
                  "hp_admitted", "lp_allocated", "preemptions", "wall_s"):
            rows.append(("large_n", cfg.name, k, float(s[k])))

    for fam in ("bursty", "adversarial"):
        cfg = LargeNConfig(name=fam, arrival=fam, n_devices=64,
                           duration=dur if fam == "adversarial" else dur / 2)
        s = run_large_n(cfg, batch_window=0.25)
        for k in ("hp_alloc_us_mean", "lp_alloc_us_mean", "wall_s"):
            rows.append(("large_n", cfg.name, k, float(s[k])))

    # HP:LP mix sweep at 64 devices
    for cfg in sweep_mix(LargeNConfig(name="mix", n_devices=64,
                                      duration=dur / 2),
                         (0.0, 0.5, 1.0) if quick else (0.0, 0.25, 0.5, 0.75, 1.0)):
        s = run_large_n(cfg, batch_window=0.25)
        rows.append(("large_n", cfg.name, "lp_alloc_us_mean",
                     float(s["lp_alloc_us_mean"])))
        rows.append(("large_n", cfg.name, "lp_allocated", float(s["lp_allocated"])))
    return rows


def bench_all(quick: bool = False) -> list[Row]:
    import gc

    rows: list[Row] = []
    rows += bench_policy_sweep()   # hard-fails if any registry entry breaks
    gc.collect()                   # isolate benches from each other's garbage
    rows += bench_mixed_workload(40 if quick else 80)  # hard-fails untyped
    gc.collect()
    rows += bench_scheduler_scaling()
    gc.collect()
    if quick:
        rows += bench_calendar_speedup(n_devices=16, n_tasks=1000, probes=15)
        gc.collect()
        rows += bench_probe_plane(probes=20)
    else:
        rows += bench_calendar_speedup()
        gc.collect()
        rows += bench_calendar_speedup(n_devices=256)
        gc.collect()
        rows += bench_probe_plane()
    gc.collect()
    rows += bench_batch_admission(16 if quick else 64, 60 if quick else 200)
    gc.collect()
    rows += bench_preemption(quick)  # hard-fails on plane/scalar divergence
    gc.collect()
    rows += bench_large_n(quick)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized workloads (seconds instead of minutes)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as machine-readable JSON")
    args = ap.parse_args()
    t0 = time.time()
    rows = bench_all(quick=args.quick)
    print("figure,scenario,metric,value")
    for fig, scen, metric, value in rows:
        print(f"{fig},{scen},{metric},{value:.3f}")
    wall = time.time() - t0
    print(f"# total scheduler_micro time: {wall:.1f}s")
    if args.json:
        doc = {
            "meta": {
                "benchmark": "scheduler_micro",
                "quick": args.quick,
                "python": platform.python_version(),
                "machine": platform.machine(),
                "total_wall_s": round(wall, 1),
                "pr3_baseline_us": PR3_BASELINE_US,
            },
            "rows": [
                {"bench": f, "config": c, "metric": m, "value": round(v, 3)}
                for f, c, m, v in rows
            ],
        }
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {len(doc['rows'])} rows to {args.json}")


if __name__ == "__main__":
    main()
