"""The vectorized preemption plane must be bit-identical to the scalar
eviction loop (DESIGN.md §12).

Three layers of evidence:

* a seeded differential fuzz suite: identical preemption-heavy workloads
  (saturated devices, link jams that displace windows mid-loop, duplicate
  deadlines for tie-breaks, partially-failed request sets for the
  ``weakest_set`` health column) run through ``preemption_plane=True`` and
  ``False``; every decision, metric and final calendar must match;
* unit tests of the `_LPMirror` sync contract (insertion order, re-reserve
  moves to the end, truncate/gc/compaction);
* unit tests of the `_HPWindowGrid` refit: after every eviction its answer
  must equal a fresh ``dev.fits`` probe.

Set ``REPRO_FUZZ_SEEDS=<k>`` to multiply the fuzz seed counts by ``k``
(CI deep-fuzz; tier-1 defaults unchanged at ``k=1``).
"""
import os
import random

import numpy as np
import pytest

from repro.core.calendar import NetworkState, _LPMirror
from repro.core.network import NetworkConfig
from repro.core.scheduler import PreemptionAwareScheduler, _HPWindowGrid
from repro.core.task import (
    LowPriorityRequest,
    Priority,
    Task,
    TaskState,
    reset_id_counters,
)
from repro.core.victims import rank_victims, select_victim

#: Seed-count multiplier (REPRO_FUZZ_SEEDS env var; default x1 = tier-1).
FUZZ_SCALE = max(1, int(os.environ.get("REPRO_FUZZ_SEEDS", "1") or "1"))


def lp_task(dev=0, deadline=30.0, frame=0):
    return Task(priority=Priority.LOW, source_device=dev, deadline=deadline,
                frame_id=frame)


# --------------------------------------------------------------------- #
# Differential fuzz: plane vs scalar over identical workloads           #
# --------------------------------------------------------------------- #
def _build(seed: int, policy: str, plane: bool):
    reset_id_counters()
    rng = random.Random(seed)
    net = NetworkConfig()
    st = NetworkState(4)
    sched = PreemptionAwareScheduler(st, net, preemption=True,
                                     victim_policy=policy,
                                     preemption_plane=plane)
    # preload LP reservations through request sets with mixed health;
    # duplicate deadlines on purpose (tie-breaks must match min()'s)
    for i in range(60):
        req = LowPriorityRequest(source_device=rng.randrange(4),
                                 deadline=rng.choice([20.0, 40.0, 40.0, 80.0]),
                                 frame_id=i, n_tasks=rng.randrange(1, 4))
        req.make_tasks()
        sched._requests[req.request_id] = req
        for t in req.tasks:
            if rng.random() < 0.3:
                t.state = TaskState.FAILED      # weakens the set
                continue
            t1 = rng.uniform(0.0, 30.0)
            t.state = TaskState.ALLOCATED
            st.devices[rng.randrange(4)].reserve(
                t1, t1 + rng.uniform(0.3, 20.0), rng.choice([2, 2, 4]), t)
    # a link jam near t=0 so preempt messages displace the window mid-loop
    st.link.reserve(0.05, 0.4, "jam")
    return st, sched


def _run(seed: int, policy: str, plane: bool):
    st, sched = _build(seed, policy, plane)
    rng = random.Random(seed + 999)
    log = []
    now = 0.0
    for i in range(40):
        now += rng.uniform(0.0, 1.5)
        task = Task(priority=Priority.HIGH, source_device=rng.randrange(4),
                    deadline=now + rng.choice([1.2, 2.0, 5.0]),
                    frame_id=1000 + i, task_id=100000 + i)
        res = sched.allocate_high_priority(task, now)
        log.append((
            res.success,
            tuple(v.task_id for v in res.preempted),
            tuple(v.state for v in res.preempted),
            tuple((a.task.task_id, a.device, a.t_start, a.t_end, a.cores)
                  for a in res.reallocations),
            None if res.allocation is None
            else (res.allocation.device, res.allocation.t_start),
        ))
    m = sched.metrics
    log.append(("metrics", m.preemptions, dict(m.preempted_by_cores),
                m.realloc_success, m.realloc_failure))
    cal = [sorted((r.t1, r.t2, r.amount, str(r.tag))
                  for r in d.reservations()) for d in st.devices]
    cal.append(sorted((r.t1, r.t2, str(r.tag))
                      for r in st.link.reservations()))
    return log, cal


@pytest.mark.parametrize("policy", ["farthest_deadline", "weakest_set"])
@pytest.mark.parametrize("seed", range(12 * FUZZ_SCALE))
def test_plane_matches_scalar_fuzz(policy, seed):
    plane_log, plane_cal = _run(seed, policy, plane=True)
    scalar_log, scalar_cal = _run(seed, policy, plane=False)
    assert plane_log == scalar_log
    assert plane_cal == scalar_cal


def test_plane_flag_respected():
    st = NetworkState(2)
    sched = PreemptionAwareScheduler(st, NetworkConfig())
    assert sched._preempt_plane
    sched_off = PreemptionAwareScheduler(st, NetworkConfig(),
                                         preemption_plane=False)
    assert not sched_off._preempt_plane
    # reference calendars have no mirror -> the plane silently disables
    from repro.core.calendar_reference import ReferenceNetworkState
    ref = PreemptionAwareScheduler(ReferenceNetworkState(2), NetworkConfig())
    assert not ref._preempt_plane


# --------------------------------------------------------------------- #
# _LPMirror sync contract                                               #
# --------------------------------------------------------------------- #
def test_mirror_matches_reservation_dict_order():
    st = NetworkState(1)
    dev = st.devices[0]
    tasks = [lp_task(frame=i) for i in range(5)]
    for i, t in enumerate(tasks):
        dev.reserve(float(i), float(i) + 10.0, 2, t)
    dev.reserve(0.0, 50.0, 1, "not-a-task")            # never mirrored
    hp = Task(priority=Priority.HIGH, source_device=0, deadline=9.0,
              frame_id=99)
    dev.reserve(0.0, 1.0, 1, hp)                       # HP: never mirrored
    mir = dev.lp_mirror()

    def live_rows():
        return [mir.tasks[i].task_id
                for i in range(mir.m) if mir.alive[i]]

    def dict_lp_order():
        return [r.tag.task_id for r in dev.reservations()
                if _LPMirror.tracks(r.tag)]

    assert live_rows() == dict_lp_order()
    # release drops the row, preserving the others' order
    dev.release(tasks[2])
    assert live_rows() == dict_lp_order()
    # re-reserve moves the tag to the END, exactly like the dict
    dev.reserve(2.5, 12.5, 4, tasks[1])
    assert live_rows() == dict_lp_order()
    assert live_rows()[-1] == tasks[1].task_id
    # truncate keeps the row but updates its t2 column
    dev.truncate(tasks[3], 5.0)
    row = mir.rows[tasks[3].task_id]
    assert mir.t2[row] == 5.0
    # truncate-to-start removes entirely
    dev.truncate(tasks[4], 4.0 - 1e-6)
    assert tasks[4].task_id not in mir.rows
    assert live_rows() == dict_lp_order()
    # gc retires expired rows (t2 <= now)
    dev.gc(6.0)
    assert live_rows() == dict_lp_order()


def test_mirror_backfill_equals_incremental():
    """A mirror built late (backfill) must equal one maintained from the
    start by the mutation hooks."""
    def populate(dev):
        ts = [lp_task(frame=i, deadline=20.0 + i) for i in range(6)]
        for i, t in enumerate(ts):
            dev.reserve(float(i), float(i) + 8.0, 2, t)
        dev.release(ts[0])
        dev.reserve(1.5, 9.5, 4, ts[2])     # re-reserve -> moves to end
        dev.truncate(ts[3], 4.0)
        return ts

    st_a = NetworkState(1)
    st_a.devices[0].lp_mirror()             # built BEFORE any reservation
    populate(st_a.devices[0])
    reset_id_counters()
    st_b = NetworkState(1)
    populate(st_b.devices[0])               # mirror built only now

    def rows(dev):
        mir = dev.lp_mirror()
        return [(mir.tasks[i].frame_id, mir.t1[i], mir.t2[i],
                 int(mir.amount[i]))
                for i in range(mir.m) if mir.alive[i]]

    reset_id_counters()
    assert rows(st_a.devices[0]) == rows(st_b.devices[0])


def test_mirror_compaction_preserves_order():
    st = NetworkState(1)
    dev = st.devices[0]
    mir = dev.lp_mirror()
    tasks = [lp_task(frame=i) for i in range(120)]
    for i, t in enumerate(tasks):
        dev.reserve(float(i), float(i) + 5.0, 2, t)
    for t in tasks[:80]:                    # kill enough to trigger compact
        dev.release(t)
    mir2 = dev.lp_mirror()                  # accessor runs compaction
    assert mir2 is mir
    assert mir.dead == 0 and mir.m == 40
    assert [t.frame_id for t in mir.tasks] == list(range(80, 120))
    assert bool(mir.alive[:40].all())


# --------------------------------------------------------------------- #
# _HPWindowGrid refit vs dev.fits                                       #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(8 * FUZZ_SCALE))
def test_window_grid_matches_fits_after_evictions(seed):
    rng = random.Random(seed)
    st = NetworkState(1)
    dev = st.devices[0]
    tasks = []
    for i in range(40):
        t = lp_task(frame=i)
        t1 = rng.uniform(0.0, 8.0)
        dev.reserve(t1, t1 + rng.uniform(0.2, 4.0), rng.choice([1, 2, 4]), t)
        tasks.append(t)
    mir = dev.lp_mirror()
    m = mir.m
    t1, t2 = 2.0, 4.5
    grid = _HPWindowGrid(dev, t1, t2 + 3.0, mir.t1[:m], mir.t2[:m],
                         mir.alive[:m])
    order = list(range(40))
    rng.shuffle(order)
    for step, k in enumerate(order[:25]):
        row = mir.rows[tasks[k].task_id]
        vt1, vt2 = float(mir.t1[row]), float(mir.t2[row])
        vamt = int(mir.amount[row])
        dev.release(tasks[k])
        grid.evict(vt1, vt2, vamt)
        # probe several windows inside the covered horizon, incl. drifted
        for w1, w2 in ((t1, t2), (t1 + 0.3 * step % 1.0, t2 + 0.4),
                       (t1 + 1.0, t2 + 2.0)):
            for cores in (1, 2, 4):
                got = grid.fits_window(w1, w2, cores)
                assert got is not None
                assert got == dev.fits(w1, w2, cores), (step, w1, w2, cores)
    # out-of-coverage probe reports None (caller must rebuild)
    assert grid.fits_window(t1, t2 + 4.0, 1) is None


# --------------------------------------------------------------------- #
# Shared victim helpers                                                 #
# --------------------------------------------------------------------- #
def test_rank_victims_matches_select_victim():
    rng = random.Random(3)
    for _ in range(50):
        n = rng.randrange(1, 8)
        tasks = [lp_task(frame=i, deadline=rng.choice([10.0, 20.0, 20.0, 30.0]))
                 for i in range(n)]
        healths = [rng.choice([0.25, 0.5, 1.0, 1.0]) for _ in range(n)]
        by_id = {t.task_id: h for t, h in zip(tasks, healths)}
        mask = np.ones(n, dtype=bool)
        dl = np.fromiter((t.deadline for t in tasks), np.float64, n)
        # farthest_deadline
        got = tasks[rank_victims(mask, dl)]
        want = select_victim(tasks, "farthest_deadline")
        assert got is want
        # weakest_set
        h = np.fromiter(healths, np.float64, n)
        got = tasks[rank_victims(mask, dl, h)]
        want = select_victim(tasks, "weakest_set",
                             set_health=lambda t: by_id[t.task_id])
        assert got is want
