"""Victim selection shared by the preemption mechanism and the baselines.

Every discipline that preempts — the paper's scheduler (§4) and the
workstealer baselines (§8 "rash" processor sharing) — ranks candidate
victims by the same two policies:

* ``farthest_deadline``  the paper's rule: evict the conflicting LP task
                         whose deadline is farthest away (it has the most
                         slack to be reallocated elsewhere).
* ``weakest_set``        the §8 future-work proposal: prefer the victim
                         whose request set is least likely to complete
                         anyway (fewest healthy siblings), tie-break by
                         farthest deadline.

Two equivalent forms live here so the scalar disciplines and the
vectorized preemption plane provably agree:

* :func:`victim_sort_key` / :func:`select_victim` — the scalar rule; a
  smaller key is a more preferred victim, and ``min()`` keeps the FIRST
  minimum in iteration order (dict insertion order for the calendars, the
  running-dict order for the workstealers).
* :func:`rank_victims` — the one-pass vectorized equivalent over stacked
  candidate columns.  ``np.argmin`` also returns the first minimum, so as
  long as rows are stored in the same iteration order the two forms pick
  bit-identical victims (tests/test_preemption_plane.py fuzzes this).
"""
from __future__ import annotations

from typing import Callable, Iterable, Optional

import numpy as np

from .task import Task, TaskState

#: Task states that count as "on track" for a request set's health (the
#: numerator of ``weakest_set``'s set-health fraction).
GOOD_STATES = (TaskState.COMPLETED, TaskState.ALLOCATED, TaskState.RUNNING)


def plan_shrink(victim: Task, profile, hp_t1: float, hp_t2: float,
                now: float, eps: float = 1e-9) -> Optional[float]:
    """Degrade-instead-of-evict (DESIGN.md §17): the new reservation end if
    this conflict victim can be shrunk in place, else None (fall back to
    eviction).

    A shrink downgrades the victim to the NEXT rung of its type's variant
    ladder at its CURRENT core count.  Ladder validation guarantees the
    rung's slot at the same cores is no longer than the previous rung's, so
    the downgraded footprint is a pure truncation of the existing
    reservation — it always fits, and applying it via the calendar's
    ``truncate`` keeps the preemption plane's LP mirror row in place (a
    re-reserve would append a new row behind the eviction loop's column
    views).  Viability rules:

    * the victim holds a future slot (``ALLOCATED``, start after ``now``) —
      a RUNNING victim's execution was sized by its admitted rung and
      cannot be resized mid-flight, so it falls back to eviction;
    * a deeper rung exists (ladder-free profiles never shrink);
    * the truncation strictly reduces the victim's footprint inside the
      contested HP window ``[hp_t1, hp_t2)`` — equal-length rungs (the
      ladder allows non-strict monotonicity) shrink nothing and must not
      stall the eviction loop.

    What this does NOT guarantee: that the freed tail alone makes the HP
    window fit — the loop re-checks and keeps selecting victims, so a
    shrunk victim may still be evicted later in the same admission.
    """
    if victim.state is not TaskState.ALLOCATED or victim.t_start <= now + eps:
        return None
    nxt = victim.variant + 1
    if nxt >= profile.n_variants:
        return None
    rung = profile.variant_profile(nxt)
    new_end = victim.t_start + rung.lp_slot_time(victim.cores)
    if new_end >= min(victim.t_end, hp_t2) - eps:
        return None                     # no strict footprint reduction
    if new_end > victim.deadline:
        return None                     # defensive; t_end <= deadline anyway
    return new_end


def victim_sort_key(
    task: Task, policy: str,
    set_health: Optional[Callable[[Task], float]] = None,
) -> tuple:
    """Scalar victim key: smaller = preferred victim (used with min())."""
    if policy == "weakest_set":
        health = set_health(task) if set_health is not None else 1.0
        return (health, -task.deadline)
    return (-task.deadline,)


def select_victim(
    tasks: Iterable[Task], policy: str = "farthest_deadline",
    set_health: Optional[Callable[[Task], float]] = None,
) -> Task:
    """Most-preferred victim; ties keep the FIRST candidate in iteration
    order (``min()`` semantics — the contract the vectorized ranking
    reproduces)."""
    return min(tasks, key=lambda t: victim_sort_key(t, policy, set_health))


def rank_victims(
    mask: np.ndarray, deadlines: np.ndarray,
    healths: Optional[np.ndarray] = None,
) -> int:
    """One-pass vectorized victim ranking over stacked candidate columns.

    ``mask`` selects the live conflicting rows (must be non-empty);
    ``deadlines`` is the per-row deadline column; ``healths`` the per-row
    set-health column for ``weakest_set`` (None = ``farthest_deadline``).
    Returns the row index of the victim, with exactly ``min()``'s
    first-tie semantics: among the healthiest-tie rows (if any), the
    farthest deadline wins, and remaining ties go to the LOWEST row index
    (np.argmin returns the first minimum).
    """
    key = np.where(mask, -deadlines, np.inf)
    if healths is not None:
        h = np.where(mask, healths, np.inf)
        key = np.where(h == h.min(), key, np.inf)
    return int(np.argmin(key))
