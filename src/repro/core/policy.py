"""Unified SchedulingPolicy API: one decision protocol + policy registry.

Every scheduling discipline the repo evaluates — the paper's
preemption-aware scheduler, the two workstealer baselines, and any future
discipline — implements the same small protocol and registers itself by
name, so the discrete-event simulation (``sim/experiment.py``) and the jax
serving engine (``serving/engine.py``) can drive *any* policy through one
shared admission/execution/completion loop (``PolicyDispatcher``) instead
of bespoke per-discipline code paths.

The protocol (DESIGN.md §9)
---------------------------
A policy answers admission questions with a :class:`Decision`:

* ``decide_hp(task, now)``            one high-priority task
* ``decide_lp(request, now)``         one low-priority request set
* ``decide_lp_batch(requests, now)``  a burst of LP requests (positional
                                      results; default: per-request loop)
* ``reallocate(task, now)``           re-place an externally preempted task

and is told about execution outcomes through structured events:

* ``on_preempt(task, now)``   the runtime stopped a running task
* ``on_complete(task, now)``  a task finished inside its reserved slot
* ``on_violate(task, now)``   a task overran its slot and was terminated
* ``finalize(now)``           end of run (drain queues, settle accounting)

Two execution styles coexist behind the protocol:

* **slot-based** (``drives_execution = False``): decisions carry
  ``Allocation`` records with reserved ``[t_start, t_end)`` windows and the
  dispatcher runs execution — either *simulated* (noisy runtimes, slot
  violations; the sim) or *exact-slot* (real compute fills the reserved
  slot; the serving engine).
* **policy-driven** (``drives_execution = True``): the policy owns its own
  execution model (the workstealers' processor sharing) and reports
  outcomes back through the dispatcher's accounting hooks
  (``lp_started`` / ``task_finished``), so metrics stay uniform across
  disciplines.

Registry
--------
``@register_policy("name")`` on a policy class makes it constructible via
``create_policy(name, n_devices=..., net=..., ...)``; ``ScenarioConfig``
and the serving engine resolve their ``algorithm`` / ``policy`` strings
through it, so a new discipline is a ~100-line plugin with zero edits to
the runtimes (see ``EDFOnlyPolicy`` below).
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from .calendar import NetworkState
from .metrics import Metrics
from .network import NetworkConfig
from .scheduler import (
    Allocation,
    HPResult,
    LinkSlotRegistry,
    LPResult,
    PreemptionAwareScheduler,
)
from .task import LowPriorityRequest, Priority, Task, TaskState


# ====================================================================== #
# Decision                                                               #
# ====================================================================== #
class DecisionStatus(enum.Enum):
    ADMITTED = "admitted"    # resources committed (possibly partially)
    DEFERRED = "deferred"    # queued; the policy will place the work later
    REJECTED = "rejected"    # nothing could be (or will be) placed


@dataclass
class Decision:
    """The unified outcome of any admission question.

    ``allocations`` carry committed placements (slot-based policies);
    ``failed`` the tasks that could not be placed; ``preempted`` the
    victims this decision evicted (the runtime must stop them);
    ``reallocations`` the victims' replacement slots.
    ``predicted_completion`` is the latest committed slot end, when known.
    """

    status: DecisionStatus
    allocations: list[Allocation] = field(default_factory=list)
    failed: list[Task] = field(default_factory=list)
    preempted: list[Task] = field(default_factory=list)
    reallocations: list[Allocation] = field(default_factory=list)
    predicted_completion: Optional[float] = None

    @property
    def admitted(self) -> bool:
        return self.status is DecisionStatus.ADMITTED

    @property
    def deferred(self) -> bool:
        return self.status is DecisionStatus.DEFERRED

    @property
    def rejected(self) -> bool:
        return self.status is DecisionStatus.REJECTED

    # -- compatibility shims over the scheduler's historic result types -- #
    @classmethod
    def from_hp_result(cls, res: HPResult) -> "Decision":
        return cls(
            status=DecisionStatus.ADMITTED if res.success
            else DecisionStatus.REJECTED,
            allocations=[res.allocation] if res.allocation is not None else [],
            preempted=list(res.preempted),
            reallocations=list(res.reallocations),
            predicted_completion=res.allocation.t_end
            if res.allocation is not None else None,
        )

    @classmethod
    def from_lp_result(cls, res: LPResult) -> "Decision":
        return cls(
            status=DecisionStatus.ADMITTED if res.allocations
            else DecisionStatus.REJECTED,
            allocations=list(res.allocations),
            failed=list(res.failed),
            predicted_completion=max((a.t_end for a in res.allocations),
                                     default=None),
        )


# ====================================================================== #
# Protocol                                                               #
# ====================================================================== #
class SchedulingPolicy:
    """Base class / protocol every scheduling discipline implements."""

    #: registry name (set by @register_policy)
    name: str = "?"
    #: True when the policy runs its own execution model (e.g. processor
    #: sharing) through the dispatcher's accounting hooks; False when the
    #: dispatcher executes the policy's reserved slots.
    drives_execution: bool = False

    def bind(self, host: "PolicyDispatcher") -> None:
        """Attach the runtime host (event queue, rng, metrics, accounting)."""
        self.host = host

    # -- decisions ----------------------------------------------------- #
    def decide_hp(self, task: Task, now: float) -> Decision:
        raise NotImplementedError

    def decide_lp(self, request: LowPriorityRequest, now: float) -> Decision:
        raise NotImplementedError

    def decide_lp_batch(
        self, requests: Sequence[LowPriorityRequest], now: float
    ) -> list[Decision]:
        return [self.decide_lp(r, now) for r in requests]

    def reallocate(self, task: Task, now: float) -> Decision:
        return Decision(DecisionStatus.REJECTED, failed=[task])

    # -- device churn (DESIGN.md §16) ----------------------------------- #
    def fail_device(self, idx: int, now: float) -> Decision:
        """Hard-fail device ``idx``; the returned Decision carries every
        orphaned task in ``preempted``, recovered LP orphans' replacement
        slots in ``reallocations``, and unrecoverable LP orphans in
        ``failed``.  Default: no-op — policies without a shared calendar
        view (the workstealing baselines own plain worker objects) have no
        device lifecycle, so churn cannot orphan their tasks."""
        return Decision(DecisionStatus.ADMITTED)

    def drain_device(self, idx: int, now: float) -> None:
        """Stop placing new work on device ``idx`` (no-op by default)."""

    def rejoin_device(self, idx: int, now: float) -> None:
        """Return device ``idx`` to the placement pool (no-op by default)."""

    # -- structured outcome events ------------------------------------- #
    def on_preempt(self, task: Task, now: float) -> None:
        """The runtime externally stopped ``task`` (before ``reallocate``)."""

    def on_complete(self, task: Task, now: float) -> None:
        """``task`` finished executing at ``now`` (release residual slot)."""

    def on_violate(self, task: Task, now: float) -> None:
        """``task`` overran its reserved slot and was terminated (§7.3)."""

    def finalize(self, now: float) -> None:
        """End of run: drain queues, settle outstanding accounting."""

    # -- execution support (slot-based policies) ------------------------ #
    def busy_fraction(self, alloc: Allocation) -> float:
        """Contending-core fraction over the slot (drives the sim's
        contention model); 0.0 when the policy has no occupancy view."""
        return 0.0


# ====================================================================== #
# Registry                                                               #
# ====================================================================== #
_REGISTRY: dict[str, Callable[..., SchedulingPolicy]] = {}


def register_policy(name: str):
    """Class decorator: make a policy constructible by name."""

    def deco(factory):
        if name in _REGISTRY:
            raise ValueError(f"policy {name!r} already registered")
        _REGISTRY[name] = factory
        factory.name = name
        return factory

    return deco


def registered_policies() -> tuple[str, ...]:
    """Sorted names of every registered policy."""
    return tuple(sorted(_REGISTRY))


def create_policy(name: str, **kwargs) -> SchedulingPolicy:
    """Instantiate a registered policy; unknown names list the options."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; registered policies: "
            + ", ".join(registered_policies())
        ) from None
    return factory(**kwargs)


# ====================================================================== #
# Dispatcher: the one shared admission/execution/completion loop         #
# ====================================================================== #
class DispatchClient:
    """Runtime-specific hooks; every method is an optional no-op default."""

    def exec_time(self, task: Task, busy_frac: float) -> float:
        """Actual (noisy) execution time in simulated mode."""
        raise NotImplementedError

    def on_start(self, task: Task) -> None:
        """Exact-slot mode: the slot began — run the real compute."""

    def on_hp_complete(self, task: Task) -> None:
        """An HP task completed in time (sim: spawn the frame's LP set)."""

    def on_lp_complete(self, task: Task) -> None:
        """An LP task completed in time."""

    def on_preempt(self, task: Task) -> None:
        """A decision evicted ``task`` (client-side victim bookkeeping)."""

    def on_admit_fail(self, task: Task) -> None:
        """A task was rejected at admission (or failed during one)."""

    def on_late(self, task: Task) -> None:
        """A task reached a terminal state past its deadline (late
        completion or slot violation) — the failure-side counterpart of the
        ``on_*_complete`` hooks, so open-ended runtimes can settle their
        per-request bookkeeping without a final sweep."""

    def on_device_lost(self, task: Task) -> None:
        """A device failure orphaned ``task``.  Fires before recovery is
        attempted: the task may still be re-placed elsewhere, re-admitted
        (HP), or settled FAILED — terminal bookkeeping arrives through the
        usual completion/failure hooks afterwards."""


class PolicyDispatcher:
    """Drives any registered policy over an event queue: admission calls,
    Decision processing, slot execution, and uniform metric accounting.

    Collapses what used to be three near-identical loops
    (``SchedulerBackend``, ``WorkstealerBackend`` accounting, and
    ``PreemptiveServingEngine``'s admit/settle/complete) into one.
    """

    def __init__(
        self,
        policy: SchedulingPolicy,
        q,                              # sim.events.EventQueue (duck-typed)
        net: NetworkConfig,
        metrics: Metrics,
        client: Optional[DispatchClient] = None,
        *,
        lp_batch_window: float = 0.0,
        exact_slots: bool = False,
        rng=None,
        exec_noise: bool = False,
        hp_noise_sigma: float = 0.0,
        lp_noise_sigma: float = 0.0,
    ) -> None:
        self.policy = policy
        self.q = q
        self.net = net
        self.metrics = metrics
        self.client = client if client is not None else DispatchClient()
        self.lp_batch_window = lp_batch_window
        self.exact_slots = exact_slots
        # Host-provided randomness/noise for execution-driving policies.
        self.rng = rng
        self.exec_noise = exec_noise
        self.hp_noise_sigma = hp_noise_sigma
        self.lp_noise_sigma = lp_noise_sigma
        self._exec_events: dict[Task, object] = {}
        self._via_preemption: set[Task] = set()
        self._lp_buffer: list[LowPriorityRequest] = []
        self._lp_flush_armed = False
        policy.bind(self)

    @property
    def now(self) -> float:
        return self.q.now

    # ------------------------------------------------------------------ #
    # Admission                                                          #
    # ------------------------------------------------------------------ #
    def submit_hp(self, task: Task) -> Decision:
        dec = self.policy.decide_hp(task, self.q.now)
        # Victims must be stopped whether or not the admission succeeded
        # (a failed HP admission may already have evicted LP tasks).
        self._apply_preemptions(dec)
        if dec.rejected:
            task.state = TaskState.FAILED
            self.metrics.hp_failed_alloc += 1
            self.metrics.count_type(task.task_type, "hp_failed_alloc")
            self.client.on_admit_fail(task)
        else:
            if dec.preempted:
                self._via_preemption.add(task)
            for alloc in dec.allocations:
                self._schedule_exec(alloc)
        # victims a policy re-placed must run even when the admission itself
        # failed (their replacement slots are already committed)
        for re in dec.reallocations:
            self._schedule_exec(re)
        return dec

    def submit_lp(self, request: LowPriorityRequest) -> Optional[Decision]:
        """Admit one LP request; with ``lp_batch_window > 0`` the request is
        buffered and admitted by the window's flush (returns None)."""
        if self.lp_batch_window <= 0.0:
            dec = self.policy.decide_lp(request, self.q.now)
            self._account_lp(dec)
            return dec
        self._lp_buffer.append(request)
        if not self._lp_flush_armed:
            self._lp_flush_armed = True
            self.q.push(self.q.now + self.lp_batch_window, self._flush_lp_batch)
        return None

    def submit_lp_batch(self, requests: Sequence[LowPriorityRequest]) -> list[Decision]:
        decs = self.policy.decide_lp_batch(requests, self.q.now)
        for dec in decs:
            self._account_lp(dec)
        return decs

    def _flush_lp_batch(self) -> None:
        self._lp_flush_armed = False
        batch, self._lp_buffer = self._lp_buffer, []
        if batch:
            self.submit_lp_batch(batch)

    def _apply_preemptions(self, dec: Decision) -> None:
        for victim in dec.preempted:
            ev = self._exec_events.pop(victim, None)
            if ev is not None:
                ev.cancel()
            self.client.on_preempt(victim)

    def _account_lp(self, dec: Decision) -> None:
        self.metrics.lp_failed_alloc += len(dec.failed)
        for task in dec.failed:
            task.state = TaskState.FAILED
            self.metrics.count_type(task.task_type, "lp_failed_alloc")
            self.client.on_admit_fail(task)
        for alloc in dec.allocations:
            self.lp_started(alloc.task, alloc.cores, alloc.offloaded)
            self._schedule_exec(alloc)

    # ------------------------------------------------------------------ #
    # Reallocation (external preemption -> new Decision)                 #
    # ------------------------------------------------------------------ #
    def reallocate(self, task: Task) -> Decision:
        """Stop + re-place a running task through the policy, arming the
        replacement slot when one is found."""
        ev = self._exec_events.pop(task, None)
        if ev is not None:
            ev.cancel()
        self.policy.on_preempt(task, self.q.now)
        dec = self.policy.reallocate(task, self.q.now)
        for alloc in dec.allocations:
            self._schedule_exec(alloc)
        for failed in dec.failed:
            self.client.on_admit_fail(failed)
        return dec

    # ------------------------------------------------------------------ #
    # Device churn (lifecycle events -> policy + client plumbing)        #
    # ------------------------------------------------------------------ #
    def device_lost(self, idx: int) -> Decision:
        """A device vanished: orphan its in-flight tasks and drive recovery.

        The policy's ``fail_device`` clears the calendar, cancels the
        orphans' pending link slots, and settles LP orphans through its
        reallocation path (ALLOCATED elsewhere or FAILED).  Here the
        orphans' pending exec events are cancelled (they describe compute
        on hardware that no longer exists), the client is notified per
        orphan, recovered slots are armed, and HP orphans are re-admitted
        immediately — ahead of the next admission window; a rejected
        re-admission settles through ``submit_hp``'s normal failure path
        (``hp_generated`` is counted at request creation, so re-submitting
        the same task keeps the terminal partition exact)."""
        dec = self.policy.fail_device(idx, self.q.now)
        hp_orphans: list[Task] = []
        for task in dec.preempted:          # every orphan, HP and LP
            ev = self._exec_events.pop(task, None)
            if ev is not None:
                ev.cancel()
            self.client.on_device_lost(task)
            if task.priority == Priority.HIGH:
                hp_orphans.append(task)
        for alloc in dec.reallocations:     # recovered LP orphans
            self._schedule_exec(alloc)
        for task in dec.failed:             # unrecoverable LP orphans
            self.client.on_admit_fail(task)
        for task in hp_orphans:
            sub = self.submit_hp(task)
            if not sub.rejected:
                self.metrics.orphans_recovered += 1
        return dec

    def device_drained(self, idx: int) -> None:
        self.policy.drain_device(idx, self.q.now)

    def device_rejoined(self, idx: int) -> None:
        self.policy.rejoin_device(idx, self.q.now)

    # ------------------------------------------------------------------ #
    # Slot execution                                                     #
    # ------------------------------------------------------------------ #
    def _schedule_exec(self, alloc: Allocation) -> None:
        task = alloc.task
        if self.exact_slots:
            self._exec_events[task] = self.q.push(
                alloc.t_start, lambda: self._start_exact(alloc))
            return

        def start() -> None:
            if task.state != TaskState.ALLOCATED:
                return                  # preempted before execution began
            task.state = TaskState.RUNNING
            actual = self.client.exec_time(task, self.policy.busy_fraction(alloc))
            finish = alloc.t_start + actual
            if finish > alloc.t_end:
                ev = self.q.push(alloc.t_end, lambda: self._violate(task))
            else:
                ev = self.q.push(finish, lambda: self._complete(task))
            self._exec_events[task] = ev

        self._exec_events[task] = self.q.push(alloc.t_start, start)

    def _complete(self, task: Task) -> None:
        now = self.q.now
        self._exec_events.pop(task, None)
        late = now > task.deadline + 1e-9
        self.policy.on_complete(task, now)   # frees the slot's remainder
        self.task_finished(task, late)

    def _violate(self, task: Task) -> None:
        """Task overran its reserved slot; the device terminates it (§7.3)."""
        self._exec_events.pop(task, None)
        task.state = TaskState.VIOLATED
        self.policy.on_violate(task, self.q.now)
        prefix = "hp" if task.priority == Priority.HIGH else "lp"
        self.metrics.count_type(task.task_type, f"{prefix}_failed_runtime")
        if task.priority == Priority.HIGH:
            self.metrics.hp_failed_runtime += 1
        else:
            self.metrics.lp_failed_runtime += 1
        self.client.on_late(task)

    def _start_exact(self, alloc: Allocation) -> None:
        task = alloc.task
        if task.state != TaskState.ALLOCATED:
            return                      # preempted before the slot began
        task.state = TaskState.RUNNING
        self.client.on_start(task)
        self._exec_events[task] = self.q.push(
            alloc.t_end, lambda: self._complete_exact(task))

    def _complete_exact(self, task: Task) -> None:
        if task.state != TaskState.RUNNING:
            return                      # preempted mid-slot
        now = self.q.now
        self._exec_events.pop(task, None)
        # a reserved slot may end past the deadline by its jitter padding —
        # judge lateness against the deadline, exactly like simulated mode
        late = now > task.deadline + 1e-9
        self.policy.on_complete(task, now)
        self.task_finished(task, late)

    # ------------------------------------------------------------------ #
    # Accounting hooks for execution-driving policies                    #
    # ------------------------------------------------------------------ #
    def lp_started(self, task: Task, cores: int, offloaded: bool) -> None:
        """An execution-driving policy started an LP task on ``cores``."""
        m = self.metrics
        m.lp_allocated += 1
        m.count_type(task.task_type, "lp_allocated")
        if task.variant > 0:
            # variant-ladder histogram (DESIGN.md §17): the rung the task
            # was admitted at — covers pre-degraded streaming admissions
            # and the scheduler's degrade-before-reject retries alike
            m.variant_admissions[task.variant] += 1
        bucket = (m.core_alloc_offloaded if offloaded
                  else m.core_alloc_local)
        bucket[cores] += 1
        if offloaded:
            m.lp_offloaded += 1

    def task_finished(self, task: Task, late: bool) -> None:
        """Uniform terminal-outcome accounting — the single path for both
        slot execution modes and execution-driving policies."""
        m = self.metrics
        task.state = TaskState.FAILED if late else TaskState.COMPLETED
        via_preemption = task in self._via_preemption
        # terminal: the membership test above is the set's last use, so an
        # open-ended streaming run doesn't retain every preempting HP task
        self._via_preemption.discard(task)
        prefix = "hp" if task.priority == Priority.HIGH else "lp"
        m.count_type(task.task_type,
                     f"{prefix}_{'failed_runtime' if late else 'completed'}")
        if task.priority == Priority.HIGH:
            if late:
                m.hp_failed_runtime += 1
                self.client.on_late(task)
            else:
                m.hp_completed += 1
                if via_preemption:
                    m.hp_completed_via_preemption += 1
                self.client.on_hp_complete(task)
        elif not late:
            m.lp_completed += 1
            # accuracy-weighted goodput numerator: the admitted rung's
            # benchmark accuracy (1.0 on every ladder-free path; the
            # summary key only appears when the ladder fired)
            m.lp_accuracy_completed += self.net.profile_for(task).accuracy
            if task.offloaded:
                m.lp_offloaded_completed += 1
            self.client.on_lp_complete(task)
        else:
            m.lp_failed_runtime += 1
            self.client.on_late(task)

    def finalize(self) -> None:
        self.policy.finalize(self.q.now)


# ====================================================================== #
# Registered policies                                                    #
# ====================================================================== #
class CalendarPolicy(SchedulingPolicy):
    """Base for slot-based policies backed by the time-slotted calendars."""

    def __init__(self, n_devices: int, net: NetworkConfig, *,
                 capacity: int = 4, metrics: Optional[Metrics] = None,
                 **_ignored) -> None:
        self.state = NetworkState(n_devices, capacity=capacity)
        self.net = net
        self.metrics = metrics if metrics is not None else Metrics()

    def on_complete(self, task: Task, now: float) -> None:
        self.state.devices[task.device].truncate(task, now)

    def on_violate(self, task: Task, now: float) -> None:
        self.state.devices[task.device].release(task)

    def busy_fraction(self, alloc: Allocation) -> float:
        dev = self.state.devices[alloc.device]
        busy = max(0, dev.max_usage(alloc.t_start, alloc.t_end) - alloc.cores)
        return busy / dev.capacity

    # -- device churn (DESIGN.md §16): generic calendar-backed handling - #
    def fail_device(self, idx: int, now: float) -> Decision:
        """Generic churn handling for calendar-backed policies: clear the
        device, cancel the orphans' still-pending link slots (when the
        policy keeps a link-slot registry), and route each LP orphan
        through the policy's own ``reallocate`` settle.  An orphan whose
        policy offers no reallocation path (the protocol default rejects
        without settling) is settled FAILED here — never stranded.  HP
        orphans come back PREEMPTED in ``preempted`` for the dispatcher's
        immediate re-admission."""
        orphans = self.state.fail_device(idx, now)
        links = getattr(self, "links", None)
        for task in orphans:
            if links is not None:
                links.cancel_pending(self.state.link, task.task_id, now)
            task.state = TaskState.PREEMPTED
        dec = Decision(DecisionStatus.ADMITTED, preempted=list(orphans))
        for task in orphans:
            if task.priority == Priority.HIGH:
                continue
            sub = self.reallocate(task, now)
            dec.reallocations.extend(sub.allocations)
            if task.state is TaskState.ALLOCATED:
                continue
            if task.state is not TaskState.FAILED:
                task.state = TaskState.FAILED
                self.metrics.realloc_failure += 1
            dec.failed.append(task)
        self.metrics.device_failures += 1
        self.metrics.orphans_created += len(orphans)
        self.metrics.orphans_recovered += len(dec.reallocations)
        return dec

    def drain_device(self, idx: int, now: float) -> None:
        self.state.drain_device(idx)
        self.metrics.device_drains += 1

    def rejoin_device(self, idx: int, now: float) -> None:
        self.state.rejoin_device(idx)
        self.metrics.device_rejoins += 1


@register_policy("scheduler")
class SchedulerPolicy(CalendarPolicy):
    """The paper's preemption-aware time-slotted scheduler (§4)."""

    def __init__(self, n_devices: int, net: NetworkConfig, *,
                 capacity: int = 4, preemption: bool = True,
                 victim_policy: str = "farthest_deadline",
                 metrics: Optional[Metrics] = None,
                 allow_offload: bool = True,
                 preemption_plane: bool = True,
                 degrade: bool = False, **_ignored) -> None:
        super().__init__(n_devices, net, capacity=capacity, metrics=metrics)
        self.sched = PreemptionAwareScheduler(
            self.state, net, preemption=preemption, metrics=self.metrics,
            victim_policy=victim_policy, allow_offload=allow_offload,
            preemption_plane=preemption_plane, degrade=degrade,
        )

    def decide_hp(self, task: Task, now: float) -> Decision:
        return Decision.from_hp_result(self.sched.allocate_high_priority(task, now))

    def decide_lp(self, request: LowPriorityRequest, now: float) -> Decision:
        return Decision.from_lp_result(self.sched.allocate_low_priority(request, now))

    def decide_lp_batch(
        self, requests: Sequence[LowPriorityRequest], now: float
    ) -> list[Decision]:
        return [Decision.from_lp_result(r)
                for r in self.sched.allocate_low_priority_batch(requests, now)]

    def reallocate(self, task: Task, now: float) -> Decision:
        alloc = self.sched.reallocate(task, now)
        if alloc is None:
            return Decision(DecisionStatus.REJECTED, failed=[task])
        return Decision(DecisionStatus.ADMITTED, allocations=[alloc],
                        predicted_completion=alloc.t_end)

    def fail_device(self, idx: int, now: float) -> Decision:
        # The scheduler's own churn pass: batch victim reallocation with
        # one shared placement context (cheaper than the generic per-orphan
        # path when a loaded device dies), identical settle semantics.
        orphans, reallocs = self.sched.fail_device(idx, now)
        return Decision(
            DecisionStatus.ADMITTED, preempted=orphans,
            reallocations=reallocs,
            failed=[t for t in orphans if t.state is TaskState.FAILED])

    def drain_device(self, idx: int, now: float) -> None:
        self.sched.drain_device(idx, now)

    def rejoin_device(self, idx: int, now: float) -> None:
        self.sched.rejoin_device(idx, now)


@register_policy("no_offload")
class NoOffloadPolicy(SchedulerPolicy):
    """The paper's scheduler with stage-3 offloading disabled: LP tasks may
    only run on their source device (quantifies what the shared network
    buys).  HP admission and preemption are unchanged."""

    def __init__(self, n_devices: int, net: NetworkConfig, **kwargs) -> None:
        kwargs.pop("allow_offload", None)
        super().__init__(n_devices, net, allow_offload=False, **kwargs)


@register_policy("edf_only")
class EDFOnlyPolicy(CalendarPolicy):
    """Greedy earliest-deadline-first baseline (~100-line plugin demo).

    Every task is committed at the earliest feasible calendar slot at
    decision time — source device first, otherwise the device with the
    earliest start after one input transfer.  Minimum core config only, no
    preemption, no §4 time-point sweep, no core upgrades; batches admit in
    deadline order.  What it shows: admission-controlled EDF without the
    paper's preemption/upgrade machinery.
    """

    def __init__(self, *args, **kwargs) -> None:
        super().__init__(*args, **kwargs)
        # link reservations of each task's latest placement, so an external
        # reallocation can cancel the stale pending ones (shared helper —
        # same bookkeeping PreemptionAwareScheduler applies to its victims).
        self.links = LinkSlotRegistry()

    def decide_hp(self, task: Task, now: float) -> Decision:
        net, link = self.net, self.state.link
        prof = net.profile(task.task_type)
        self.state.gc(now)
        self.links.prune(now)
        dev = self.state.devices[task.source_device]
        if not dev.is_up:
            # HP runs on its (DRAINING/DOWN) home device only: reject.
            return Decision(DecisionStatus.REJECTED, failed=[task])
        msg_dur = net.slot(net.msg.hp_alloc)
        msg_t1 = link.earliest_slot(msg_dur, now)
        arrival = msg_t1 + msg_dur
        t1 = dev.earliest_fit(prof.hp_slot_time, arrival, 1)
        if t1 + prof.hp_exec > task.deadline:
            return Decision(DecisionStatus.REJECTED, failed=[task])
        t2 = t1 + prof.hp_slot_time
        slots = [link.reserve(msg_t1, msg_t1 + msg_dur,
                              ("hp_alloc", task.task_id))]
        dev.reserve(t1, t2, 1, task)
        upd_dur = net.slot(prof.output_bytes)
        slots.append(link.reserve_earliest(upd_dur, t2,
                                           ("update", task.task_id)))
        self.links.record(task.task_id, slots)
        task.state = TaskState.ALLOCATED
        task.device, task.cores = task.source_device, 1
        task.t_start, task.t_end, task.offloaded = t1, t2, False
        alloc = Allocation(task, task.source_device, t1, t2, 1, False)
        return Decision(DecisionStatus.ADMITTED, allocations=[alloc],
                        predicted_completion=t2)

    def _place_lp(self, task: Task, now: float, deadline: float) -> Optional[Allocation]:
        net, link = self.net, self.state.link
        prof = net.profile_for(task)            # the task's ladder rung
        cores = prof.core_options[0]
        proc = prof.lp_slot_time(cores)
        msg_dur = net.slot(net.msg.lp_alloc)
        msg_t1 = link.earliest_slot(msg_dur, now)
        arrival = msg_t1 + msg_dur
        sdev = self.state.devices[task.source_device]
        best_dev, best_t1, offloaded = (
            sdev,
            sdev.earliest_fit(proc, arrival, cores) if sdev.is_up else math.inf,
            False)
        xfer_dur = net.slot(prof.input_bytes)
        xfer_t1 = link.earliest_slot(xfer_dur, arrival)
        t1_off = xfer_t1 + xfer_dur
        for d in self.state.devices:
            if d is sdev or not d.is_up:
                continue
            t1 = d.earliest_fit(proc, t1_off, cores)
            if t1 < best_t1:
                best_dev, best_t1, offloaded = d, t1, True
        if best_t1 + proc > deadline:
            return None
        t1, t2 = best_t1, best_t1 + proc
        slots = [link.reserve(msg_t1, msg_t1 + msg_dur,
                              ("lp_alloc", task.task_id))]
        if offloaded:
            slots.append(link.reserve(xfer_t1, xfer_t1 + xfer_dur,
                                      ("xfer", task.task_id)))
        best_dev.reserve(t1, t2, cores, task)
        upd_dur = net.slot(prof.output_bytes)
        slots.append(link.reserve_earliest(upd_dur, t2,
                                           ("update", task.task_id)))
        self.links.record(task.task_id, slots)
        task.state = TaskState.ALLOCATED
        task.device, task.cores = best_dev.device, cores
        task.t_start, task.t_end, task.offloaded = t1, t2, offloaded
        return Allocation(task, best_dev.device, t1, t2, cores, offloaded)

    def decide_lp(self, request: LowPriorityRequest, now: float) -> Decision:
        return self.decide_lp_batch([request], now)[0]

    def decide_lp_batch(
        self, requests: Sequence[LowPriorityRequest], now: float
    ) -> list[Decision]:
        self.state.gc(now)
        self.links.prune(now)
        decs = [Decision(DecisionStatus.REJECTED) for _ in requests]
        pool = [(req.deadline, i, ridx, task)
                for ridx, req in enumerate(requests)
                for i, task in enumerate(req.tasks)
                if task.state == TaskState.PENDING]
        pool.sort(key=lambda item: (item[0], item[2], item[1]))
        for deadline, _, ridx, task in pool:
            alloc = self._place_lp(task, now, deadline)
            if alloc is None:
                task.state = TaskState.FAILED
                decs[ridx].failed.append(task)
            else:
                decs[ridx].allocations.append(alloc)
                decs[ridx].status = DecisionStatus.ADMITTED
        return decs

    def reallocate(self, task: Task, now: float) -> Decision:
        # Tear down the previous placement first (same hygiene as the
        # scheduler): release the device slot, cancel pending link slots.
        if task.device is not None:
            self.state.devices[task.device].release(task)
        self.links.cancel_pending(self.state.link, task.task_id, now)
        alloc = self._place_lp(task, now, task.deadline)
        if alloc is None:
            task.state = TaskState.FAILED
            self.metrics.realloc_failure += 1
            return Decision(DecisionStatus.REJECTED, failed=[task])
        self.metrics.realloc_success += 1
        return Decision(DecisionStatus.ADMITTED, allocations=[alloc],
                        predicted_completion=alloc.t_end)


# Workstealer baselines register themselves on import (kept in their own
# module: they bring a processor-sharing execution model with them).
from . import workstealer as _workstealer  # noqa: E402,F401  (registration)
# The offline optimal-placement oracle (quality reference, DESIGN.md §13).
from . import oracle as _oracle  # noqa: E402,F401  (registration)
