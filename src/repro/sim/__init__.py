from .events import EventQueue
from .traces import TraceConfig, generate_trace, potential_counts
from .experiment import ScenarioConfig, run_scenario, SCENARIOS
from .scenarios import (
    LargeNConfig,
    generate_arrivals,
    run_large_n,
    sweep_devices,
    sweep_mix,
)

__all__ = [
    "EventQueue",
    "TraceConfig",
    "generate_trace",
    "potential_counts",
    "ScenarioConfig",
    "run_scenario",
    "SCENARIOS",
    "LargeNConfig",
    "generate_arrivals",
    "run_large_n",
    "sweep_devices",
    "sweep_mix",
]
