"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434].

60L d_model=5120 128H (GQA kv=128) d_ff=1536 (per routed expert)
vocab=102400.  First layer dense (d_ff=12288 per the V2 paper).
"""
from __future__ import annotations

from dataclasses import replace

from ..models.config import LayerDef, MLAConfig, ModelConfig, MoEConfig, StageDef

_DENSE_FF = 12288      # V2 paper value for the dense first layer

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=_DENSE_FF,
    vocab_size=102400,
    head_dim=192,
    stages=(
        StageDef((LayerDef("mla", "dense"),), 1),
        StageDef((LayerDef("mla", "moe"),), 59),
    ),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
                  router="softmax"),
    source="arXiv:2405.04434",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        head_dim=48, d_ff=256, vocab_size=512,
        stages=(
            StageDef((LayerDef("mla", "dense"),), 1),
            StageDef((LayerDef("mla", "moe"),), 1),
        ),
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0, rope_head_dim=16,
                      nope_head_dim=32, v_head_dim=32),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=64, n_shared=2,
                      router="softmax"),
    )
