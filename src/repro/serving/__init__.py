"""Serving layer: the one-shot jax engine and the streaming engine.

Exports resolve lazily (PEP 562) so jax-free consumers — the streaming
engine, the open-ended trace generators, ``benchmarks/soak.py`` — can
``import repro.serving.stream`` without paying (or requiring) the jax
import that ``engine``/``cost_model`` pull in.
"""
from importlib import import_module

_LAZY = {
    "CostModel": ".cost_model",
    "PhaseCost": ".cost_model",
    "analytic_cost_model": ".cost_model",
    "measure_cost_model": ".cost_model",
    "PreemptiveServingEngine": ".engine",
    "ServeRequest": ".engine",
    "engine_network_config": ".engine",
    "StreamingEngine": ".stream",
    "StreamRequest": ".stream",
    "StreamArrival": ".stream",
    "Backpressure": ".stream",
    "AdmissionQueue": ".stream",
    "validate_submission": ".stream",
    "register_shed_policy": ".stream",
    "create_shed_policy": ".stream",
    "registered_shed_policies": ".stream",
}

__all__ = sorted(_LAZY)


def __getattr__(name: str):
    try:
        module = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    return getattr(import_module(module, __name__), name)


def __dir__():
    return __all__
