"""The unified SchedulingPolicy API: registry, Decision protocol,
config validation, and reallocate() outcomes (DESIGN.md §9)."""
import pytest

from repro.core.calendar import NetworkState
from repro.core.metrics import Metrics
from repro.core.network import NetworkConfig
from repro.core.policy import (
    Decision,
    DecisionStatus,
    PolicyDispatcher,
    SchedulerPolicy,
    SchedulingPolicy,
    create_policy,
    register_policy,
    registered_policies,
)
from repro.core.scheduler import HPResult, LPResult
from repro.core.task import LowPriorityRequest, Priority, Task, TaskState
from repro.sim import ScenarioConfig, run_scenario
from repro.sim.events import EventQueue


def lp_request(dev=0, deadline=30.0, n=1, frame=0):
    req = LowPriorityRequest(source_device=dev, deadline=deadline,
                             frame_id=frame, n_tasks=n)
    req.make_tasks()
    return req


# --------------------------------------------------------------------- #
# Registry                                                              #
# --------------------------------------------------------------------- #
def test_registry_contains_all_disciplines():
    names = registered_policies()
    for expected in ("scheduler", "central_ws", "decentral_ws",
                     "edf_only", "no_offload"):
        assert expected in names


def test_create_policy_unknown_name_lists_options():
    with pytest.raises(ValueError) as e:
        create_policy("bogus", n_devices=4, net=NetworkConfig())
    msg = str(e.value)
    assert "bogus" in msg
    for name in registered_policies():
        assert name in msg


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError):
        @register_policy("scheduler")
        class Clash(SchedulingPolicy):
            pass


def test_every_policy_constructs_with_uniform_kwargs():
    """The registry contract: one construction signature fits all."""
    for name in registered_policies():
        p = create_policy(name, n_devices=4, net=NetworkConfig(),
                          capacity=4, preemption=True,
                          victim_policy="farthest_deadline",
                          metrics=Metrics())
        assert p.name == name
        assert isinstance(p.drives_execution, bool)


# --------------------------------------------------------------------- #
# ScenarioConfig validation (early, named options)                      #
# --------------------------------------------------------------------- #
def test_scenario_config_rejects_unknown_algorithm():
    with pytest.raises(ValueError) as e:
        ScenarioConfig("x", "uniform", "not_a_policy", True)
    assert "scheduler" in str(e.value) and "central_ws" in str(e.value)


def test_scenario_config_rejects_unknown_trace():
    with pytest.raises(ValueError) as e:
        ScenarioConfig("x", "weighted_7", "scheduler", True)
    assert "weighted_1..weighted_4" in str(e.value)


def test_scenario_config_rejects_unknown_victim_policy():
    with pytest.raises(ValueError) as e:
        ScenarioConfig("x", "uniform", "scheduler", True,
                       victim_policy="strongest_set")
    assert "farthest_deadline" in str(e.value)


def test_scenario_config_accepts_every_registered_policy():
    for name in registered_policies():
        cfg = ScenarioConfig(name, "uniform", name, True)
        assert cfg.algorithm == name


# --------------------------------------------------------------------- #
# Decision shims                                                        #
# --------------------------------------------------------------------- #
def test_decision_from_hp_result():
    ok = Decision.from_hp_result(HPResult(False))
    assert ok.rejected and not ok.allocations
    t = Task(priority=Priority.LOW, source_device=0, deadline=9.0, frame_id=0)
    failed_with_victims = Decision.from_hp_result(
        HPResult(False, preempted=[t]))
    assert failed_with_victims.rejected and failed_with_victims.preempted == [t]


def test_decision_from_lp_result_partial_is_admitted():
    state = NetworkState(1)
    net = NetworkConfig()
    from repro.core.scheduler import PreemptionAwareScheduler
    sched = PreemptionAwareScheduler(state, net)
    state.devices[0].reserve(0.0, 1000.0, 2, "background")
    req = lp_request(dev=0, deadline=120.0, n=2)
    dec = Decision.from_lp_result(sched.allocate_low_priority(req, 0.0))
    assert dec.admitted                    # partial allocation still admits
    assert len(dec.allocations) == 1 and len(dec.failed) == 1
    assert dec.predicted_completion == dec.allocations[0].t_end


# --------------------------------------------------------------------- #
# reallocate() through the Decision API                                 #
# --------------------------------------------------------------------- #
def _allocated_policy(n_devices=2):
    """A SchedulerPolicy with one offloaded LP allocation in flight."""
    net = NetworkConfig()
    pol = create_policy("scheduler", n_devices=n_devices, net=net)
    # fill the source device so the request offloads (gets an xfer slot)
    pol.state.devices[0].reserve(0.0, 300.0, 4, "blocker")
    req = lp_request(dev=0, deadline=120.0)
    dec = pol.decide_lp(req, 0.0)
    assert dec.admitted and dec.allocations[0].offloaded
    return pol, req.tasks[0], dec.allocations[0]


def _externally_preempt(pol, task):
    # note: no device release — reallocate() itself must tear down the old
    # placement (device slot + pending link slots) in one call
    task.state = TaskState.PREEMPTED


def test_reallocate_success_returns_admitted_decision():
    pol, task, alloc = _allocated_policy()
    _externally_preempt(pol, task)
    dec = pol.reallocate(task, alloc.t_start + 1.0)
    assert dec.admitted and len(dec.allocations) == 1
    assert task.state == TaskState.ALLOCATED
    assert dec.predicted_completion == dec.allocations[0].t_end
    assert dec.allocations[0].t_end <= task.deadline
    assert pol.metrics.realloc_success == 1
    # the stale device reservation was released: exactly one device holds
    # the task (its replacement slot)
    assert sum(1 for d in pol.state.devices if d.get(task) is not None) == 1


def test_reallocate_past_deadline_is_rejected():
    pol, task, alloc = _allocated_policy()
    _externally_preempt(pol, task)
    dec = pol.reallocate(task, task.deadline + 5.0)
    assert dec.rejected and dec.failed == [task]
    assert task.state == TaskState.FAILED
    assert pol.metrics.realloc_failure == 1


def _jammed_policy():
    """A SchedulerPolicy whose admitted LP task has its input-transfer slot
    scheduled far in the future (link jammed), so the xfer is still PENDING
    when the task is externally preempted."""
    net = NetworkConfig()
    pol = create_policy("scheduler", n_devices=2, net=net)
    pol.state.devices[0].reserve(0.0, 300.0, 4, "blocker")   # force offload
    pol.state.link.reserve(0.003, 40.0, "jam")               # delay the xfer
    req = lp_request(dev=0, deadline=120.0)
    dec = pol.decide_lp(req, 0.0)
    assert dec.admitted and dec.allocations[0].offloaded
    task = req.tasks[0]
    xfer = next(s for s in pol.state.link.reservations()
                if s.tag == ("xfer", task.task_id))
    assert xfer.t1 >= 40.0                                   # still pending
    return pol, task, dec.allocations[0]


def test_failed_reallocation_releases_link_slots():
    """A failed reallocation must cancel the task's still-pending xfer/update
    link slots — leaving them reserved would permanently inflate link
    congestion with traffic for a task that will never run."""
    pol, task, alloc = _jammed_policy()
    tags = [s.tag for s in pol.state.link.reservations()]
    assert ("xfer", task.task_id) in tags
    assert ("update", task.task_id) in tags
    _externally_preempt(pol, task)
    # saturate device 1 too, so the reallocation cannot land anywhere
    pol.state.devices[1].reserve(0.0, 300.0, 4, "sat")
    dec = pol.reallocate(task, 1.0)
    assert dec.rejected
    tags = [s.tag for s in pol.state.link.reservations()]
    assert ("xfer", task.task_id) not in tags
    assert ("update", task.task_id) not in tags


def test_reallocate_success_replaces_link_slots():
    """A successful reallocation re-reserves fresh link slots and cancels
    every still-pending stale one (no leak on the shared link)."""
    pol, task, alloc = _jammed_policy()
    old = [s for s in pol.state.link.reservations()
           if isinstance(s.tag, tuple) and s.tag[1] == task.task_id]
    _externally_preempt(pol, task)
    now = 1.0
    dec = pol.reallocate(task, now)
    assert dec.admitted
    live = [s for s in pol.state.link.reservations()
            if isinstance(s.tag, tuple) and s.tag[1] == task.task_id]
    assert live                                       # fresh slots exist
    stale = [s for s in old if s.t2 > now]            # were still pending
    assert stale and not any(s in live for s in stale)


def test_edf_reallocate_releases_previous_placement():
    """The edf_only plugin applies the same reallocation hygiene as the
    scheduler: old device slot released, pending link slots cancelled."""
    net = NetworkConfig()
    pol = create_policy("edf_only", n_devices=2, net=net)
    req = lp_request(dev=0, deadline=120.0)
    dec = pol.decide_lp(req, 0.0)
    assert dec.admitted
    task = req.tasks[0]
    task.state = TaskState.PREEMPTED
    now = 1.0
    dec2 = pol.reallocate(task, now)
    assert dec2.admitted
    assert sum(1 for d in pol.state.devices if d.get(task) is not None) == 1
    pending_updates = [s for s in pol.state.link.reservations()
                       if s.tag == ("update", task.task_id) and s.t2 > now]
    assert len(pending_updates) == 1          # only the fresh placement's


def test_dispatcher_reallocate_arms_replacement_slot():
    """PolicyDispatcher.reallocate: stop + re-place + arm in one call."""
    q = EventQueue()
    net = NetworkConfig()
    metrics = Metrics()
    pol = create_policy("scheduler", n_devices=2, net=net, metrics=metrics)
    disp = PolicyDispatcher(pol, q, net, metrics)
    req = lp_request(dev=0, deadline=120.0)
    disp.submit_lp(req)
    task = req.tasks[0]
    assert task.state == TaskState.ALLOCATED
    preempt_seen = []
    pol.on_preempt = lambda t, now: preempt_seen.append(t)
    _externally_preempt(pol, task)
    dec = disp.reallocate(task)
    assert preempt_seen == [task]
    assert dec.admitted and task.state == TaskState.ALLOCATED


# --------------------------------------------------------------------- #
# New baselines behave as documented                                    #
# --------------------------------------------------------------------- #
def test_no_offload_never_offloads():
    cfg = ScenarioConfig("no_off", "weighted_4", "no_offload", True,
                         n_frames=120, seed=3)
    m = run_scenario(cfg)
    assert m.lp_offloaded == 0
    assert m.lp_allocated > 0                 # local admissions still happen
    assert m.hp_completed > 0


def test_edf_only_runs_and_never_preempts():
    cfg = ScenarioConfig("edf", "uniform", "edf_only", True,
                         n_frames=120, seed=3)
    m = run_scenario(cfg)
    assert m.preemptions == 0
    assert m.hp_completed > 0 and m.lp_completed > 0


def test_scheduler_beats_edf_only_on_hp():
    """The paper's discipline must dominate the greedy EDF baseline on
    HP completion under the same workload."""
    sched = run_scenario(ScenarioConfig("s", "uniform", "scheduler", True,
                                        n_frames=150, seed=5))
    edf = run_scenario(ScenarioConfig("e", "uniform", "edf_only", True,
                                      n_frames=150, seed=5))
    assert sched.pct(sched.hp_completed, sched.hp_generated) >= \
        edf.pct(edf.hp_completed, edf.hp_generated)


# --------------------------------------------------------------------- #
# Serving engine drives registered policies (no engine edits needed)    #
# --------------------------------------------------------------------- #
def test_serving_engine_rejects_execution_driving_policy():
    jax = pytest.importorskip("jax")
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.cost_model import CostModel, PhaseCost
    from repro.serving.engine import PreemptiveServingEngine

    cfg = get_smoke_config("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cost = CostModel()
    cost.prefill[1] = PhaseCost(0.05, 0.005)
    cost.decode[2] = PhaseCost(0.02, 0.002)
    cost.decode[4] = PhaseCost(0.014, 0.0014)
    with pytest.raises(ValueError) as e:
        PreemptiveServingEngine(cfg, params, cost, n_slices=2,
                                policy="central_ws")
    assert "slot-based" in str(e.value)
