"""Step factories: train_step / prefill_step / serve_step.

These are the functions the launcher jits with in/out shardings; they are
also used directly (unjitted or single-device jitted) by the smoke tests and
examples.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ModelConfig
from .optimizer import AdamWConfig, adamw_update, init_opt_state


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_size: int) -> jax.Array:
    """Mean next-token CE; positions with label < 0 are masked.  Padded
    vocab tail can never be a label (labels < vocab_size), so no extra
    masking of logits is needed for the loss."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(params, cfg: ModelConfig, batch: dict, *, remat: bool = True,
            moe_group_size: int = 256,
            unroll: int | bool = 1) -> tuple[jax.Array, dict]:
    logits, aux = M.forward(params, cfg, batch, remat=remat,
                            moe_group_size=moe_group_size, unroll=unroll)
    # For multimodal decoder-only archs the modality tokens are prepended;
    # only text positions carry labels.
    t_text = batch["labels"].shape[1]
    logits = logits[:, -t_text:, :]
    ce = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    return ce + aux, {"ce": ce, "aux": aux}


def make_train_step(
    cfg: ModelConfig,
    opt: Optional[AdamWConfig] = None,
    *,
    remat: bool = True,
    moe_group_size: int = 256,
    unroll: int | bool = 1,
) -> Callable:
    opt = opt or AdamWConfig()

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat=remat,
                              moe_group_size=moe_group_size, unroll=unroll),
            has_aux=True,
        )(params)
        params, opt_state, om = adamw_update(opt, params, grads, opt_state)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: int, *,
                      moe_group_size: int = 256,
                      unroll: int | bool = 1) -> Callable:
    def prefill_step(params, batch):
        logits, caches = M.prefill(params, cfg, batch, cache_len,
                                   moe_group_size=moe_group_size,
                                   unroll=unroll)
        next_token = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)
        return next_token.astype(jnp.int32), caches

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, moe_group_size: int = 256,
                    unroll: int | bool = 1) -> Callable:
    """ONE new token against the KV/state caches (the decode shapes)."""

    def serve_step(params, caches, token, pos):
        logits, caches = M.decode_step(params, cfg, caches, token, pos,
                                       moe_group_size=moe_group_size,
                                       unroll=unroll)
        next_token = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)
        return next_token.astype(jnp.int32)[:, None], caches

    return serve_step


def init_train_state(cfg: ModelConfig, key, opt: Optional[AdamWConfig] = None):
    opt = opt or AdamWConfig()
    params = M.init_params(cfg, key)
    return params, init_opt_state(opt, params)
