"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned family, one forward + one train step on CPU, asserting output
shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.shapes import InputShape
from repro.data import train_batches
from repro.models import model as M
from repro.training import make_train_step
from repro.training.optimizer import AdamWConfig, init_opt_state

SHAPE = InputShape("smoke", seq_len=16, global_batch=2, kind="train")


def _batch(cfg):
    return next(iter(train_batches(cfg, SHAPE)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in _batch(cfg).items()}
    logits, aux = M.forward(params, cfg, batch)
    t_total = batch["tokens"].shape[1]
    if cfg.modality_embed_dim and not cfg.is_encoder_decoder:
        t_total += batch["modality_emb"].shape[1]
    assert logits.shape == (2, t_total, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_opt_state(opt, params)
    step = jax.jit(make_train_step(cfg, opt))
    batch = _batch(cfg)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0.0
    # at least the embedding moved
    delta = float(jnp.abs(params2["embed"] - params["embed"]).max())
    assert delta > 0.0
    # no NaNs anywhere in the updated tree
    for leaf in jax.tree.leaves(params2):
        assert not bool(jnp.isnan(leaf).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full (dry-run) configs carry the exact assigned dimensions."""
    cfg = get_config(arch)
    expected = {
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "deepseek-v3-671b": (61, 7168, 128, 128, None, 129280),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "deepseek-v2-236b": (60, 5120, 128, 128, None, 102400),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }[arch]
    nl, d, h, kv, ff, vocab = expected
    assert cfg.n_layers == nl
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    if ff is not None:
        assert cfg.d_ff == ff
    assert cfg.vocab_size == vocab
    assert cfg.source  # every config cites its source


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "deepseek-v2-236b",
                                  "jamba-1.5-large-398b"])
def test_moe_expert_counts(arch):
    cfg = get_config(arch)
    m = cfg.moe
    expected = {
        "deepseek-v3-671b": (256, 8, 1),
        "deepseek-v2-236b": (160, 6, 2),
        "jamba-1.5-large-398b": (16, 2, 0),
    }[arch]
    assert (m.n_experts, m.top_k, m.n_shared) == expected


def test_param_counts_roughly_match_names():
    """Total parameter count lands near the model-name scale."""
    tol = {
        "smollm-135m": (135e6, 0.35),
        "deepseek-7b": (7e9, 0.35),
        "phi3-mini-3.8b": (3.8e9, 0.35),
        "qwen2-0.5b": (0.5e9, 0.4),
        "deepseek-v3-671b": (671e9, 0.25),
        "deepseek-v2-236b": (236e9, 0.25),
        "jamba-1.5-large-398b": (398e9, 0.3),
        "xlstm-1.3b": (1.3e9, 0.45),
        "llava-next-34b": (34e9, 0.35),
    }
    for arch, (target, frac) in tol.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < frac, (arch, n, target)
