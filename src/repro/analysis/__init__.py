"""repro.analysis — the AST-based invariant lint plane ("replint").

The reproduction's bit-identity discipline (golden replays, fuzz
differentials, the accounting-invariant suite) catches mirror-desync,
metrics-leak and nondeterminism bugs at *runtime*, after a differential has
to run.  This package certifies the same bug classes *statically*: a small
rule engine walks every source module's AST and reports repo-specific
invariant violations with file:line precision, before any replay runs.

Rule families (DESIGN.md §15 is the catalog):

* ``mirror-sync`` / ``dirty-notify`` — writes to skyline / probe-plane /
  ``_LPMirror`` buffers outside the calendar mutation API, and calendar
  mutation paths missing the dirty-mark notification (the stale-mirror
  class PR 4/5 could only catch by fuzzing).
* ``terminal-state`` — terminal ``TaskState`` assignments outside the
  designated settle helpers audited by tests/test_accounting_invariants.py
  (the PR 6 metrics-leak class).
* ``determinism-wallclock`` / ``determinism-rng`` / ``determinism-set-iter``
  — wall-clock reads, unseeded RNG, and unordered set iteration inside the
  ``core/`` + ``sim/`` decision paths.
* ``pallas-index`` / ``jax-free-boundary`` — bare-int ``pl.load`` /
  ``pl.store`` / ``pl.swap`` indices (the interpret-mode discharge bug
  fixed in PR 3) and module-level jax imports in the streaming-path
  modules PR 7 deliberately kept jax-free.

Suppression is explicit and line-scoped: ``# replint: disable=<rule>`` on
the flagged line, or an entry in the committed baseline file
(``replint_baseline.json``) carrying a one-line justification.  Run as
``python -m repro.analysis [--gate]``; the CI gate blocks on any
unbaselined finding and on stale baseline entries.
"""
from .engine import (
    Finding,
    Module,
    Report,
    Rule,
    default_rules,
    finding_key,
    load_baseline,
    run_analysis,
)
from .rules.determinism import SetIterRule, UnseededRngRule, WallClockRule
from .rules.kernel_rules import JaxImportRule, PallasIndexRule
from .rules.mirror_sync import DirtyNotifyRule, MirrorWriteRule
from .rules.terminal_state import SETTLE_HELPERS, TerminalStateRule

__all__ = [
    "Finding",
    "Module",
    "Report",
    "Rule",
    "default_rules",
    "finding_key",
    "load_baseline",
    "run_analysis",
    "MirrorWriteRule",
    "DirtyNotifyRule",
    "TerminalStateRule",
    "SETTLE_HELPERS",
    "WallClockRule",
    "UnseededRngRule",
    "SetIterRule",
    "PallasIndexRule",
    "JaxImportRule",
]
