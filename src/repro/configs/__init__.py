"""Architecture registry: ``--arch <id>`` -> ModelConfig (+ smoke variants).

Every entry cites its source paper/model-card; the exact dims come from the
assignment table (see DESIGN.md §8.1).
"""
from __future__ import annotations

from importlib import import_module

from ..models.config import ModelConfig

_MODULES = {
    "smollm-135m": "smollm_135m",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "deepseek-7b": "deepseek_7b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "qwen2-0.5b": "qwen2_0_5b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "llava-next-34b": "llava_next_34b",
    "xlstm-1.3b": "xlstm_1_3b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    return import_module(f".{_MODULES[arch]}", __package__).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    return import_module(f".{_MODULES[arch]}", __package__).smoke_config()
