"""The seed's O(n)-per-query resource calendars, kept frozen as a reference.

This module is the pre-optimisation implementation of ``repro.core.calendar``
(linear sweeps over flat reservation lists).  It is retained for two reasons:

1. **Differential testing** — ``tests/test_calendar_equivalence.py`` replays
   randomized reservation sequences against both implementations and asserts
   identical answers for ``fits`` / ``max_usage`` / ``free_cores`` / ``load``
   / ``earliest_slot`` / ``completion_times``.
2. **Measured speedups** — ``benchmarks/scheduler_micro.py`` times the same
   admission workload on both network states, so the O(log n) rewrite's
   speedup is reported as a number, not asserted in prose (DESIGN.md §2.3).

Do not use these classes in production paths; they scale as O(total
reservations) per probe and O(total reservations) per ``gc``.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Iterable, Optional

from .calendar import EPS, Reservation


class ReferenceLinkCalendar:
    """Seed unit-capacity link calendar (O(n) scans)."""

    def __init__(self) -> None:
        self._starts: list[float] = []
        self._res: list[Reservation] = []

    def __len__(self) -> int:
        return len(self._res)

    def earliest_slot(self, duration: float, not_before: float) -> float:
        """Earliest t >= not_before such that [t, t+duration) is free."""
        t = not_before
        idx = bisect.bisect_left(self._starts, t)
        # A reservation starting before t may still cover it.
        if idx > 0 and self._res[idx - 1].t2 > t + EPS:
            t = self._res[idx - 1].t2
        for r in self._res[idx:]:
            if r.t1 >= t + duration - EPS:
                break
            t = max(t, r.t2)
        return t

    def reserve(self, t1: float, t2: float, tag: object = None) -> Reservation:
        r = Reservation(t1, t2, 1, tag)
        idx = bisect.bisect_left(self._starts, t1)
        self._starts.insert(idx, t1)
        self._res.insert(idx, r)
        return r

    def reserve_earliest(
        self, duration: float, not_before: float, tag: object = None
    ) -> Reservation:
        t1 = self.earliest_slot(duration, not_before)
        return self.reserve(t1, t1 + duration, tag)

    def cancel(self, res: Reservation) -> None:
        try:
            idx = self._res.index(res)
        except ValueError:
            return
        del self._res[idx]
        del self._starts[idx]

    def gc(self, now: float) -> None:
        keep = [r for r in self._res if r.t2 > now]
        self._res = keep
        self._starts = [r.t1 for r in keep]


class ReferenceDeviceCalendar:
    """Seed capacity-C device calendar (O(n) sweeps per probe)."""

    def __init__(self, device: int, capacity: int = 4) -> None:
        self.device = device
        self.capacity = capacity
        self._res: dict[object, Reservation] = {}

    def __len__(self) -> int:
        return len(self._res)

    def reservations(self) -> Iterable[Reservation]:
        return self._res.values()

    def usage_profile(self, t1: float, t2: float) -> list[tuple[float, int]]:
        """Sweep-line (time, cores-in-use) change points within [t1, t2)."""
        events: list[tuple[float, int]] = []
        for r in self._res.values():
            if r.overlaps(t1, t2):
                events.append((max(r.t1, t1), r.amount))
                events.append((min(r.t2, t2), -r.amount))
        events.sort()
        return events

    def max_usage(self, t1: float, t2: float) -> int:
        cur = peak = 0
        for _, delta in self.usage_profile(t1, t2):
            cur += delta
            peak = max(peak, cur)
        return peak

    def free_cores(self, t1: float, t2: float) -> int:
        return self.capacity - self.max_usage(t1, t2)

    def fits(self, t1: float, t2: float, cores: int) -> bool:
        return self.max_usage(t1, t2) + cores <= self.capacity

    def reserve(self, t1: float, t2: float, cores: int, tag: object) -> Reservation:
        r = Reservation(t1, t2, cores, tag)
        self._res[tag] = r
        return r

    def release(self, tag: object) -> Optional[Reservation]:
        return self._res.pop(tag, None)

    def get(self, tag: object) -> Optional[Reservation]:
        return self._res.get(tag)

    def truncate(self, tag: object, t_end: float) -> None:
        """Shorten a reservation (early completion / violation)."""
        r = self._res.get(tag)
        if r is None:
            return
        if t_end <= r.t1 + EPS:
            self._res.pop(tag)
        else:
            r.t2 = min(r.t2, t_end)

    def load(self, t1: float, t2: float) -> float:
        """Reserved core-seconds overlapping [t1, t2) (for even spreading)."""
        total = 0.0
        for r in self._res.values():
            if r.overlaps(t1, t2):
                total += (min(r.t2, t2) - max(r.t1, t1)) * r.amount
        return total

    def completion_times(self, after: float, before: float) -> list[float]:
        return sorted(
            {r.t2 for r in self._res.values() if after + EPS < r.t2 < before - EPS}
        )

    def gc(self, now: float) -> None:
        dead = [tag for tag, r in self._res.items() if r.t2 <= now]
        for tag in dead:
            del self._res[tag]


@dataclass
class ReferenceNetworkState:
    """Seed network state over the reference calendars."""

    n_devices: int
    capacity: int = 4
    link: ReferenceLinkCalendar = field(default_factory=ReferenceLinkCalendar)
    devices: list[ReferenceDeviceCalendar] = field(default_factory=list)

    def __post_init__(self) -> None:
        if not self.devices:
            self.devices = [
                ReferenceDeviceCalendar(d, self.capacity) for d in range(self.n_devices)
            ]

    def completion_times(self, after: float, before: float) -> list[float]:
        pts: set[float] = set()
        for dev in self.devices:
            pts.update(dev.completion_times(after, before))
        return sorted(pts)

    def iter_completion_times(self, after: float, before: float):
        """Same sorted unique points as :meth:`completion_times` — eager
        under the hood (the seed structures have no incremental merge), but
        the iterator form lets the scheduler call one grid API for both
        network-state implementations."""
        return iter(self.completion_times(after, before))

    def total_allocated_tasks(self) -> int:
        return sum(len(d) for d in self.devices)

    def gc(self, now: float) -> None:
        self.link.gc(now)
        for d in self.devices:
            d.gc(now)
