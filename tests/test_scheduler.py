"""Behavioural tests of the paper's two scheduling algorithms (§4)."""
import pytest

from repro.core.calendar import NetworkState
from repro.core.network import NetworkConfig
from repro.core.scheduler import PreemptionAwareScheduler
from repro.core.task import LowPriorityRequest, Priority, Task, TaskState


def make(preemption=True, n_devices=4):
    state = NetworkState(n_devices)
    net = NetworkConfig()
    return state, net, PreemptionAwareScheduler(state, net,
                                                preemption=preemption)


def hp_task(dev=0, deadline=2.0, frame=0):
    return Task(priority=Priority.HIGH, source_device=dev, deadline=deadline,
                frame_id=frame)


def lp_request(dev=0, deadline=30.0, n=1, frame=0):
    req = LowPriorityRequest(source_device=dev, deadline=deadline,
                             frame_id=frame, n_tasks=n)
    req.make_tasks()
    return req


def test_hp_allocates_locally_single_core():
    state, net, sched = make()
    t = hp_task()
    res = sched.allocate_high_priority(t, 0.0)
    assert res.success
    assert t.device == t.source_device == 0
    assert t.cores == 1 and not t.offloaded
    assert t.t_end - t.t_start == pytest.approx(net.hp_slot_time)


def test_hp_fails_if_deadline_impossible():
    state, net, sched = make()
    t = hp_task(deadline=0.5)       # < t_hp = 0.98
    res = sched.allocate_high_priority(t, 0.0)
    assert not res.success


def test_lp_prefers_source_device_no_transfer():
    state, net, sched = make()
    req = lp_request(dev=2, n=1)
    res = sched.allocate_low_priority(req, 0.0)
    assert len(res.allocations) == 1 and not res.failed
    a = res.allocations[0]
    assert a.device == 2 and not a.offloaded
    # minimum viable config first, then the upgrade pass may raise it;
    # with an empty network the upgrade to 4 cores must succeed
    assert a.cores == 4


def test_lp_offloads_when_source_full():
    state, net, sched = make()
    # fill device 0 with a fake long-running reservation
    blocker = lp_request(dev=0, n=1)
    state.devices[0].reserve(0.0, 100.0, 4, blocker.tasks[0])
    req = lp_request(dev=0, n=1, deadline=25.0)
    res = sched.allocate_low_priority(req, 0.0)
    assert len(res.allocations) == 1
    a = res.allocations[0]
    assert a.device != 0 and a.offloaded
    # offload requires an input-transfer link slot
    tags = [s.tag for s in a.link_slots]
    assert any(isinstance(t, tuple) and t[0] == "xfer" for t in tags)


def test_lp_spreads_evenly():
    state, net, sched = make()
    req = lp_request(dev=0, n=4, deadline=30.0)
    res = sched.allocate_low_priority(req, 0.0)
    assert not res.failed
    devices = sorted(a.device for a in res.allocations)
    # 4 tasks, 4 devices, each can hold max 2x2-core in window -> spread
    assert len(set(devices)) >= 2


def test_preemption_evicts_farthest_deadline():
    state, net, sched = make()
    # two LP tasks filling device 0, different deadlines
    req_near = lp_request(dev=0, deadline=20.0)
    req_far = lp_request(dev=0, deadline=40.0)
    state.devices[0].reserve(0.0, 15.0, 2, req_near.tasks[0])
    req_near.tasks[0].state = TaskState.ALLOCATED
    req_near.tasks[0].deadline = 20.0
    state.devices[0].reserve(0.0, 15.0, 2, req_far.tasks[0])
    req_far.tasks[0].state = TaskState.ALLOCATED
    req_far.tasks[0].deadline = 40.0

    t = hp_task(dev=0, deadline=3.0)
    res = sched.allocate_high_priority(t, 0.0)
    assert res.success
    assert res.preempted == [req_far.tasks[0]]
    assert req_far.tasks[0].preempt_count == 1
    # the near-deadline task kept its slot
    assert state.devices[0].get(req_near.tasks[0]) is not None


def test_no_preemption_mode_fails_instead():
    state, net, sched = make(preemption=False)
    blocker = lp_request(dev=0)
    state.devices[0].reserve(0.0, 15.0, 4, blocker.tasks[0])
    t = hp_task(dev=0, deadline=3.0)
    res = sched.allocate_high_priority(t, 0.0)
    assert not res.success and not res.preempted


def test_preempted_task_reallocated_elsewhere():
    state, net, sched = make()
    victim_req = lp_request(dev=0, deadline=40.0)
    victim = victim_req.tasks[0]
    state.devices[0].reserve(0.0, 15.0, 4, victim)
    victim.state = TaskState.ALLOCATED
    t = hp_task(dev=0, deadline=3.0)
    res = sched.allocate_high_priority(t, 0.0)
    assert res.success and victim in res.preempted
    # the network is otherwise idle, so reallocation must succeed (source
    # device preferred — possibly at a later time-point — else another dev)
    assert len(res.reallocations) == 1
    assert res.reallocations[0].t_end <= victim.deadline
    assert victim.state == TaskState.ALLOCATED
    assert sched.metrics.realloc_success == 1


def test_hp_never_preempts_hp():
    state, net, sched = make()
    other_hp = hp_task(dev=0, deadline=5.0, frame=1)
    # fill all 4 cores with HP reservations
    for i in range(4):
        t = hp_task(dev=0, deadline=5.0, frame=10 + i)
        state.devices[0].reserve(0.0, 1.0, 1, t)
    t = hp_task(dev=0, deadline=1.5)
    res = sched.allocate_high_priority(t, 0.0)
    assert not res.success
    assert not res.preempted            # HP tasks are never victims


def test_lp_uses_future_time_points():
    state, net, sched = make(n_devices=1)
    # device busy until t=10 with an existing task
    blocker = lp_request(dev=0)
    state.devices[0].reserve(0.0, 10.0, 4, blocker.tasks[0])
    req = lp_request(dev=0, n=1, deadline=40.0)
    res = sched.allocate_low_priority(req, 0.0)
    assert len(res.allocations) == 1
    assert res.allocations[0].t_start >= 10.0   # allocated at the time point


def test_lp_fails_when_no_capacity_before_deadline():
    state, net, sched = make(n_devices=1)
    blocker = lp_request(dev=0)
    state.devices[0].reserve(0.0, 50.0, 4, blocker.tasks[0])
    req = lp_request(dev=0, n=1, deadline=20.0)
    res = sched.allocate_low_priority(req, 0.0)
    assert res.failed == req.tasks
    assert req.tasks[0].state == TaskState.FAILED


def test_weakest_set_victim_policy():
    """§8 beyond-paper policy: with two conflicting 2-core LP victims, the
    one from the less-healthy request set is evicted; the paper's rule picks
    the farthest deadline regardless."""
    for policy, expect_weak in (("weakest_set", True),
                                ("farthest_deadline", False)):
        state = NetworkState(4)
        net = NetworkConfig()
        sched = PreemptionAwareScheduler(state, net, preemption=True,
                                         victim_policy=policy)
        dev0 = state.devices[0]
        # healthy set (2/2 on track), deadline FARTHER -> paper rule's pick
        healthy = lp_request(dev=0, deadline=100.0, n=2)
        for t in healthy.tasks:
            t.state = TaskState.ALLOCATED
        # weak set (1/2 on track: a sibling already failed), deadline NEARER
        weak = lp_request(dev=0, deadline=90.0, n=2)
        weak.tasks[0].state = TaskState.ALLOCATED
        weak.tasks[1].state = TaskState.FAILED
        sched._requests[healthy.request_id] = healthy
        sched._requests[weak.request_id] = weak
        # both occupy dev0 (2 cores each) over the HP window
        dev0.reserve(0.0, 50.0, 2, healthy.tasks[0])
        dev0.reserve(0.0, 50.0, 2, weak.tasks[0])

        hp = hp_task(dev=0, deadline=5.0)
        res = sched.allocate_high_priority(hp, 0.0)
        assert res.success and len(res.preempted) == 1
        victim = res.preempted[0]
        is_weak = victim.request_id == weak.request_id
        assert is_weak == expect_weak, (policy, victim.request_id)


def test_preemption_cancels_victim_link_slots():
    """Seed bug regression: a preempted victim's pending xfer/update link
    slots must be cancelled, or the shared link permanently inflates with
    traffic for a task that will never run in that slot."""
    state, net, sched = make(n_devices=2)
    # fill the victim's source device so its request offloads to device 1
    blocker = lp_request(dev=0, deadline=200.0)
    state.devices[0].reserve(0.0, 100.0, 4, blocker.tasks[0])
    victim_req = lp_request(dev=0, deadline=60.0, frame=1)
    res = sched.allocate_low_priority(victim_req, 0.0)
    [alloc] = res.allocations
    assert alloc.offloaded and alloc.device == 1
    victim = victim_req.tasks[0]
    tags = [s.tag for s in state.link.reservations()]
    assert ("xfer", victim.task_id) in tags
    assert ("update", victim.task_id) in tags

    # block the remaining cores of device 1 with another (farther-deadline
    # safe) LP reservation, then force preemption with a tight HP task
    filler = lp_request(dev=1, deadline=55.0, frame=2)
    state.devices[1].reserve(alloc.t_start, alloc.t_end, 2, filler.tasks[0])
    hp = hp_task(dev=1, deadline=3.0)
    hp_res = sched.allocate_high_priority(hp, 0.0)
    assert hp_res.success
    assert victim in hp_res.preempted

    tags = [s.tag for s in state.link.reservations()]
    assert ("xfer", victim.task_id) not in tags
    assert ("update", victim.task_id) not in tags
    # reallocation (if any) re-reserves fresh slots for the victim
    for re in hp_res.reallocations:
        if re.task is victim:
            assert ("update", victim.task_id) in [
                s.tag for s in state.link.reservations()
            ]


def test_lp_grid_is_snapshot_of_entry_state():
    """Regression: the §4 search grid must be the completion times as of
    request entry — allocations committed DURING the sweep must not add new
    grid points (the seed's snapshot semantics; a lazily-materialised grid
    once leaked the first round's commits into it).  With one device, two
    background cores and a 2-task request, task A commits at now and ends
    inside the deadline window; the seed never probes A's completion, so
    task B must fail — on both calendar implementations."""
    from repro.core.calendar_reference import ReferenceNetworkState

    for make_state in (lambda: NetworkState(1), lambda: ReferenceNetworkState(1)):
        state = make_state()
        net = NetworkConfig()
        sched = PreemptionAwareScheduler(state, net)
        state.devices[0].reserve(0.0, 1000.0, 2, "background")
        req = lp_request(dev=0, deadline=120.0, n=2)
        res = sched.allocate_low_priority(req, 0.0)
        assert len(res.allocations) == 1, type(state).__name__
        assert len(res.failed) == 1, type(state).__name__


def test_skip_hint_respects_link_delayed_windows():
    """Regression: the skip-hint pruning must compare the hint against the
    time-point's ACTUAL link-derived execution windows, not the raw grid
    time.  Here the link is busy until just before t=100, so probing at
    grid point t=50 actually yields arrival = 100.0 — exactly when the
    source device frees up.  A tp-based skip would discard that point and
    fail a perfectly schedulable task."""
    state, net, sched = make(n_devices=2)
    msg_dur = net.slot(net.msg.lp_alloc)
    # source device: 3/4 cores busy on [0, 100); one reservation ends at 50
    # so the search grid contains a point strictly between now and 100
    state.devices[0].reserve(0.0, 100.0, 2, "busyA")
    state.devices[0].reserve(0.0, 50.0, 1, "busyB")
    # other device: fully busy past the deadline
    state.devices[1].reserve(0.0, 130.0, 4, "busyC")
    # link: free only in [0, msg_dur) and [100 - msg_dur, 100)
    state.link.reserve(msg_dur, 100.0 - msg_dur, "jam1")
    state.link.reserve(100.0, 140.0, "jam2")

    req = lp_request(dev=0, deadline=120.0)
    res = sched.allocate_low_priority(req, 0.0)
    assert len(res.allocations) == 1, "hint pruning skipped a feasible point"
    a = res.allocations[0]
    assert a.device == 0 and not a.offloaded
    assert a.t_start == pytest.approx(100.0)
    # the seed implementation admits identically
    from repro.core.calendar_reference import ReferenceNetworkState
    ref_state = ReferenceNetworkState(2)
    ref_sched = PreemptionAwareScheduler(ref_state, net)
    ref_state.devices[0].reserve(0.0, 100.0, 2, "busyA")
    ref_state.devices[0].reserve(0.0, 50.0, 1, "busyB")
    ref_state.devices[1].reserve(0.0, 130.0, 4, "busyC")
    ref_state.link.reserve(msg_dur, 100.0 - msg_dur, "jam1")
    ref_state.link.reserve(100.0, 140.0, "jam2")
    ref_req = lp_request(dev=0, deadline=120.0, frame=9)
    ref_res = ref_sched.allocate_low_priority(ref_req, 0.0)
    assert len(ref_res.allocations) == 1
    assert ref_res.allocations[0].t_start == pytest.approx(a.t_start)

    # batch path: same scenario, same admission
    state2, _, sched2 = make(n_devices=2)
    state2.devices[0].reserve(0.0, 100.0, 2, "busyA")
    state2.devices[0].reserve(0.0, 50.0, 1, "busyB")
    state2.devices[1].reserve(0.0, 130.0, 4, "busyC")
    state2.link.reserve(msg_dur, 100.0 - msg_dur, "jam1")
    state2.link.reserve(100.0, 140.0, "jam2")
    breq = lp_request(dev=0, deadline=120.0, frame=10)
    [bres] = sched2.allocate_low_priority_batch([breq], 0.0)
    assert len(bres.allocations) == 1
    assert bres.allocations[0].t_start == pytest.approx(100.0)


def test_set_health_request_id_zero():
    """Regression guard: request_id == 0 must still hit the registry
    (truthiness bug bait)."""
    state = NetworkState(2)
    sched = PreemptionAwareScheduler(state, NetworkConfig(),
                                     victim_policy="weakest_set")
    req = lp_request(dev=0, n=2)
    req.request_id = 0
    req.tasks[0].request_id = 0
    req.tasks[0].state = TaskState.ALLOCATED
    req.tasks[1].request_id = 0
    req.tasks[1].state = TaskState.FAILED
    sched._requests[0] = req
    assert sched._set_health(req.tasks[0]) == 0.0 + 0.5
