"""Corpus: mirror-safe usage — the calendar mutation API plus reads."""


def wellbehaved(dev, now):
    r = dev.reserve(now, now + 1.0, 0.5)   # good: the mutation API
    dev.release(r)
    dev.truncate(r, now)
    dev.gc(now)                            # good: mutator name, clean receiver
    return dev._sky                        # good: reads are unrestricted
