"""Degrade storms: streaming serving under saturating LP overload with the
variant ladder (DESIGN.md §17).

``run_storm`` runs the SAME seeded firehose twice through the streaming
engine over a ladder workload:

* **reject-only** — the pre-ladder baseline: ``reject_newest`` shedding,
  no degrade-before-reject, no degrade-instead-of-evict.
* **degrade** — the full ladder stack: the ``degrade`` shed policy walks
  queued LP requests down the ladder at the soft watermark, the scheduler
  retries infeasible LP admissions down the ladder before rejecting, and
  the ``degrade_shrink`` victim policy shrinks conflict victims in place
  before falling back to eviction.

The gate pins the ladder's value proposition — under overload, trading
accuracy beats dropping work:

* ``awg`` (accuracy-weighted goodput, % of the full-accuracy maximum)
  must be STRICTLY higher with the ladder than without, by at least
  ``min_awg_gain_pct`` points;
* HP completion must be equal or better with the ladder
  (``hp_slack_pct`` tolerates only float-level noise, default 0.0);
* the ladder must actually fire (``lp_degraded > 0``) — a storm too mild
  to degrade anything gates nothing.

Both runs are seeded and deterministic: the gate compares two exact
replays, not noisy samples.

CLI (the CI degrade-storm smoke step)::

    python -m repro.sim.degrade_storm --scenario smoke --gate \\
        --json degrade_storm.json

``--sweep`` replays the scenario across a rate ladder and prints the
accuracy-vs-completion frontier (EXPERIMENTS.md §Variant ladder).
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass, replace
from typing import Any, Optional

# NOTE: ``serving.stream`` is imported inside :func:`_run_mode`, not here —
# the same sim/__init__ circularity ``sim/openended.py`` documents.


@dataclass(frozen=True)
class StormConfig:
    """One degrade storm: offered overload + ladder knobs + gate floors."""

    name: str = "degrade_storm"
    n_devices: int = 8
    rate: float = 40.0              # firehose arrivals / s (network-wide)
    lp_fraction: float = 0.9        # storms are LP-heavy by construction
    duration: float = 10.0          # arrival horizon (virtual s)
    window: float = 0.25
    queue_capacity: int = 4096      # sized so the queue never sheds HP:
    #                                 saturation must come from the
    #                                 scheduler, which is what the ladder
    #                                 negotiates with
    seed: int = 0
    workload: str = "paper_ladder"
    victim_policy: str = "degrade_shrink"
    max_requests: Optional[int] = 2000
    # gate floors (``storm_gate``)
    min_awg_gain_pct: float = 1.0   # ladder awg - reject awg, strict floor
    hp_slack_pct: float = 0.0       # tolerated HP drop (0 = equal-or-better)


STORM_SCENARIOS: dict[str, StormConfig] = {
    # CI smoke: small fleet, 10x LP overload, seconds of wall-clock.
    "smoke": StormConfig(
        name="smoke", n_devices=4, rate=40.0, duration=6.0,
        max_requests=400, min_awg_gain_pct=1.0),
    # The acceptance storm: sustained saturating overload on a mid fleet.
    "storm": StormConfig(
        name="storm", n_devices=8, rate=80.0, duration=10.0,
        max_requests=2000, min_awg_gain_pct=1.0),
    # Preemption-heavy mix: enough HP traffic that degrade-instead-of-
    # evict sees conflict victims to shrink.
    "shrink_storm": StormConfig(
        name="shrink_storm", n_devices=8, rate=60.0, lp_fraction=0.6,
        duration=10.0, max_requests=2000, min_awg_gain_pct=0.5),
}


def _run_mode(cfg: StormConfig, degrade: bool) -> dict[str, Any]:
    """One engine run; absolute outcome numbers for one mode."""
    from ..serving.stream import StreamingEngine   # lazy: see module note
    from .openended import FirehoseConfig, firehose

    engine = StreamingEngine(
        cfg.n_devices, workload=cfg.workload, window=cfg.window,
        queue_capacity=cfg.queue_capacity,
        shed="degrade" if degrade else "reject_newest",
        policy_kwargs={"degrade": degrade,
                       "victim_policy": (cfg.victim_policy if degrade
                                         else "farthest_deadline")})
    fire = FirehoseConfig(
        name=cfg.name, n_devices=cfg.n_devices, rate=cfg.rate,
        lp_fraction=cfg.lp_fraction, seed=cfg.seed)
    report = engine.run(firehose(fire), until=cfg.duration,
                        max_requests=cfg.max_requests)
    m = engine.metrics
    # Accuracy-weighted goodput, % of the full-accuracy maximum.  Computed
    # from the raw accumulator (not the summary) so the reject-only run —
    # whose summary rightly omits the ladder block — reports it too.
    awg = (100.0 * m.lp_accuracy_completed / m.lp_generated
           if m.lp_generated else 0.0)
    s = report["metrics"]
    return {
        "mode": "degrade" if degrade else "reject_only",
        "hp_completion_pct": s.get("hp_completion_pct", 0.0),
        "lp_completion_pct": s.get("lp_completion_pct", 0.0),
        "awg_pct": round(awg, 3),
        "lp_generated": m.lp_generated,
        "lp_shed": m.lp_shed,
        "lp_failed_alloc": m.lp_failed_alloc,
        "lp_degraded": m.lp_degraded,
        "degrade_shrinks": m.degrade_shrinks,
        "variant_admissions": {str(v): n for v, n in
                               sorted(m.variant_admissions.items())},
        "unresolved": report["unresolved"],
    }


def run_storm(cfg: StormConfig) -> dict[str, Any]:
    """Both modes on the identical arrival replay, plus the gate deltas."""
    reject = _run_mode(cfg, degrade=False)
    degrade = _run_mode(cfg, degrade=True)
    return {
        "scenario": cfg.name,
        "n_devices": cfg.n_devices,
        "rate": cfg.rate,
        "workload": cfg.workload,
        "reject_only": reject,
        "degrade": degrade,
        "awg_gain_pct": round(degrade["awg_pct"] - reject["awg_pct"], 3),
        "hp_delta_pct": round(degrade["hp_completion_pct"]
                              - reject["hp_completion_pct"], 3),
    }


def storm_gate(result: dict[str, Any], cfg: StormConfig) -> list[str]:
    """Return the list of gate violations (empty = pass)."""
    failures: list[str] = []
    for mode in ("reject_only", "degrade"):
        if result[mode]["unresolved"] != 0:
            failures.append(
                f"{mode}: unresolved={result[mode]['unresolved']} "
                "(must be 0)")
    if result["degrade"]["lp_degraded"] == 0:
        failures.append(
            "ladder never fired (lp_degraded=0) — the storm is too mild "
            "to gate anything")
    if result["awg_gain_pct"] < cfg.min_awg_gain_pct:
        failures.append(
            f"awg_gain_pct={result['awg_gain_pct']:.3f} < "
            f"floor {cfg.min_awg_gain_pct} (degrade must STRICTLY beat "
            "reject-only on accuracy-weighted goodput)")
    if result["hp_delta_pct"] < -cfg.hp_slack_pct:
        failures.append(
            f"hp_delta_pct={result['hp_delta_pct']:.3f} < "
            f"-{cfg.hp_slack_pct} (degrade must keep HP completion "
            "equal-or-better)")
    return failures


def sweep(cfg: StormConfig, rates: list[float]) -> list[dict[str, Any]]:
    """The accuracy-vs-completion frontier: one storm per offered rate."""
    rows = []
    for rate in rates:
        r = run_storm(replace(cfg, name=f"{cfg.name}_r{rate:g}", rate=rate))
        rows.append({
            "rate": rate,
            "reject_lp_pct": r["reject_only"]["lp_completion_pct"],
            "reject_awg_pct": r["reject_only"]["awg_pct"],
            "degrade_lp_pct": r["degrade"]["lp_completion_pct"],
            "degrade_awg_pct": r["degrade"]["awg_pct"],
            "awg_gain_pct": r["awg_gain_pct"],
            "hp_delta_pct": r["hp_delta_pct"],
        })
    return rows


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Run a degrade storm (variant ladder vs reject-only)")
    ap.add_argument("--scenario", default="smoke",
                    choices=sorted(STORM_SCENARIOS))
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 unless the ladder strictly beats "
                         "reject-only (see storm_gate)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the result dict as JSON")
    ap.add_argument("--sweep", default=None, metavar="RATES",
                    help="comma-separated offered rates: print the "
                         "accuracy-vs-completion frontier instead")
    args = ap.parse_args(argv)

    cfg = STORM_SCENARIOS[args.scenario]
    if args.seed is not None:
        cfg = replace(cfg, seed=args.seed)

    if args.sweep:
        rows = sweep(cfg, [float(r) for r in args.sweep.split(",")])
        head = (f"{'rate':>8}{'reject lp%':>12}{'reject awg%':>13}"
                f"{'degrade lp%':>13}{'degrade awg%':>14}"
                f"{'awg gain':>10}{'hp delta':>10}")
        print(head)
        print("-" * len(head))
        for row in rows:
            print(f"{row['rate']:>8g}{row['reject_lp_pct']:>12.2f}"
                  f"{row['reject_awg_pct']:>13.2f}"
                  f"{row['degrade_lp_pct']:>13.2f}"
                  f"{row['degrade_awg_pct']:>14.2f}"
                  f"{row['awg_gain_pct']:>10.2f}"
                  f"{row['hp_delta_pct']:>10.2f}")
        if args.json:
            with open(args.json, "w") as fh:
                json.dump(rows, fh, indent=2, sort_keys=True)
            print(f"[storm] wrote {args.json}")
        return 0

    result = run_storm(cfg)
    print(f"[storm] {cfg.name}: devices={cfg.n_devices} rate={cfg.rate:g} "
          f"workload={cfg.workload}")
    for mode in ("reject_only", "degrade"):
        r = result[mode]
        print(f"[storm]   {mode:<12} hp={r['hp_completion_pct']:.2f}% "
              f"lp={r['lp_completion_pct']:.2f}% awg={r['awg_pct']:.2f}% "
              f"shed={r['lp_shed']} rejected={r['lp_failed_alloc']} "
              f"degraded={r['lp_degraded']} shrinks={r['degrade_shrinks']}")
    print(f"[storm]   awg_gain={result['awg_gain_pct']:+.3f}pp "
          f"hp_delta={result['hp_delta_pct']:+.3f}pp")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(result, fh, indent=2, sort_keys=True)
        print(f"[storm] wrote {args.json}")
    if args.gate:
        failures = storm_gate(result, cfg)
        for f in failures:
            print(f"[storm] GATE FAIL: {f}", file=sys.stderr)
        if failures:
            return 1
        print("[storm] gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
