"""End-to-end reproduction driver: the paper's 4-device RPi2B waste-
classification experiment, as a calibrated discrete-event simulation.

Runs the preemption-aware scheduler against its non-preemption variant and
the two workstealer baselines on the paper's workload, and prints the
headline comparison (paper §6):

  PYTHONPATH=src python examples/edge_pipeline_sim.py [--frames 300]
  PYTHONPATH=src python examples/edge_pipeline_sim.py --scenario WPS_4

Scenario ids follow the paper's Table 1 legend (UPS, UNPS, WPS_1..4,
WNPS_4, DPW, DNPW, CPW, CNPW), plus the beyond-paper mixed-model fleet
(MPS, MNPS, MPS_W4 — DESIGN.md §10).
"""
import argparse
from dataclasses import replace

from repro.sim.experiment import MIXED_SCENARIOS, SCENARIOS, run_scenario

ALL_SCENARIOS = {**SCENARIOS, **MIXED_SCENARIOS}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=300,
                    help="paper uses 1296 (~15s on this host)")
    ap.add_argument("--scenario", choices=tuple(ALL_SCENARIOS), default=None,
                    help="run one scenario verbosely instead of the sweep")
    args = ap.parse_args()

    names = [args.scenario] if args.scenario else \
        ["UPS", "UNPS", "WPS_4", "WNPS_4", "DPW", "DNPW", "CPW", "CNPW"]

    print(f"{'scenario':8s} {'frames%':>8s} {'HP%':>7s} {'HP-preempt%':>11s} "
          f"{'LP%':>7s} {'LP/req%':>8s} {'preempts':>8s} {'realloc ok':>10s}")
    for name in names:
        cfg = replace(ALL_SCENARIOS[name], n_frames=args.frames)
        m = run_scenario(cfg)
        s = m.summary()
        print(f"{name:8s} {s['frame_completion_pct']:8.2f} "
              f"{s['hp_completion_pct']:7.2f} "
              f"{s['hp_via_preemption_pct']:11.2f} "
              f"{s['lp_completion_pct']:7.2f} "
              f"{s['lp_per_request_completion_pct']:8.2f} "
              f"{m.preemptions:8d} {m.realloc_success:10d}")

    if not args.scenario:
        print("\npaper's headline claims (1296 frames): preemption scheduler "
              "completes ~99% of HP tasks (vs 72-80% without) and +3-8% "
              "frames; schedulers beat workstealers by ~23% under "
              "weighted-4. Run with --frames 1296 to reproduce "
              "benchmarks/paper_figures.py exactly.")


if __name__ == "__main__":
    main()
