"""Churn-tolerant network plane (DESIGN.md §16): lifecycle transitions,
the masked probe plane, orphan recovery, the seeded injector, the chaos
harness and lifecycle checkpointing.

The load-bearing invariants:

* every orphan terminates — ALLOCATED elsewhere before its deadline or
  FAILED, never stranded in a transient state;
* admission never places onto a DOWN or DRAINING device (scalar path,
  vectorized probe plane, and HP's source-local gate all agree);
* churn-free runs execute zero churn code (bit-identity pinned in
  tests/test_accounting_invariants.py's differential);
* the lifecycle plane round-trips through the checkpoint store, so a
  restore mid-drain resumes recovery instead of forgetting orphans.
"""
import math

import numpy as np
import pytest

from repro.checkpoint import lifecycle as ck_lifecycle
from repro.checkpoint import store as ck_store
from repro.core.calendar import DeviceLifecycle, NetworkState
from repro.core.metrics import Metrics
from repro.core.network import NetworkConfig
from repro.core.scheduler import PreemptionAwareScheduler
from repro.core.task import (
    LowPriorityRequest,
    Priority,
    Task,
    TaskState,
    reset_id_counters,
)
from repro.serving.stream import StreamingEngine
from repro.sim.chaos import CHAOS_SCENARIOS, chaos_gate, run_chaos
from repro.sim.churn import ChurnConfig, ChurnInjector, churn_schedule
from repro.sim.scenarios import LargeNConfig, run_large_n


def make(preemption=True, n_devices=4):
    reset_id_counters()
    state = NetworkState(n_devices)
    net = NetworkConfig()
    metrics = Metrics("churn_test")
    sched = PreemptionAwareScheduler(state, net, preemption=preemption,
                                     metrics=metrics)
    return state, net, sched, metrics


def hp_task(dev=0, deadline=2.0, frame=0):
    return Task(priority=Priority.HIGH, source_device=dev,
                deadline=deadline, frame_id=frame)


def lp_request(dev=0, deadline=30.0, n=1, frame=0):
    req = LowPriorityRequest(source_device=dev, deadline=deadline,
                             frame_id=frame, n_tasks=n)
    req.make_tasks()
    return req


# --------------------------------------------------------------------- #
# Lifecycle state machine                                               #
# --------------------------------------------------------------------- #
def test_devices_start_up_and_transitions_mark_the_plane():
    st = NetworkState(3)
    assert all(d.lifecycle is DeviceLifecycle.UP for d in st.devices)
    assert st.alive_mask().tolist() == [True, True, True]
    st.drain_device(1)
    assert st.devices[1].lifecycle is DeviceLifecycle.DRAINING
    assert st.alive_mask().tolist() == [True, False, True]
    st.fail_device(2, now=0.0)
    assert st.lifecycle_codes().tolist() == [0, 1, 2]
    st.rejoin_device(1)
    st.rejoin_device(2)
    assert st.alive_mask().all()


def test_drain_of_a_down_device_is_an_error():
    st = NetworkState(2)
    st.fail_device(0, now=0.0)
    with pytest.raises(ValueError, match="DOWN"):
        st.drain_device(0)
    st.rejoin_device(0)
    st.drain_device(0)          # legal again after rejoin


def test_fail_device_orphans_in_flight_and_clears_the_calendar():
    st = NetworkState(2)
    req = lp_request(dev=0, n=2)
    t0, t1 = req.tasks
    st.devices[0].reserve(0.0, 5.0, 2, t0)
    st.devices[0].reserve(1.0, 6.0, 2, t1)
    done = lp_request(dev=0, frame=1).tasks[0]
    st.devices[0].reserve(0.0, 1.0, 2, done)     # finishes before the fail
    orphans = st.fail_device(0, now=2.0)
    # gc retires the finished reservation first; orphans sorted by task id
    assert orphans == sorted([t0, t1], key=lambda t: t.task_id)
    assert not list(st.devices[0].reservations())
    assert st.devices[0].lifecycle is DeviceLifecycle.DOWN


def test_rejoin_after_fail_restores_a_cleared_admissible_calendar():
    st = NetworkState(2)
    st.devices[1].reserve(0.0, 50.0, 4, lp_request(dev=1).tasks[0])
    st.fail_device(1, now=0.0)
    st.rejoin_device(1)
    dev = st.devices[1]
    assert dev.lifecycle is DeviceLifecycle.UP
    assert not list(dev.reservations())
    assert dev.fits(0.0, 10.0, 4)


# --------------------------------------------------------------------- #
# Masked probe plane                                                    #
# --------------------------------------------------------------------- #
def test_probe_plane_masks_down_and_draining_devices():
    st = NetworkState(3)
    st.drain_device(1)
    st.fail_device(2, now=0.0)
    plane = st.probe_plane()
    assert plane.alive.tolist() == [True, False, False]
    assert plane.fits_mask(0.0, 5.0, 1).tolist() == [True, False, False]
    starts = plane.earliest_fit(1.0, 0.0, 1)
    assert starts[0] == 0.0
    assert math.isinf(starts[1]) and math.isinf(starts[2])


def test_probe_plane_unmasks_on_rejoin_via_dirty_mark():
    st = NetworkState(2)
    plane = st.probe_plane()
    assert plane.fits_mask(0.0, 1.0, 1).all()
    st.fail_device(0, now=0.0)
    plane = st.probe_plane()
    assert plane.fits_mask(0.0, 1.0, 1).tolist() == [False, True]
    st.rejoin_device(0)
    plane = st.probe_plane()
    assert plane.fits_mask(0.0, 1.0, 1).tolist() == [True, True]


def test_probe_window_carries_the_alive_mask():
    st = NetworkState(3)
    st.fail_device(1, now=0.0)
    win = st.probe_plane(0.0, 4.0)
    assert win.fits(1).tolist() == [True, False, True]


# --------------------------------------------------------------------- #
# Scheduler-level orphan recovery                                       #
# --------------------------------------------------------------------- #
def test_lp_orphans_reallocate_elsewhere_or_fail_never_strand():
    st, net, sched, m = make(n_devices=3)
    req = lp_request(dev=1, deadline=300.0, n=2)
    res = sched.allocate_low_priority(req, 0.0)
    assert len(res.allocations) == 2
    host_devs = {t.device for t in req.tasks}
    victim_dev = req.tasks[0].device
    orphans, reallocs = sched.fail_device(victim_dev, 0.5)
    moved = [t for t in req.tasks if t.device == victim_dev] or []
    for task in orphans:
        assert task.state in (TaskState.ALLOCATED, TaskState.FAILED), \
            f"orphan {task.task_id} stranded in {task.state}"
        if task.state is TaskState.ALLOCATED:
            assert task.device != victim_dev
            assert task.t_end <= task.deadline + 1e-9
    assert m.device_failures == 1
    assert m.orphans_created == len(orphans)
    assert m.orphans_recovered == len(reallocs)
    # the partition absorbs the orphans: no new terminal bucket
    assert m.realloc_failure == sum(
        1 for t in orphans if t.state is TaskState.FAILED)


def test_orphan_link_slots_are_cancelled_like_preemption_cleanup():
    st, net, sched, m = make(n_devices=2)
    # saturate the source so the request offloads over the link to dev 1
    blocker = lp_request(dev=0, deadline=200.0)
    st.devices[0].reserve(0.0, 100.0, 4, blocker.tasks[0])
    req = lp_request(dev=0, deadline=60.0, frame=1)
    res = sched.allocate_low_priority(req, 0.0)
    [alloc] = res.allocations
    assert alloc.offloaded and alloc.device == 1
    victim = req.tasks[0]
    tags = [s.tag for s in st.link.reservations()]
    assert ("xfer", victim.task_id) in tags
    assert ("update", victim.task_id) in tags

    orphans, reallocs = sched.fail_device(1, 0.0)
    assert victim in orphans
    tags = [s.tag for s in st.link.reservations()]
    assert ("xfer", victim.task_id) not in tags
    assert ("update", victim.task_id) not in tags
    # source saturated and host dead: recovery is impossible -> FAILED
    assert victim.state is TaskState.FAILED
    assert m.realloc_failure == 1


def test_hp_orphans_settle_failed_when_their_source_is_down():
    st, net, sched, m = make(n_devices=2)
    hp = hp_task(dev=0, deadline=5.0)
    m.hp_generated += 1
    assert sched.allocate_high_priority(hp, 0.0).success
    orphans, _ = sched.fail_device(0, 0.1)
    assert hp in orphans
    sched.settle_hp_orphans(orphans, 0.1)
    # HP is source-local (paper rule): a dead source cannot host it again
    assert hp.state is TaskState.FAILED
    assert m.hp_failed_alloc == 1
    assert m.hp_generated == m.hp_completed + m.hp_failed_alloc \
        + m.hp_failed_runtime


def test_admission_rejects_down_and_draining_sources():
    st, net, sched, m = make(n_devices=2)
    st.drain_device(0)
    assert not sched.allocate_high_priority(hp_task(dev=0), 0.0).success
    st.rejoin_device(0)
    assert sched.allocate_high_priority(hp_task(dev=0, frame=1), 0.0).success
    st.fail_device(1, now=0.0)
    assert not sched.allocate_high_priority(
        hp_task(dev=1, frame=2), 0.0).success


def test_lp_placement_avoids_non_up_devices():
    st, net, sched, m = make(n_devices=3)
    st.fail_device(2, now=0.0)
    st.drain_device(1)
    res = sched.allocate_low_priority(lp_request(dev=1, deadline=300.0), 0.0)
    # source is DRAINING, dev 2 is DOWN: only dev 0 may host
    for alloc in res.allocations:
        assert alloc.device == 0


# --------------------------------------------------------------------- #
# Seeded churn injector                                                 #
# --------------------------------------------------------------------- #
def test_disabled_injector_is_a_strict_noop():
    cfg = ChurnConfig(n_devices=16)          # all rates default to 0
    assert not cfg.enabled
    assert churn_schedule(cfg) == []
    inj = ChurnInjector(cfg)
    assert not inj.enabled and len(inj) == 0
    assert inj.counts() == {"fail": 0, "drain": 0, "rejoin": 0, "link": 0}


def test_injector_is_seed_deterministic():
    cfg = ChurnConfig(n_devices=32, fail_rate=2.0, drain_rate=1.0,
                      link_rate=0.5, duration=10.0, seed=7)
    a, b = churn_schedule(cfg), churn_schedule(cfg)
    assert a == b and len(a) > 0
    c = churn_schedule(ChurnConfig(
        n_devices=32, fail_rate=2.0, drain_rate=1.0, link_rate=0.5,
        duration=10.0, seed=8))
    assert a != c


def test_injector_events_are_time_sorted_and_well_formed():
    cfg = ChurnConfig(n_devices=16, fail_rate=3.0, drain_rate=1.0,
                      link_rate=1.0, duration=8.0, seed=3)
    events = churn_schedule(cfg)
    assert all(e1.t <= e2.t for e1, e2 in zip(events, events[1:]))
    down = set()
    for ev in events:
        if ev.kind in ("fail", "drain"):
            assert 0 <= ev.device < cfg.n_devices
            assert ev.device not in down, \
                "churn must never target an already-lost device"
            down.add(ev.device)
        elif ev.kind == "rejoin":
            assert ev.device in down
            down.remove(ev.device)
        else:
            assert ev.kind == "link" and ev.duration > 0.0


def test_injector_respects_the_down_cap():
    cfg = ChurnConfig(n_devices=10, fail_rate=100.0, duration=5.0,
                      rejoin=False, max_down_frac=0.3, seed=1)
    inj = ChurnInjector(cfg)
    assert inj.counts()["fail"] == 3          # max(1, int(10 * 0.3))


def test_injector_rejoins_every_lost_device():
    cfg = ChurnConfig(n_devices=16, fail_rate=2.0, drain_rate=1.0,
                      duration=6.0, rejoin=True, rejoin_delay=1.5, seed=5)
    counts = ChurnInjector(cfg).counts()
    assert counts["rejoin"] == counts["fail"] + counts["drain"] > 0


# --------------------------------------------------------------------- #
# Streaming engine churn API + chaos harness                            #
# --------------------------------------------------------------------- #
def test_streaming_engine_fail_device_recovers_and_resolves_all():
    reset_id_counters()
    eng = StreamingEngine(3, window=0.25)
    for d in range(3):
        eng.offer(_lp_stream(eng, device=d))
    eng.flush_window(0.0)
    assert eng.metrics.lp_allocated > 0
    eng.fail_device(0, now=0.05)
    assert eng.telemetry.devices_failed == 1
    report = eng.run([])                      # drain everything admitted
    assert report["unresolved"] == 0
    m = eng.metrics
    assert m.lp_generated == (m.lp_completed + m.lp_failed_alloc
                              + m.lp_failed_runtime + m.realloc_failure)
    assert "churn" in report["telemetry"]


def _lp_stream(eng, device=0, deadline=200.0, n_tasks=2):
    from repro.serving.stream import StreamRequest
    return StreamRequest(priority=Priority.LOW, deadline=deadline,
                         home_device=device, n_tasks=n_tasks)


def test_streaming_drain_then_rejoin_round_trip():
    eng = StreamingEngine(2, window=0.25)
    eng.drain_device(1)
    assert eng.state.devices[1].lifecycle is DeviceLifecycle.DRAINING
    eng.rejoin_device(1)
    assert eng.state.devices[1].lifecycle is DeviceLifecycle.UP
    tel = eng.telemetry
    assert tel.devices_drained == 1 and tel.devices_rejoined == 1


def test_chaos_smoke_scenario_passes_its_gate():
    cfg = CHAOS_SCENARIOS["smoke"]
    result = run_chaos(cfg)
    assert result["unresolved"] == 0
    assert result["devices_failed"] > 0
    assert result["orphans_created"] > 0
    assert chaos_gate(result, cfg) == []


def test_chaos_is_seed_deterministic():
    cfg = CHAOS_SCENARIOS["smoke"]
    a, b = run_chaos(cfg), run_chaos(cfg)

    def virtual(rep):
        # wall-clock latency sketches (t_*_ms) are real time, not virtual
        return {k: v for k, v in rep["metrics"].items()
                if not k.startswith("t_")}

    assert virtual(a["report"]) == virtual(b["report"])
    assert a["churn_events"] == b["churn_events"]
    assert a["recovery_ratio"] == b["recovery_ratio"]


# --------------------------------------------------------------------- #
# run_large_n churn wiring                                              #
# --------------------------------------------------------------------- #
def test_run_large_n_applies_churn_and_reports_counters():
    cfg = LargeNConfig("churn_large", n_devices=8, duration=30.0,
                       hp_rate=0.2, seed=11)
    inj = ChurnInjector(ChurnConfig(
        name="churn_large", n_devices=8, fail_rate=0.2, drain_rate=0.1,
        duration=20.0, start=5.0, rejoin_delay=2.0, seed=11))
    assert inj.enabled
    out = run_large_n(cfg, churn=inj)
    assert out["device_failures"] >= 1
    assert out["orphans_created"] >= out["orphans_recovered"] >= 0
    base = run_large_n(cfg)
    assert "device_failures" not in base, \
        "churn-free summaries must keep their historic key set"


# --------------------------------------------------------------------- #
# Lifecycle checkpointing                                               #
# --------------------------------------------------------------------- #
def test_lifecycle_checkpoint_roundtrip_mid_drain(tmp_path):
    st = NetworkState(4)
    st.drain_device(1)
    orphans = []
    req = lp_request(dev=2, n=2)
    st.devices[2].reserve(0.0, 9.0, 2, req.tasks[0])
    orphans = [t.task_id for t in st.fail_device(2, now=1.0)]
    path = str(tmp_path / "ckpt")
    ck_lifecycle.save_lifecycle(path, st, pending_orphans=orphans,
                                metadata={"virtual_now": 1.0})
    meta = ck_store.load_metadata(path)
    assert meta["kind"] == "device_lifecycle"
    assert meta["n_devices"] == 4 and meta["n_orphans"] == len(orphans)

    # restore into a fresh fleet that has picked up unrelated state
    st2 = NetworkState(4)
    st2.devices[2].reserve(0.0, 5.0, 4, lp_request(dev=2, frame=9).tasks[0])
    pending = ck_lifecycle.restore_lifecycle(path, st2)
    assert pending == sorted(orphans)
    assert st2.devices[1].lifecycle is DeviceLifecycle.DRAINING
    assert st2.devices[2].lifecycle is DeviceLifecycle.DOWN
    # a DOWN restore clears the calendar (those reservations died with
    # the device in the checkpointed world)
    assert not list(st2.devices[2].reservations())
    plane = st2.probe_plane()
    assert plane.alive.tolist() == [True, False, False, True]


def test_lifecycle_restore_validates_fleet_size_and_kind(tmp_path):
    st = NetworkState(3)
    path = str(tmp_path / "ckpt")
    ck_lifecycle.save_lifecycle(path, st)
    with pytest.raises(ValueError, match="3 devices"):
        ck_lifecycle.restore_lifecycle(path, NetworkState(5))
    other = str(tmp_path / "other")
    ck_store.save(other, {"x": np.zeros(3)}, metadata={"kind": "weights"})
    with pytest.raises(ValueError, match="not a device-lifecycle"):
        ck_lifecycle.restore_lifecycle(other, st)


def test_lifecycle_restore_rejects_tampered_payloads(tmp_path):
    st = NetworkState(3)
    st.fail_device(0, now=0.0)
    tree = ck_lifecycle.lifecycle_tree(st)
    # mask/codes disagreement (edited payload) must refuse
    bad = dict(tree, alive_mask=np.array([True, True, True]))
    path = str(tmp_path / "bad")
    ck_store.save(path, bad, metadata={
        "kind": "device_lifecycle", "n_devices": 3, "n_orphans": 0})
    with pytest.raises(ValueError, match="disagrees"):
        ck_lifecycle.restore_lifecycle(path, NetworkState(3))
    # unknown code value must refuse before touching the state
    bad2 = dict(tree, lifecycle=np.array([7, 0, 0], dtype=np.int8),
                alive_mask=np.array([False, True, True]))
    path2 = str(tmp_path / "bad2")
    ck_store.save(path2, bad2, metadata={
        "kind": "device_lifecycle", "n_devices": 3, "n_orphans": 0})
    with pytest.raises(ValueError, match="unknown lifecycle codes"):
        ck_lifecycle.restore_lifecycle(path2, NetworkState(3))
    # dtype smuggling (float codes) dies in the store's leaf validation
    bad3 = dict(tree, lifecycle=tree["lifecycle"].astype(np.float32))
    path3 = str(tmp_path / "bad3")
    ck_store.save(path3, bad3, metadata={
        "kind": "device_lifecycle", "n_devices": 3, "n_orphans": 0})
    with pytest.raises(ValueError, match="dtype"):
        ck_lifecycle.restore_lifecycle(path3, NetworkState(3))


def test_lifecycle_enum_values_are_the_wire_encoding():
    # the checkpoint encodes DeviceLifecycle.value directly: reordering
    # the enum would silently corrupt every existing snapshot
    assert DeviceLifecycle.UP.value == 0
    assert DeviceLifecycle.DRAINING.value == 1
    assert DeviceLifecycle.DOWN.value == 2
