"""Pure-jnp oracle for blocked causal (optionally windowed) attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  *, causal: bool = True, window: int = 0) -> jax.Array:
    """q/k/v [B, H, T, D] -> [B, H, T, D] (f32 math)."""
    t = q.shape[2]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        pos = jnp.arange(t)
        mask = pos[None, :] <= pos[:, None]
        if window > 0:
            mask &= pos[None, :] > pos[:, None] - window
        s = jnp.where(mask[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", w, v.astype(jnp.float32)).astype(q.dtype)
