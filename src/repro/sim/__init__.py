from .events import EventQueue
from .traces import TraceConfig, generate_trace, generate_type_trace, \
    potential_counts
from .experiment import MIXED_SCENARIOS, ScenarioConfig, run_scenario, \
    SCENARIOS
from .openended import FirehoseConfig, firehose
from .churn import ChurnConfig, ChurnEvent, ChurnInjector, churn_schedule
from .chaos import CHAOS_SCENARIOS, ChaosConfig, chaos_gate, run_chaos
from .scenarios import (
    LargeNConfig,
    generate_arrivals,
    run_large_n,
    LARGE_N_TIERS,
    sweep_devices,
    sweep_mix,
)

__all__ = [
    "EventQueue",
    "TraceConfig",
    "generate_trace",
    "generate_type_trace",
    "potential_counts",
    "MIXED_SCENARIOS",
    "ScenarioConfig",
    "run_scenario",
    "SCENARIOS",
    "FirehoseConfig",
    "firehose",
    "ChurnConfig",
    "ChurnEvent",
    "ChurnInjector",
    "churn_schedule",
    "CHAOS_SCENARIOS",
    "ChaosConfig",
    "chaos_gate",
    "run_chaos",
    "LargeNConfig",
    "generate_arrivals",
    "run_large_n",
    "LARGE_N_TIERS",
    "sweep_devices",
    "sweep_mix",
]
