"""Determinism rules for the ``core/`` + ``sim/`` decision paths.

The golden replays, the placement-oracle differentials and the paired
perf gates all assume a scheduling decision is a pure function of
(scenario config, seed).  Three rule classes guard the classic leaks:

* ``determinism-wallclock`` — ANY wall-clock read (``time.time``,
  ``time.perf_counter``, ``datetime.now``, ...).  Telemetry timing is
  legitimate but must be *attested*: every existing site is baselined
  with a justification, so a new clock read cannot silently feed a
  decision.
* ``determinism-rng`` — unseeded generators (``np.random.default_rng()``
  / ``random.Random()`` with no seed) and the module-level global-state
  draws (``np.random.normal(...)``, ``random.shuffle(...)``,
  ``random.seed(...)``): cross-test global state even when seeded.
* ``determinism-set-iter`` — iterating a set in a ``for`` loop or
  comprehension.  CPython's set order is an implementation detail (value
  hashing for ints, randomized for strs); a decision loop over a set is
  ordered by accident.  Wrap in ``sorted(...)``.  The checker is
  syntactic + lightly flow-aware: it tracks locals whose latest lexical
  assignment is a set expression / set-annotated, ``self.<attr>`` sets
  annotated anywhere in the class, and locals aliasing an attribute name
  that is set-annotated anywhere in the module.

Deliberately NOT certified: set iteration reached through function
returns or cross-module attributes, dict-ordering assumptions, and
randomness threaded through injected generator objects (seeded by
construction elsewhere) — the same-seed replay suites remain the runtime
backstop.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional, Sequence

from ..engine import Finding, Module, Rule

DECISION_PATHS: tuple[str, ...] = ("repro/core/", "repro/sim/")

WALL_CLOCK = frozenset({
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.process_time",
    "time.process_time_ns", "time.localtime", "time.gmtime", "time.ctime",
    "time.asctime",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
})

NP_GLOBAL_DRAWS = frozenset({
    "beta", "binomial", "choice", "exponential", "gamma", "geometric",
    "lognormal", "normal", "permutation", "poisson", "rand", "randint",
    "randn", "random", "random_sample", "seed", "shuffle",
    "standard_normal", "uniform",
})

PY_GLOBAL_DRAWS = frozenset({
    "betavariate", "choice", "choices", "expovariate", "gauss",
    "getrandbits", "normalvariate", "randint", "random", "randrange",
    "sample", "seed", "shuffle", "triangular", "uniform",
})


class _DecisionPathRule(Rule):
    paths: tuple[str, ...] = DECISION_PATHS

    def __init__(self, paths: Optional[Sequence[str]] = None) -> None:
        if paths is not None:
            self.paths = tuple(paths)

    def applies_to(self, rel: str) -> bool:
        return rel.startswith(self.paths)


class WallClockRule(_DecisionPathRule):
    name = "determinism-wallclock"
    description = "wall-clock reads inside core/ and sim/ decision paths"

    def check(self, mod: Module) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            origin = mod.resolve(node.func)
            if origin in WALL_CLOCK:
                yield Finding(
                    self.name, mod.rel, node.lineno, node.col_offset,
                    f"wall-clock read {origin}() in a decision-path module "
                    "— thread simulated time through explicitly; timing "
                    "telemetry must be baselined with a justification "
                    "attesting it never feeds a decision",
                    mod.qualname(node.lineno))


class UnseededRngRule(_DecisionPathRule):
    name = "determinism-rng"
    description = ("unseeded or global-state RNG inside core/ and sim/ "
                   "decision paths")

    def check(self, mod: Module) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            origin = mod.resolve(node.func)
            if origin is None:
                continue
            msg = None
            if origin == "numpy.random.default_rng" and not node.args:
                msg = ("np.random.default_rng() without a seed — derive "
                       "the seed from the scenario config")
            elif origin == "random.Random" and not node.args:
                msg = ("random.Random() without a seed — derive the seed "
                       "from the scenario config")
            elif (origin.startswith("numpy.random.")
                  and origin.rsplit(".", 1)[1] in NP_GLOBAL_DRAWS):
                msg = (f"global-state numpy RNG call {origin}() — use a "
                       "seeded np.random.default_rng(...) Generator")
            elif (origin.startswith("random.")
                  and origin.count(".") == 1
                  and origin.rsplit(".", 1)[1] in PY_GLOBAL_DRAWS):
                msg = (f"global-state RNG call {origin}() — use a seeded "
                       "random.Random(...) instance")
            if msg:
                yield Finding(self.name, mod.rel, node.lineno,
                              node.col_offset, msg,
                              mod.qualname(node.lineno))


def _is_set_annotation(node: Optional[ast.AST]) -> bool:
    if isinstance(node, ast.Subscript):
        node = node.value
    return isinstance(node, ast.Name) and node.id in ("set", "Set", "frozenset")


def _is_set_expr(node: Optional[ast.AST]) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset")):
        return True
    if isinstance(node, ast.IfExp):
        return _is_set_expr(node.body) or _is_set_expr(node.orelse)
    return False


def _self_attr(node: ast.AST) -> Optional[str]:
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


class SetIterRule(_DecisionPathRule):
    name = "determinism-set-iter"
    description = ("unordered set iteration inside core/ and sim/ "
                   "decision paths")

    MESSAGE = ("iteration over a set — CPython set order is an "
               "implementation detail, so any order-sensitive effect is "
               "ordered by accident; iterate sorted(...) (or pragma with "
               "a justification if provably order-independent)")

    def check(self, mod: Module) -> Iterator[Finding]:
        # Pass 1 (module-wide): attribute NAMES that are set-typed anywhere
        # (``self._dirty: set[int] = ...``) — used both for ``self.X``
        # iteration and for locals aliasing ``<expr>._dirty``.
        set_attrs: set[str] = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.AnnAssign):
                attr = _self_attr(node.target)
                if attr and _is_set_annotation(node.annotation):
                    set_attrs.add(attr)
            elif isinstance(node, ast.Assign):
                if _is_set_expr(node.value):
                    for t in node.targets:
                        attr = _self_attr(t)
                        if attr:
                            set_attrs.add(attr)

        funcs = [n for n in ast.walk(mod.tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for func in funcs:
            yield from self._check_function(mod, func, set_attrs)

    def _own_nodes(self, func: ast.AST) -> Iterator[ast.AST]:
        """Walk a function body without descending into nested defs
        (nested functions are visited as functions of their own)."""
        stack = list(ast.iter_child_nodes(func))
        while stack:
            node = stack.pop()
            yield node
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                stack.extend(ast.iter_child_nodes(node))

    def _check_function(self, mod: Module, func: ast.AST,
                        set_attrs: set[str]) -> Iterator[Finding]:
        # Lexically ordered local assignments: name -> [(lineno, is_set)].
        assigns: dict[str, list[tuple[int, bool]]] = {}

        def record(name: str, lineno: int, is_set: bool) -> None:
            assigns.setdefault(name, []).append((lineno, is_set))

        for node in self._own_nodes(func):
            if isinstance(node, ast.Assign):
                is_set = (_is_set_expr(node.value)
                          or (isinstance(node.value, ast.Attribute)
                              and node.value.attr in set_attrs))
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        record(t.id, node.lineno, is_set)
            elif isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                               ast.Name):
                record(node.target.id, node.lineno,
                       _is_set_annotation(node.annotation)
                       or _is_set_expr(node.value))
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                # loop targets rebind — treat as non-set
                for t in ast.walk(node.target):
                    if isinstance(t, ast.Name):
                        record(t.id, node.lineno, False)

        def latest_is_set(name: str, lineno: int) -> bool:
            best = None
            for ln, is_set in assigns.get(name, ()):
                if ln <= lineno and (best is None or ln >= best[0]):
                    best = (ln, is_set)
            return bool(best and best[1])

        def iter_is_set(expr: ast.AST, lineno: int) -> bool:
            if _is_set_expr(expr):
                return True
            if isinstance(expr, ast.Name):
                return latest_is_set(expr.id, lineno)
            attr = _self_attr(expr)
            if attr is not None:
                return attr in set_attrs
            return False

        for node in self._own_nodes(func):
            iters: list[ast.AST] = []
            if isinstance(node, (ast.For, ast.AsyncFor)):
                iters.append(node.iter)
            elif isinstance(node, (ast.ListComp, ast.DictComp,
                                   ast.GeneratorExp)):
                # SetComp is exempt by construction: its output is itself
                # an unordered set, so the source set's order cannot leak
                # (a list/dict/generator output preserves — and therefore
                # leaks — iteration order).
                iters.extend(gen.iter for gen in node.generators)
            for expr in iters:
                if iter_is_set(expr, expr.lineno):
                    yield Finding(self.name, mod.rel, expr.lineno,
                                  expr.col_offset, self.MESSAGE,
                                  mod.qualname(expr.lineno))
