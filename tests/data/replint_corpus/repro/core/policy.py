"""Corpus: settle helpers from the audited registry are exempt; anything
else in the same file is not."""
from repro.core.task import TaskState


class PolicyDispatcher:
    def submit_hp(self, task):             # good: registry settle helper
        task.state = TaskState.FAILED

    def rogue(self, task):                 # BAD: not in the registry
        task.state = TaskState.VIOLATED
