"""PreemptiveServingEngine behaviour: the paper's scheduler as a serving
feature — HP deadline guarantees, LP preemption, and the beyond-paper
resume mode (KV cache survives preemption)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core.task import Priority
from repro.models import model as M
from repro.serving.cost_model import CostModel, PhaseCost
from repro.serving.engine import (
    PreemptiveServingEngine,
    ServeRequest,
    engine_network_config,
)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # synthetic cost model (fast, deterministic; no timing needed)
    cost = CostModel()
    cost.prefill[1] = PhaseCost(0.05, 0.005)
    cost.decode[2] = PhaseCost(0.02, 0.002)
    cost.decode[4] = PhaseCost(0.014, 0.0014)
    return cfg, params, cost


def _engine(cfg, params, cost, lp_tokens=6, **kw):
    net = engine_network_config(cost, lp_tokens)
    return PreemptiveServingEngine(cfg, params, cost, n_slices=2,
                                   units_per_slice=4, net=net, **kw), net


def _prompt(cfg, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (1, 8), 0,
                              cfg.vocab_size)


def test_engine_network_config_carries_workload_spec(setup):
    """The timing model is a real WorkloadSpec built from the cost model
    (DESIGN.md §10), not constants folded into the three legacy fields —
    and per-degree slot padding uses each degree's OWN measured std-dev."""
    cfg, params, cost = setup
    net = engine_network_config(cost, 10)
    prof = net.profile()
    assert prof.name == "serve"
    assert prof.lp_exec[2] == pytest.approx(0.2)
    assert prof.lp_exec[4] == pytest.approx(0.14)
    assert prof.lp_pad[2] == pytest.approx(0.02)
    assert prof.lp_pad[4] == pytest.approx(0.014)   # not degree 2's 0.02
    # legacy scalar mirrors stay consistent for direct readers
    assert net.t_hp == prof.hp_exec
    assert net.t_lp_2core == prof.lp_exec[2]
    assert net.t_lp_4core == prof.lp_exec[4]


def test_hp_request_completes_within_deadline(setup):
    cfg, params, cost = setup
    eng, net = _engine(cfg, params, cost)
    req = ServeRequest(prompt=_prompt(cfg), max_new_tokens=1,
                       priority=Priority.HIGH, deadline=net.t_hp * 3 + 1.0,
                       home_slice=0)
    eng.submit(req)
    m = eng.run()
    assert req.state == "done"
    assert req.completed_at <= req.deadline + 1e-9
    assert m.hp_completed == 1
    assert len(req.tokens_out) == 1          # real compute happened


def test_lp_generates_requested_tokens(setup):
    cfg, params, cost = setup
    eng, net = _engine(cfg, params, cost, lp_tokens=5)
    req = ServeRequest(prompt=_prompt(cfg), max_new_tokens=5,
                       priority=Priority.LOW, deadline=60.0, home_slice=1)
    eng.submit(req)
    eng.run()
    assert req.state == "done"
    assert len(req.tokens_out) == 5
    assert all(0 <= t < cfg.vocab_size for t in req.tokens_out)


def test_hp_preempts_saturating_lp(setup):
    """Saturate slice 0 with LP work, then submit an HP request with a tight
    deadline: with preemption it completes; without, it fails."""
    cfg, params, cost = setup
    for preemption, expect in ((True, "done"), (False, "failed")):
        eng, net = _engine(cfg, params, cost, preemption=preemption)
        lps = []
        for i in range(4):                  # 4 x 2-core >= 4-unit slice
            lp = ServeRequest(prompt=_prompt(cfg, i + 2), max_new_tokens=4,
                              priority=Priority.LOW, deadline=120.0,
                              home_slice=0)
            lps.append(lp)
            eng.submit(lp)
        hp = ServeRequest(prompt=_prompt(cfg), max_new_tokens=1,
                          priority=Priority.HIGH,
                          deadline=net.t_hp * 2 + 0.2, home_slice=0)
        eng.q.push(0.01, lambda r=hp: eng.submit(r))
        m = eng.run()
        assert hp.state == expect, (preemption, hp.state)
        if preemption:
            assert m.preemptions >= 1
            assert any(lp.n_preemptions > 0 for lp in lps)


def test_resume_mode_keeps_partial_decode(setup):
    """Beyond-paper lose_work=False: a preempted-and-reallocated LP resumes
    from its cached state rather than restarting (paper-faithful mode wipes
    tokens_out on preemption)."""
    cfg, params, cost = setup
    eng, net = _engine(cfg, params, cost, preemption=True, lose_work=False)
    victim = ServeRequest(prompt=_prompt(cfg, 5), max_new_tokens=4,
                          priority=Priority.LOW, deadline=120.0, home_slice=0)
    eng.submit(victim)
    eng.run()
    assert victim.state == "done"
    # decode state registry is cleaned up on completion either way
    assert victim.rid not in eng._decode_state


def test_engine_drives_registered_policy(setup):
    """The engine resolves its discipline through the policy registry
    (DESIGN.md §9): running the edf_only baseline requires no engine edits —
    real compute still lands in that policy's reserved slots."""
    cfg, params, cost = setup
    net = engine_network_config(cost, 4)
    eng = PreemptiveServingEngine(cfg, params, cost, n_slices=2,
                                  units_per_slice=4, net=net,
                                  policy="edf_only")
    hp = ServeRequest(prompt=_prompt(cfg), max_new_tokens=1,
                      priority=Priority.HIGH, deadline=net.t_hp * 3 + 1.0,
                      home_slice=0)
    lp = ServeRequest(prompt=_prompt(cfg, 8), max_new_tokens=4,
                      priority=Priority.LOW, deadline=60.0, home_slice=1)
    eng.submit(hp)
    eng.submit(lp)
    m = eng.run()
    assert hp.state == "done" and lp.state == "done"
    assert len(lp.tokens_out) == 4
    assert m.hp_completed == 1 and m.lp_completed == 1
    assert m.preemptions == 0            # edf_only never preempts


def test_submit_batch_admits_lp_burst(setup):
    """submit_batch routes LP requests through the scheduler's batch API
    (DESIGN.md §4.3) and HP requests through per-request admission; every
    request must settle with correct result/request pairing."""
    cfg, params, cost = setup
    eng, net = _engine(cfg, params, cost, lp_tokens=3)
    lps = [ServeRequest(prompt=_prompt(cfg, i + 20), max_new_tokens=3,
                        priority=Priority.LOW, deadline=300.0,
                        home_slice=i % 2)
           for i in range(4)]
    hp = ServeRequest(prompt=_prompt(cfg, 30), max_new_tokens=1,
                      priority=Priority.HIGH, deadline=net.t_hp * 3 + 1.0,
                      home_slice=0)
    eng.submit_batch(lps + [hp])
    m = eng.run()
    assert hp.state == "done"
    assert [r.state for r in lps] == ["done"] * 4
    # positional pairing: each request generated ITS token budget
    assert all(len(r.tokens_out) == 3 for r in lps)
    assert m.lp_requests_total == 4 and m.lp_allocated == 4
    assert m.lp_completed == 4 and m.hp_completed == 1
