"""Static lint over the Pallas kernel sources: no bare-int ``pl.load``
indices.

This JAX version's interpret-mode discharge rule for ``pl.load`` rejects a
bare Python int inside the index tuple (``'int' object has no attribute
'shape'``) — the bug that broke all 18 flash-attention sweeps until the
index was rewritten as ``pl.ds(0, 1)`` + squeeze.  The check is the
``pallas-index`` AST rule from ``repro.analysis`` (which replaced this
file's original regex/paren-walker), run here per kernel file so the class
cannot regress silently and the offender is named in the test id.
"""
import textwrap
from pathlib import Path

import pytest

from repro.analysis import PallasIndexRule, run_analysis

SRC = Path(__file__).parent.parent / "src"
KERNELS_DIR = SRC / "repro" / "kernels"


def _kernel_sources() -> list[Path]:
    return sorted(KERNELS_DIR.rglob("*.py"))


def test_kernel_sources_exist():
    assert _kernel_sources(), f"no kernel sources under {KERNELS_DIR}"


@pytest.mark.parametrize("path", _kernel_sources(),
                         ids=lambda p: str(p.relative_to(KERNELS_DIR)))
def test_no_bare_int_pl_load_indices(path):
    report = run_analysis(SRC, rules=[PallasIndexRule()], files=[path])
    assert not report.findings, "\n".join(
        f"{f.path}:{f.line}: {f.message}" for f, _ in report.findings
    )


def test_rule_catches_known_bad_pattern(tmp_path):
    """The exact shape of the PR 3 bug — plus the swap variant and a
    multi-line call the old regex needed whitespace-flattening for —
    must still be caught after the AST migration."""
    bad = tmp_path / "repro" / "kernels" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text(textwrap.dedent("""\
        from jax.experimental import pallas as pl

        def kernel(q_ref, o_ref):
            row = pl.load(q_ref, (0, pl.ds(0, 4)))
            pl.store(
                o_ref,
                (pl.ds(0, 4),
                 0),
                row,
            )
            pl.swap(o_ref, (-1, pl.ds(0, 4)), row)
    """))
    report = run_analysis(tmp_path, rules=[PallasIndexRule()])
    lines = sorted(f.line for f, _ in report.findings)
    assert lines == [4, 5, 11], report.findings
