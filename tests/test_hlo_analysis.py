"""HLO collective-parsing unit tests (roofline methodology)."""
def test_hlo_tuple_allreduce_parsing():
    """Fused gradient all-reduces with /*index=N*/ tuple comments must be
    counted (regression: the tuple regex once rejected '=' inside)."""
    from repro.launch.hlo_analysis import collective_bytes
    txt = ("  %all-reduce.768 = (f32[4,4096]{1,0}, f32[4,4096]{1,0}, "
           "f32[4,4096]{1,0}, f32[4,4096]{1,0}, f32[4,4096]{1,0}, "
           "/*index=5*/f32[8192,2048]{1,0}, f32[8192,2048]{1,0}) "
           "all-reduce(%a, %b), channel_id=1, "
           "replica_groups=[1,256]<=[256], use_global_device_ids=true\n")
    s = collective_bytes(txt)
    expected = 2 * (5 * 4 * 4096 * 4 + 2 * 8192 * 2048 * 4) * (255 / 256)
    assert abs(s.by_kind["all-reduce"] - expected) < 1.0
    assert s.count == 1
