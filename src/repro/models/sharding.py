"""Logical-axis -> mesh-axis sharding rules with divisibility fallback.

Baseline (paper-faithful floor, "divisibility-driven"): every parameter
shards its tensor-parallel-able axis on ``model`` and its embed axis on
``data`` (FSDP) *iff* the dimension is divisible by the mesh axis size;
otherwise that axis is replicated.  Activations shard batch on
``(pod, data)``; decode caches shard batch on ``(pod, data)`` and heads /
d_inner on ``model``; for long_500k (batch 1) caches shard the *sequence*
slot axis on ``data``.

The optimized variants (§Perf) override individual rules — see
``RuleSet`` fields.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Logical axis -> preferred mesh axis (None = replicate).
BASE_RULES: dict[str, Optional[str]] = {
    "vocab": "model",
    "embed": "data",            # FSDP weight shard
    "embed2": None,
    "ff": "model",
    "expert_ff": "model",
    "experts": "model",
    "heads": "model",
    "kv_heads": "model",
    "heads_flat": "model",
    "head_dim": None,
    "head_dim2": None,
    "modality": None,
    "layers": None,             # scan axis, never sharded
    "q_rank": None,
    "kv_rank": None,
    "kv_rank_rope": None,
    "rope_dim": None,
    "d_inner": "model",
    "d_inner2": "model",
    "dt_state": None,
    "dt_rank": None,
    "state": None,
    "conv": None,
    "gates": None,
    # activations / caches
    "batch": ("pod", "data"),
    "seq": None,
    "cache": None,
}


@dataclass(frozen=True)
class RuleSet:
    """Sharding policy knobs (baseline + §Perf overrides)."""

    rules: dict = field(default_factory=lambda: dict(BASE_RULES))
    # decode/batch==1: shard cache sequence axis on data
    shard_cache_seq_when_b1: bool = True
    # activations: shard sequence on data when batch < data-axis size
    shard_seq_when_small_batch: bool = True
    # §Perf (measured, EXPERIMENTS.md): when a decode cache cannot shard its
    # head axis on `model` (kv_heads % model != 0, or MLA's head-less latent
    # cache), shard the cache *sequence* axis on `model` instead of
    # replicating.  Replication invites GSPMD to re-shard + all-gather the
    # whole cache every step (llava decode_32k: 112.7 GB/step wire).
    # False reproduces the paper-faithful divisibility-only baseline.
    seq_shard_cache_fallback: bool = True

    def with_overrides(self, **over) -> "RuleSet":
        r = dict(self.rules)
        r.update(over)
        return replace(self, rules=r)


def _mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _axis_size(mesh_sizes: dict, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh_sizes.get(a, 1) for a in axis]))
    return mesh_sizes.get(axis, 1)


def spec_for(
    axes: tuple,
    shape: tuple,
    mesh: Mesh,
    ruleset: RuleSet,
) -> P:
    """Build a PartitionSpec for one leaf, checking divisibility per axis."""
    sizes = _mesh_axis_sizes(mesh)
    out = []
    used: set = set()
    for dim, name in zip(shape, axes):
        axis = ruleset.rules.get(name)
        if axis is None:
            out.append(None)
            continue
        # drop mesh axes not present in this mesh (e.g. 'pod' on single pod)
        if isinstance(axis, tuple):
            axis = tuple(a for a in axis if a in sizes)
            if not axis:
                out.append(None)
                continue
            flat: tuple = axis
        else:
            if axis not in sizes:
                out.append(None)
                continue
            flat = (axis,)
        if any(a in used for a in flat):
            out.append(None)
            continue
        if dim % _axis_size(sizes, axis) != 0:
            out.append(None)            # divisibility fallback: replicate
            continue
        used.update(flat)
        out.append(axis if not isinstance(axis, tuple) else axis)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(
    tree_axes,
    tree_shapes,          # pytree of ShapeDtypeStruct (or arrays)
    mesh: Mesh,
    ruleset: Optional[RuleSet] = None,
):
    """Map (axes tree, abstract tree) -> tree of NamedShardings."""
    ruleset = ruleset or RuleSet()

    def one(axes, leaf):
        return NamedSharding(mesh, spec_for(axes, leaf.shape, mesh, ruleset))

    return jax.tree.map(
        one, tree_axes, tree_shapes,
        is_leaf=lambda v: isinstance(v, tuple) and all(
            isinstance(x, (str, type(None))) for x in v),
    )


# --------------------------------------------------------------------------- #
# Activation shardings                                                        #
# --------------------------------------------------------------------------- #


def batch_spec(mesh: Mesh, global_batch: int, seq_len: int,
               ruleset: Optional[RuleSet] = None) -> P:
    """Sharding for [B, T] token arrays (and [B, T, ...] activations)."""
    ruleset = ruleset or RuleSet()
    sizes = _mesh_axis_sizes(mesh)
    rule = ruleset.rules.get("batch", ("pod", "data"))
    if rule is None:
        rule = ()
    elif isinstance(rule, str):
        rule = (rule,)
    dp_axes = tuple(a for a in rule if a in sizes)
    dp = int(np.prod([sizes[a] for a in dp_axes]))
    if global_batch % dp == 0:
        return P(dp_axes, None)
    if ruleset.shard_seq_when_small_batch and seq_len % dp == 0:
        return P(None, dp_axes)
    # fall back: shard over the largest dividing prefix of dp axes
    for k in range(len(dp_axes), 0, -1):
        sub = dp_axes[:k]
        if global_batch % _axis_size(sizes, sub) == 0:
            return P(sub, None)
    return P(None, None)


def cache_batch_rules(mesh: Mesh, global_batch: int,
                      ruleset: Optional[RuleSet] = None,
                      prefer_seq_shard: bool = False) -> RuleSet:
    """Decode-cache ruleset: when batch can't use the data axis (B=1
    long-context), shard the cache slot axis on data instead.

    ``prefer_seq_shard`` (§Perf default, see RuleSet.seq_shard_cache_fallback)
    shards the cache sequence axis on `model` when the caller determined the
    head axis can't be — measured 34.6x / 7.0x dominant-term wins on
    llava/deepseek-v3 decode_32k."""
    ruleset = ruleset or RuleSet()
    sizes = _mesh_axis_sizes(mesh)
    dp_axes = tuple(a for a in ("pod", "data") if a in sizes)
    dp = int(np.prod([sizes[a] for a in dp_axes]))
    if global_batch % dp == 0:
        out = ruleset.with_overrides(batch=dp_axes)
        if (prefer_seq_shard and ruleset.seq_shard_cache_fallback
                and ruleset.rules.get("cache") is None
                and "model" in sizes):
            out = out.with_overrides(cache="model")
        return out
    if ruleset.shard_cache_seq_when_b1:
        return ruleset.with_overrides(batch=None, cache="data")
    return ruleset.with_overrides(batch=None, cache=None)
