"""Open-ended arrival traces for the streaming engine.

The closed-workload generators (``sim/traces.py``, ``sim/scenarios.py``)
materialise a whole experiment's arrivals up front.  A soak run can't:
this module yields :class:`~repro.serving.stream.StreamArrival` records
**lazily** from a seeded generator, so a 10^6-request trace costs O(1)
memory and two runs with the same :class:`FirehoseConfig` produce the
identical arrival sequence (the streaming determinism test leans on
this).

Arrival process: a network-wide Poisson stream at ``rate`` arrivals per
virtual second, optionally modulated by a square-wave burst (``rate *
(1 + burstiness)`` during the first ``burst_duty`` of every
``burst_period`` — a crude on/off MMPP that exercises backpressure and
shedding without changing the long-run offered load much).  Each arrival
independently draws its device, priority class, LP set size and task
type from the config's distributions.  Deadlines stay relative (or
profile-derived) — the engine makes them absolute against its workload
profiles at offer time.
"""
from __future__ import annotations

import random
import zlib
from dataclasses import dataclass
from typing import Iterator, Optional, Sequence

from ..core.task import Priority

# NOTE: ``serving.stream`` is imported inside :func:`firehose`, not here —
# stream.py imports ``sim.events`` (and importing any ``sim`` submodule
# runs ``sim/__init__``, which imports this module), so a module-level
# import would be circular whichever side is loaded first.


@dataclass(frozen=True)
class FirehoseConfig:
    """A seeded, unbounded arrival stream (all rates in virtual seconds)."""

    name: str = "firehose"
    n_devices: int = 64
    rate: float = 100.0                 # network-wide arrivals / s
    lp_fraction: float = 0.4            # P(arrival is an LP request set)
    lp_set_sizes: Sequence[int] = (1, 2, 3, 4)
    task_mix: Sequence[tuple[Optional[str], float]] = ((None, 1.0),)
    burstiness: float = 0.0             # extra rate multiplier in bursts
    burst_period: float = 4.0           # seconds per on/off cycle
    burst_duty: float = 0.25            # burst fraction of each cycle
    hp_rel_deadline: Optional[float] = None   # None -> profile-derived
    lp_rel_deadline: Optional[float] = None   # None -> profile-derived
    seed: int = 0

    def __post_init__(self) -> None:
        if self.n_devices < 1:
            raise ValueError("n_devices must be >= 1")
        if self.rate <= 0.0:
            raise ValueError("rate must be positive")
        if not (0.0 <= self.lp_fraction <= 1.0):
            raise ValueError("lp_fraction must be in [0, 1]")
        if not self.lp_set_sizes or min(self.lp_set_sizes) < 1:
            raise ValueError("lp_set_sizes must be non-empty, all >= 1")
        if self.burstiness < 0.0:
            raise ValueError("burstiness must be >= 0")


def firehose(cfg: FirehoseConfig,
             limit: Optional[int] = None) -> Iterator["StreamArrival"]:
    """Yield arrivals forever (or up to ``limit``) — O(1) memory, fully
    determined by ``cfg`` (including its seed)."""
    from ..serving.stream import StreamArrival  # lazy: see module note

    # name-salted seed, crc32 not hash() (stable across PYTHONHASHSEED) —
    # the same per-stream independence trick sim/traces.py uses
    rng = random.Random(cfg.seed ^ zlib.crc32(cfg.name.encode()))
    types = [t for t, _ in cfg.task_mix]
    weights = [w for _, w in cfg.task_mix]
    sizes = tuple(cfg.lp_set_sizes)
    t = 0.0
    n = 0
    while limit is None or n < limit:
        rate = cfg.rate
        if cfg.burstiness > 0.0:
            phase = (t % cfg.burst_period) / cfg.burst_period
            if phase < cfg.burst_duty:
                rate *= 1.0 + cfg.burstiness
        t += rng.expovariate(rate)
        task_type = types[0] if len(types) == 1 \
            else rng.choices(types, weights)[0]
        device = rng.randrange(cfg.n_devices)
        if rng.random() < cfg.lp_fraction:
            yield StreamArrival(
                t=t, device=device, priority=Priority.LOW,
                n_tasks=rng.choice(sizes), task_type=task_type,
                rel_deadline=cfg.lp_rel_deadline)
        else:
            yield StreamArrival(
                t=t, device=device, priority=Priority.HIGH,
                task_type=task_type, rel_deadline=cfg.hp_rel_deadline)
        n += 1
