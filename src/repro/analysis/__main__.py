"""CLI: ``python -m repro.analysis [paths...] [--gate] [--json PATH]``.

Exit codes: 0 clean (or report-only mode), 1 gate failure (unbaselined
findings or stale baseline entries), 2 usage/budget errors.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from .engine import default_rules, load_baseline, run_analysis


def _default_root() -> Path:
    # .../src/repro/analysis/__main__.py -> .../src
    return Path(__file__).resolve().parents[2]


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based invariant lint plane (replint)")
    parser.add_argument("paths", nargs="*",
                        help="files/dirs to restrict the scan to "
                             "(default: the whole source root)")
    parser.add_argument("--root", type=Path, default=None,
                        help="analysis root (default: the src/ directory "
                             "containing the repro package)")
    parser.add_argument("--baseline", type=Path, default=None,
                        help="baseline JSON (default: "
                             "<root>/../replint_baseline.json when present)")
    parser.add_argument("--rules", default=None,
                        help="comma-separated rule names to run "
                             "(default: all)")
    parser.add_argument("--gate", action="store_true",
                        help="exit 1 on any unbaselined finding or stale "
                             "baseline entry")
    parser.add_argument("--json", type=Path, default=None, metavar="PATH",
                        help="write the deterministic JSON report here")
    parser.add_argument("--budget-s", type=float, default=None,
                        help="fail (exit 2) if the run exceeds this many "
                             "wall-clock seconds — keeps the CI gate cheap")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    args = parser.parse_args(argv)

    rules = default_rules()
    if args.list_rules:
        for rule in rules:
            print(f"{rule.name}: {rule.description}")
        return 0
    if args.rules:
        wanted = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = wanted - {r.name for r in rules}
        if unknown:
            print(f"unknown rule(s): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2
        rules = [r for r in rules if r.name in wanted]

    root = (args.root or _default_root()).resolve()
    baseline_path = args.baseline
    if baseline_path is None:
        candidate = root.parent / "replint_baseline.json"
        baseline_path = candidate if candidate.exists() else None
    baseline = load_baseline(baseline_path) if baseline_path else {}

    files = None
    if args.paths:
        files = []
        for p in args.paths:
            p = Path(p).resolve()
            files.extend(p.rglob("*.py") if p.is_dir() else [p])

    t0 = time.perf_counter()
    report = run_analysis(root, rules=rules, files=files, baseline=baseline,
                          root_label=root.name)
    elapsed = time.perf_counter() - t0

    if args.json:
        args.json.write_text(report.to_json())

    for f, _key in report.findings:
        loc = f"{root / f.path}:{f.line}:{f.col}"
        sym = f" [in {f.symbol}]" if f.symbol else ""
        print(f"{loc}: {f.rule}: {f.message}{sym}")
    for key in report.stale_baseline:
        print(f"stale baseline entry (finding no longer exists — remove "
              f"it): {key}")
    c = report.to_dict()["counts"]
    print(f"replint: {report.files_scanned} files, "
          f"{c['findings']} finding(s), {c['baselined']} baselined, "
          f"{c['suppressed']} pragma-suppressed, "
          f"{c['stale_baseline']} stale baseline entr(ies) "
          f"[{elapsed:.2f}s]")

    if args.budget_s is not None and elapsed > args.budget_s:
        print(f"replint: wall-clock budget exceeded: {elapsed:.2f}s > "
              f"{args.budget_s:.2f}s", file=sys.stderr)
        return 2
    if args.gate and not report.gate_ok:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
