"""Experiment counters — one field-group per paper figure/table."""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from statistics import mean
from typing import Any


def _mean_ms(xs) -> float:
    """Mean in milliseconds of a latency series — a plain list or any sink
    exposing an exact ``mean()`` (telemetry.BoundedSeries on the streaming
    path, whose iteration covers only its recent window)."""
    if not xs:
        return 0.0
    m = xs.mean() if hasattr(xs, "mean") else mean(xs)
    return m * 1e3


@dataclass
class Metrics:
    scenario: str = ""

    # Fig 2 — frame completion
    frames_total: int = 0
    frames_completed: int = 0

    # Fig 3 — high-priority completion (split by whether preemption was used)
    hp_generated: int = 0
    hp_completed: int = 0
    hp_completed_via_preemption: int = 0
    hp_failed_alloc: int = 0
    hp_failed_runtime: int = 0

    # Fig 4/5/6, Table 2 — low-priority completion
    lp_generated: int = 0
    lp_allocated: int = 0
    lp_completed: int = 0
    lp_failed_alloc: int = 0
    lp_failed_runtime: int = 0
    lp_offloaded: int = 0
    lp_offloaded_completed: int = 0
    lp_requests_total: int = 0
    lp_requests_completed: int = 0
    lp_request_fractions: list[float] = field(default_factory=list)

    # Streaming path (serving/stream.py) — load shedding at the admission
    # queue.  A shed request's tasks never reach the scheduler: they are
    # their own terminal bucket, partitioning the generated set together
    # with the completed/failed counters (tests/test_accounting_invariants).
    # Always zero on the closed-workload paths, where the summary keys are
    # omitted so legacy summaries (and the golden replays) stay byte-equal.
    hp_shed: int = 0
    lp_shed: int = 0
    lp_degraded: int = 0

    # Variant ladder (DESIGN.md §17) — accuracy-aware degradation.
    # ``variant_admissions`` histograms LP allocations by the ladder rung
    # they were admitted at (rung > 0 only; a legacy one-bit degrade on a
    # ladder-free profile counts under rung 1).  ``lp_accuracy_completed``
    # accumulates the admitted rung's benchmark accuracy over completed LP
    # tasks — the numerator of accuracy-weighted goodput.  The accumulator
    # runs unconditionally (deterministic, same order as lp_completed), but
    # the summary keys appear only when some task ran degraded, so
    # ladder-free summaries stay byte-identical.
    variant_admissions: Counter = field(default_factory=Counter)
    lp_accuracy_completed: float = 0.0
    degrade_shrinks: int = 0        # degrade-instead-of-evict shrink count

    # Churn plane (DESIGN.md §16) — device lifecycle events and orphan
    # recovery.  Orphans are NOT a new terminal bucket: a recovered orphan
    # counts realloc_success (then completes or fails at runtime like any
    # allocation), an unrecoverable LP orphan counts realloc_failure, and a
    # non-re-admittable HP orphan counts hp_failed_alloc — the existing
    # partition absorbs all of them.  These counters are observability
    # only; always zero (and omitted from summaries) without churn.
    device_failures: int = 0
    device_drains: int = 0
    device_rejoins: int = 0
    orphans_created: int = 0
    orphans_recovered: int = 0

    # Fig 7, Table 3 — preemption
    preemptions: int = 0
    preempted_by_cores: Counter = field(default_factory=Counter)
    realloc_success: int = 0
    realloc_failure: int = 0

    # Fig 8 — core allocation of LP tasks
    core_alloc_local: Counter = field(default_factory=Counter)
    core_alloc_offloaded: Counter = field(default_factory=Counter)

    # Fig 9/10 — scheduler wall-clock times (seconds)
    t_hp_initial: list[float] = field(default_factory=list)
    t_hp_preempt: list[float] = field(default_factory=list)
    t_lp_alloc: list[float] = field(default_factory=list)
    t_realloc: list[float] = field(default_factory=list)
    # eviction-loop phase of preempting HP admissions only (DESIGN.md §12;
    # the quantity bench_preemption's vectorized-vs-scalar gate compares)
    t_evict: list[float] = field(default_factory=list)

    # Heterogeneous workloads (core/profiles.py): outcome counters per task
    # type.  Un-annotated tasks (task_type=None — the paper's single-model
    # world) record nothing here, so legacy summaries stay byte-identical.
    task_type_counts: dict[str, Counter] = field(default_factory=dict)

    def count_type(self, task_type, key: str, n: int = 1) -> None:
        """Bump a per-task-type outcome counter (no-op for untyped tasks)."""
        if task_type is None:
            return
        self.task_type_counts.setdefault(task_type, Counter())[key] += n

    def pct(self, num: int, den: int) -> float:
        return 100.0 * num / den if den else 0.0

    def summary(self) -> dict[str, Any]:
        out: dict[str, Any] = {
            "scenario": self.scenario,
            "frames_total": self.frames_total,
            "frame_completion_pct": round(
                self.pct(self.frames_completed, self.frames_total), 2
            ),
            "hp_generated": self.hp_generated,
            # Raw terminal-outcome counts: together with the ``realloc_*``
            # pair below they partition the generated task set (asserted
            # per scenario x policy by tests/test_accounting_invariants.py).
            "hp_completed": self.hp_completed,
            "hp_failed_alloc": self.hp_failed_alloc,
            "hp_failed_runtime": self.hp_failed_runtime,
            "lp_completed": self.lp_completed,
            "lp_failed_alloc": self.lp_failed_alloc,
            "lp_failed_runtime": self.lp_failed_runtime,
            "hp_completion_pct": round(self.pct(self.hp_completed, self.hp_generated), 2),
            "hp_via_preemption_pct": round(
                self.pct(self.hp_completed_via_preemption, self.hp_generated), 2
            ),
            "lp_generated": self.lp_generated,
            "lp_completion_pct": round(self.pct(self.lp_completed, self.lp_generated), 2),
            "lp_offloaded": self.lp_offloaded,
            "lp_offloaded_completion_pct": round(
                self.pct(self.lp_offloaded_completed, self.lp_offloaded), 2
            ),
            "lp_per_request_completion_pct": round(
                100.0 * mean(self.lp_request_fractions), 2
            )
            if self.lp_request_fractions
            else 0.0,
            "lp_set_completion_pct": round(
                self.pct(self.lp_requests_completed, self.lp_requests_total), 2
            ),
            "preemptions": self.preemptions,
            "preempted_2core": self.preempted_by_cores.get(2, 0),
            "preempted_4core": self.preempted_by_cores.get(4, 0),
            "realloc_success": self.realloc_success,
            "realloc_failure": self.realloc_failure,
            "core2_local": self.core_alloc_local.get(2, 0),
            "core4_local": self.core_alloc_local.get(4, 0),
            "core2_offloaded": self.core_alloc_offloaded.get(2, 0),
            "core4_offloaded": self.core_alloc_offloaded.get(4, 0),
            "t_hp_initial_ms": round(_mean_ms(self.t_hp_initial), 3),
            "t_hp_preempt_ms": round(_mean_ms(self.t_hp_preempt), 3),
            "t_lp_alloc_ms": round(_mean_ms(self.t_lp_alloc), 3),
            "t_realloc_ms": round(_mean_ms(self.t_realloc), 3),
        }
        if self.hp_shed or self.lp_shed or self.lp_degraded:
            # Present only on the streaming path: closed-workload summaries
            # keep their historic key set (golden replays compare exact
            # dict equality).
            out["hp_shed"] = self.hp_shed
            out["lp_shed"] = self.lp_shed
            out["lp_degraded"] = self.lp_degraded
        if self.variant_admissions or self.degrade_shrinks:
            # Present only when the variant ladder actually fired (a task
            # was admitted below rung 0 or shrunk in place): ladder-free
            # runs — every committed golden — keep their historic key set.
            out["variant_admissions"] = {
                str(v): n for v, n in sorted(self.variant_admissions.items())
            }
            out["degrade_shrinks"] = self.degrade_shrinks
            out["accuracy_goodput_pct"] = round(
                100.0 * self.lp_accuracy_completed / self.lp_generated, 2
            ) if self.lp_generated else 0.0
        if (self.device_failures or self.device_drains
                or self.device_rejoins or self.orphans_created):
            # Present only under churn: the closed-workload golden replays
            # (and every churn-free run) keep their historic key set.
            out["device_failures"] = self.device_failures
            out["device_drains"] = self.device_drains
            out["device_rejoins"] = self.device_rejoins
            out["orphans_created"] = self.orphans_created
            out["orphans_recovered"] = self.orphans_recovered
        if self.task_type_counts:
            # Present only for heterogeneous workloads: single-model (paper)
            # summaries keep their historic key set, which the golden-replay
            # suite compares with exact dict equality.
            out["task_types"] = {
                t: dict(sorted(c.items()))
                for t, c in sorted(self.task_type_counts.items())
            }
        return out
