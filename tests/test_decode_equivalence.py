"""prefill + decode_step must agree with the full-sequence forward for every
architecture (MoE capacity pinned high so no tokens drop — capacity-based
dispatch is not strictly causal under drops, which is expected)."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import model as M

T = 12
TOL = 2e-4


def _uncap(cfg):
    if cfg.moe is not None:
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=8.0))
    return cfg


def _batches(cfg, key):
    tokens = jax.random.randint(key, (2, T + 1), 0, cfg.vocab_size)
    full = {"tokens": tokens}
    pre = {"tokens": tokens[:, :T]}
    if cfg.modality_embed_dim:
        n_mod = cfg.n_modality_tokens or T
        emb = jax.random.normal(jax.random.PRNGKey(9),
                                (2, n_mod, cfg.modality_embed_dim))
        full["modality_emb"] = emb
        pre["modality_emb"] = emb
    return full, pre, tokens


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch):
    cfg = _uncap(get_smoke_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    full, pre, tokens = _batches(cfg, jax.random.PRNGKey(1))

    # positions are offset by any prepended image tokens (decoder-only VLM)
    pos_off = 0
    if cfg.modality_embed_dim and not cfg.is_encoder_decoder:
        pos_off = full["modality_emb"].shape[1]

    full_logits, _ = M.forward(params, cfg, full)
    pre_logits, caches = M.prefill(params, cfg, pre, cache_len=64)
    err_pre = float(jnp.abs(
        pre_logits[:, 0] - full_logits[:, pos_off + T - 1]).max())
    assert err_pre < TOL, f"prefill mismatch {err_pre}"

    dec_logits, caches = M.decode_step(
        params, cfg, caches, tokens[:, T:T + 1], jnp.int32(T + pos_off))
    err_dec = float(jnp.abs(
        dec_logits[:, 0] - full_logits[:, pos_off + T]).max())
    assert err_dec < TOL, f"decode mismatch {err_dec}"


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen2-0.5b"])
def test_multi_step_decode_chain(arch):
    """Three consecutive decode steps track the full forward."""
    cfg = _uncap(get_smoke_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, T + 3), 0,
                                cfg.vocab_size)
    full_logits, _ = M.forward(params, cfg, {"tokens": tokens})
    _, caches = M.prefill(params, cfg, {"tokens": tokens[:, :T]},
                          cache_len=32)
    for i in range(3):
        dec_logits, caches = M.decode_step(
            params, cfg, caches, tokens[:, T + i:T + i + 1], jnp.int32(T + i))
        err = float(jnp.abs(dec_logits[:, 0] - full_logits[:, T + i]).max())
        assert err < TOL, f"step {i}: {err}"


def test_sliding_window_decode_matches_windowed_forward():
    """Rotating cache + window masks == full-seq sliding-window attention."""
    cfg = _uncap(get_smoke_config("smollm-135m"))
    cfg = replace(cfg, sliding_window=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 21), 0,
                                cfg.vocab_size)
    full_logits, _ = M.forward(params, cfg, {"tokens": tokens})
    # cache_len == window -> rotating writes
    _, caches = M.prefill(params, cfg, {"tokens": tokens[:, :16]},
                          cache_len=8)
    for i in range(4):
        dec_logits, caches = M.decode_step(
            params, cfg, caches, tokens[:, 16 + i:17 + i], jnp.int32(16 + i))
        err = float(jnp.abs(dec_logits[:, 0] - full_logits[:, 16 + i]).max())
        assert err < TOL, f"windowed step {i}: {err}"
