"""The composable model: embeddings, (optional) encoder, decoder stages,
LM head — with init / forward / prefill / decode_step entry points and
mirror logical-axis trees for sharding.

Modality carve-out (per the brief): audio/vision frontends are stubs — the
model consumes precomputed frame/patch embeddings (``modality_emb``) through
a learned 2-layer projector; everything downstream is real.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from .blocks import (
    LayerCtx,
    stage_apply,
    stage_axes,
    stage_cache_axes,
    stage_cache_init,
    stage_init,
)
from .config import ModelConfig
from .layers.common import dense_init, normal_init, rmsnorm, rmsnorm_axes, \
    rmsnorm_init

Params = dict
Caches = dict


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# --------------------------------------------------------------------------- #
# Init                                                                        #
# --------------------------------------------------------------------------- #


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 6 + len(cfg.stages) + len(cfg.encoder_stages))
    p: Params = {
        "embed": normal_init(keys[0], (cfg.padded_vocab, cfg.d_model), 0.02, dt),
        "final_norm": rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.padded_vocab, dtype=dt)
    if cfg.modality_embed_dim:
        p["proj_in"] = dense_init(keys[2], cfg.modality_embed_dim, cfg.d_model,
                                  dtype=dt)
        p["proj_mid"] = dense_init(keys[3], cfg.d_model, cfg.d_model, dtype=dt)
    for i, st in enumerate(cfg.encoder_stages):
        p[f"enc{i}"] = stage_init(keys[4 + i], st, cfg, dt)
    if cfg.encoder_stages:
        p["enc_norm"] = rmsnorm_init(cfg.d_model, dt)
    off = 4 + len(cfg.encoder_stages)
    for i, st in enumerate(cfg.stages):
        p[f"dec{i}"] = stage_init(keys[off + i], st, cfg, dt)
    return p


def params_axes(cfg: ModelConfig) -> dict:
    a: dict = {
        "embed": ("vocab", "embed"),
        "final_norm": rmsnorm_axes(),
    }
    if not cfg.tie_embeddings:
        a["lm_head"] = ("embed", "vocab")
    if cfg.modality_embed_dim:
        a["proj_in"] = ("modality", "embed")
        a["proj_mid"] = ("embed", "embed2")
    for i, st in enumerate(cfg.encoder_stages):
        a[f"enc{i}"] = stage_axes(st, cfg)
    if cfg.encoder_stages:
        a["enc_norm"] = rmsnorm_axes()
    for i, st in enumerate(cfg.stages):
        a[f"dec{i}"] = stage_axes(st, cfg)
    return a


def abstract_params(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct param tree (no allocation) for dry-runs."""
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


# --------------------------------------------------------------------------- #
# Embedding / head                                                            #
# --------------------------------------------------------------------------- #


def embed_tokens(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embed"], tokens, axis=0)


def project_modality(params: Params, emb: jax.Array) -> jax.Array:
    h = jnp.einsum("bsm,md->bsd", emb, params["proj_in"])
    h = jax.nn.gelu(h)
    return jnp.einsum("bsd,de->bse", h, params["proj_mid"])


def lm_logits(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return jnp.einsum("btd,vd->btv", x, params["embed"])
    return jnp.einsum("btd,dv->btv", x, params["lm_head"])


# --------------------------------------------------------------------------- #
# Encoder                                                                     #
# --------------------------------------------------------------------------- #


def encode(params: Params, cfg: ModelConfig, enc_input: jax.Array,
           remat: bool = False, unroll: int | bool = 1) -> jax.Array:
    """enc_input [B, S, d] (already projected frame embeddings)."""
    positions = jnp.arange(enc_input.shape[1])
    ctx = LayerCtx(cfg=cfg, positions=positions, causal=False)
    x = enc_input
    for i, st in enumerate(cfg.encoder_stages):
        x, _, _ = stage_apply(params[f"enc{i}"], st, x, ctx, remat=remat,
                              unroll=unroll)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


# --------------------------------------------------------------------------- #
# Decoder forward (training / prefill, full sequence)                         #
# --------------------------------------------------------------------------- #


def _decoder_input(params: Params, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Builds [B, T, d] decoder input from tokens (+ modality embeddings for
    decoder-only multimodal archs, where they are *prepended*)."""
    x = embed_tokens(params, cfg, batch["tokens"])
    if cfg.modality_embed_dim and not cfg.is_encoder_decoder:
        vis = project_modality(params, batch["modality_emb"])
        x = jnp.concatenate([vis, x], axis=1)
    return x


def forward(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    *,
    remat: bool = False,
    moe_group_size: int = 256,
    unroll: int | bool = 1,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence decode-only/enc-dec forward.

    batch: {"tokens": [B, T_text] int32,
            "modality_emb": [B, S_mod, modality_dim] (audio/vision archs)}
    Returns (logits [B, T, padded_vocab], aux_loss).
    """
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_in = project_modality(params, batch["modality_emb"])
        enc_out = encode(params, cfg, enc_in, remat=remat, unroll=unroll)
    x = _decoder_input(params, cfg, batch)
    positions = jnp.arange(x.shape[1])
    ctx = LayerCtx(cfg=cfg, positions=positions, causal=True,
                   window=cfg.sliding_window, enc_out=enc_out,
                   moe_group_size=moe_group_size, inner_unroll=unroll)
    aux = jnp.zeros((), jnp.float32)
    for i, st in enumerate(cfg.stages):
        x, _, a = stage_apply(params[f"dec{i}"], st, x, ctx, remat=remat,
                              unroll=unroll)
        aux = aux + a
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(params, cfg, x), aux


# --------------------------------------------------------------------------- #
# KV / state caches                                                           #
# --------------------------------------------------------------------------- #


def init_caches(cfg: ModelConfig, batch: int, cache_len: int,
                enc_len: int = 0) -> Caches:
    dt = _dtype(cfg)
    return {
        f"dec{i}": stage_cache_init(st, cfg, batch, cache_len, dt, enc_len)
        for i, st in enumerate(cfg.stages)
    }


def caches_axes(cfg: ModelConfig) -> dict:
    return {
        f"dec{i}": stage_cache_axes(st) for i, st in enumerate(cfg.stages)
    }


def abstract_caches(cfg: ModelConfig, batch: int, cache_len: int,
                    enc_len: int = 0) -> Caches:
    return jax.eval_shape(
        lambda: init_caches(cfg, batch, cache_len, enc_len))


# --------------------------------------------------------------------------- #
# Prefill (fill caches with a prompt) and single-token decode                 #
# --------------------------------------------------------------------------- #


def prefill(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    cache_len: int,
    *,
    moe_group_size: int = 256,
    unroll: int | bool = 1,
) -> tuple[jax.Array, Caches]:
    """Runs the full prompt, returns (last-position logits, filled caches).

    Prefill recomputes K/V for the whole prompt and writes them into the
    cache in one shot (scatter-free: dynamic_update_slice at 0).
    """
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_in = project_modality(params, batch["modality_emb"])
        enc_out = encode(params, cfg, enc_in, unroll=unroll)
    x = _decoder_input(params, cfg, batch)
    b, t, _ = x.shape
    positions = jnp.arange(t)
    window = cfg.sliding_window
    ctx = LayerCtx(cfg=cfg, positions=positions, causal=True, window=window,
                   enc_out=enc_out, moe_group_size=moe_group_size,
                   inner_unroll=unroll)
    caches = init_caches(cfg, b, cache_len,
                         enc_len=enc_out.shape[1] if enc_out is not None else 0)
    new_caches: Caches = {}
    aux = jnp.zeros((), jnp.float32)
    for i, st in enumerate(cfg.stages):
        x, nc, a = _prefill_stage(params[f"dec{i}"], st, x, ctx,
                                  caches[f"dec{i}"], cache_len, unroll)
        new_caches[f"dec{i}"] = nc
        aux = aux + a
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params, cfg, x[:, -1:, :])
    return logits, new_caches


def _prefill_stage(stage_params, st, x, ctx: LayerCtx, caches, cache_len: int,
                   unroll: int | bool = 1):
    """Stage apply that also fills each layer's cache from full-seq K/V."""
    from .blocks import layer_apply
    from .layers import attention as attn_mod

    cfg = ctx.cfg

    def body(carry, xs):
        x, aux = carry
        p, cache = xs
        new_caches = {}
        for i, ld in enumerate(st.pattern):
            ci = cache[f"p{i}"]
            x, nc, a = _prefill_layer(p[f"p{i}"], ld, x, ctx, ci, cache_len)
            aux = aux + a
            new_caches[f"p{i}"] = nc
        return (x, aux), new_caches

    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stage_params, caches),
        unroll=unroll)
    return x, new_caches, aux


def _prefill_layer(p, ld, x, ctx: LayerCtx, cache, cache_len: int):
    """Run the layer in full-sequence mode, then write K/V/state into cache."""
    from .blocks import layer_apply
    from .layers import attention as A, mamba as M, mla as L, xlstm as X
    from .layers.common import rmsnorm as _rms, silu as _silu

    cfg = ctx.cfg
    t = x.shape[1]
    window = ctx.window

    # 1. run the layer WITHOUT cache (parallel form), collecting nothing
    x_out, _, aux = layer_apply(p, ld, x, ctx, cache=None)

    # 2. recompute the cacheable state and write it
    h = _rms(p["norm1"], x, cfg.norm_eps)
    new_cache = dict(cache)
    if ld.mixer == "attn":
        new_cache["self"] = _fill_kv(p["mixer"], h, cfg, ctx, cache["self"],
                                     cache_len)
    elif ld.mixer == "mla":
        new_cache["self"] = _fill_mla(p["mixer"], h, cfg, ctx, cache["self"],
                                      cache_len)
    elif ld.mixer == "mamba":
        new_cache["self"] = _fill_mamba(p["mixer"], h, cfg, cache["self"])
    elif ld.mixer == "mlstm":
        new_cache["self"] = _fill_mlstm(p["mixer"], h, cfg, cache["self"])
    elif ld.mixer == "slstm":
        new_cache["self"] = _fill_slstm(p["mixer"], h, cfg, cache["self"])
    if ld.cross_attn:
        from .layers.attention import cross_kv
        new_cache["cross"] = cross_kv(p["cross"], ctx.enc_out)
    return x_out, new_cache, aux


def _fill_kv(p, h, cfg, ctx, cache, cache_len):
    from .layers import attention as A
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    if "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    from .layers.common import rope_cos_sin, apply_rope
    cos, sin = rope_cos_sin(ctx.positions, cfg.resolved_head_dim, cfg.rope_theta)
    k = apply_rope(k, cos, sin)
    return _scatter_tail(cache, {"k": k, "v": v}, ctx.positions, cache_len,
                         ctx.window)


def _fill_mla(p, h, cfg, ctx, cache, cache_len):
    from .layers.mla import _compress
    c_kv, k_rope = _compress(p, h, cfg, ctx.positions)
    return _scatter_tail(cache, {"c_kv": c_kv, "k_rope": k_rope},
                         ctx.positions, cache_len, ctx.window)


def _scatter_tail(cache: dict, seqs: dict, positions: jax.Array,
                  cache_len: int, window: int) -> dict:
    """Write per-position values into the cache honouring rotation."""
    t = positions.shape[0]
    b = next(iter(seqs.values())).shape[0]
    new = dict(cache)
    if window <= 0 or t <= cache_len:
        # contiguous write at slot positions[0] (prefill starts at 0)
        n = min(t, cache_len)
        for name, val in seqs.items():
            new[name] = jax.lax.dynamic_update_slice_in_dim(
                cache[name], val[:, -n:].astype(cache[name].dtype), 0, 1)
        pos_row = jnp.full((cache_len,), -1, jnp.int32).at[:n].set(
            positions[-n:].astype(jnp.int32))
        new["positions"] = jnp.broadcast_to(pos_row, (b, cache_len))
        return new
    # rotating: keep only the last cache_len positions, placed at pos % len
    tail_pos = positions[-cache_len:]
    slots = tail_pos % cache_len
    for name, val in seqs.items():
        tail = val[:, -cache_len:].astype(cache[name].dtype)
        new[name] = cache[name].at[:, slots].set(tail)
    pos_row = jnp.zeros((cache_len,), jnp.int32).at[slots].set(
        tail_pos.astype(jnp.int32))
    new["positions"] = jnp.broadcast_to(pos_row, (b, cache_len))
    return new


def _fill_mamba(p, h, cfg, cache):
    """Run the SSM over the prompt once more to get the final state."""
    from .layers import mamba as M
    from .layers.common import silu as _silu
    di = cfg.mamba_d_inner
    xz = jnp.einsum("btd,de->bte", h, p["in_proj"])
    xi = xz[..., :di]
    xc = _silu(M._conv_causal(p, xi, None))
    abar, bx, _ = M._ssm_terms(p, xc, cfg)

    def step(hs, ab):
        a, bxt = ab
        return a * hs + bxt, None

    h_final, _ = jax.lax.scan(step, jnp.zeros_like(bx[:, 0]),
                              (abar.swapaxes(0, 1), bx.swapaxes(0, 1)))
    k = p["conv_w"].shape[0]
    conv_tail = xi[:, -(k - 1):, :] if k > 1 else xi[:, :0, :]
    pad = (k - 1) - conv_tail.shape[1]
    if pad > 0:
        conv_tail = jnp.pad(conv_tail, [(0, 0), (pad, 0), (0, 0)])
    return {"conv": conv_tail.astype(cache["conv"].dtype), "ssm": h_final}


def _fill_mlstm(p, h, cfg, cache):
    from .layers import xlstm as X
    from .layers.common import silu as _silu
    di = p["skip"].shape[0]
    up = jnp.einsum("btd,de->bte", h, p["up_proj"])
    xi_raw = up[..., :di]
    xi = _silu(X._conv_causal(p["conv_w"], p["conv_b"], xi_raw, None))
    q, k, v, i_pre, f_pre = X._qkv_gates(p, xi)

    def step(state, inp):
        c, n, m = state
        kt, vt, it, ft = inp
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        f_eff = jnp.exp(logf + m - m_new)
        i_eff = jnp.exp(it - m_new)
        c = f_eff[..., None, None] * c + i_eff[..., None, None] * \
            kt[..., :, None] * vt[..., None, :]
        n = f_eff[..., None] * n + i_eff[..., None] * kt
        return (c, n, m_new), None

    b, t, hh, dh = q.shape
    state0 = (jnp.zeros((b, hh, dh, dh), jnp.float32),
              jnp.zeros((b, hh, dh), jnp.float32),
              jnp.full((b, hh), -1e30, jnp.float32))
    (c, n, m), _ = jax.lax.scan(
        step, state0,
        (k.swapaxes(0, 1).astype(jnp.float32),
         v.swapaxes(0, 1).astype(jnp.float32),
         i_pre.swapaxes(0, 1), f_pre.swapaxes(0, 1)))
    kk = p["conv_w"].shape[0]
    conv_tail = xi_raw[:, -(kk - 1):, :] if kk > 1 else xi_raw[:, :0, :]
    pad = (kk - 1) - conv_tail.shape[1]
    if pad > 0:
        conv_tail = jnp.pad(conv_tail, [(0, 0), (pad, 0), (0, 0)])
    return {"conv": conv_tail.astype(cache["conv"].dtype), "c": c, "n": n,
            "m": m}


def _fill_slstm(p, h, cfg, cache):
    from .layers import xlstm as X
    b, t, d = h.shape
    wx = jnp.einsum("btd,dghk->btghk", h, p["w"])
    state = (cache["h"] * 0, cache["c"] * 0, cache["n"] * 0 + 1.0,
             cache["m"] * 0)

    def step(state, wx_t):
        return X._slstm_step(p, state, wx_t), None

    (hh, c, n, m), _ = jax.lax.scan(step, state, wx.swapaxes(0, 1))
    return {"h": hh, "c": c, "n": n, "m": m}


def decode_step(
    params: Params,
    cfg: ModelConfig,
    caches: Caches,
    token: jax.Array,            # [B, 1] int32
    pos: jax.Array,              # scalar int32 — current absolute position
    *,
    moe_group_size: int = 256,
    unroll: int | bool = 1,
) -> tuple[jax.Array, Caches]:
    """One-token decode against the caches. Returns (logits [B,1,V], caches)."""
    x = embed_tokens(params, cfg, token)
    positions = jnp.full((1,), pos, jnp.int32)
    ctx = LayerCtx(cfg=cfg, positions=positions, causal=True,
                   window=cfg.sliding_window, decode=True,
                   moe_group_size=moe_group_size)
    new_caches: Caches = {}
    for i, st in enumerate(cfg.stages):
        x, nc, _ = stage_apply(params[f"dec{i}"], st, x, ctx,
                               caches=caches[f"dec{i}"], unroll=unroll)
        new_caches[f"dec{i}"] = nc
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(params, cfg, x), new_caches
