"""Telemetry substrate: sketch accuracy, flat memory, list-compatibility.

The streaming engine's telemetry (core/telemetry.py) must answer p50/p99/
p999 queries within the documented error bound while holding a fixed
allocation regardless of how many samples were recorded — these tests pin
both properties, plus the ``BoundedSeries`` shim the streaming path swaps
into ``Metrics``' latency lists.
"""
import math
import random

import numpy as np
import pytest

from repro.core.metrics import Metrics
from repro.core.telemetry import (
    BoundedSeries,
    LogHistogram,
    RingSampler,
    SloTracker,
    StreamTelemetry,
)


# --------------------------------------------------------------------- #
# LogHistogram                                                          #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dist", ["lognormal", "uniform", "bimodal"])
def test_quantiles_within_documented_relative_error(dist):
    rng = random.Random(42)
    if dist == "lognormal":
        xs = [math.exp(rng.gauss(-4.0, 1.5)) for _ in range(20_000)]
    elif dist == "uniform":
        xs = [rng.uniform(1e-4, 10.0) for _ in range(20_000)]
    else:
        xs = [rng.uniform(1e-4, 1e-3) if rng.random() < 0.7
              else rng.uniform(1.0, 2.0) for _ in range(20_000)]
    h = LogHistogram(lo=1e-7, hi=1e5, growth=1.02)
    h.record_many(xs)
    # documented bound: relative error <= sqrt(growth) - 1 (~1%); allow a
    # hair extra for the rank-interpolation difference vs np.percentile
    bound = math.sqrt(h.growth) - 1.0 + 0.01
    for q in (0.50, 0.90, 0.99, 0.999):
        true = float(np.percentile(xs, q * 100.0))
        est = h.quantile(q)
        assert abs(est - true) <= bound * true + 1e-12, (
            f"{dist} q={q}: est={est:g} true={true:g}")


def test_exact_aggregates_and_extremes():
    h = LogHistogram()
    xs = [0.5, 0.001, 3.0, 0.02]
    for x in xs:
        h.record(x)
    assert h.count == 4
    assert h.mean == pytest.approx(sum(xs) / 4)
    assert h.vmin == min(xs) and h.vmax == max(xs)
    assert h.quantile(0.0) >= min(xs) * 0.99
    assert h.quantile(1.0) == max(xs)


def test_record_many_equals_record_loop():
    xs = [math.exp(random.Random(1).gauss(0, 2)) for _ in range(500)]
    a, b = LogHistogram(), LogHistogram()
    for x in xs:
        a.record(x)
    b.record_many(xs)
    assert a.count == b.count
    assert a.total == pytest.approx(b.total)
    assert np.array_equal(a._counts, b._counts)


def test_under_and_overflow_pin_instead_of_dropping():
    h = LogHistogram(lo=1e-3, hi=1e3)
    h.record(1e-9)       # underflow
    h.record(1e9)        # overflow
    assert h.count == 2
    assert h.quantile(0.0) <= h.lo
    assert h.quantile(1.0) == 1e9     # overflow reports the exact max


def test_merge_matches_single_sketch():
    xs = [random.Random(7).uniform(0.001, 5.0) for _ in range(1000)]
    whole, a, b = LogHistogram(), LogHistogram(), LogHistogram()
    whole.record_many(xs)
    a.record_many(xs[:400])
    b.record_many(xs[400:])
    a.merge(b)
    assert a.count == whole.count
    assert a.quantile(0.99) == pytest.approx(whole.quantile(0.99))
    with pytest.raises(ValueError, match="geometry"):
        a.merge(LogHistogram(lo=1e-5))


def test_nbytes_is_flat_under_load():
    h = LogHistogram()
    before = h.nbytes
    h.record_many(np.random.default_rng(0).lognormal(0, 2, 50_000))
    assert h.nbytes == before


def test_empty_sketch_snapshot_is_zeroed():
    s = LogHistogram().snapshot()
    assert s == {"count": 0, "mean": 0.0, "p50": 0.0, "p99": 0.0,
                 "p999": 0.0, "max": 0.0}


# --------------------------------------------------------------------- #
# RingSampler / SloTracker                                              #
# --------------------------------------------------------------------- #
def test_ring_sampler_keeps_most_recent_in_order():
    r = RingSampler(capacity=4)
    for i in range(10):
        r.sample(float(i), float(i * 10))
    assert len(r) == 4
    assert r.total_samples == 10
    assert list(r.values()) == [60.0, 70.0, 80.0, 90.0]
    assert list(r.times()) == [6.0, 7.0, 8.0, 9.0]
    snap = r.snapshot()
    assert snap["last"] == 90.0 and snap["max"] == 90.0
    assert snap["count"] == 10


def test_slo_tracker_per_type_attainment():
    s = SloTracker()
    for _ in range(3):
        s.record("chat", True)
    s.record("chat", False)
    s.record(None, True)          # None folds into "default"
    assert s.attainment("chat") == pytest.approx(0.75)
    snap = s.snapshot()
    assert snap["chat"]["attainment_pct"] == 75.0
    assert snap["default"]["attained"] == 1
    assert s.attainment("never_seen") == 0.0


# --------------------------------------------------------------------- #
# BoundedSeries as a Metrics latency sink                               #
# --------------------------------------------------------------------- #
def test_bounded_series_is_list_compatible():
    b = BoundedSeries(window=8)
    assert not b and len(b) == 0
    b.extend(0.001 * (i + 1) for i in range(100))
    assert b and len(b) == 100
    assert list(b) == [0.001 * (i + 1) for i in range(92, 100)]
    assert b.mean() == pytest.approx(sum(0.001 * (i + 1)
                                         for i in range(100)) / 100)


def test_metrics_summary_accepts_bounded_series():
    m = Metrics(scenario="stream")
    for f in ("t_hp_initial", "t_hp_preempt", "t_lp_alloc",
              "t_realloc", "t_evict"):
        setattr(m, f, BoundedSeries())
    for _ in range(5000):
        m.t_hp_initial.append(0.002)
    s = m.summary()
    assert s["t_hp_initial_ms"] == pytest.approx(2.0, rel=1e-6)
    assert s["t_lp_alloc_ms"] == 0.0


def test_shed_keys_only_appear_on_streaming_path():
    m = Metrics(scenario="x")
    assert "hp_shed" not in m.summary()    # legacy summaries: byte-stable
    m.lp_shed = 3
    s = m.summary()
    assert s["lp_shed"] == 3 and s["hp_shed"] == 0 and s["lp_degraded"] == 0


def test_stream_telemetry_snapshot_shape():
    t = StreamTelemetry(depth_samples=16)
    t.admission.record(1e-4)
    t.e2e.record(0.5)
    t.queue_depth.sample(1.0, 12.0)
    t.slo.record(None, True)
    t.shed_queue_full += 2
    t.shed_expired += 1
    snap = t.snapshot()
    assert snap["shed_total"] == 3
    assert snap["admission_latency_s"]["count"] == 1
    assert snap["slo"]["default"]["attained"] == 1
    assert snap["queue_depth"]["last"] == 12.0
