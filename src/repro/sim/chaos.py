"""Chaos scenarios: streaming serving under seeded device churn.

``run_chaos`` wires the three open-ended pieces together — a
:class:`~repro.serving.stream.StreamingEngine`, a :func:`~repro.sim.openended.firehose`
arrival stream and a :class:`~repro.sim.churn.ChurnInjector` — and
reports recovery health on top of the usual streaming report:

* ``unresolved`` — the engine's safety valve; **must** be zero (every
  orphaned task terminates ALLOCATED-elsewhere or FAILED, never
  stranded — the accounting partition is asserted by
  ``tests/test_accounting_invariants.py``).
* ``recovery_ratio`` — orphans re-placed / orphans created.
* ``hp_completion_pct`` — HP completion under churn (the paper's
  headline metric must survive device loss, not just load).

Everything is seeded: the same :class:`ChaosConfig` replays the same
arrivals *and* the same failures, and a config with churn disabled runs
the engine bit-identically to a plain firehose run (pinned by the
zero-churn differential test).

CLI (the CI chaos-smoke step)::

    python -m repro.sim.chaos --scenario smoke --gate --json chaos.json
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass, replace
from typing import Any, Optional

from .churn import ChurnConfig, ChurnInjector

# NOTE: ``serving.stream`` is imported inside :func:`run_chaos`, not here —
# the same sim/__init__ circularity ``sim/openended.py`` documents.


@dataclass(frozen=True)
class ChaosConfig:
    """One chaos scenario: offered load + churn schedule + gate floors."""

    name: str = "chaos"
    n_devices: int = 64
    policy: str = "scheduler"
    rate: float = 100.0             # firehose arrivals / s (network-wide)
    lp_fraction: float = 0.4
    duration: float = 20.0          # arrival horizon (virtual s)
    window: float = 0.25
    queue_capacity: int = 4096
    shed: str = "reject_newest"
    seed: int = 0
    # churn knobs (fractions of the fleet lost over ``duration``)
    fail_frac: float = 0.1          # expected hard-failed fraction
    drain_frac: float = 0.0         # expected drained fraction
    rejoin: bool = True
    rejoin_delay: float = 1.0
    link_rate: float = 0.0
    link_duration: float = 0.05
    max_down_frac: float = 0.5
    # gate floors (``chaos_gate``)
    min_recovery_ratio: float = 0.5
    min_hp_completion_pct: float = 95.0

    def churn_config(self) -> ChurnConfig:
        """Derive the churn schedule: rates sized so the expected event
        count is ``frac * n_devices`` over the arrival horizon, with
        churn confined to the middle 80% of the run (work exists to
        orphan, and the tail leaves room to recover)."""
        start = 0.1 * self.duration
        span = 0.8 * self.duration
        return ChurnConfig(
            name=self.name,
            n_devices=self.n_devices,
            fail_rate=self.fail_frac * self.n_devices / span,
            drain_rate=self.drain_frac * self.n_devices / span,
            rejoin=self.rejoin,
            rejoin_delay=self.rejoin_delay,
            link_rate=self.link_rate,
            link_duration=self.link_duration,
            start=start,
            duration=span,
            max_down_frac=self.max_down_frac,
            seed=self.seed,
        )


CHAOS_SCENARIOS: dict[str, ChaosConfig] = {
    # CI smoke: small fleet, heavy relative churn, seconds of wall-clock.
    # (The global recovery ratio includes inherently-unrecoverable orphans
    # — HP is source-local, so an HP orphan of a hard-failed source can
    # never re-admit — hence floors well below 1.0.)
    "smoke": ChaosConfig(
        name="smoke", n_devices=32, rate=20.0, lp_fraction=0.25,
        duration=10.0, fail_frac=0.25, drain_frac=0.1, rejoin_delay=1.0,
        min_recovery_ratio=0.25, min_hp_completion_pct=90.0),
    # Medium fleet with drains and link degradation mixed in.
    "churn_mixed": ChaosConfig(
        name="churn_mixed", n_devices=64, rate=20.0, lp_fraction=0.2,
        duration=20.0, fail_frac=0.15, drain_frac=0.1, link_rate=1.0,
        min_recovery_ratio=0.25, min_hp_completion_pct=90.0),
    # The acceptance scenario: 256 devices, >=10% hard-failing mid-run,
    # HP completion must stay above the paper-level 95% floor.  Offered
    # load is sized for a 100% churn-free baseline (the shared offload
    # link saturates near rate ~160 at this fleet size) so the gate
    # measures churn tolerance, not load shedding.
    "churn_heavy": ChaosConfig(
        name="churn_heavy", n_devices=256, rate=80.0, lp_fraction=0.2,
        duration=20.0, fail_frac=0.12, drain_frac=0.05, rejoin_delay=1.0,
        min_recovery_ratio=0.4, min_hp_completion_pct=95.0),
    # No rejoin: failed capacity stays gone (stress; relaxed HP floor).
    "churn_no_rejoin": ChaosConfig(
        name="churn_no_rejoin", n_devices=64, rate=20.0, lp_fraction=0.2,
        duration=15.0, fail_frac=0.1, rejoin=False,
        min_recovery_ratio=0.2, min_hp_completion_pct=80.0),
}


def run_chaos(cfg: ChaosConfig,
              max_requests: Optional[int] = None) -> dict[str, Any]:
    """Run one chaos scenario end to end; returns the streaming report
    plus the recovery metrics the gate reads."""
    from ..serving.stream import StreamingEngine   # lazy: see module note
    from .openended import FirehoseConfig, firehose

    engine = StreamingEngine(
        cfg.n_devices, policy=cfg.policy, window=cfg.window,
        queue_capacity=cfg.queue_capacity, shed=cfg.shed)
    fire = FirehoseConfig(
        name=cfg.name, n_devices=cfg.n_devices, rate=cfg.rate,
        lp_fraction=cfg.lp_fraction, seed=cfg.seed)
    injector = ChurnInjector(cfg.churn_config())
    report = engine.run(
        firehose(fire), until=cfg.duration, max_requests=max_requests,
        churn=iter(injector) if injector.enabled else None)
    m = report["metrics"]
    seen = m.get("orphans_created", 0)
    recovered = m.get("orphans_recovered", 0)
    return {
        "scenario": cfg.name,
        "policy": cfg.policy,
        "n_devices": cfg.n_devices,
        "churn_events": injector.counts(),
        "devices_failed": m.get("device_failures", 0),
        "devices_drained": m.get("device_drains", 0),
        "devices_rejoined": m.get("device_rejoins", 0),
        "orphans_created": seen,
        "orphans_recovered": recovered,
        "recovery_ratio": (recovered / seen) if seen else 1.0,
        "hp_completion_pct": m.get("hp_completion_pct", 0.0),
        "unresolved": report["unresolved"],
        "report": report,
    }


def chaos_gate(result: dict[str, Any], cfg: ChaosConfig) -> list[str]:
    """Return the list of gate violations (empty = pass)."""
    failures: list[str] = []
    if result["unresolved"] != 0:
        failures.append(
            f"unresolved={result['unresolved']} (must be 0: an orphan was "
            "stranded without a terminal state)")
    if result["devices_failed"] == 0 and cfg.fail_frac > 0.0:
        failures.append("no device failures fired (churn schedule empty?)")
    if result["recovery_ratio"] < cfg.min_recovery_ratio:
        failures.append(
            f"recovery_ratio={result['recovery_ratio']:.3f} < "
            f"floor {cfg.min_recovery_ratio}")
    if result["hp_completion_pct"] < cfg.min_hp_completion_pct:
        failures.append(
            f"hp_completion_pct={result['hp_completion_pct']:.2f} < "
            f"floor {cfg.min_hp_completion_pct}")
    return failures


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Run a chaos scenario (streaming engine under churn)")
    ap.add_argument("--scenario", default="smoke",
                    choices=sorted(CHAOS_SCENARIOS))
    ap.add_argument("--policy", default=None,
                    help="override the scenario's scheduling policy")
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 unless every recovery floor holds")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the result dict as JSON")
    args = ap.parse_args(argv)

    cfg = CHAOS_SCENARIOS[args.scenario]
    if args.policy is not None:
        cfg = replace(cfg, policy=args.policy)
    if args.seed is not None:
        cfg = replace(cfg, seed=args.seed)
    result = run_chaos(cfg)
    print(f"[chaos] {cfg.name}: policy={cfg.policy} "
          f"devices={cfg.n_devices} failed={result['devices_failed']} "
          f"drained={result['devices_drained']} "
          f"rejoined={result['devices_rejoined']} "
          f"orphans={result['orphans_created']} "
          f"recovered={result['orphans_recovered']} "
          f"(ratio {result['recovery_ratio']:.3f}) "
          f"hp={result['hp_completion_pct']:.2f}% "
          f"unresolved={result['unresolved']}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(result, fh, indent=2, sort_keys=True)
        print(f"[chaos] wrote {args.json}")
    if args.gate:
        failures = chaos_gate(result, cfg)
        for f in failures:
            print(f"[chaos] GATE FAIL: {f}", file=sys.stderr)
        if failures:
            return 1
        print("[chaos] gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
