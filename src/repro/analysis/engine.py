"""Rule engine: module loading, pragma/baseline semantics, deterministic
reports.

Design contract (tests/test_replint.py):

* a ``# replint: disable=<rule>[,<rule>...]`` pragma suppresses findings of
  the named rules on THAT physical line only (a finding's line is its AST
  node's ``lineno`` — multi-clause rules anchor findings where the pragma
  should go, e.g. the ``def`` line for method-granular rules);
* baseline keys are content-addressed, not line-addressed —
  ``rule::path::<normalized line text>::<occurrence>`` — so unrelated edits
  above a grandfathered finding do not invalidate the entry;
* stale baseline entries (keys no current finding matches) are reported and
  fail ``--gate``: a fixed finding must also retire its justification;
* the JSON report is byte-deterministic: relative posix paths, sorted
  findings, sorted keys, no timestamps or absolute paths.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Iterator, Optional, Sequence

# Rule list terminates at the first token that is not a rule name, so a
# justification can follow: ``# replint: disable=rule-a,rule-b (why)``.
PRAGMA_RE = re.compile(
    r"#\s*replint:\s*disable=([A-Za-z0-9_\-]+(?:\s*,\s*[A-Za-z0-9_\-]+)*)")


@dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to the line a pragma would go on."""

    rule: str
    path: str          # posix path relative to the analysis root
    line: int          # 1-indexed
    col: int           # 0-indexed
    message: str
    symbol: str = ""   # enclosing function qualname when known

    def sort_key(self) -> tuple:
        return (self.path, self.line, self.col, self.rule, self.message)


class Module:
    """A parsed source module plus the lookups rules share: pragma map,
    import table, and line -> enclosing-function qualname."""

    def __init__(self, root: Path, path: Path) -> None:
        self.path = path
        self.rel = path.relative_to(root).as_posix()
        self.source = path.read_text()
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=str(path))
        self.pragmas: dict[int, set[str]] = {}
        for lineno, text in enumerate(self.lines, 1):
            m = PRAGMA_RE.search(text)
            if m:
                self.pragmas[lineno] = {
                    r.strip() for r in m.group(1).split(",") if r.strip()
                }
        self._imports: Optional[dict[str, str]] = None
        self._spans: Optional[list[tuple[int, int, str]]] = None

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def suppressed(self, finding: Finding) -> bool:
        disabled = self.pragmas.get(finding.line)
        return bool(disabled) and (finding.rule in disabled or "all" in disabled)

    # -- import table ------------------------------------------------------ #
    @property
    def imports(self) -> dict[str, str]:
        """Local name -> dotted origin (``np`` -> ``numpy``,
        ``perf_counter`` -> ``time.perf_counter``)."""
        if self._imports is None:
            table: dict[str, str] = {}
            for node in ast.walk(self.tree):
                if isinstance(node, ast.Import):
                    for alias in node.names:
                        table[alias.asname or alias.name.split(".")[0]] = (
                            alias.name if alias.asname else alias.name.split(".")[0]
                        )
                        if alias.asname is None and "." in alias.name:
                            # ``import a.b`` binds ``a``; record the root
                            table[alias.name.split(".")[0]] = alias.name.split(".")[0]
                elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
                    for alias in node.names:
                        table[alias.asname or alias.name] = f"{node.module}.{alias.name}"
            self._imports = table
        return self._imports

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Dotted origin of a Name/Attribute chain through the import table
        (``_time.perf_counter`` -> ``time.perf_counter``); None when the
        chain's base is not an imported name."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        base = self.imports.get(node.id)
        if base is None:
            return None
        return ".".join([base] + list(reversed(parts)))

    # -- enclosing qualnames ----------------------------------------------- #
    def qualname(self, lineno: int) -> str:
        """Innermost enclosing function qualname (``Class.method``), or ""
        at module level."""
        if self._spans is None:
            spans: list[tuple[int, int, str]] = []

            def walk(node: ast.AST, stack: list[str]) -> None:
                for child in ast.iter_child_nodes(node):
                    if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                          ast.ClassDef)):
                        if not isinstance(child, ast.ClassDef):
                            spans.append((child.lineno,
                                          child.end_lineno or child.lineno,
                                          ".".join(stack + [child.name])))
                        walk(child, stack + [child.name])
                    else:
                        walk(child, stack)

            walk(self.tree, [])
            self._spans = spans
        best = ""
        best_len = None
        for lo, hi, name in self._spans:
            if lo <= lineno <= hi and (best_len is None or hi - lo <= best_len):
                best, best_len = name, hi - lo
        return best


class Rule:
    """Base rule: subclasses set ``name``/``description``, scope themselves
    via :meth:`applies_to` (posix relpath from the analysis root, e.g.
    ``repro/core/scheduler.py``) and yield :class:`Finding`s from
    :meth:`check`."""

    name = ""
    description = ""

    def applies_to(self, rel: str) -> bool:
        return True

    def check(self, mod: Module) -> Iterator[Finding]:
        raise NotImplementedError


def default_rules() -> list[Rule]:
    """The shipped rule set, in stable catalog order (DESIGN.md §15)."""
    from .rules.determinism import SetIterRule, UnseededRngRule, WallClockRule
    from .rules.kernel_rules import JaxImportRule, PallasIndexRule
    from .rules.mirror_sync import DirtyNotifyRule, MirrorWriteRule
    from .rules.terminal_state import TerminalStateRule

    return [
        MirrorWriteRule(),
        DirtyNotifyRule(),
        TerminalStateRule(),
        WallClockRule(),
        UnseededRngRule(),
        SetIterRule(),
        PallasIndexRule(),
        JaxImportRule(),
    ]


# -------------------------------------------------------------------------- #
# Baseline                                                                   #
# -------------------------------------------------------------------------- #
def norm_text(text: str) -> str:
    return " ".join(text.split())


def finding_key(finding: Finding, line_text: str, occurrence: int) -> str:
    """Content-addressed baseline key: stable across unrelated line shifts,
    disambiguated among identical lines by in-file occurrence order."""
    return "::".join([finding.rule, finding.path, norm_text(line_text),
                      str(occurrence)])


def load_baseline(path: Path) -> dict[str, str]:
    """Baseline file: ``{finding key: one-line justification}``."""
    data = json.loads(Path(path).read_text())
    if not isinstance(data, dict) or not all(
        isinstance(k, str) and isinstance(v, str) for k, v in data.items()
    ):
        raise ValueError(
            f"{path}: baseline must be a JSON object mapping finding keys "
            "to one-line justification strings"
        )
    return data


@dataclass
class Report:
    """Outcome of one analysis run.  ``findings`` are actionable (neither
    pragma-suppressed nor baselined); the gate passes iff it is empty AND
    no baseline entry went stale."""

    root_label: str
    rules: list[str]
    files_scanned: int
    findings: list[tuple[Finding, str]] = field(default_factory=list)
    baselined: list[tuple[Finding, str, str]] = field(default_factory=list)
    suppressed: list[Finding] = field(default_factory=list)
    stale_baseline: list[str] = field(default_factory=list)

    @property
    def gate_ok(self) -> bool:
        return not self.findings and not self.stale_baseline

    def to_dict(self) -> dict:
        def row(f: Finding, key: str) -> dict:
            return {
                "rule": f.rule, "path": f.path, "line": f.line, "col": f.col,
                "symbol": f.symbol, "message": f.message, "key": key,
            }

        return {
            "version": 1,
            "root": self.root_label,
            "rules": sorted(self.rules),
            "files_scanned": self.files_scanned,
            "gate_ok": self.gate_ok,
            "counts": {
                "findings": len(self.findings),
                "baselined": len(self.baselined),
                "suppressed": len(self.suppressed),
                "stale_baseline": len(self.stale_baseline),
            },
            "findings": [row(f, k) for f, k in self.findings],
            "baselined": [dict(row(f, k), justification=j)
                          for f, k, j in self.baselined],
            "suppressed": [row(f, "") for f in self.suppressed],
            "stale_baseline": sorted(self.stale_baseline),
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n"


# -------------------------------------------------------------------------- #
# Runner                                                                     #
# -------------------------------------------------------------------------- #
def iter_py_files(root: Path) -> list[Path]:
    return sorted(p for p in root.rglob("*.py") if p.is_file())


def run_analysis(
    root: Path,
    rules: Optional[Sequence[Rule]] = None,
    files: Optional[Iterable[Path]] = None,
    baseline: Optional[dict[str, str]] = None,
    root_label: str = "",
) -> Report:
    """Run ``rules`` over every ``*.py`` under ``root`` (or just ``files``).

    Paths/relpaths are computed against ``root`` — pointing ``root`` at a
    fixture tree shaped like ``src/`` (``repro/core/...``) exercises the
    exact same scoping as the real repo.
    """
    root = Path(root).resolve()
    active = list(rules) if rules is not None else default_rules()
    baseline = dict(baseline or {})
    todo = (iter_py_files(root) if files is None
            else sorted(Path(f).resolve() for f in files))

    raw: list[Finding] = []
    suppressed: list[Finding] = []
    n_files = 0
    for path in todo:
        rel = path.relative_to(root).as_posix()
        n_files += 1
        try:
            mod = Module(root, path)
        except SyntaxError as exc:
            raw.append(Finding("parse-error", rel, exc.lineno or 1, 0,
                               f"syntax error: {exc.msg}"))
            continue
        for rule in active:
            if not rule.applies_to(rel):
                continue
            for f in rule.check(mod):
                (suppressed if mod.suppressed(f) else raw).append(f)

    raw.sort(key=Finding.sort_key)
    suppressed.sort(key=Finding.sort_key)

    # Content-addressed keys (occurrence-indexed among identical lines),
    # then split against the baseline.
    line_cache: dict[str, list[str]] = {}
    occ: dict[tuple[str, str, str], int] = {}
    report = Report(root_label=root_label or root.name,
                    rules=[r.name for r in active], files_scanned=n_files)
    matched: set[str] = set()
    for f in raw:
        if f.path not in line_cache:
            try:
                line_cache[f.path] = (root / f.path).read_text().splitlines()
            except OSError:
                line_cache[f.path] = []
        lines = line_cache[f.path]
        text = lines[f.line - 1] if 1 <= f.line <= len(lines) else ""
        ident = (f.rule, f.path, norm_text(text))
        n = occ.get(ident, 0)
        occ[ident] = n + 1
        key = finding_key(f, text, n)
        if key in baseline:
            matched.add(key)
            report.baselined.append((f, key, baseline[key]))
        else:
            report.findings.append((f, key))
    report.suppressed = suppressed
    report.stale_baseline = sorted(set(baseline) - matched)
    return report
