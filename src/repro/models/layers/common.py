"""Shared primitives: norms, RoPE, initialisers, logical-axis helpers.

Every ``*_init`` function has a mirror ``*_axes`` function returning the same
tree structure with logical-axis name tuples instead of arrays; sharding.py
maps logical axes onto mesh axes with divisibility-checked rules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------- #
# Initialisers                                                                #
# --------------------------------------------------------------------------- #


def normal_init(key, shape, scale: float, dtype) -> jax.Array:
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


def dense_init(key, in_dim: int, *out_dims: int, dtype) -> jax.Array:
    """Fan-in scaled normal for a [in, *out] projection."""
    return normal_init(key, (in_dim, *out_dims), in_dim ** -0.5, dtype)


def zeros(shape, dtype) -> jax.Array:
    return jnp.zeros(shape, dtype=dtype)


# --------------------------------------------------------------------------- #
# Norms                                                                       #
# --------------------------------------------------------------------------- #


def rmsnorm_init(dim: int, dtype) -> dict:
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm_axes(axis: str = "embed") -> dict:
    return {"scale": (axis,)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def groupnorm_heads(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Per-head group norm over the last (head_dim) axis, no learned params."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mean) * jax.lax.rsqrt(var + eps)).astype(dtype)


# --------------------------------------------------------------------------- #
# RoPE                                                                        #
# --------------------------------------------------------------------------- #


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies [head_dim // 2]."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float):
    """positions [...,] -> cos/sin tables [..., head_dim // 2]."""
    angles = positions.astype(jnp.float32)[..., None] * rope_freqs(head_dim, theta)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., T, H, D]; cos/sin [T, D/2] (broadcast over batch and heads)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    # cos/sin [T, D/2] -> [T, 1, D/2] so they broadcast over the head axis.
    cos = cos[..., :, None, :]
    sin = sin[..., :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dtype)


# --------------------------------------------------------------------------- #
# Activations                                                                 #
# --------------------------------------------------------------------------- #


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return silu(gate) * up


# --------------------------------------------------------------------------- #
# Stable helpers                                                              #
# --------------------------------------------------------------------------- #


def softmax_f32(scores: jax.Array, axis: int = -1) -> jax.Array:
    return jax.nn.softmax(scores.astype(jnp.float32), axis=axis)


def masked_softmax(scores: jax.Array, mask: jax.Array, axis: int = -1) -> jax.Array:
    """Softmax with additive -inf masking; rows with no valid key yield 0."""
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(mask, scores.astype(jnp.float32), neg)
    out = jax.nn.softmax(scores, axis=axis)
    # If an entire row is masked the softmax is garbage; zero it.
    any_valid = jnp.any(mask, axis=axis, keepdims=True)
    return jnp.where(any_valid, out, 0.0)
