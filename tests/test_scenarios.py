"""Large-N scenario suite (sim/scenarios.py): generators + end-to-end runs."""
import pytest

from repro.core.calendar_reference import ReferenceNetworkState
from repro.sim.scenarios import (
    LARGE_N_TIERS,
    LargeNConfig,
    generate_arrivals,
    run_large_n,
    sweep_devices,
    sweep_mix,
)


@pytest.mark.parametrize("family", ["poisson", "bursty", "adversarial",
                                    "preempt_storm"])
def test_arrivals_deterministic_and_sorted(family):
    cfg = LargeNConfig(name="t", arrival=family, n_devices=8, duration=30.0,
                       seed=3)
    a1 = generate_arrivals(cfg)
    a2 = generate_arrivals(cfg)
    assert a1 == a2
    assert a1 == sorted(a1, key=lambda a: (a.t, a.device))
    assert all(0.0 <= a.t < cfg.duration for a in a1)
    assert all(0 <= a.device < cfg.n_devices for a in a1)
    assert all(0 <= a.n_lp_tasks <= 4 for a in a1)
    # different seed, different stream
    a3 = generate_arrivals(LargeNConfig(name="t", arrival=family, n_devices=8,
                                        duration=30.0, seed=4))
    assert a1 != a3


def test_adversarial_waves_are_synchronised():
    cfg = LargeNConfig(name="t", arrival="adversarial", n_devices=16,
                       duration=20.0, wave_period=5.0)
    arrivals = generate_arrivals(cfg)
    times = sorted({a.t for a in arrivals})
    assert times == [0.0, 5.0, 10.0, 15.0]
    for t in times:
        assert len([a for a in arrivals if a.t == t]) == 16


def test_preempt_storm_shape():
    """Saturation phase first (max-size LP sets at every device inside one
    wave period), then synchronized HP-only waves at EVERY device — the
    preemption-adversarial family bench_preemption runs across the tier
    ladder."""
    cfg = LargeNConfig(name="t", arrival="preempt_storm", n_devices=8,
                       duration=20.0, wave_period=5.0, seed=2)
    arrivals = generate_arrivals(cfg)
    sat = [a for a in arrivals if a.t < 5.0]
    waves = [a for a in arrivals if a.t >= 5.0]
    assert sat and waves
    assert all(a.n_lp_tasks == max(cfg.lp_set_sizes) for a in sat)
    assert {a.device for a in sat} == set(range(8))
    assert all(a.n_lp_tasks == 0 for a in waves)
    wave_times = sorted({a.t for a in waves})
    assert wave_times == [5.0, 10.0, 15.0]
    for t in wave_times:
        assert len([a for a in waves if a.t == t]) == 8


def test_preempt_storm_runs_and_preempts():
    s = run_large_n(LargeNConfig(name="t", arrival="preempt_storm",
                                 n_devices=8, duration=16.0, seed=1))
    assert s["preemptions"] > 0
    assert s["n_hp_preempt"] > 0
    # the bugfix's accounting invariant: every preemption is settled
    assert s["realloc_success"] + s["realloc_failure"] == s["preemptions"]


def test_mix_sweep_controls_lp_volume():
    none = LargeNConfig(name="m0", lp_fraction=0.0, n_devices=8, duration=60.0)
    full = LargeNConfig(name="m1", lp_fraction=1.0, n_devices=8, duration=60.0)
    assert all(a.n_lp_tasks == 0 for a in generate_arrivals(none))
    assert all(a.n_lp_tasks >= 1 for a in generate_arrivals(full))


def test_sweep_helpers():
    base = LargeNConfig(name="s")
    devs = sweep_devices(base, (4, 256))
    assert [c.n_devices for c in devs] == [4, 256]
    assert [c.name for c in devs] == ["s_n4", "s_n256"]
    mixes = sweep_mix(base, (0.0, 1.0))
    assert [c.lp_fraction for c in mixes] == [0.0, 1.0]


def test_unknown_arrival_family_rejected():
    with pytest.raises(ValueError):
        LargeNConfig(name="x", arrival="nope")


def test_run_large_n_end_to_end_small():
    cfg = LargeNConfig(name="e2e", n_devices=8, duration=40.0, seed=1)
    s = run_large_n(cfg)
    assert s["n_arrivals"] == s["hp_admitted"] + s["hp_failed"] > 0
    assert s["lp_allocated"] + s["lp_failed"] > 0
    assert s["hp_alloc_us_mean"] > 0


def test_run_large_n_256_devices_mixed_end_to_end():
    """The acceptance scenario: 256 devices, mixed HP/LP workload, batched
    admission, runs end to end."""
    cfg = LargeNConfig(name="big", n_devices=256, duration=10.0,
                       lp_fraction=0.6, seed=0)
    s = run_large_n(cfg, batch_window=0.25)
    assert s["n_devices"] == 256
    assert s["hp_admitted"] > 0
    assert s["lp_allocated"] > 0
    assert s["wall_s"] < 60.0


def test_run_large_n_1024_devices_completes():
    """The new LARGE_N tier: a four-digit fleet through the vectorized probe
    plane — short stream, but every admission path (HP, preemption, batched
    LP) is exercised at 1024 devices."""
    assert 1024 in LARGE_N_TIERS
    cfg = LargeNConfig(name="huge", n_devices=1024, duration=4.0,
                       lp_fraction=0.6, seed=0)
    s = run_large_n(cfg, batch_window=0.25)
    assert s["n_devices"] == 1024
    assert s["hp_admitted"] > 0
    assert s["lp_allocated"] > 0
    assert s["wall_s"] < 120.0


def test_run_large_n_batch_matches_request_level_totals():
    """Batched and per-request admission must conserve tasks."""
    cfg = LargeNConfig(name="cmp", n_devices=16, duration=40.0, seed=2)
    a = run_large_n(cfg)
    b = run_large_n(cfg, batch_window=0.25)
    assert a["lp_allocated"] + a["lp_failed"] == b["lp_allocated"] + b["lp_failed"]
    assert a["n_arrivals"] == b["n_arrivals"]


def test_run_large_n_reference_state_equivalence():
    """The same scenario on the seed calendars yields identical admission
    decisions (the optimisation changed the cost, not the policy)."""
    cfg = LargeNConfig(name="ref", n_devices=8, duration=40.0, seed=5)
    new = run_large_n(cfg)
    ref = run_large_n(cfg, state=ReferenceNetworkState(8))
    for key in ("hp_admitted", "hp_failed", "lp_allocated", "lp_failed",
                "preemptions", "realloc_success", "realloc_failure"):
        assert new[key] == ref[key], key
