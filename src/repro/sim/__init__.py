from .events import EventQueue
from .traces import TraceConfig, generate_trace, potential_counts
from .experiment import ScenarioConfig, run_scenario, SCENARIOS

__all__ = [
    "EventQueue",
    "TraceConfig",
    "generate_trace",
    "potential_counts",
    "ScenarioConfig",
    "run_scenario",
    "SCENARIOS",
]
