"""Offline optimal-placement oracle: the scheduling-quality measuring stick.

PRs 4-5 gate *speed* (calendar/preemption-plane CI benchmarks); nothing
gated decision *quality* — a fast path that silently schedules worse would
pass every gate.  This module provides the missing reference: an exact
solver for the one-shot joint placement problem every slot-based policy
answers online (DESIGN.md §13).

The model
---------
An :class:`OracleInstance` freezes one admission question: a set of tasks
(released at decision time), the devices' existing skyline occupancy, and
the shared link's existing occupancy.  A *placement* assigns each task at
most one ``(device, cores, start)`` option subject to

* **deadline** — ``start + completion_duration(cores) <= deadline``;
* **device capacity** — chosen slots plus existing occupancy never exceed
  the core capacity on any device;
* **link occupancy** — an offloaded task's input transfer (one link-slot of
  ``net.slot(input_bytes)`` seconds) must fit on the shared unit-capacity
  link between release and the task's start.  The oracle relaxes the real
  policies' *contiguous* transfers to *preemptible* ones (classic EDF
  interval conditions), and charges no allocation/state-update messages.

Both relaxations only widen the feasible set, so the oracle's optimum is an
upper bound on what any registered slot-based policy can achieve on the
same instance — a policy "beating" it means the model is wrong (and a test
fails).  The objective is the lexicographic quality order the paper argues
for — HP completions, then total completions, then an accuracy-weighted
earliness ("goodput") tiebreak — encoded as a single weighted sum.

Start times are restricted to a finite *candidate grid*: existing calendar
breakpoints, task releases, link-backlog clearing points, closed under sums
of the instance's slot durations.  Any feasible schedule left-shifts onto
this grid without losing completions (each start anchors at a release, an
existing breakpoint, another chosen slot's end, or the point where the link
backlog clears), so the grid optimum equals the continuous optimum.

Backends
--------
* ``"milp"``  — ``scipy.optimize.milp`` (HiGHS) over binary option vars.
* ``"brute"`` — exhaustive depth-first branch-and-bound over the same
  option set; independent of any solver, it doubles as the correctness
  oracle for the MILP encoding (differential-tested in
  ``tests/test_oracle.py``).
* ``"cpsat"`` — ``ortools`` CP-SAT, behind a feature check (the container
  does not ship ortools; the backend raises a clear error when absent).
* ``"auto"``  — brute below a search-space threshold or at tiny job
  counts (where the suffix-max bound beats MILP even at thousands of
  option columns), else MILP (brute when scipy is unavailable).

:class:`OraclePolicy` (registered as ``"oracle"``) applies the instance
solver online, one decision at a time: HP admission via the closed-form
optimum (earliest feasible 1-core slot on the source device), each LP
request as one joint instance.  It is per-decision optimal, *not*
clairvoyant across future arrivals and it never preempts — see DESIGN.md
§13 for exactly what competitive ratios against it do and don't certify.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Optional, Sequence

import numpy as np

from .calendar import NetworkState
from .metrics import Metrics
from .network import NetworkConfig
from .policy import CalendarPolicy, Decision, DecisionStatus, register_policy
from .scheduler import Allocation
from .task import LowPriorityRequest, Priority, Task, TaskState

#: Feasibility slack for float comparisons (well below EPS and any slot).
FEAS = 1e-9
#: Link-interval rows compare *sums* of transfer durations against free
#: time measured between grid points rounded at ``_ROUND`` — accumulated
#: rounding error can exceed ``FEAS``, and HiGHS applies its own ~1e-7
#: primal tolerance anyway.  All backends must use the SAME slack on link
#: rows or the brute/MILP differential diverges on exactly-packed links.
LINK_TOL = 1e-7
#: Grid points are deduplicated at nanosecond resolution.
_ROUND = 9

#: Default instance-size guards (DESIGN.md §13 — "oracle-sized" means a
#: handful of tasks over a few devices; beyond these the instance raises).
MAX_GRID = 4000
#: Above this many option columns BOTH backends degrade (brute's bound
#: stops pruning enough; HiGHS crawls on near-degenerate binaries), so the
#: instance errors and the policy falls back to singletons.  Variant
#: ladders triple the columns per job, which is what pushed sustained-load
#: joint instances from "slow" to "minutes each" at the old 20k cap;
#: 6k keeps the worst measured joint solve under ~1 s at identical
#: measured quality on the ladder scenarios.
MAX_OPTIONS = 6_000
MAX_SUMS = 20_000

#: ``auto`` backend: brute-force below this assignment-space size.
_BRUTE_SPACE = 20_000
#: ``auto`` also prefers brute at or below this JOB count regardless of
#: option count: the branch-and-bound's suffix-max bound makes its cost
#: near-linear in options when the branching depth is tiny, while HiGHS
#: degrades badly on thousands of near-degenerate binary columns (variant
#: ladders triple the columns at nearly identical objective weights — a
#: 2-job/4k-option ladder instance measured 0.03 s brute vs >170 s MILP).
_BRUTE_JOBS = 3


def _have_scipy_milp() -> bool:
    try:
        from scipy.optimize import milp  # noqa: F401
        return True
    except ImportError:                                  # pragma: no cover
        return False


def have_ortools() -> bool:
    """Feature check for the optional CP-SAT backend (not in the image)."""
    try:
        from ortools.sat.python import cp_model  # noqa: F401
        return True
    except ImportError:
        return False


class OracleInstanceError(ValueError):
    """The instance exceeds the oracle's size guards (or cannot be built)."""


# ====================================================================== #
# Problem data                                                           #
# ====================================================================== #
@dataclass(frozen=True)
class JobRung:
    """One variant-ladder rung of an LP job (DESIGN.md §17): the same
    shape as the job's base fields, at the rung's benchmark stats."""

    accuracy: float
    durations: Mapping[int, float]
    completion_durations: Mapping[int, float]
    xfer: float


@dataclass(frozen=True)
class OracleJob:
    """One task of the one-shot placement instance."""

    idx: int
    is_hp: bool
    source_device: int
    release: float
    deadline: float
    #: cores -> reserved slot duration (what occupies the calendar)
    durations: Mapping[int, float]
    #: cores -> completion offset (HP completes at exec mean, before its
    #: padded slot ends; LP completion criterion is the padded slot itself,
    #: matching the admission rules the policies implement)
    completion_durations: Mapping[int, float]
    xfer: float                    # input-transfer link-slot duration
    offloadable: bool
    accuracy: float = 1.0
    #: Variant-ladder rungs below the base (variant 0 = the fields above).
    #: The oracle enumerates one option column per rung, so its optimum
    #: covers every admissible variant choice — what the quality report's
    #: accuracy-weighted-goodput ratio certifies the greedy ladder against.
    rungs: tuple[JobRung, ...] = ()
    task: Optional[Task] = None    # backref for committing placements

    @property
    def n_variants(self) -> int:
        return 1 + len(self.rungs)

    def rung(self, variant: int) -> tuple[float, Mapping[int, float],
                                          Mapping[int, float], float]:
        """(accuracy, durations, completion_durations, xfer) at a rung;
        variant 0 is the base, past-bottom clamps (the profiles'
        ``variant_profile`` contract — ladder-free jobs always resolve to
        the base)."""
        if variant <= 0 or not self.rungs:
            return (self.accuracy, self.durations,
                    self.completion_durations, self.xfer)
        r = self.rungs[min(variant, len(self.rungs)) - 1]
        return r.accuracy, r.durations, r.completion_durations, r.xfer


@dataclass(frozen=True)
class PlacementOption:
    """One admissible ``(job, device, cores, start)`` assignment."""

    job: int
    device: int
    cores: int
    start: float
    end: float                     # start + slot duration
    completion: float              # start + completion duration
    offloaded: bool
    weight: float = 0.0
    variant: int = 0               # ladder rung this option runs at
    accuracy: float = 1.0          # the rung's benchmark accuracy
    xfer: float = 0.0              # the rung's transfer (0 if local)


@dataclass
class OracleSolution:
    objective: float
    hp_completed: int
    completed: int
    goodput: float
    placements: dict[int, PlacementOption]   # job idx -> chosen option
    backend: str

    @property
    def lex(self) -> tuple[int, int, float]:
        """The lexicographic quality tuple the objective encodes."""
        return (self.hp_completed, self.completed, self.goodput)


class OracleInstance:
    """A frozen one-shot joint placement problem (see module docstring)."""

    def __init__(
        self,
        jobs: Sequence[OracleJob],
        device_profiles: Mapping[int, tuple[np.ndarray, np.ndarray]],
        link_profile: tuple[np.ndarray, np.ndarray],
        capacity: int,
        now: float,
        horizon: float,
        *,
        max_grid: int = MAX_GRID,
        max_options: int = MAX_OPTIONS,
        max_sums: int = MAX_SUMS,
    ) -> None:
        if not jobs:
            raise OracleInstanceError("instance has no jobs")
        self.jobs = list(jobs)
        self.capacity = capacity
        self.now = now
        self.horizon = horizon
        self.span = max(horizon - now, FEAS)
        self.device_profiles = dict(device_profiles)
        self.link_profile = link_profile
        self.max_grid = max_grid
        self.max_options = max_options
        self.max_sums = max_sums
        self._build_grid()
        self._build_options()
        self._build_capacity_rows()
        self._build_link_rows()

    # ------------------------------------------------------------------ #
    # Construction                                                       #
    # ------------------------------------------------------------------ #
    @classmethod
    def from_state(
        cls,
        state: NetworkState,
        net: NetworkConfig,
        tasks: Sequence[Task],
        now: float,
        **caps,
    ) -> "OracleInstance":
        """Freeze the current calendars + ``tasks`` into an instance.

        Every task is treated as released at ``now`` (the admission
        moment).  HP tasks are local-only on one core (the paper's rule);
        LP tasks may take any benchmarked core configuration on any
        device, paying one input transfer when offloaded.
        """
        jobs: list[OracleJob] = []
        for i, task in enumerate(tasks):
            prof = net.profile(task.task_type)
            if task.priority == Priority.HIGH:
                jobs.append(OracleJob(
                    idx=i, is_hp=True, source_device=task.source_device,
                    release=now, deadline=task.deadline,
                    durations={1: prof.hp_slot_time},
                    completion_durations={1: prof.hp_exec},
                    xfer=0.0, offloadable=False,
                    accuracy=prof.accuracy, task=task,
                ))
            else:
                durs = {c: prof.lp_slot_time(c) for c in prof.core_options}
                rungs = []
                for v in range(1, prof.n_variants):
                    rp = prof.variant_profile(v)
                    rd = {c: rp.lp_slot_time(c) for c in rp.core_options}
                    rungs.append(JobRung(rp.accuracy, rd, dict(rd),
                                         net.slot(rp.input_bytes)))
                jobs.append(OracleJob(
                    idx=i, is_hp=False, source_device=task.source_device,
                    release=now, deadline=task.deadline,
                    durations=durs, completion_durations=dict(durs),
                    xfer=net.slot(prof.input_bytes), offloadable=True,
                    accuracy=prof.accuracy, rungs=tuple(rungs), task=task,
                ))
        horizon = max(
            j.deadline + max(
                j.durations[c] - j.completion_durations[c]
                for c in j.durations
            )
            for j in jobs
        ) + FEAS
        profiles = {}
        for d in state.devices:
            if getattr(d, "is_up", True):
                profiles[d.device] = d.usage_segments(now, horizon)
            else:
                # DRAINING/DOWN devices take no new placements: present a
                # saturated profile so no placement option ever fits there.
                profiles[d.device] = (np.array([now]),
                                      np.array([d.capacity], dtype=np.int64))
        link_profile = state.link.usage_segments(now, horizon)
        return cls(jobs, profiles, link_profile,
                   capacity=state.devices[0].capacity if state.devices
                   else 4,
                   now=now, horizon=horizon, **caps)

    # -- candidate start grid ------------------------------------------- #
    def _free_link_segments(self) -> list[tuple[float, float]]:
        """Maximal free intervals of the link inside [now, horizon)."""
        starts, vals = self.link_profile
        segs: list[tuple[float, float]] = []
        n = len(starts)
        if n == 0:
            return [(self.now, self.horizon)]
        for i in range(n):
            if vals[i] == 0:
                t1 = float(starts[i])
                t2 = float(starts[i + 1]) if i + 1 < n else self.horizon
                if segs and abs(segs[-1][1] - t1) <= FEAS:
                    segs[-1] = (segs[-1][0], t2)
                else:
                    segs.append((t1, t2))
        return segs

    def _link_clear_point(self, release: float, demand: float) -> float:
        """Earliest ``t`` with ``demand`` seconds of free link in
        ``[release, t]`` — where a transfer backlog of that size clears."""
        acc = 0.0
        for t1, t2 in self._free_segments_cache:
            if t2 <= release:
                continue
            t1 = max(t1, release)
            if acc + (t2 - t1) >= demand - FEAS:
                return t1 + (demand - acc)
            acc += t2 - t1
        return self.horizon  # backlog never clears inside the window

    def free_link_time(self, a: float, b: float) -> float:
        """Free link seconds in [a, b]."""
        total = 0.0
        for t1, t2 in self._free_segments_cache:
            lo, hi = max(t1, a), min(t2, b)
            if hi > lo:
                total += hi - lo
        return total

    def _build_grid(self) -> None:
        jobs = self.jobs
        # Latest start any job could use (later points are never starts;
        # they are also not needed as capacity checkpoints, because options
        # only *end* there and usage never increases at an end).
        self._max_start = max(
            j.deadline - min(
                min(j.rung(v)[2].values()) for v in range(j.n_variants)
            )
            for j in jobs
        ) + FEAS
        base: set[float] = {round(self.now, _ROUND)}
        for starts, _ in self.device_profiles.values():
            base.update(round(float(t), _ROUND) for t in starts)
        lstarts, _ = self.link_profile
        base.update(round(float(t), _ROUND) for t in lstarts)
        for j in jobs:
            base.add(round(j.release, _ROUND))

        # Link-backlog clearing points: for every subset-sum of transfer
        # durations, the earliest time that much free link exists after
        # release.  (All jobs share the decision-time release.)
        self._free_segments_cache = self._free_link_segments()
        # Each offloadable job contributes AT MOST ONE of its rung xfers to
        # any schedule; unioning over the per-job alternatives closes the
        # sums over every admissible variant choice.
        xfer_sums: set[float] = {0.0}
        for j in jobs:
            if not j.offloadable:
                continue
            alts = sorted({round(j.rung(v)[3], _ROUND)
                           for v in range(j.n_variants)
                           if j.rung(v)[3] > FEAS})
            if not alts:
                continue
            add = {round(s + x, _ROUND)
                   for s in xfer_sums for x in alts
                   if s + x <= self.span}
            xfer_sums |= add
            if len(xfer_sums) > self.max_sums:
                raise OracleInstanceError(
                    f"transfer subset-sums exceed {self.max_sums}")
        release0 = min(j.release for j in jobs)
        for d in xfer_sums:  # replint: disable=determinism-set-iter (set-to-set accumulation into `base`; grid is sorted() at the end)
            if d > FEAS:
                base.add(round(self._link_clear_point(release0, d), _ROUND))

        # Closure under sums of slot durations: chains of back-to-back
        # chosen slots anchor later starts.
        deltas: list[tuple[float, ...]] = []
        for j in jobs:
            opts = sorted({round(dur, _ROUND)
                          for v in range(j.n_variants)
                          for dur in j.rung(v)[1].values()})
            deltas.append(tuple(opts))
        sums: set[float] = {0.0}
        limit = self._max_start - self.now
        for opts in deltas:
            new = set()
            for s in sums:  # replint: disable=determinism-set-iter (set-to-set accumulation; order-free union)
                for d in opts:
                    v = round(s + d, _ROUND)
                    if v <= limit:
                        new.add(v)
            sums |= new
            if len(sums) > self.max_sums:
                raise OracleInstanceError(
                    f"slot-duration subset-sums exceed {self.max_sums}")

        # The base x sums product can reach millions of points on instances
        # that are doomed anyway (ladder jobs carry up to 3x the distinct
        # durations, so `sums` saturates fast under load) — check the cap
        # INSIDE the loop so an over-sized instance fails in O(max_grid)
        # instead of building the whole product first.  The now-FEAS floor
        # is applied at insertion so the in-loop count is exact.
        floor = self.now - FEAS
        pts: set[float] = set()
        for b in base:  # replint: disable=determinism-set-iter (set-to-set accumulation into `pts`; grid is sorted() at the end)
            if b > self._max_start:
                if b <= self.horizon and b >= floor:
                    pts.add(b)        # capacity breakpoint past last start
                continue
            for s in sums:  # replint: disable=determinism-set-iter (set-to-set accumulation; order-free union)
                v = round(b + s, _ROUND)
                if v <= self._max_start and v >= floor:
                    pts.add(v)
            if len(pts) > self.max_grid:
                raise OracleInstanceError(
                    f"candidate grid exceeds {self.max_grid} points; "
                    "the oracle is for oracle-sized instances (DESIGN.md §13)")
        if len(pts) > self.max_grid:
            raise OracleInstanceError(
                f"candidate grid has {len(pts)} points (> {self.max_grid}); "
                "the oracle is for oracle-sized instances (DESIGN.md §13)")
        self.grid = np.array(sorted(pts))

        # Existing free capacity per device per grid segment (segment i is
        # [grid[i], grid[i+1]), the last running to the horizon).  Existing
        # usage is constant on each segment because every calendar
        # breakpoint is a grid point.
        g = self.grid
        nseg = len(g)
        self.free: dict[int, np.ndarray] = {}
        for dev, (starts, vals) in self.device_profiles.items():
            free = np.full(nseg, self.capacity, dtype=np.int64)
            if len(starts):
                idx = np.searchsorted(starts, g + FEAS, side="right") - 1
                inside = idx >= 0
                free[inside] = self.capacity - vals[idx[inside]]
            self.free[dev] = free

    # -- options -------------------------------------------------------- #
    def _goodput(self, accuracy: float, completion: float) -> float:
        """Accuracy-weighted earliness in [0, 1): the objective tiebreak."""
        frac = max(0.0, 1.0 - (completion - self.now) / self.span)
        return accuracy * min(frac, 1.0)

    def _build_options(self) -> None:
        jobs, g = self.jobs, self.grid
        n = len(jobs)
        # Weighted lexicographic objective: one HP completion outweighs
        # every possible LP gain (2n + 4 > 2n + 1), one completion of any
        # kind outweighs the total goodput tiebreak (2 > 1 > sum of
        # per-job goodput terms scaled by 1/(n+1)).  A completion counts
        # the same at any ladder rung — accuracy enters through the
        # goodput term only, so the oracle degrades exactly when doing so
        # buys a completion (or a better accuracy-earliness product).
        self.w_total = 2.0
        self.w_hp = 2.0 * n + 4.0
        options: list[PlacementOption] = []
        for j in jobs:
            devices = ([j.source_device] if j.is_hp else
                       sorted(self.device_profiles))
            for dev in devices:
                offloaded = (not j.is_hp) and dev != j.source_device
                if offloaded and not j.offloadable:
                    continue
                free = self.free[dev]
                for variant in range(j.n_variants):
                    acc, durs, comps, xfer = j.rung(variant)
                    for cores, dur in sorted(durs.items()):
                        comp_dur = comps[cores]
                        lo = j.release + (xfer if offloaded else 0.0)
                        hi = j.deadline - comp_dur + FEAS
                        if hi < lo - FEAS:
                            continue
                        i1 = int(np.searchsorted(g, lo - FEAS, side="left"))
                        i2 = int(np.searchsorted(g, hi + FEAS, side="right"))
                        for gi in range(i1, i2):
                            s = float(g[gi])
                            e = s + dur
                            # static feasibility vs *existing* occupancy
                            j2 = int(np.searchsorted(g, e - FEAS,
                                                     side="left"))
                            if j2 > gi and int(free[gi:j2].min()) < cores:
                                continue
                            comp = s + comp_dur
                            w = (self.w_total
                                 + (self.w_hp if j.is_hp else 0.0)
                                 + self._goodput(acc, comp) / (n + 1.0))
                            options.append(PlacementOption(
                                j.idx, dev, cores, s, e, comp, offloaded,
                                w, variant, acc,
                                xfer if offloaded else 0.0))
                            if len(options) > self.max_options:
                                raise OracleInstanceError(
                                    f"option count exceeds "
                                    f"{self.max_options}; oracle-sized "
                                    "instances only (DESIGN.md §13)")
        self.options = options
        self.by_job: list[list[int]] = [[] for _ in jobs]
        for oi, o in enumerate(options):
            self.by_job[o.job].append(oi)

    # -- constraint rows ------------------------------------------------ #
    def _build_capacity_rows(self) -> None:
        """(device, grid-segment) checkpoints covered by >= 1 option."""
        g = self.grid
        self._opt_span: list[tuple[int, int]] = []
        covered: dict[tuple[int, int], list[int]] = {}
        for oi, o in enumerate(self.options):
            i1 = int(np.searchsorted(g, o.start - FEAS, side="left"))
            i2 = int(np.searchsorted(g, o.end - FEAS, side="left"))
            self._opt_span.append((i1, i2))
            for seg in range(i1, i2):
                covered.setdefault((o.device, seg), []).append(oi)
        self.capacity_rows: list[tuple[list[int], int]] = []
        self._cap_row_of: dict[tuple[int, int], int] = {}
        for (dev, seg), ois in sorted(covered.items()):
            rhs = int(self.free[dev][seg])
            if sum(self.options[oi].cores for oi in ois) <= rhs:
                continue                        # can never bind
            self._cap_row_of[(dev, seg)] = len(self.capacity_rows)
            self.capacity_rows.append((ois, rhs))

    def _build_link_rows(self) -> None:
        """Preemptive-EDF interval conditions: for release ``a`` and
        candidate start ``b``, transfers of chosen offloaded options with
        release >= a and start <= b must fit in the free link time of
        [a, b]."""
        offload = [oi for oi, o in enumerate(self.options) if o.offloaded]
        self.link_rows: list[tuple[list[int], list[float], float]] = []
        if not offload:
            return
        releases = sorted({self.jobs[self.options[oi].job].release
                           for oi in offload})
        starts = sorted({self.options[oi].start for oi in offload})
        for a in releases:
            for b in starts:
                if b < a - FEAS:
                    continue
                ois = [oi for oi in offload
                       if self.jobs[self.options[oi].job].release >= a - FEAS
                       and self.options[oi].start <= b + FEAS]
                if not ois:
                    continue
                xf = [self.options[oi].xfer for oi in ois]
                rhs = self.free_link_time(a, b)
                if sum(xf) <= rhs + LINK_TOL:
                    continue                    # can never bind
                self.link_rows.append((ois, xf, rhs))

    # ------------------------------------------------------------------ #
    # Solving                                                            #
    # ------------------------------------------------------------------ #
    def solve(self, backend: str = "auto") -> OracleSolution:
        if backend == "auto":
            space = 1.0
            for ois in self.by_job:
                space *= len(ois) + 1
            backend = ("brute" if space <= _BRUTE_SPACE
                       or len(self.jobs) <= _BRUTE_JOBS
                       or not _have_scipy_milp() else "milp")
        if backend == "brute":
            return self._solve_brute()
        if backend == "milp":
            return self._solve_milp()
        if backend == "cpsat":
            return self._solve_cpsat()
        raise ValueError(f"unknown oracle backend {backend!r}")

    def _solution(self, chosen: Sequence[int], backend: str) -> OracleSolution:
        placements = {self.options[oi].job: self.options[oi] for oi in chosen}
        hp = sum(1 for o in placements.values() if self.jobs[o.job].is_hp)
        goodput = sum(self._goodput(o.accuracy, o.completion)
                      for o in placements.values())
        objective = sum(self.options[oi].weight for oi in chosen)
        return OracleSolution(objective, hp, len(placements), goodput,
                              placements, backend)

    # -- brute force (the oracle's own correctness oracle) -------------- #
    def _solve_brute(self) -> OracleSolution:
        jobs = self.jobs
        order = sorted(
            range(len(jobs)),
            key=lambda ji: -max(
                (self.options[oi].weight for oi in self.by_job[ji]),
                default=0.0),
        )
        # per-job options, best weight first (first full descent is greedy)
        opts = [sorted(self.by_job[ji],
                       key=lambda oi: -self.options[oi].weight)
                for ji in order]
        suffix = [0.0] * (len(order) + 1)
        for k in range(len(order) - 1, -1, -1):
            best = max((self.options[oi].weight for oi in opts[k]),
                       default=0.0)
            suffix[k] = suffix[k + 1] + best

        free = {d: arr.astype(np.int64).copy()
                for d, arr in self.free.items()}
        link_used = [0.0] * len(self.link_rows)
        link_rows_of: dict[int, list[int]] = {}
        for ri, (ois, _, _) in enumerate(self.link_rows):
            for oi in ois:
                link_rows_of.setdefault(oi, []).append(ri)

        best_obj = -1.0
        best_chosen: list[int] = []
        chosen: list[int] = []

        def feasible(oi: int) -> bool:
            o = self.options[oi]
            i1, i2 = self._opt_span[oi]
            if i2 > i1 and int(free[o.device][i1:i2].min()) < o.cores:
                return False
            for ri in link_rows_of.get(oi, ()):
                if link_used[ri] + o.xfer > self.link_rows[ri][2] + LINK_TOL:
                    return False
            return True

        def apply(oi: int, sign: int) -> None:
            o = self.options[oi]
            i1, i2 = self._opt_span[oi]
            free[o.device][i1:i2] -= sign * o.cores
            for ri in link_rows_of.get(oi, ()):
                link_used[ri] += sign * o.xfer

        def dfs(k: int, acc: float) -> None:
            nonlocal best_obj, best_chosen
            if acc + suffix[k] <= best_obj + 1e-12:
                return
            if k == len(order):
                best_obj = acc
                best_chosen = list(chosen)
                return
            for oi in opts[k]:
                if not feasible(oi):
                    continue
                apply(oi, 1)
                chosen.append(oi)
                dfs(k + 1, acc + self.options[oi].weight)
                chosen.pop()
                apply(oi, -1)
            dfs(k + 1, acc)                     # leave job k unplaced

        dfs(0, 0.0)
        return self._solution(best_chosen, "brute")

    # -- MILP (scipy / HiGHS) ------------------------------------------- #
    def _solve_milp(self) -> OracleSolution:
        try:
            from scipy import sparse
            from scipy.optimize import Bounds, LinearConstraint, milp
        except ImportError as exc:               # pragma: no cover
            raise OracleInstanceError(
                "scipy.optimize.milp unavailable; use the brute backend"
            ) from exc
        n_opts = len(self.options)
        if n_opts == 0:
            return self._solution([], "milp")
        rows_i: list[int] = []
        cols: list[int] = []
        vals: list[float] = []
        ub: list[float] = []
        row = 0
        for ois in self.by_job:                 # one option per job
            if not ois:
                continue
            for oi in ois:
                rows_i.append(row)
                cols.append(oi)
                vals.append(1.0)
            ub.append(1.0)
            row += 1
        for ois, rhs in self.capacity_rows:     # device capacity
            for oi in ois:
                rows_i.append(row)
                cols.append(oi)
                vals.append(float(self.options[oi].cores))
            ub.append(float(rhs))
            row += 1
        for ois, xf, rhs in self.link_rows:     # link intervals
            for oi, x in zip(ois, xf):
                rows_i.append(row)
                cols.append(oi)
                vals.append(x)
            ub.append(rhs + LINK_TOL)
            row += 1
        A = sparse.csr_matrix((vals, (rows_i, cols)), shape=(row, n_opts))
        c = -np.array([o.weight for o in self.options])
        res = milp(
            c,
            constraints=LinearConstraint(A, -np.inf, np.array(ub)),
            integrality=np.ones(n_opts),
            bounds=Bounds(0.0, 1.0),
        )
        if res.x is None:                        # pragma: no cover
            raise OracleInstanceError(f"MILP solve failed: {res.message}")
        chosen = [oi for oi in range(n_opts) if res.x[oi] > 0.5]
        return self._solution(chosen, "milp")

    # -- CP-SAT (optional; requires ortools) ---------------------------- #
    def _solve_cpsat(self) -> OracleSolution:
        if not have_ortools():
            raise OracleInstanceError(
                "ortools is not installed; the cpsat backend is optional — "
                "use 'milp', 'brute' or 'auto'")
        from ortools.sat.python import cp_model
        SCALE = 10**9
        model = cp_model.CpModel()
        xs = [model.NewBoolVar(f"x{oi}") for oi in range(len(self.options))]
        for ois in self.by_job:
            if ois:
                model.AddAtMostOne(xs[oi] for oi in ois)
        for ois, rhs in self.capacity_rows:
            model.Add(sum(self.options[oi].cores * xs[oi]
                          for oi in ois) <= rhs)
        for ois, xf, rhs in self.link_rows:
            model.Add(sum(int(round(x * SCALE)) * xs[oi]
                          for oi, x in zip(ois, xf))
                      <= int(round((rhs + LINK_TOL) * SCALE)))
        model.Maximize(sum(int(round(o.weight * SCALE)) * x
                           for o, x in zip(self.options, xs)))
        solver = cp_model.CpSolver()
        status = solver.Solve(model)
        if status not in (cp_model.OPTIMAL,):    # pragma: no cover
            raise OracleInstanceError(f"CP-SAT solve status {status}")
        chosen = [oi for oi in range(len(xs))
                  if solver.BooleanValue(xs[oi])]
        return self._solution(chosen, "cpsat")

    # ------------------------------------------------------------------ #
    # Verification + scoring (tests / quality report)                    #
    # ------------------------------------------------------------------ #
    def verify(self, sol: OracleSolution) -> None:
        """Independently re-check a solution against the instance model
        (deadlines, capacity vs existing occupancy, link intervals).
        Raises AssertionError on any violation."""
        placements = list(sol.placements.values())
        for o in placements:
            j = self.jobs[o.job]
            assert o.completion <= j.deadline + 1e-6, \
                f"job {o.job} misses deadline"
            assert o.start >= j.release - 1e-9
            if o.offloaded:
                assert j.offloadable
        for dev in self.device_profiles:
            events: list[tuple[float, int]] = []
            for o in placements:
                if o.device == dev:
                    events.append((o.start, o.cores))
                    events.append((o.end, -o.cores))
            for t, _ in sorted(events):
                load = sum(o.cores for o in placements
                           if o.device == dev
                           and o.start <= t + FEAS and o.end > t + FEAS)
                gi = int(np.searchsorted(self.grid, t + FEAS, side="right")) - 1
                existing = self.capacity - int(self.free[dev][max(gi, 0)])
                assert load + existing <= self.capacity + 1e-9, \
                    f"device {dev} over capacity at t={t}"
        offl = [o for o in placements if o.offloaded]
        for a in sorted({self.jobs[o.job].release for o in offl}):
            for b in sorted({o.start for o in offl}):
                demand = sum(o.xfer for o in offl
                             if self.jobs[o.job].release >= a - FEAS
                             and o.start <= b + FEAS)
                assert demand <= self.free_link_time(a, b) + 1e-6, \
                    f"link overflow on [{a}, {b}]"

    def score_tasks(self, tasks: Sequence[Task]) -> tuple[float, tuple]:
        """Score a policy's committed placements of ``tasks`` (parallel to
        the instance's jobs) under the oracle objective.  A task counts as
        completed when it holds a slot whose model completion time meets
        the deadline — exactly the instance's completion rule.  A task
        admitted at a ladder rung is scored at that rung's completion
        duration and accuracy (``task.variant``, DESIGN.md §17)."""
        obj, hp, total, good = 0.0, 0, 0, 0.0
        n = len(self.jobs)
        for j, task in zip(self.jobs, tasks):
            if task.t_start is None or task.cores is None:
                continue
            if task.state not in (TaskState.ALLOCATED, TaskState.RUNNING,
                                  TaskState.COMPLETED):
                continue
            acc, _, comps, _ = j.rung(task.variant)
            comp = task.t_start + comps.get(task.cores, float("inf"))
            if comp > j.deadline + 1e-6:
                continue
            g = self._goodput(acc, comp)
            obj += (self.w_total + (self.w_hp if j.is_hp else 0.0)
                    + g / (n + 1.0))
            hp += 1 if j.is_hp else 0
            total += 1
            good += g
        return obj, (hp, total, good)


# ====================================================================== #
# The registered policy                                                  #
# ====================================================================== #
@register_policy("oracle")
class OraclePolicy(CalendarPolicy):
    """Per-decision application of the placement oracle (DESIGN.md §13).

    HP admission uses the closed-form instance optimum — the earliest
    feasible 1-core slot on the source device (earlier is strictly better
    under the goodput tiebreak, and feasibility is monotone).  Each LP
    request is solved as one joint oracle instance over its pending tasks;
    an oversized instance falls back to per-task singleton instances.

    The policy never preempts and pays no allocation/update messages —
    its per-run metrics are a *reference*, not a physical discipline.
    Offloaded transfers are committed as (possibly fragmented) link
    reservations realising the preemptive-EDF schedule the instance
    certified, so successive decisions see real link contention.
    """

    def __init__(self, n_devices: int, net: NetworkConfig, *,
                 capacity: int = 4, metrics: Optional[Metrics] = None,
                 backend: str = "auto", **_ignored) -> None:
        super().__init__(n_devices, net, capacity=capacity, metrics=metrics)
        self.backend = backend

    # -- HP: closed-form instance optimum ------------------------------- #
    def decide_hp(self, task: Task, now: float) -> Decision:
        self.state.gc(now)
        prof = self.net.profile(task.task_type)
        dev = self.state.devices[task.source_device]
        if not dev.is_up:
            # HP runs on its (DRAINING/DOWN) home device only: reject.
            return Decision(DecisionStatus.REJECTED, failed=[task])
        t1 = dev.earliest_fit(prof.hp_slot_time, now, 1)
        if t1 + prof.hp_exec > task.deadline:
            return Decision(DecisionStatus.REJECTED, failed=[task])
        t2 = t1 + prof.hp_slot_time
        dev.reserve(t1, t2, 1, task)
        task.state = TaskState.ALLOCATED
        task.device, task.cores = task.source_device, 1
        task.t_start, task.t_end, task.offloaded = t1, t2, False
        alloc = Allocation(task, task.source_device, t1, t2, 1, False)
        return Decision(DecisionStatus.ADMITTED, allocations=[alloc],
                        predicted_completion=t2)

    # -- LP: one joint instance per request ----------------------------- #
    def decide_lp(self, request: LowPriorityRequest, now: float) -> Decision:
        self.state.gc(now)
        pending = [t for t in request.tasks
                   if t.state == TaskState.PENDING]
        if not pending:
            return Decision(DecisionStatus.REJECTED)
        placed = self._solve_and_commit(pending, now)
        dec = Decision(DecisionStatus.REJECTED)
        for task in pending:
            alloc = placed.get(task)
            if alloc is None:
                dec.failed.append(task)
            else:
                dec.allocations.append(alloc)
                dec.status = DecisionStatus.ADMITTED
        if dec.allocations:
            dec.predicted_completion = max(a.t_end for a in dec.allocations)
        return dec

    def _solve_and_commit(
        self, tasks: list[Task], now: float
    ) -> dict[Task, Allocation]:
        try:
            groups: list[list[Task]] = [tasks]
            inst = OracleInstance.from_state(self.state, self.net, tasks, now)
        except OracleInstanceError:
            groups = [[t] for t in tasks]       # oversized: singletons
        placed: dict[Task, Allocation] = {}
        for group in groups:
            try:
                if group is not tasks:
                    inst = OracleInstance.from_state(
                        self.state, self.net, group, now)
                sol = inst.solve(self.backend)
            except OracleInstanceError:
                continue                        # group stays unplaced
            for o in sorted(sol.placements.values(), key=lambda o: o.start):
                task = group[o.job]
                dev = self.state.devices[o.device]
                dev.reserve(o.start, o.end, o.cores, task)
                if o.offloaded:
                    # o.xfer is the chosen rung's input transfer (the base
                    # profile's for variant 0 — the historic behaviour).
                    self._commit_transfer(task, now, o.start, o.xfer)
                task.state = TaskState.ALLOCATED
                task.device, task.cores = o.device, o.cores
                task.t_start, task.t_end = o.start, o.end
                task.offloaded = o.offloaded
                task.variant = o.variant
                placed[task] = Allocation(task, o.device, o.start, o.end,
                                          o.cores, o.offloaded)
        return placed

    def _commit_transfer(self, task: Task, release: float, start: float,
                         xfer: float) -> None:
        """Realise the certified preemptive transfer as (possibly
        fragmented) link reservations, earliest-free-first."""
        link = self.state.link
        remaining = xfer
        starts, vals = link.usage_segments(release, start)
        n = len(starts)
        if n == 0:                              # link untouched: one piece
            take = min(start - release, remaining)
            if take > 1e-12:
                link.reserve(release, release + take,
                             ("oxfer", task.task_id))
                remaining -= take
        for i in range(n):
            if remaining <= 1e-9:
                break
            if vals[i] > 0:
                continue
            t1 = float(starts[i])
            t2 = float(starts[i + 1]) if i + 1 < n else start
            take = min(t2 - t1, remaining)
            if take <= 1e-12:
                continue
            link.reserve(t1, t1 + take, ("oxfer", task.task_id))
            remaining -= take
        # Residual < 1e-6 s can remain from float round-off; the instance
        # certified feasibility, so anything larger indicates a model bug.
        assert remaining <= 1e-6 + FEAS, (
            f"uncommittable transfer residual {remaining} for task "
            f"{task.task_id}")
