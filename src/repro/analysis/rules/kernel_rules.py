"""Kernel and import-boundary rules.

* ``pallas-index`` — a bare Python int as a TOP-LEVEL element of a
  ``pl.load`` / ``pl.store`` / ``pl.swap`` index tuple.  This JAX
  version's interpret-mode discharge rule rejects it (``'int' object has
  no attribute 'shape'``) — the bug that broke all 18 flash-attention
  sweeps until PR 3 rewrote the index as ``pl.ds(0, 1)`` + squeeze.
  Ints nested inside ``pl.ds(0, 1)`` or arithmetic (``s * bk``) are fine;
  only a naked integer element trips the discharge rule.
* ``jax-free-boundary`` — module-level jax imports in the modules the
  streaming path deliberately keeps jax-free (``core/``, ``sim/``,
  ``serving/stream.py`` and the lazy ``serving/__init__.py``): a single
  top-level ``import jax`` there makes every soak / golden-replay
  consumer pay the full jax import.  Function-level (deferred) imports
  and ``if TYPE_CHECKING:`` blocks are allowed.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional, Sequence

from ..engine import Finding, Module, Rule

PALLAS = "jax.experimental.pallas"
INDEXED_OPS = frozenset({"load", "store", "swap"})

#: Modules that must stay importable without jax (PR 7's streaming path).
JAX_FREE_PREFIXES: tuple[str, ...] = ("repro/core/", "repro/sim/",
                                      "repro/analysis/")
JAX_FREE_FILES: frozenset[str] = frozenset({
    "repro/serving/stream.py",
    "repro/serving/__init__.py",
})


def _bare_int(node: ast.AST) -> bool:
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        node = node.operand
    return (isinstance(node, ast.Constant)
            and type(node.value) is int)


class PallasIndexRule(Rule):
    name = "pallas-index"
    description = ("bare Python int inside a pl.load/pl.store/pl.swap "
                   "index tuple (interpret-mode discharge rejects it)")

    def check(self, mod: Module) -> Iterator[Finding]:
        aliases = {name for name, origin in mod.imports.items()
                   if origin == PALLAS}
        if not aliases:
            return
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if not (isinstance(func, ast.Attribute)
                    and func.attr in INDEXED_OPS
                    and isinstance(func.value, ast.Name)
                    and func.value.id in aliases):
                continue
            if len(node.args) < 2:
                continue
            idx = node.args[1]
            elements = idx.elts if isinstance(idx, ast.Tuple) else [idx]
            bad = [e for e in elements if _bare_int(e)]
            if bad:
                rendered = ", ".join(ast.unparse(e) for e in bad)
                yield Finding(
                    self.name, mod.rel, node.lineno, node.col_offset,
                    f"bare Python int ({rendered}) as a top-level element "
                    f"of a {func.value.id}.{func.attr} index tuple — the "
                    "interpret-mode discharge rule rejects it; use "
                    "pl.ds(i, 1) + squeeze instead",
                    mod.qualname(node.lineno))


class JaxImportRule(Rule):
    name = "jax-free-boundary"
    description = ("module-level jax import in a module the streaming "
                   "path keeps jax-free")

    def __init__(self, prefixes: Optional[Sequence[str]] = None,
                 files: Optional[Sequence[str]] = None) -> None:
        self.prefixes = tuple(JAX_FREE_PREFIXES if prefixes is None
                              else prefixes)
        self.files = frozenset(JAX_FREE_FILES if files is None else files)

    def applies_to(self, rel: str) -> bool:
        return rel.startswith(self.prefixes) or rel in self.files

    def _module_level(self, body: Sequence[ast.stmt]) -> Iterator[ast.stmt]:
        """Statements executed at import time: recurse into module-level
        control flow and class bodies, skip function bodies and
        ``if TYPE_CHECKING:`` blocks."""
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(stmt, ast.If):
                test = stmt.test
                if (isinstance(test, ast.Name)
                        and test.id == "TYPE_CHECKING") or (
                        isinstance(test, ast.Attribute)
                        and test.attr == "TYPE_CHECKING"):
                    continue
                yield from self._module_level(stmt.body)
                yield from self._module_level(stmt.orelse)
                continue
            yield stmt
            if isinstance(stmt, ast.ClassDef):
                yield from self._module_level(stmt.body)
            elif isinstance(stmt, ast.Try):
                yield from self._module_level(stmt.body)
                yield from self._module_level(stmt.orelse)
                yield from self._module_level(stmt.finalbody)
                for handler in stmt.handlers:
                    yield from self._module_level(handler.body)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                yield from self._module_level(stmt.body)

    def check(self, mod: Module) -> Iterator[Finding]:
        for stmt in self._module_level(mod.tree.body):
            names: list[str] = []
            if isinstance(stmt, ast.Import):
                names = [a.name for a in stmt.names]
            elif isinstance(stmt, ast.ImportFrom) and stmt.module:
                names = [stmt.module]
            for name in names:
                if name == "jax" or name.startswith("jax."):
                    yield Finding(
                        self.name, mod.rel, stmt.lineno, stmt.col_offset,
                        f"module-level import of {name!r} in a jax-free "
                        "module — the streaming path must import without "
                        "jax; defer the import into the function that "
                        "needs it", mod.qualname(stmt.lineno))
                    break
