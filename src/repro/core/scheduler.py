"""The paper's two scheduling algorithms (§4), scaled for large networks.

High-priority allocation (`allocate_high_priority`): local-only, single-core,
allocated at arrival time; optionally backed by the deadline-aware preemption
mechanism (victims are conflicting LP reservations, farthest deadline first,
each followed by a reallocation attempt).

Low-priority allocation (`allocate_low_priority`): offloadable,
multi-configuration (2/4-core horizontal partitioning), searching over the
completion time-points of already-allocated tasks up to the request deadline,
with partial allocation, even spreading (least-loaded device first) and a
core-upgrade pass.

Complexity (DESIGN.md §2.3, paper §6.3)
---------------------------------------
Every probe the algorithms issue (`fits`, `load`, `earliest_slot`,
`completion_times`) is answered by the skyline calendars in
O(log n + k) for k structures intersecting the probed window, so:

* HP admission is O(log n + conflicts) per call — the preemption loop only
  enumerates reservations on the *source device*, and through the
  vectorized preemption plane (DESIGN.md §12) that enumeration is ONE
  overlap mask over the device's LP-reservation mirror plus one masked
  argmin per victim, with incremental refit (`_HPWindowGrid`) instead of a
  full ``fits`` re-probe after each eviction.  The scalar loop is kept as
  the differential reference (``preemption_plane=False``).
* LP admission is O(T · D · (log n + k)) for T time-points searched and D
  devices, with T bounded by the completion points inside the request's
  deadline window rather than every reservation in the network.
* `allocate_low_priority_batch` admits a whole arrival burst in ONE
  `gc` + ONE network-wide time-point sweep (a monotone heap that also absorbs
  completion points created by the batch itself), instead of re-running the
  full sweep per request — the per-request cost at high arrival rates drops
  by roughly the batch size (measured in benchmarks/scheduler_micro.py).

Victim lifecycle: EVERY evicted victim gets the best-effort reallocation
pass (`_reallocate_victims`) — also when the HP admission itself ultimately
fails after its preemptions (deadline slipped or non-LP blockers remain).
A victim is never left stranded in ``PREEMPTED``: it either re-enters
``ALLOCATED`` with a fresh slot before its deadline or transitions to
``FAILED``, and ``realloc_success``/``realloc_failure`` account for both
paths (the failure path was a PR 5 bugfix).

Link-slot hygiene: every committed allocation records its link reservations
(`alloc`/`xfer`/`update` messages); when a victim is preempted, its
still-pending link slots are cancelled so the shared link does not
permanently inflate with dead traffic (a seed bug — see
tests/test_scheduler.py::test_preemption_cancels_victim_link_slots).
"""
from __future__ import annotations

import heapq
import itertools
import time as _time
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from .calendar import EPS, NetworkState, Reservation
from .metrics import Metrics
from .network import NetworkConfig
from .task import LowPriorityRequest, Priority, Task, TaskState
from .victims import GOOD_STATES, plan_shrink, rank_victims, victim_sort_key

#: Victim-selection rules accepted by the preemption mechanism (also the
#: options surfaced by ``ScenarioConfig`` validation).  ``degrade_shrink``
#: ranks like ``farthest_deadline`` but first tries to shrink the chosen
#: victim in place down its variant ladder (DESIGN.md §17), evicting only
#: when no viable shrink exists.
VICTIM_POLICIES = ("farthest_deadline", "weakest_set", "degrade_shrink")


def _dev_up(dev) -> bool:
    """Placement eligibility of one device calendar.  The seed reference
    calendars (calendar_reference) predate the lifecycle plane and are
    treated as always UP."""
    return getattr(dev, "is_up", True)


@dataclass
class Allocation:
    """A committed placement decision for a single task."""

    task: Task
    device: int
    t_start: float
    t_end: float                       # end of reserved slot (incl. padding)
    cores: int
    offloaded: bool
    link_slots: list[Reservation] = field(default_factory=list)


@dataclass
class HPResult:
    success: bool
    allocation: Optional[Allocation] = None
    preempted: list[Task] = field(default_factory=list)
    reallocations: list[Allocation] = field(default_factory=list)


@dataclass
class LPResult:
    allocations: list[Allocation] = field(default_factory=list)
    failed: list[Task] = field(default_factory=list)


class LinkSlotRegistry:
    """Link reservations per task, so a discipline can cancel a preempted or
    reallocated task's still-pending messages (alloc/xfer/update).  Shared by
    ``PreemptionAwareScheduler`` and the calendar-backed policy plugins."""

    def __init__(self) -> None:
        self._slots: dict[int, list[Reservation]] = {}
        self._prune_at = 256

    def record(self, task_id: int, slots: list[Reservation]) -> None:
        self._slots[task_id] = slots

    def pop(self, task_id: int) -> list[Reservation]:
        return self._slots.pop(task_id, [])

    def cancel_pending(self, link, task_id: int, now: float) -> None:
        """Cancel the task's link slots that still lie in the future."""
        for slot in self.pop(task_id):
            if slot.t2 > now + EPS:
                link.cancel(slot)

    def prune(self, now: float) -> None:
        """Drop records whose messages all lie in the past.  Amortised
        O(1): runs only when the registry doubled."""
        if len(self._slots) <= self._prune_at:
            return
        self._slots = {
            tid: slots
            for tid, slots in self._slots.items()
            if any(s.t2 > now for s in slots)
        }
        self._prune_at = max(256, 2 * len(self._slots))


class _HPWindowGrid:
    """Incremental refit tracker for one HP admission.

    The scalar eviction loop re-probes ``dev.fits(t1, t2, 1)`` after every
    eviction, paying a skyline flush (one splice per buffered release) per
    probe.  This grid instead materialises the usage segments ONCE over an
    *extended* horizon ``[t1, cover)`` — the admission window plus the
    forward drift the loop's own preempt messages can cause (each message
    occupies the link and pushes the re-derived window later, never
    earlier) — cut at every live LP candidate's endpoints so any future
    eviction aligns with existing breakpoints.  Each eviction is then an
    exact usage-mass delta over its segment range, and each refit a
    searchsorted slice-max, both O(covered segments) C-level with no
    skyline interaction at all.

    Integer arithmetic over the same EPS-shrunk windows every skyline
    query uses, so ``fits_window`` is bit-identical to ``dev.fits`` after
    the same evictions (fuzzed in tests/test_preemption_plane.py).
    ``fits_window`` returns None when the window drifted past ``cover`` —
    the caller rebuilds (a cold rebuild is always exact: the flushed
    skyline already reflects every eviction so far).
    """

    __slots__ = ("a", "cover", "cap", "bp", "vals")

    def __init__(self, dev, t1: float, cover: float,
                 cand_t1: np.ndarray, cand_t2: np.ndarray,
                 alive: np.ndarray) -> None:
        a = t1 + EPS
        self.a, self.cover = a, cover
        self.cap = dev.capacity
        starts, vals = dev.usage_segments(a, cover)
        if starts.size:
            cuts = np.concatenate((cand_t1[alive], cand_t2[alive]))
            cuts = cuts[(cuts > a) & (cuts < cover)]
            if cuts.size:
                bp = np.unique(np.concatenate((starts, cuts)))
                vals = vals[np.searchsorted(starts, bp, side="right") - 1]
                starts = bp
        self.bp = starts
        self.vals = vals.astype(np.int64, copy=True)

    def fits_window(self, t1: float, t2: float, cores: int):
        """Whether ``cores`` more cores fit everywhere in [t1, t2)
        (EPS-shrunk, like every calendar query); None = window no longer
        covered, rebuild required."""
        a, b = t1 + EPS, t2 - EPS
        if a < self.a - EPS or b > self.cover:
            return None
        if b <= a or self.vals.size == 0:
            return True
        bp = self.bp
        i1 = int(bp.searchsorted(a, side="right")) - 1
        i2 = int(bp.searchsorted(b, side="left"))
        if i1 < 0:
            i1 = 0
        return int(self.vals[i1:i2].max()) + cores <= self.cap

    def evict(self, vt1: float, vt2: float, amount: int) -> None:
        """Subtract an evicted reservation's usage mass from the grid."""
        if self.vals.size == 0:
            return
        bp = self.bp
        j1 = int(bp.searchsorted(vt1 if vt1 > self.a else self.a,
                                 side="left"))
        j2 = int(bp.searchsorted(vt2, side="left"))
        self.vals[j1:j2] -= amount


class PreemptionAwareScheduler:
    """Controller-side scheduler over the time-slotted network state."""

    def __init__(
        self,
        state: NetworkState,
        net: NetworkConfig,
        preemption: bool = True,
        metrics: Optional[Metrics] = None,
        on_preempt: Optional[Callable[[Task], None]] = None,
        victim_policy: str = "farthest_deadline",
        allow_offload: bool = True,
        preemption_plane: bool = True,
        degrade: bool = False,
    ) -> None:
        self.state = state
        self.net = net
        self.preemption = preemption
        self.allow_offload = allow_offload
        self.metrics = metrics if metrics is not None else Metrics()
        # Degrade-before-reject (DESIGN.md §17): when an LP task cannot be
        # placed at its current ladder rung, retry the admission down the
        # task type's variant ladder before settling it FAILED.  Off by
        # default — every golden path runs reject-only.
        self.degrade = degrade
        # Callback into the runtime so a running victim is actually stopped.
        self.on_preempt = on_preempt
        # Victim selection among conflicting LP reservations:
        #   "farthest_deadline"  the paper's §4 rule.
        #   "weakest_set"        the paper's §8 future-work proposal
        #                        (beyond-paper): prefer a victim whose request
        #                        set is least likely to complete anyway —
        #                        fewest healthy siblings — so preemption
        #                        destroys the least prospective frame value;
        #                        tie-break by farthest deadline.
        #   "degrade_shrink"     degrade-instead-of-evict (DESIGN.md §17):
        #                        same ranking as farthest_deadline, but the
        #                        chosen victim is shrunk in place down its
        #                        variant ladder when viable (core/victims.py
        #                        plan_shrink), evicted only otherwise.
        if victim_policy not in VICTIM_POLICIES:
            raise ValueError(
                f"unknown victim_policy {victim_policy!r}; expected one of "
                + ", ".join(VICTIM_POLICIES)
            )
        self.victim_policy = victim_policy
        self._degrade_evict = victim_policy == "degrade_shrink"
        self._requests: dict[int, LowPriorityRequest] = {}
        self._requests_prune_at = 256
        # link reservations per task, so preemption/reallocation can cancel
        # a task's still-pending xfer/update messages.
        self.links = LinkSlotRegistry()
        # The vectorized network-wide probe plane (calendar.py): one pass
        # answers fits/loads/earliest-fit for EVERY device.  The reference
        # network state (calendar_reference.py) predates it, so the
        # benchmarks can still drive this scheduler over the seed calendars
        # through the per-device scalar path.
        self._plane_ok = hasattr(state, "probe_plane")
        # The vectorized preemption plane (DESIGN.md §12): HP eviction via
        # overlap masks + one-pass victim ranking over each device's
        # LP-reservation mirror, with incremental refit.  Decision-identical
        # to the scalar loop (`_evict_conflicts_scalar`, kept as the
        # differential reference); ``preemption_plane=False`` forces the
        # scalar path for differential tests and benchmarks.
        self._preempt_plane = (preemption_plane and bool(state.devices)
                               and hasattr(state.devices[0], "lp_mirror"))
        # Probe accounting (tests/test_grid_dedup.py, DESIGN.md §11): how
        # many per-task placement probes ran, how many time-point rounds the
        # LP sweeps walked, and how much grid traffic the exact-duplicate
        # dedup removed.  Plain counters — negligible overhead, always on.
        self.lp_probes = 0
        self.grid_rounds = 0
        self.grid_pushes = 0
        self.grid_dups_skipped = 0
        self._dedup_grid = True

    # ------------------------------------------------------------------ #
    # High-priority algorithm                                            #
    # ------------------------------------------------------------------ #
    def allocate_high_priority(self, task: Task, now: float) -> HPResult:
        t_wall = _time.perf_counter()
        self.state.gc(now)
        self.links.prune(now)
        result = self._hp_inner(task, now)
        elapsed = _time.perf_counter() - t_wall
        if result.preempted:
            self.metrics.t_hp_preempt.append(elapsed)
        else:
            self.metrics.t_hp_initial.append(elapsed)
        return result

    def _hp_inner(self, task: Task, now: float) -> HPResult:
        net, link = self.net, self.state.link
        dev = self.state.devices[task.source_device]
        if not _dev_up(dev):
            # HP execution is source-local (paper rule): a DRAINING/DOWN
            # home device takes no new placements, so admission fails.
            return HPResult(False)
        prof = net.profile(task.task_type)
        msg_dur = net.slot(net.msg.hp_alloc)

        def placement():
            """(msg_t1, t1, t2) for the earliest feasible window, or None if
            the deadline can't be met.  Recomputed after every preemption —
            each preempt message occupies the link and pushes the allocation
            message (and hence the processing window) later."""
            msg_t1 = link.earliest_slot(msg_dur, now)
            arrival = msg_t1 + msg_dur
            if arrival + prof.hp_exec > task.deadline:
                return None
            return msg_t1, arrival, arrival + prof.hp_slot_time

        plan = placement()
        if plan is None:
            return HPResult(False)          # can't meet the deadline at all
        msg_t1, t1, t2 = plan

        if dev.fits(t1, t2, 1):
            return HPResult(True, self._commit_hp(task, msg_t1, msg_dur, t1, t2))

        if not self.preemption:
            return HPResult(False)

        # 3. preemption: evict conflicting LP tasks in victim-policy order
        # until the window fits — through the vectorized preemption plane
        # (DESIGN.md §12) or the scalar differential reference.
        e_wall = _time.perf_counter()
        if self._preempt_plane:
            plan, preempted, shrunk = self._evict_conflicts_plane(
                dev, plan, placement, now)
        else:
            plan, preempted, shrunk = self._evict_conflicts_scalar(
                dev, plan, placement, now)
        self.metrics.t_evict.append(_time.perf_counter() - e_wall)

        if plan is None or not dev.fits(plan[1], plan[2], 1):
            # The HP task ultimately cannot be placed — but its victims were
            # already evicted.  They STILL get the reallocation pass (each
            # one a placement attempt before its own deadline, else FAILED):
            # returning them stranded in PREEMPTED forever broke the paper's
            # reallocation guarantee and skewed the realloc accounting
            # (tests/test_victim_lifecycle.py::
            # test_failed_hp_admission_still_reallocates_victims).
            return HPResult(
                False, preempted=preempted + shrunk,
                reallocations=self._reallocate_victims(preempted, now)
                + self._rearm_shrunk(shrunk))
        msg_t1, t1, t2 = plan

        alloc = self._commit_hp(task, msg_t1, msg_dur, t1, t2)

        # 4. attempt to reallocate every victim before its deadline.
        # Shrunk victims ride the same two result lists as a
        # preempted-then-reallocated victim: ``preempted`` cancels their
        # stale execution event, ``reallocations`` re-arms the shortened
        # slot — to the dispatcher the two are indistinguishable.
        return HPResult(True, alloc, preempted + shrunk,
                        self._reallocate_victims(preempted, now)
                        + self._rearm_shrunk(shrunk))

    # ------------------------------------------------------------------ #
    # Preemption: eviction loop (vectorized plane + scalar reference)    #
    # ------------------------------------------------------------------ #
    def _preempt_victim(self, dev, victim: Task, amount: int,
                        now: float) -> None:
        """Evict one victim — the side effects both eviction loops share."""
        net, link = self.net, self.state.link
        dev.release(victim)
        # Cancel the victim's still-pending link slots (xfer/update):
        # leaving them reserved would permanently inflate link congestion
        # with traffic for a task that will never run in that slot.
        self.links.cancel_pending(link, victim.task_id, now)
        victim.state = TaskState.PREEMPTED
        victim.preempt_count += 1
        self.metrics.preemptions += 1
        self.metrics.preempted_by_cores[amount] += 1
        # preemption message to the executing device
        pre_dur = net.slot(net.msg.preempt)
        link.reserve_earliest(pre_dur, now, ("preempt", victim.task_id))
        if self.on_preempt is not None:
            self.on_preempt(victim)

    def _shrink_victim(self, dev, victim: Task, new_end: float,
                       now: float) -> None:
        """Degrade-instead-of-evict one victim (DESIGN.md §17): drop it to
        the next ladder rung at its current core count and truncate its
        reservation to the shorter slot.  ``truncate`` updates the skyline
        AND the preemption plane's LP-mirror row in place (a re-reserve
        would append a fresh mirror row behind the eviction loop's column
        views).  The victim stays ALLOCATED; its link slots stay reserved
        (the input already shipped at the admitted rung's size, and the
        update slot at the old end is simply a late update).  A resize
        notification occupies the link like a preempt message, so the
        caller must re-derive the HP window afterwards."""
        net, link = self.net, self.state.link
        dev.truncate(victim, new_end)
        victim.variant += 1
        victim.t_end = new_end
        self.metrics.degrade_shrinks += 1
        self.metrics.lp_degraded += 1
        msg_dur = net.slot(net.msg.preempt)
        link.reserve_earliest(msg_dur, now, ("degrade", victim.task_id))

    def _try_shrink(self, dev, victim: Task, t1: float, t2: float,
                    now: float) -> bool:
        """Shrink ``victim`` out of the HP window [t1, t2) when the
        ``degrade_shrink`` policy is active and a viable plan exists."""
        if not self._degrade_evict:
            return False
        new_end = plan_shrink(victim, self.net.profile(victim.task_type),
                              t1, t2, now, EPS)
        if new_end is None:
            return False
        self._shrink_victim(dev, victim, new_end, now)
        return True

    def _rearm_shrunk(self, shrunk: list[Task]) -> list[Allocation]:
        """Fresh Allocation records for shrunk victims, so the dispatcher
        re-arms their (shortened) slots exactly like reallocated victims."""
        return [Allocation(t, t.device, t.t_start, t.t_end, t.cores,
                           t.offloaded) for t in shrunk]

    def _evict_conflicts_scalar(self, dev, plan, placement, now: float):
        """The scalar eviction loop, kept verbatim as the differential
        reference for the vectorized plane (the `calendar_reference`
        pattern): per iteration it rebuilds the conflicting-LP list with a
        Python sweep over every reservation on the device and picks one
        victim with ``min()``.  Returns ``(plan, preempted, shrunk)``;
        ``plan`` is None when the preempt messages pushed the window past
        the task's deadline."""
        msg_t1, t1, t2 = plan
        preempted: list[Task] = []
        shrunk: list[Task] = []
        while not dev.fits(t1, t2, 1):
            conflicts = [
                r
                for r in dev.reservations()
                if r.overlaps(t1, t2)
                and isinstance(r.tag, Task)
                and r.tag.priority == Priority.LOW
            ]
            if not conflicts:
                break
            victim_res = min(conflicts, key=self._victim_key)
            victim = victim_res.tag
            if self._try_shrink(dev, victim, t1, t2, now):
                if victim not in shrunk:
                    shrunk.append(victim)
            else:
                self._preempt_victim(dev, victim, victim_res.amount, now)
                preempted.append(victim)
                if victim in shrunk:    # shrunk earlier, evicted after all
                    shrunk.remove(victim)
            plan = placement()          # link moved; re-derive the window
            if plan is None:
                return None, preempted, shrunk
            msg_t1, t1, t2 = plan
        return plan, preempted, shrunk

    def _evict_conflicts_plane(self, dev, plan, placement, now: float):
        """Vectorized eviction (DESIGN.md §12), decision-identical to
        `_evict_conflicts_scalar` (tests/test_preemption_plane.py):

        * conflict enumeration is ONE overlap mask over the device's
          LP-reservation mirror (stacked t1/t2 columns in reservation-dict
          insertion order) — the scalar loop's O(reservations) Python sweep
          per victim becomes an O(reservations) C-level compare;
        * victim ranking is one pass over the stacked `_victim_key` columns
          of the handful of MASKED rows — deadlines are gathered live per
          conflict (a column snapshot would go stale: callers may legally
          mutate ``task.deadline`` after reserving), and the
          ``weakest_set`` set-health column is backed by per-request good
          counters built lazily and decremented as the loop's own evictions
          transition victims out of their sets' good states;
        * refit is the incremental `_HPWindowGrid`: each eviction subtracts
          the victim's usage mass from a segment grid built once over the
          window plus its expected drift, instead of re-probing
          ``dev.fits`` (and re-flushing the skyline) per victim; the grid
          is rebuilt only if an eviction chain outruns the covered
          horizon.

        The loop assumes the only task-state/calendar mutations during the
        admission are its own (the ``on_preempt`` callback must not reserve
        on this device or flip sibling task states — none of the runtimes
        do)."""
        mir = dev.lp_mirror()
        m = mir.m
        msg_t1, t1, t2 = plan
        if m == 0:
            # no LP reservations at all -> the scalar loop's first conflict
            # sweep comes back empty and it breaks immediately
            return plan, [], []
        ct1, ct2, camt = mir.t1[:m], mir.t2[:m], mir.amount[:m]
        alive = mir.alive[:m]       # live view: release flips rows in place
        tasks = mir.tasks
        weakest = self.victim_policy == "weakest_set"
        goods: dict[int, int] = {}      # per-request good-state counters,
        sizes: dict[int, int] = {}      # built lazily per ranked candidate
        preempted: list[Task] = []
        shrunk: list[Task] = []
        # Grid horizon: the window plus the drift this loop's own preempt
        # messages can cause (each pushes the re-derived window later by at
        # most its own link slot) — covers long eviction chains without a
        # rebuild, and a chain that outruns it just rebuilds.
        drift = 64.0 * self.net.slot(self.net.msg.preempt)
        grid = _HPWindowGrid(dev, t1, t2 + drift + 0.5 * (t2 - t1),
                             ct1, ct2, alive)
        while True:
            fits = grid.fits_window(t1, t2, 1)
            if fits is None:            # drifted past coverage: rebuild
                grid = _HPWindowGrid(dev, t1, t2 + drift + 0.5 * (t2 - t1),
                                     ct1, ct2, alive)
                fits = grid.fits_window(t1, t2, 1)
            if fits:
                break
            cand = np.flatnonzero(alive & (ct1 < t2 - EPS)
                                  & (t1 < ct2 - EPS))
            if cand.size == 0:
                break
            # victim-key columns for the masked rows only; ``cand`` is
            # ascending, so a first-tie argmin lands on the lowest row
            # index — exactly min()'s tie-break over dict iteration order
            dl = np.fromiter((tasks[i].deadline for i in cand),
                             np.float64, cand.size)
            if weakest:
                health = np.fromiter(
                    (self._cand_health(tasks[i], goods, sizes)
                     for i in cand),
                    np.float64, cand.size)
                k = rank_victims(np.ones(cand.size, dtype=bool), dl, health)
            else:
                # first max deadline == min() over (-deadline,) tuples with
                # its first-tie break (np.argmax keeps the first maximum)
                k = int(np.argmax(dl))
            idx = int(cand[k])
            victim = tasks[idx]
            vt1, vt2 = float(ct1[idx]), float(ct2[idx])
            vamt = int(camt[idx])
            if self._try_shrink(dev, victim, t1, t2, now):
                # The truncate synced ct2[idx] in place (mirror row), so the
                # candidate mask stays exact — but the new endpoint need not
                # align with the grid's breakpoints, so a partial-segment
                # delta would under-free.  Rebuild instead (the established
                # exact fallback; the flushed skyline already reflects the
                # truncation) after re-deriving the drifted window.
                if victim not in shrunk:
                    shrunk.append(victim)
                plan = placement()      # link moved; re-derive the window
                if plan is None:
                    return None, preempted, shrunk
                msg_t1, t1, t2 = plan
                grid = _HPWindowGrid(dev, t1, t2 + drift + 0.5 * (t2 - t1),
                                     ct1, ct2, alive)
                continue
            was_good = victim.state in GOOD_STATES
            self._preempt_victim(dev, victim, vamt, now)   # flips alive[idx]
            preempted.append(victim)
            if victim in shrunk:        # shrunk earlier, evicted after all
                shrunk.remove(victim)
            if weakest and was_good and victim.request_id in goods:
                # the eviction moved the victim out of its set's good
                # states; its still-candidate siblings weaken accordingly
                goods[victim.request_id] -= 1
            grid.evict(vt1, vt2, vamt)
            plan = placement()          # link moved; re-derive the window
            if plan is None:
                return None, preempted, shrunk
            msg_t1, t1, t2 = plan
        return plan, preempted, shrunk

    def _cand_health(self, task: Task, goods: dict, sizes: dict) -> float:
        """`_set_health` backed by the eviction loop's incremental
        per-request counters (identical fractions: same integer numerator
        and denominator as the scalar scan)."""
        rid = task.request_id
        if rid is None:
            return 1.0
        if rid not in goods:
            req = self._requests.get(rid)
            if req is None or not req.tasks:
                return 1.0
            goods[rid] = sum(1 for t in req.tasks if t.state in GOOD_STATES)
            sizes[rid] = len(req.tasks)
        return goods[rid] / sizes[rid]

    def _reallocate_victims(self, victims: list[Task],
                            now: float) -> list[Allocation]:
        """Batch victim reallocation: every evicted LP task gets one
        placement attempt before its own deadline (success -> ALLOCATED,
        else FAILED), all sharing ONE placement context so same-type
        victims reuse the probe plane's link windows and feasibility scan
        (a commit invalidates the memo, exactly like the LP sweep — the
        decisions are identical to N independent `_allocate_lp_task`
        calls).  Runs on BOTH outcomes of the HP admission; running it on
        the failure path too is the PR 5 stranded-victim bugfix."""
        if not victims:
            return []
        reallocs: list[Allocation] = []
        ctx: dict = {}
        for victim in victims:
            r_wall = _time.perf_counter()
            re = self._allocate_lp_task(victim, now, victim.deadline, ctx)
            if re is None and self.degrade:
                # degrade-before-reject: retry down the victim's ladder
                # before settling it FAILED.  The retry commits through its
                # own context, so the shared memo must be invalidated.
                re = self._degrade_retry(victim, now, victim.deadline)
                if re is not None:
                    ctx["valid"] = False
            self.metrics.t_realloc.append(_time.perf_counter() - r_wall)
            if re is not None:
                victim.state = TaskState.ALLOCATED
                self.metrics.realloc_success += 1
                reallocs.append(re)
            else:
                victim.state = TaskState.FAILED
                self.metrics.realloc_failure += 1
        return reallocs

    def _victim_key(self, r: Reservation):
        """Smaller = preferred victim (used with min()); the shared scalar
        rule from core/victims.py over this reservation's task."""
        return victim_sort_key(r.tag, self.victim_policy, self._set_health)

    _TERMINAL = (TaskState.COMPLETED, TaskState.FAILED, TaskState.VIOLATED)

    def _prune_requests(self) -> None:
        """Drop set-health registry entries whose tasks are all terminal.

        A request only matters to ``_set_health``/``_cand_health`` while one
        of its tasks can still be a preemption candidate — i.e. holds a live
        reservation (ALLOCATED/RUNNING).  Once every task is terminal
        (COMPLETED/FAILED/VIOLATED) the entry can never be consulted again,
        so dropping it is decision-identical.  Amortised O(1): runs only
        when the registry doubled (the ``LinkSlotRegistry.prune`` pattern) —
        without this, an open-ended streaming run retains every
        LowPriorityRequest ever admitted."""
        if len(self._requests) <= self._requests_prune_at:
            return
        terminal = self._TERMINAL
        self._requests = {
            rid: req
            for rid, req in self._requests.items()
            if any(t.state not in terminal for t in req.tasks)
        }
        self._requests_prune_at = max(256, 2 * len(self._requests))

    def _set_health(self, task: Task) -> float:
        """Fraction of the task's request set still on track to complete."""
        req = (self._requests.get(task.request_id)
               if task.request_id is not None else None)
        if req is None or not req.tasks:
            return 1.0
        good = sum(1 for t in req.tasks if t.state in GOOD_STATES)
        return good / len(req.tasks)

    def _commit_hp(
        self, task: Task, msg_t1: float, msg_dur: float, t1: float, t2: float
    ) -> Allocation:
        net, link = self.net, self.state.link
        dev = self.state.devices[task.source_device]
        slots = [link.reserve(msg_t1, msg_t1 + msg_dur, ("hp_alloc", task.task_id))]
        dev.reserve(t1, t2, 1, task)
        # completion state-update sized by the task's own profile (the paper
        # profile's output_bytes IS msg.state_update, so the default world
        # is unchanged)
        upd_dur = net.slot(net.profile(task.task_type).output_bytes)
        slots.append(link.reserve_earliest(upd_dur, t2, ("update", task.task_id)))
        task.state = TaskState.ALLOCATED
        task.device, task.cores = task.source_device, 1
        task.t_start, task.t_end, task.offloaded = t1, t2, False
        self.links.record(task.task_id, slots)
        return Allocation(task, task.source_device, t1, t2, 1, False, slots)

    # ------------------------------------------------------------------ #
    # Low-priority algorithm                                             #
    # ------------------------------------------------------------------ #
    def allocate_low_priority(self, request: LowPriorityRequest, now: float) -> LPResult:
        """Admit one LP request: search the §4 time-point grid, partially
        allocating each task at its minimum viable configuration, then try to
        upgrade allocations at every time-point while tasks remain pending.

        The search order and results are the paper's exactly; the only
        scalability addition is the skip-hint pruning (see `_hint_start`),
        which elides time-points where a full device scan would *provably*
        fail and therefore cannot change the outcome."""
        t_wall = _time.perf_counter()
        self.state.gc(now)
        self.links.prune(now)
        self._prune_requests()
        self._requests[request.request_id] = request     # set-health registry
        deadline = request.deadline
        unallocated = [t for t in request.tasks if t.state == TaskState.PENDING]
        result = LPResult()

        hints: dict[int, float] = {}
        ctx: dict = {}                        # shared placement memo (§4 scan)
        # Explicit iteration so a satisfied request stops BEFORE pulling the
        # next grid point — with the lazy grid, finishing at ``now`` (the
        # common steady-state case) then never materialises the merge.
        time_points = self._time_point_grid(now, deadline)
        while unallocated:
            tp = next(time_points, None)
            if tp is None:
                break
            self.grid_rounds += 1
            round_hints: dict = {}            # per-profile, lazily per tp
            for task in list(unallocated):
                hint = hints.get(task.task_id)
                if hint is not None and \
                        self._task_t1_off(ctx, tp, task) < hint - EPS:
                    continue
                alloc = self._allocate_lp_task(task, tp, deadline, ctx)
                if alloc is not None:
                    unallocated.remove(task)
                    result.allocations.append(alloc)
                    continue
                round_hint = self._round_hint(round_hints, tp, task)
                if round_hint is not None:
                    hints[task.task_id] = round_hint
            # upgrade pass: try to give every allocated task more cores
            self._upgrade_pass(result.allocations, hints)

        for task in list(unallocated):
            # degrade-before-reject (DESIGN.md §17): the base rung failed
            # across the whole grid; retry down the ladder before FAILED.
            alloc = self._degrade_retry(task, now, deadline)
            if alloc is not None:
                unallocated.remove(task)
                result.allocations.append(alloc)
        result.failed = unallocated
        for t in unallocated:
            t.state = TaskState.FAILED
        self.metrics.t_lp_alloc.append(_time.perf_counter() - t_wall)
        return result

    def _time_point_grid(self, now: float, deadline: float):
        """The §4 search grid: ``now`` followed by the network-wide
        completion points up to the deadline — lazily (requests usually
        allocate within the first few points, so the rest of the merge
        never runs).  Exact duplicates are skipped: a repeated time-point
        re-derives the identical link windows and placement answers, so
        dropping it provably cannot change a decision (the counter and the
        identical-decision proof live in tests/test_grid_dedup.py)."""
        grid = itertools.chain([now],
                               self.state.iter_completion_times(now, deadline))
        return self._dedup(grid) if self._dedup_grid else grid

    def _dedup(self, grid):
        last = None
        for tp in grid:
            if last is not None and tp == last:
                self.grid_dups_skipped += 1
                continue
            last = tp
            yield tp

    def _refresh_ctx(self, ctx: dict, tp: float) -> dict:
        """(Re)derive the link-dependent placement windows for time-point
        ``tp``: the allocation-message slot and the resulting ``arrival``.
        These are identical for every task probed at the same time-point
        while nothing commits, so they are memoised in ``ctx`` (a commit
        invalidates it).  Profile-dependent windows — the input transfer and
        the offloaded execution start — live in per-profile sub-memos
        (``_profile_ctx``).  Probing does not mutate the link."""
        if ctx.get("valid") and ctx.get("tp") == tp:
            return ctx
        net, link = self.net, self.state.link
        msg_dur = net.slot(net.msg.lp_alloc)
        msg_t1 = link.earliest_slot(msg_dur, tp)
        arrival = msg_t1 + msg_dur
        ctx.clear()
        ctx.update(tp=tp, valid=True, msg_t1=msg_t1, msg_dur=msg_dur,
                   arrival=arrival, prof={})
        return ctx

    def _profile_ctx(self, ctx: dict, prof) -> dict:
        """Per-profile slice of the placement memo: the input-transfer slot
        (sized by the profile's ``input_bytes``), the offloaded execution
        start ``t1_off``, and the network-wide offload feasibility scan
        (which depends on the profile's min-config duration).  Tasks of the
        same type probed at the same time-point share all of it."""
        sub = ctx["prof"].get(prof.name)
        if sub is None:
            link = self.state.link
            xfer_dur = self.net.slot(prof.input_bytes)
            xfer_t1 = link.earliest_slot(xfer_dur, ctx["arrival"])
            sub = ctx["prof"][prof.name] = dict(
                xfer_dur=xfer_dur, xfer_t1=xfer_t1,
                t1_off=xfer_t1 + xfer_dur, feasible=None)
        return sub

    def _window_loads(self, ctx: dict, arrival: float,
                      deadline: float) -> np.ndarray:
        """Stacked per-device loads over [arrival, deadline) from the probe
        plane, memoised in the placement context: within one time-point
        nothing mutates between commits, so every candidate scan sharing the
        window (same request deadline) reuses one vectorized pass."""
        memo = ctx.setdefault("loads", {})
        loads = memo.get(deadline)
        if loads is None:
            loads = memo[deadline] = \
                self.state.probe_plane().loads(arrival, deadline)
        return loads

    def _task_t1_off(self, ctx: dict, tp: float, task: Task) -> float:
        """The offloaded execution start a task would see at ``tp``."""
        prof = self.net.profile_for(task)
        return self._profile_ctx(self._refresh_ctx(ctx, tp), prof)["t1_off"]

    def _round_hint(self, round_hints: dict, tp: float,
                    task: Task) -> Optional[float]:
        """`_hint_start` for the task's profile, computed lazily once per
        (time-point, profile) — every same-type task failing a full scan at
        the same time-point shares the bound.  Profiles resolve through the
        task's ladder rung (``profile_for``); variant profiles carry
        distinct names, so rungs memoise separately."""
        prof = self.net.profile_for(task)
        if prof.name not in round_hints:
            round_hints[prof.name] = self._hint_start(tp, prof)
        return round_hints[prof.name]

    def _hint_start(self, tp: float, prof) -> Optional[float]:
        """Earliest instant ANY device could start a minimum-config LP task
        of profile ``prof``, given occupancy as of now.  It is
        task-independent (within a task type) and a valid lower bound until
        occupancy *shrinks* (reservations only ever get added during a
        request sweep; core upgrades are the one shrinking case and
        `_upgrade_pass` scopes the invalidation).

        A time-point can then be skipped for a hinted task when BOTH of its
        candidate execution starts — local ``arrival`` and offloaded
        ``t1_off`` — lie below the bound (``t1_off >= arrival``, so checking
        ``t1_off`` suffices).  The comparison must use the *actual*
        link-derived windows of that time-point (`_task_t1_off`), never
        ``tp`` itself: link congestion can push the windows far past ``tp``,
        to where a device has already freed up.  Returns None when the
        calendars don't support skyline queries (reference implementation)."""
        devices = self.state.devices
        if not devices or not hasattr(devices[0], "earliest_fit"):
            return None
        cores_min = prof.core_options[0]
        proc_min = prof.lp_slot_time(cores_min)
        if self._plane_ok:
            # One vectorized first-fit pass over every device (bit-identical
            # to the per-device scalar min below).
            plane = self.state.probe_plane()
            return float(plane.earliest_fit(proc_min, tp, cores_min).min())
        return min(d.earliest_fit(proc_min, tp, cores_min) for d in devices)

    def _upgrade_pass(self, allocations, hints: dict[int, float]) -> list[float]:
        """Raise core configs where possible, then drop the skip hints a
        successful upgrade may have invalidated: an upgrade only *frees*
        capacity in the tail [t_end_new, t_end_old) of its slot, so any
        newly feasible min-config window must overlap that tail, i.e. start
        after ``t_end_new - proc_min``.  Hints at or below that threshold
        remain valid lower bounds regardless of device capacity (with
        capacity 4 the early part of an upgraded slot is saturated anyway;
        with larger capacities it need not be, hence the proc_min margin).

        Returns the upgraded allocations' new completion times so the batch
        sweep can keep its time-point grid in sync (an upgrade moves a
        completion point earlier; the stale point is already in the grid).

        ``proc_min`` is the workload-wide minimum min-config slot duration:
        with heterogeneous profiles a freed tail might admit the *fastest*
        task type, so the threshold must use its duration (for the paper's
        single-profile spec this is exactly the old global constant)."""
        proc_min = self.net.spec.min_lp_slot_time
        new_ends: list[float] = []
        for alloc in allocations:
            if self._try_upgrade(alloc):
                new_ends.append(alloc.t_end)
        if new_ends and hints:
            thresh = min(new_ends) - proc_min
            for tid in [t for t, h in hints.items() if h > thresh + EPS]:
                del hints[tid]
        return new_ends

    def allocate_low_priority_batch(
        self, requests: Sequence[LowPriorityRequest], now: float
    ) -> list[LPResult]:
        """Admit a burst of LP requests in ONE gc + ONE time-point sweep.

        The sequential path (`allocate_low_priority`) re-derives the
        network-wide completion-time grid and re-runs the sweep for every
        request; under a large arrival burst that is O(requests x grid).
        This method instead:

        * garbage-collects once,
        * pools every pending task, ordered earliest-deadline-first across
          the whole batch (deterministic tie-break: submission order),
        * walks one monotone time-point heap seeded with the current
          network-wide completion times and fed with the completion points
          of allocations made *by this batch*, so later tasks immediately
          see slots freed/created by earlier ones,
        * prunes a task permanently once the sweep passes its request
          deadline (it can never allocate at a later point), and
        * runs the core-upgrade pass per time-point for the requests that
          progressed there (the batch analogue of the §4 upgrade sweep).

        Results are returned positionally (one LPResult per input request).
        Per-task placement rules (minimum config, even spreading, upgrade
        pass) are the sequential path's; the *search* deliberately differs
        in two ways, so a batch is NOT guaranteed to reproduce sequential
        admissions call-for-call: requests are interleaved
        earliest-deadline-first rather than in caller order (the fairer
        policy at scale), and the grid absorbs completion points created by
        the batch itself, which the sequential path's snapshot grid never
        revisits.  Per-request latency metrics are recorded as the batch's
        amortised share so Fig-9/10 style summaries stay comparable.
        """
        t_wall = _time.perf_counter()
        self.state.gc(now)
        self.links.prune(now)
        self._prune_requests()
        results = [LPResult() for _ in requests]
        order = itertools.count()
        pending: list[tuple[float, int, int, Task]] = []
        for ridx, req in enumerate(requests):
            self._requests[req.request_id] = req         # set-health registry
            for task in req.tasks:
                if task.state == TaskState.PENDING:
                    pending.append((req.deadline, next(order), ridx, task))
        if pending:
            pending.sort()
            max_dl = max(req.deadline for req in requests)
            # The network-wide grid, merged in one vectorized pass; a sorted
            # unique list is already a valid min-heap, so no heapify.  The
            # ``in_grid`` set keeps the heap duplicate-free: batch-created
            # completion points (allocations, upgrades) that coincide with a
            # point already in the grid would only pop into the existing
            # ``cand <= tp`` skip, so dropping them at push time is provably
            # decision-neutral (tests/test_grid_dedup.py).
            tp_heap = self.state.completion_times(now, max_dl)
            self.grid_pushes += len(tp_heap)
            in_grid = set(tp_heap) if self._dedup_grid else None
            tp = now
            # Skip hints (see `_hint_start`): a task that failed a full scan
            # is skipped in O(1) at every time-point whose actual execution
            # windows lie provably below the earliest instant any device
            # could start it; a successful core-upgrade shrinks a
            # reservation, so it prunes the invalidated hints.
            hints: dict[int, float] = {}
            ctx: dict = {}                    # shared placement memo (§4 scan)
            def push_tp(t_end: float) -> None:
                """Feed a batch-created completion point into the grid,
                skipping exact duplicates of points already queued."""
                if not (tp + EPS < t_end < max_dl - EPS):
                    return
                if in_grid is not None:
                    if t_end in in_grid:
                        self.grid_dups_skipped += 1
                        return
                    in_grid.add(t_end)
                self.grid_pushes += 1
                heapq.heappush(tp_heap, t_end)

            while pending:
                self.grid_rounds += 1
                still: list[tuple[float, int, int, Task]] = []
                progressed: set[int] = set()
                round_hints: dict = {}        # per-profile, lazily per tp
                for item in pending:
                    deadline, _, ridx, task = item
                    if deadline <= tp + EPS:
                        # the sweep passed the request deadline at the base
                        # rung; degrade-before-reject gets one ladder retry
                        # over the original window before FAILED settles
                        alloc = self._degrade_retry(task, now, deadline)
                        if alloc is not None:
                            ctx["valid"] = False    # retry committed
                            round_hints.clear()     # occupancy grew
                            results[ridx].allocations.append(alloc)
                            progressed.add(ridx)
                            push_tp(alloc.t_end)
                        else:
                            task.state = TaskState.FAILED
                            results[ridx].failed.append(task)
                        continue
                    hint = hints.get(task.task_id)
                    if hint is not None and \
                            self._task_t1_off(ctx, tp, task) < hint - EPS:
                        still.append(item)
                        continue
                    alloc = self._allocate_lp_task(task, tp, deadline, ctx)
                    if alloc is None:
                        round_hint = self._round_hint(round_hints, tp, task)
                        if round_hint is not None:
                            hints[task.task_id] = round_hint
                        still.append(item)
                        continue
                    round_hints.clear()       # occupancy grew; recompute
                    results[ridx].allocations.append(alloc)
                    progressed.add(ridx)
                    push_tp(alloc.t_end)
                # Sorted: upgrades shrink reservations, so cross-request
                # upgrade order can change what later upgrades see — pin
                # it to ascending request index instead of set order
                # (which only coincides with it for small ints).
                for ridx in sorted(progressed):
                    for t_end in self._upgrade_pass(results[ridx].allocations,
                                                    hints):
                        # the upgrade moved this completion point earlier;
                        # the grid must contain the new one too
                        push_tp(t_end)
                pending = still
                if not pending:
                    break
                # Earliest instant any still-pending task could possibly
                # start (after the upgrade pass pruned stale hints): a grid
                # point whose actual execution windows lie below it is
                # provably useless for EVERY pending task, so skip whole
                # rounds, not just tasks.  As in the per-task skip, the
                # comparison needs the candidate's link-derived windows,
                # not the raw grid time — and with heterogeneous profiles
                # the LATEST execution start any pending type would see
                # (the largest input transfer), so the skip stays a safe
                # over-approximation for every profile at once.
                floor_hint: Optional[float] = None
                for item in pending:
                    h = hints.get(item[3].task_id)
                    if h is None:
                        floor_hint = None
                        break
                    if floor_hint is None or h < floor_hint:
                        floor_hint = h
                worst_prof = self.net.profile(
                    self.net.spec.max_input_bytes_type)
                nxt = None
                while tp_heap:
                    cand = heapq.heappop(tp_heap)
                    if cand <= tp + EPS:
                        continue
                    if floor_hint is not None and \
                            self._profile_ctx(self._refresh_ctx(ctx, cand),
                                              worst_prof)["t1_off"] < \
                            floor_hint - EPS:
                        continue
                    nxt = cand
                    break
                if nxt is None:
                    break
                tp = nxt
            for d, _, ridx, task in pending:      # deadline passed mid-sweep
                alloc = self._degrade_retry(task, now, d)
                if alloc is not None:
                    results[ridx].allocations.append(alloc)
                    continue
                task.state = TaskState.FAILED
                results[ridx].failed.append(task)
        share = (_time.perf_counter() - t_wall) / max(len(requests), 1)
        self.metrics.t_lp_alloc.extend([share] * len(requests))
        return results

    def reallocate(self, task: Task, now: float) -> Optional[Allocation]:
        """Public reallocation entry (used by runtimes on external preemption).

        The task's previous allocation is torn down first — its device
        reservation is released and its still-pending link slots
        (xfer/update) are cancelled — whether or not the reallocation
        succeeds: the old slots describe work and traffic that will never
        happen (same hygiene the preemption loop applies to its victims).
        """
        r_wall = _time.perf_counter()
        if task.device is not None:
            self.state.devices[task.device].release(task)
        self.links.cancel_pending(self.state.link, task.task_id, now)
        alloc = self._allocate_lp_task(task, now, task.deadline)
        if alloc is None:
            alloc = self._degrade_retry(task, now, task.deadline)
        self.metrics.t_realloc.append(_time.perf_counter() - r_wall)
        if alloc is not None:
            task.state = TaskState.ALLOCATED
            self.metrics.realloc_success += 1
        else:
            task.state = TaskState.FAILED
            self.metrics.realloc_failure += 1
        return alloc

    # ------------------------------------------------------------------ #
    # Device churn (DESIGN.md §16)                                       #
    # ------------------------------------------------------------------ #
    def fail_device(self, idx: int, now: float) -> tuple[list[Task],
                                                         list[Allocation]]:
        """Hard-fail a device: orphan its in-flight tasks and drive recovery.

        Every orphan's still-pending link slots are cancelled exactly like
        preemption's slot cleanup; LP orphans then go through the batch
        victim-reallocation pass (one shared placement context — the PR 5
        plane), so each terminates ALLOCATED-elsewhere-before-deadline or
        FAILED.  HP orphans come back PREEMPTED for immediate re-admission
        — the dispatcher's ``device_lost`` (or ``settle_hp_orphans`` on
        scheduler-direct drivers) settles them.  Returns
        ``(orphans, lp_reallocations)``.
        """
        self.state.gc(now)
        self.links.prune(now)
        orphans = self.state.fail_device(idx, now)
        link = self.state.link
        lp_orphans: list[Task] = []
        for task in orphans:
            self.links.cancel_pending(link, task.task_id, now)
            task.state = TaskState.PREEMPTED    # transient, like an eviction
            if task.priority == Priority.LOW:
                lp_orphans.append(task)
        self.metrics.device_failures += 1
        self.metrics.orphans_created += len(orphans)
        reallocs = self._reallocate_victims(lp_orphans, now)
        self.metrics.orphans_recovered += len(reallocs)
        return orphans, reallocs

    def settle_hp_orphans(self, orphans: Sequence[Task],
                          now: float) -> list[HPResult]:
        """Re-admit HP orphans immediately — ahead of the next admission
        window.  HP execution is source-local (paper rule), so an orphan
        whose home device stays DOWN settles FAILED (``hp_failed_alloc``);
        an orphan is never left stranded in PREEMPTED."""
        results: list[HPResult] = []
        for task in orphans:
            if task.priority != Priority.HIGH:
                continue
            res = self.allocate_high_priority(task, now)
            if res.success:
                self.metrics.orphans_recovered += 1
            else:
                task.state = TaskState.FAILED
                self.metrics.hp_failed_alloc += 1
                self.metrics.count_type(task.task_type, "hp_failed_alloc")
            results.append(res)
        return results

    def drain_device(self, idx: int, now: float) -> None:
        """Graceful drain: in-flight reservations finish, no new placements
        (the probe plane's alive mask excludes the device immediately)."""
        self.state.drain_device(idx)
        self.metrics.device_drains += 1

    def rejoin_device(self, idx: int, now: float) -> None:
        """Bring a drained or failed device back into the placement pool."""
        self.state.rejoin_device(idx)
        self.metrics.device_rejoins += 1

    def _degrade_retry(self, task: Task, now: float,
                       deadline: float) -> Optional[Allocation]:
        """Degrade-before-reject (DESIGN.md §17): one ladder walk for an
        otherwise-failed LP task.

        Runs only at SETTLE time — after the base-rung search exhausted the
        whole time-point grid — never per time-point, so a task is only
        degraded when its current rung provably cannot be placed anywhere
        in its window (accuracy is sacrificed last, not first).  Each
        deeper rung re-walks the §4 grid through the normal placement path
        (`_allocate_lp_task` resolves the rung's profile; variant profiles
        carry distinct names, so the probe memos stay sound).  On success
        the task keeps the admitted rung in ``task.variant`` and counts
        ``lp_degraded``; on failure the original rung is restored and the
        caller settles FAILED (this helper assigns no terminal state).
        """
        if not self.degrade or task.priority is not Priority.LOW:
            return None
        base = self.net.profile(task.task_type)
        original = task.variant
        for rung in range(original + 1, base.n_variants):
            task.variant = rung
            ctx: dict = {}
            for tp in self._time_point_grid(now, deadline):
                alloc = self._allocate_lp_task(task, tp, deadline, ctx)
                if alloc is not None:
                    self.metrics.lp_degraded += 1
                    return alloc
        task.variant = original
        return None

    def _allocate_lp_task(
        self, task: Task, tp: float, deadline: float,
        ctx: Optional[dict] = None,
    ) -> Optional[Allocation]:
        """Partial allocation of one task at the minimum viable config (§4).

        Placement policy (identical outcome to the paper's load-sorted scan,
        restructured for scale):

        * source device first (no input transfer), else the least-loaded
          device among those that *fit* — feasibility is checked before
          computing loads, because ``fits`` is an early-exit skyline probe
          while ``load`` integrates the whole deadline window, and in a
          saturated network most devices fail the cheap check;
        * ``ctx`` (same dict passed across calls of one sweep) memoises the
          link-derived windows and the network-wide offload feasibility
          scan, which are identical for every task probed at the same
          time-point — nothing mutates between two commits, so when a burst
          of pending tasks wakes at a freed slot, only the first pays the
          O(devices) scan.  A commit invalidates the context.
        """
        net, link = self.net, self.state.link
        prof = net.profile_for(task)            # the task's ladder rung
        cores = prof.core_options[0]            # minimum viable config
        proc = prof.lp_slot_time(cores)
        if ctx is None:
            ctx = {}
        self._refresh_ctx(ctx, tp)
        self.lp_probes += 1
        msg_t1, msg_dur = ctx["msg_t1"], ctx["msg_dur"]
        arrival = ctx["arrival"]
        if arrival + proc > deadline:
            return None

        source = task.source_device
        sdev = self.state.devices[source]
        if _dev_up(sdev) and sdev.fits(arrival, arrival + proc, cores):
            dev, offloaded, xfer_t1, xfer_dur, t1 = sdev, False, 0.0, 0.0, arrival
        elif not self.allow_offload:
            return None
        else:
            sub = self._profile_ctx(ctx, prof)
            xfer_t1, xfer_dur = sub["xfer_t1"], sub["xfer_dur"]
            t1 = sub["t1_off"]
            if t1 + proc > deadline:
                return None
            if sub["feasible"] is None:
                # All offloaded candidates of one task type share the same
                # transfer slot, hence the same execution window and
                # feasibility scan — one vectorized fits-mask over every
                # device (per-device scalar loop only for the reference
                # calendars, which predate the probe plane).
                if self._plane_ok:
                    plane = self.state.probe_plane()
                    sub["feasible"] = np.flatnonzero(
                        plane.fits_mask(t1, t1 + proc, cores))
                else:
                    sub["feasible"] = [d.device for d in self.state.devices
                                       if _dev_up(d)
                                       and d.fits(t1, t1 + proc, cores)]
            # even spreading: least load over the deadline window; argmin
            # over the stacked load vector returns the FIRST minimum, i.e.
            # ties break toward the lowest device index — exactly the old
            # (load, d.device) key.
            if self._plane_ok:
                feas = sub["feasible"]
                cands = feas[feas != source]
                if cands.size == 0:
                    return None
                loads = self._window_loads(ctx, arrival, deadline)
                dev = self.state.devices[int(cands[np.argmin(loads[cands])])]
            else:
                cands = [self.state.devices[i] for i in sub["feasible"]
                         if i != source]
                if not cands:
                    return None
                dev = min(cands,
                          key=lambda d: (d.load(arrival, deadline), d.device))
            offloaded = True

        # commit (mutates the link and a device calendar -> context dies)
        ctx["valid"] = False
        t2 = t1 + proc
        slots = [link.reserve(msg_t1, msg_t1 + msg_dur, ("lp_alloc", task.task_id))]
        if offloaded:
            slots.append(
                link.reserve(xfer_t1, xfer_t1 + xfer_dur, ("xfer", task.task_id))
            )
        dev.reserve(t1, t2, cores, task)
        upd_dur = net.slot(prof.output_bytes)
        slots.append(link.reserve_earliest(upd_dur, t2, ("update", task.task_id)))
        task.state = TaskState.ALLOCATED
        task.device, task.cores = dev.device, cores
        task.t_start, task.t_end, task.offloaded = t1, t2, offloaded
        self.links.record(task.task_id, slots)
        return Allocation(task, dev.device, t1, t2, cores, offloaded, slots)

    def _try_upgrade(self, alloc: Allocation) -> bool:
        """Improve an allocation by raising its core configuration (§4).

        Feasibility is probed with the task's own slot still in place: the
        slot spans the whole candidate window (more cores = shorter slot)
        and contributes exactly ``alloc.cores`` everywhere in it, so asking
        for ``cores - alloc.cores`` MORE cores is bit-identical to the
        release-then-probe formulation — without paying two calendar
        mutations per failed attempt."""
        if alloc.task.degraded:
            # load-shedding degrade mode (serving/stream.py): the task is
            # pinned to its minimum core configuration under overload
            return False
        prof = self.net.profile(alloc.task.task_type)
        options = [c for c in prof.core_options if c > alloc.cores]
        if not options:
            return False
        dev = self.state.devices[alloc.device]
        res = dev.get(alloc.task)
        if res is None:
            return False
        for cores in reversed(options):          # largest improvement first
            t2 = alloc.t_start + prof.lp_slot_time(cores)
            if t2 <= alloc.task.deadline and \
                    dev.fits(alloc.t_start, t2, cores - res.amount):
                dev.reserve(alloc.t_start, t2, cores, alloc.task)
                alloc.cores, alloc.t_end = cores, t2
                alloc.task.cores, alloc.task.t_end = cores, t2
                return True
        return False
