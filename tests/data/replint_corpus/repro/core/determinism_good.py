"""Corpus: determinism-safe counterparts for every bad shape."""
import random

import numpy as np


class Plane:
    def __init__(self, seed):
        self._pending: set[int] = set()
        self._rng = np.random.default_rng(seed)     # good: seeded
        self._py = random.Random(seed)              # good: seeded

    def refresh(self, groups):
        for idx in sorted(self._pending):           # good: pinned order
            pass
        seen = {i + 1 for i in self._pending}       # good: SetComp is exempt
        batch = set()
        for batch in groups:                        # rebinds batch (non-set)
            for item in batch:                      # good: target was rebound
                pass
        return seen
