"""Per-(arch, phase, parallel-degree) step-time cost model.

The paper derives task resource requirements from offline benchmarks of each
(task type x core configuration) and pads slots with the benchmark std-dev
(§3, §5).  The TPU adaptation does the same: step times per model-parallel
degree come either from

  * ``measure``: real timed executions of the jitted steps (smoke-scale
    models on this host), or
  * ``analytic``: roofline-derived estimates (full-scale configs, using the
    dry-run terms + v5e constants),

and the scheduler pads with the measured std-dev, exactly mirroring the
paper's methodology.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as M
from ..models.config import ModelConfig
from ..training.steps import make_prefill_step, make_serve_step


@dataclass
class PhaseCost:
    mean_s: float
    std_s: float

    @property
    def padded(self) -> float:
        return self.mean_s + self.std_s


@dataclass
class CostModel:
    """Step times per model-parallel degree (the 2-core/4-core analogue)."""

    prefill: dict[int, PhaseCost] = field(default_factory=dict)
    decode: dict[int, PhaseCost] = field(default_factory=dict)

    def _cost(self, table: dict[int, PhaseCost], degree: int,
              phase: str) -> PhaseCost:
        try:
            return table[degree]
        except KeyError:
            raise ValueError(
                f"no {phase} cost measured for parallel degree {degree}; "
                f"available degrees: {sorted(table) or 'none'}"
            ) from None

    def lp_exec_time(self, degree: int, n_tokens: int) -> float:
        return self._cost(self.decode, degree, "decode").mean_s * n_tokens

    def lp_slot_time(self, degree: int, n_tokens: int) -> float:
        d = self._cost(self.decode, degree, "decode")
        return (d.mean_s + d.std_s) * n_tokens

    def hp_exec_time(self, degree: int = 1) -> float:
        return self._cost(self.prefill, degree, "prefill").mean_s

    def hp_slot_time(self, degree: int = 1) -> float:
        return self._cost(self.prefill, degree, "prefill").padded

    @property
    def degrees(self) -> tuple[int, ...]:
        return tuple(sorted(self.decode))


def measure_cost_model(
    cfg: ModelConfig,
    *,
    batch: int = 1,
    prompt_len: int = 32,
    cache_len: int = 128,
    degrees: tuple[int, ...] = (2, 4),
    reps: int = 5,
    key=None,
) -> CostModel:
    """Time the real jitted steps.  Model-parallel degree on one host is
    emulated by its compute split: degree d's per-step time is measured as
    the single-device time scaled by the parallel efficiency curve measured
    from the sharded compile (here: ideal/d with a 10% halo/collective tax
    per doubling, matching the paper's 2-core:4-core ratio of
    16.862:2*11.611).  ``degrees`` selects which parallel degrees the model
    is tabulated at (each doubling from the measured baseline applies the
    calibrated efficiency ratio)."""
    degrees = tuple(degrees)
    if not degrees:
        raise ValueError("degrees must be a non-empty sequence")
    bad = [d for d in degrees if not isinstance(d, int) or d < 1]
    if bad:
        raise ValueError(
            f"invalid parallel degree(s) {bad}: degrees must be positive "
            "integers"
        )
    if len(set(degrees)) != len(degrees):
        raise ValueError(f"duplicate parallel degrees in {degrees}")
    key = key if key is not None else jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    tokens = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    batch_d = {"tokens": tokens}
    if cfg.modality_embed_dim:
        n_mod = cfg.n_modality_tokens or prompt_len
        batch_d["modality_emb"] = jax.random.normal(
            key, (batch, n_mod, cfg.modality_embed_dim))

    pre = jax.jit(make_prefill_step(cfg, cache_len))
    srv = jax.jit(make_serve_step(cfg))
    nxt, caches = jax.tree.map(jnp.asarray, pre(params, batch_d))
    jax.block_until_ready(nxt)

    def timeit(fn, *a):
        ts = []
        out = None
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn(*a)
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
        return float(np.mean(ts)), float(np.std(ts)), out

    p_mean, p_std, _ = timeit(pre, params, batch_d)
    pos = jnp.asarray(prompt_len, jnp.int32)
    d_mean, d_std, _ = timeit(srv, params, caches, nxt[:, None], pos)

    # paper-calibrated parallel efficiency: every doubling of the degree
    # multiplies the step time by t(4) / t(2) = 11.611 / 16.862; the
    # measured single-host time anchors degree 2 (the paper's minimum
    # horizontal split), other degrees follow the curve.
    eff_ratio = 11.611 / 16.862
    cm = CostModel()
    cm.prefill[1] = PhaseCost(p_mean, p_std)
    for deg in sorted(degrees):
        scale = eff_ratio ** math.log2(deg / 2.0)
        cm.decode[deg] = PhaseCost(d_mean * scale, d_std * scale)
    return cm


def analytic_cost_model(
    roofline_terms: dict[int, float],
    *,
    prefill_s: float,
    std_frac: float = 0.05,
) -> CostModel:
    """Build a CostModel from roofline-derived per-degree decode times."""
    cm = CostModel()
    cm.prefill[1] = PhaseCost(prefill_s, prefill_s * std_frac)
    for deg, t in roofline_terms.items():
        cm.decode[deg] = PhaseCost(t, t * std_frac)
    return cm
