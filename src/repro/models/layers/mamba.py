"""Mamba selective-SSM mixer (arXiv:2312.00752), as used by Jamba
(arXiv:2403.19887).

Prefill/train path: chunked associative scan over time (chunk size bounds the
[B, chunk, d_inner, d_state] working set — important for the 512-device
dry-run of jamba at seq 4k/32k).  Decode path: single-step recurrence with a
(conv window, ssm state) cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..config import ModelConfig
from .common import dense_init, normal_init, silu

CHUNK = 512


def mamba_init(key, cfg: ModelConfig, dtype) -> dict:
    mc = cfg.mamba
    assert mc is not None
    d, di, ds, dtr = cfg.d_model, cfg.mamba_d_inner, mc.d_state, cfg.mamba_dt_rank
    keys = jax.random.split(key, 6)
    # S4D-real initialisation for A
    a_log = jnp.log(jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                     (di, ds)))
    return {
        "in_proj": dense_init(keys[0], d, 2 * di, dtype=dtype),
        "conv_w": normal_init(keys[1], (mc.d_conv, di), mc.d_conv ** -0.5, dtype),
        "conv_b": jnp.zeros((di,), dtype=dtype),
        "x_proj": dense_init(keys[2], di, dtr + 2 * ds, dtype=dtype),
        "dt_proj": dense_init(keys[3], dtr, di, dtype=dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype=dtype),   # softplus^-1(~0.01)
        "a_log": a_log,                                   # f32
        "d_skip": jnp.ones((di,), dtype=dtype),
        "out_proj": dense_init(keys[5], di, d, dtype=dtype),
    }


def mamba_axes(cfg: ModelConfig) -> dict:
    return {
        "in_proj": ("embed", "d_inner2"),
        "conv_w": ("conv", "d_inner"),
        "conv_b": ("d_inner",),
        "x_proj": ("d_inner", "dt_state"),
        "dt_proj": ("dt_rank", "d_inner"),
        "dt_bias": ("d_inner",),
        "a_log": ("d_inner", "state"),
        "d_skip": ("d_inner",),
        "out_proj": ("d_inner", "embed"),
    }


def init_mamba_cache(batch: int, cfg: ModelConfig, dtype) -> dict:
    mc = cfg.mamba
    di = cfg.mamba_d_inner
    return {
        "conv": jnp.zeros((batch, mc.d_conv - 1, di), dtype=dtype),
        "ssm": jnp.zeros((batch, di, mc.d_state), dtype=jnp.float32),
    }


def mamba_cache_axes() -> dict:
    return {
        "conv": ("batch", "conv", "d_inner"),
        "ssm": ("batch", "d_inner", "state"),
    }


def _ssm_terms(params: dict, xc: jax.Array, cfg: ModelConfig):
    """xc [..., di] (post-conv, post-silu) -> (a, bx, c) selective terms."""
    mc = cfg.mamba
    dtr, ds = cfg.mamba_dt_rank, mc.d_state
    proj = jnp.einsum("...i,ij->...j", xc, params["x_proj"])
    dt_in, b, c = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("...r,ri->...i", dt_in, params["dt_proj"])
        + params["dt_bias"]
    ).astype(jnp.float32)                                     # [..., di]
    a = -jnp.exp(params["a_log"])                             # [di, ds]
    abar = jnp.exp(dt[..., None] * a)                         # [..., di, ds]
    # bx [..., di, ds]: (dt * x) outer B, broadcast over d_inner
    bx = (dt * xc.astype(jnp.float32))[..., None] * b.astype(jnp.float32)[..., None, :]
    return abar, bx, c.astype(jnp.float32)


def _conv_causal(params: dict, x: jax.Array, prior: Optional[jax.Array]) -> jax.Array:
    """Depthwise causal conv over time. x [B,T,di]; prior [B,k-1,di] or None."""
    k = params["conv_w"].shape[0]
    if prior is None:
        prior = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prior, x], axis=1)                  # [B, T+k-1, di]
    out = sum(
        xp[:, i : i + x.shape[1], :] * params["conv_w"][i]
        for i in range(k)
    )
    return out + params["conv_b"]


def mamba_apply(
    params: dict,
    x: jax.Array,                        # [B, T, d]
    cfg: ModelConfig,
    *,
    cache: Optional[dict] = None,
    unroll: int | bool = 1,
) -> tuple[jax.Array, Optional[dict]]:
    di = cfg.mamba_d_inner
    xz = jnp.einsum("btd,de->bte", x, params["in_proj"])
    xi, z = xz[..., :di], xz[..., di:]

    if cache is None:
        xc = silu(_conv_causal(params, xi, None))
        abar, bx, c = _ssm_terms(params, xc, cfg)             # [B,T,di,ds]
        b, t = x.shape[:2]

        def chunk_step(h0, ab):
            a_ch, b_ch = ab                                    # [B,C,di,ds]
            # prepend carry as an extra step with a=1 (identity), b=h0
            a_all = jnp.concatenate(
                [jnp.ones_like(a_ch[:, :1]), a_ch], axis=1)
            b_all = jnp.concatenate([h0[:, None], b_ch], axis=1)

            def combine(l, r):
                al, bl = l
                ar, br = r
                return al * ar, bl * ar + br

            _, hs = jax.lax.associative_scan(combine, (a_all, b_all), axis=1)
            return hs[:, -1], hs[:, 1:]

        n_pad = (-t) % CHUNK
        if n_pad:
            pad = [(0, 0), (0, n_pad), (0, 0), (0, 0)]
            abar = jnp.pad(abar, pad, constant_values=1.0)
            bx = jnp.pad(bx, pad)
        nch = abar.shape[1] // CHUNK
        abar = abar.reshape(b, nch, CHUNK, di, -1).swapaxes(0, 1)
        bx = bx.reshape(b, nch, CHUNK, di, -1).swapaxes(0, 1)
        h0 = jnp.zeros((b, di, abar.shape[-1]), jnp.float32)
        h_last, hs = jax.lax.scan(chunk_step, h0, (abar, bx), unroll=unroll)
        hs = hs.swapaxes(0, 1).reshape(b, nch * CHUNK, di, -1)[:, :t]
        y = jnp.einsum("btis,bts->bti", hs, c)
        new_cache = None
    else:
        # decode: T == 1
        conv_win = jnp.concatenate([cache["conv"], xi], axis=1)
        xc = silu(
            jnp.einsum("bki,ki->bi", conv_win, params["conv_w"])
            + params["conv_b"]
        )[:, None, :]                                          # [B,1,di]
        abar, bx, c = _ssm_terms(params, xc, cfg)              # [B,1,di,ds]
        h = abar[:, 0] * cache["ssm"] + bx[:, 0]
        y = jnp.einsum("bis,bs->bi", h, c[:, 0])[:, None, :]
        new_cache = {"conv": conv_win[:, 1:], "ssm": h}

    y = y.astype(x.dtype) + params["d_skip"] * xi
    y = y * silu(z)
    return jnp.einsum("bti,id->btd", y, params["out_proj"]), new_cache
