from .optimizer import AdamWConfig, adamw_update, init_opt_state, lr_schedule  # noqa: F401
from .steps import (  # noqa: F401
    cross_entropy,
    init_train_state,
    loss_fn,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
