"""Pure-jnp oracle for single-token GQA decode attention over a
position-tracked (optionally rotating) KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,               # [B, H, D] one token of queries
    k_cache: jax.Array,         # [B, S, KV, D]
    v_cache: jax.Array,
    positions: jax.Array,       # [B, S] absolute stored positions (-1 empty)
    pos: jax.Array,             # scalar current position
    *,
    window: int = 0,
) -> jax.Array:
    b, h, d = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    scale = d ** -0.5
    qg = q.reshape(b, kv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg,
                        k_cache.astype(jnp.float32)) * scale
    valid = (positions >= 0) & (positions <= pos)
    if window > 0:
        valid &= positions > pos - window
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)
