"""Trace-file generation (paper §5, Table 4).

Each trace entry holds one value per device per frame:
  -1   no object detected
   0   high-priority task only
   1-4 high-priority task followed by an LP request of that many DNN tasks

The paper does not publish the exact distributions, so they are fitted to
Table 4's potential-task counts (see DESIGN.md §7):
  uniform     : P(v) = 1/6 for v in {-1, 0, 1, 2, 3, 4}
                -> E[LP] = 10/6 per entry = 8640 over 5184 entries (exact),
                   P(HP) = 5/6 -> 4320 (exact)
  weighted X  : family P(-1) = P(0) = 0.05, P(X) = b, P(other in 1..4) = c,
                with (b, c) solved per X so that E[LP per device-frame]
                matches Table 4 *exactly*:
                  X=1: b=0.4535, c=0.1488   (9296 potential LP)
                  X=2: b=0.5988, c=0.1004   (10372)
                  X=3: b=0.6045, c=0.0985   (12973)
                  X=4: b=0.4446, c=0.1518   (13941)
                All satisfy the paper's "devices will predominantly
                generate X tasks" (b >> c).

Beyond the paper (used by the large-N scenario suite, sim/scenarios.py):
  ratio_P     : HP:LP mix sweep.  P(-1) = 0.05; of the detected frames, a
                fraction P/100 spawns an LP set (sizes 1..4 uniform) and the
                rest stay HP-only:
                  P(0) = 0.95 * (1 - P/100),   P(k in 1..4) = 0.95 * P/400.
                ratio_0 is an HP-only stream, ratio_100 makes every detected
                frame spawn stage-3 work.
"""
from __future__ import annotations

from dataclasses import dataclass

import zlib

import numpy as np

VALUES = (-1, 0, 1, 2, 3, 4)

# (b, c) per weighted-X, fitted to Table 4 potential-LP counts with
# P(-1)=P(0)=0.05:  b + 3c = 0.9  and  b*X + c*(10-X) = table4_X / 5184.
_WEIGHTED_BC = {
    1: (0.4535, 0.14883),
    2: (0.5988, 0.10040),
    3: (0.6045, 0.09850),
    4: (0.4446, 0.15180),
}


@dataclass(frozen=True)
class TraceConfig:
    name: str
    n_frames: int = 1296
    n_devices: int = 4
    seed: int = 0

    def probabilities(self) -> np.ndarray:
        if self.name == "uniform":
            return np.full(6, 1.0 / 6.0)
        if self.name.startswith("weighted_"):
            x = int(self.name.split("_")[1])
            assert 1 <= x <= 4
            b, c = _WEIGHTED_BC[x]
            p = np.full(6, c)
            p[0] = p[1] = 0.05          # -1 and 0
            p[1 + x] = b
            p /= p.sum()                # exact normalisation
            return p
        if self.name.startswith("ratio_"):
            pct = float(self.name.split("_")[1])
            assert 0.0 <= pct <= 100.0
            f = pct / 100.0
            p = np.empty(6)
            p[0] = 0.05                 # -1: nothing detected
            p[1] = 0.95 * (1.0 - f)     # 0: HP only
            p[2:] = 0.95 * f / 4.0      # 1..4: HP + LP set
            p /= p.sum()
            return p
        raise ValueError(f"unknown trace: {self.name}")


#: Human-readable description of the accepted trace names (for errors).
TRACE_FAMILIES = "uniform, weighted_1..weighted_4, ratio_P (0 <= P <= 100)"


def validate_trace_name(name: str) -> None:
    """Raise an early ValueError naming the accepted families for an
    unknown trace string (instead of failing deep inside generation)."""
    try:
        TraceConfig(name).probabilities()
    except (ValueError, AssertionError, KeyError, IndexError):
        raise ValueError(
            f"unknown trace {name!r}; expected one of: {TRACE_FAMILIES}"
        ) from None


def generate_trace(cfg: TraceConfig) -> np.ndarray:
    """Return an int array of shape [n_frames, n_devices]."""
    # zlib.crc32, NOT hash(): str hash is PYTHONHASHSEED-randomised per
    # process, which silently made every scenario a different draw per run.
    name_salt = zlib.crc32(cfg.name.encode()) % (2 ** 16)
    rng = np.random.default_rng(cfg.seed + name_salt)
    p = cfg.probabilities()
    idx = rng.choice(6, size=(cfg.n_frames, cfg.n_devices), p=p)
    return np.asarray(VALUES, dtype=np.int64)[idx]


def generate_type_trace(cfg: TraceConfig, weights) -> np.ndarray:
    """Task-type assignment for a mixed workload: an object array of shape
    [n_frames, n_devices] of task-type names drawn from ``weights``
    ((type, probability) pairs — ``WorkloadSpec.mix_weights()``).

    Seeded independently of :func:`generate_trace` (distinct salt), so a
    workload's type stream never perturbs the value stream: the same
    (trace, seed) pair generates identical frame values whether the
    scenario runs the paper's single model or a mixed fleet.
    """
    types = [t for t, _ in weights]
    p = np.asarray([w for _, w in weights], dtype=float)
    if len(types) == 0 or p.sum() <= 0:
        raise ValueError("generate_type_trace: empty or zero-weight mix")
    p /= p.sum()
    name_salt = zlib.crc32(("types:" + cfg.name).encode()) % (2 ** 16)
    rng = np.random.default_rng(cfg.seed + name_salt)
    idx = rng.choice(len(types), size=(cfg.n_frames, cfg.n_devices), p=p)
    return np.asarray(types, dtype=object)[idx]


def potential_counts(trace: np.ndarray) -> dict[str, int]:
    """Reproduce Table 4: potential HP/LP task counts for a trace."""
    return {
        "potential_low_priority": int(trace[trace > 0].sum()),
        "potential_high_priority": int((trace >= 0).sum()),
        "frames": int(trace.shape[0]),
        "device_frames": int(trace.size),
    }
