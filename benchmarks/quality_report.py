"""Decision-quality report: competitive ratios of every policy vs the oracle.

The perf benchmarks (scheduler_micro.py) gate *speed*; this module gates
*scheduling quality* the same way.  Every registered policy replays the
golden scenario matrix (``SCENARIOS`` + ``MIXED_SCENARIOS`` at a reduced
frame count, plus two small large-N tiers) and is scored against the
``oracle`` policy (core/oracle.py) run end-to-end on the SAME scenario:

* ``hp_completion_ratio``     — HP completion %, policy / oracle
* ``frame_completion_ratio``  — frames fully completed %, policy / oracle
* ``goodput_ratio``           — accuracy-weighted LP goodput, policy / oracle.
                                Each completed LP task is weighted by the
                                accuracy of the ladder rung it was ADMITTED
                                at (``task.variant``, DESIGN.md §17), over a
                                denominator of every generated task at full
                                (rung-0) accuracy.  The paper workload is
                                all-1.0 and ladder-free; mixed_edge varies
                                accuracy across types; the ``paper_ladder``
                                scenarios below vary it across variants —
                                there the oracle enumerates variant columns,
                                so the ratio certifies greedy-vs-optimal
                                variant selection.

The oracle is *per-decision* optimal, non-preemptive and non-clairvoyant
(DESIGN.md §13) — so ratios are a calibrated yardstick, NOT bounded by 1.0:
the preemption-aware scheduler legitimately beats the oracle's HP completion
because it can evict LP work the oracle must schedule around.  What the gate
pins is that the paper scheduler's measured ratios never silently regress.

Everything is seeded and deterministic, so the committed capture
(``QUALITY_10.json``; ``QUALITY_6.json`` is the pre-ladder capture, kept
for history) reproduces exactly on any machine; the gate margin only
absorbs environment drift (numpy versions etc.), not noise.

Runs are deduplicated by their effective configuration: WPS_4 / DPW / CPW
share (trace, preemption, workload, devices, seed), so each policy runs that
base once.  The oracle likewise ignores preemption and victim policy, so one
oracle run serves every scenario sharing its base.

Usage::

    PYTHONPATH=src python benchmarks/quality_report.py                 # table
    PYTHONPATH=src python benchmarks/quality_report.py --json QUALITY_10.json
    PYTHONPATH=src python benchmarks/quality_report.py --quick \\
        --gate QUALITY_10.json                                         # CI

``--json`` captures BOTH tiers (quick + full) and pins per-scenario gate
floors at ``measured - margin`` for the gated policy.  ``--gate`` replays
the selected tier and fails (exit 1) if any gated ratio lands below its
pinned floor.
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import replace
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.policy import registered_policies          # noqa: E402
from repro.core.profiles import get_workload               # noqa: E402
from repro.core.task import TaskState                      # noqa: E402
from repro.sim.experiment import (                         # noqa: E402
    MIXED_SCENARIOS,
    SCENARIOS,
    Runtime,
    ScenarioConfig,
)

#: The policy whose ratios the CI gate pins (the paper's scheduler).
GATED_POLICY = "scheduler"
#: Ratios the gate enforces.  ``goodput_ratio`` joined the gated set with
#: the variant ladder (DESIGN.md §17): it is the accuracy-weighted-goodput
#: floor that pins degrade-before-reject's quality on the ladder scenarios
#: (and rides along at ~all-1.0 accuracy everywhere else).
GATED_METRICS = ("hp_completion_ratio", "frame_completion_ratio",
                 "goodput_ratio")
#: Floor = measured - MARGIN.  Runs are deterministic; the margin absorbs
#: cross-environment drift only.
MARGIN = 0.05

#: Small large-N tiers: the golden matrix stops at the paper's 4 devices;
#: these keep the ratio report honest about fleet-size effects without
#: turning the oracle into the bottleneck.
LARGE_N_SCENARIOS: dict[str, ScenarioConfig] = {
    "LN8": ScenarioConfig("LN8", "uniform", "scheduler", True,
                          n_devices=8, seed=13),
    "LN16": ScenarioConfig("LN16", "weighted_2", "scheduler", True,
                           n_devices=16, seed=13),
}

#: Variant-ladder scenarios (DESIGN.md §17): the paper workload with a
#: two-rung degradation ladder, with and without degrade-before-reject.
#: The two differ ONLY in the degrade flag — ``_run_key`` must keep them
#: apart (they share one oracle run, which enumerates the ladder either
#: way and so bounds optimal variant selection for both).
LADDER_SCENARIOS: dict[str, ScenarioConfig] = {
    "LDPS": ScenarioConfig("LDPS", "weighted_4", "scheduler", True,
                           workload="paper_ladder", degrade=True),
    "LDNPS": ScenarioConfig("LDNPS", "weighted_4", "scheduler", True,
                            workload="paper_ladder"),
}

ALL_SCENARIOS: dict[str, ScenarioConfig] = {
    **SCENARIOS, **MIXED_SCENARIOS, **LARGE_N_SCENARIOS,
    **LADDER_SCENARIOS,
}

TIERS = {"quick": 20, "full": 40}            # n_frames per tier


def _run_key(cfg: ScenarioConfig, policy: str, n_frames: int) -> tuple:
    """Effective-configuration key — collapses scenarios that differ only
    in their (replaced) algorithm.  The oracle additionally ignores
    preemption, victim selection and the degrade flag (it enumerates the
    variant ladder unconditionally); every other policy keys on ``degrade``
    too, so configs differing only in degrade mode are NOT collapsed."""
    if policy == "oracle":
        return (policy, cfg.trace, cfg.workload, cfg.n_devices, cfg.seed,
                n_frames)
    return (policy, cfg.trace, cfg.workload, cfg.n_devices, cfg.seed,
            n_frames, cfg.preemption, cfg.victim_policy, cfg.lp_batch_window,
            cfg.degrade)


def _measure(cfg: ScenarioConfig, policy: str, n_frames: int) -> dict:
    """One end-to-end run; absolute quality metrics."""
    rt = Runtime(replace(cfg, name=f"q_{cfg.name}_{policy}",
                         algorithm=policy, n_frames=n_frames))
    rt.run()
    s = rt.metrics.summary()
    spec = get_workload(cfg.workload)
    lp_tasks = [t for req in rt.requests for t in req.tasks]
    # Denominator: every generated task at full (rung-0) accuracy — the
    # maximum attainable.  Numerator: completed tasks at the accuracy of
    # the ladder rung they were admitted at (variant 0 = the base profile,
    # so ladder-free workloads score exactly as before).
    total = sum(spec.profile(t.task_type).accuracy for t in lp_tasks)
    good = sum(
        spec.profile(t.task_type).variant_profile(t.variant).accuracy
        for t in lp_tasks if t.state == TaskState.COMPLETED)
    return {
        "hp_completion_pct": s["hp_completion_pct"],
        "frame_completion_pct": s["frame_completion_pct"],
        "goodput_pct": 100.0 * good / total if total else 100.0,
    }


def _ratio(policy_val: float, oracle_val: float) -> float:
    if oracle_val <= 0.0:
        return 1.0 if policy_val <= 0.0 else float("inf")
    return policy_val / oracle_val


def run_tier(n_frames: int, cache: dict | None = None) -> dict[str, dict]:
    """Per-scenario, per-policy ratio rows for one tier."""
    cache = {} if cache is None else cache
    policies = registered_policies()

    def measured(cfg: ScenarioConfig, policy: str) -> dict:
        key = _run_key(cfg, policy, n_frames)
        if key not in cache:
            cache[key] = _measure(cfg, policy, n_frames)
        return cache[key]

    report: dict[str, dict] = {}
    for name, cfg in ALL_SCENARIOS.items():
        oracle = measured(cfg, "oracle")
        rows: dict[str, dict] = {}
        for policy in policies:
            m = measured(cfg, policy)
            rows[policy] = {
                "hp_completion_ratio": round(_ratio(
                    m["hp_completion_pct"], oracle["hp_completion_pct"]), 6),
                "frame_completion_ratio": round(_ratio(
                    m["frame_completion_pct"],
                    oracle["frame_completion_pct"]), 6),
                "goodput_ratio": round(_ratio(
                    m["goodput_pct"], oracle["goodput_pct"]), 6),
            }
        report[name] = {"oracle_abs": oracle, "policies": rows}
    return report


def floors_from(report: dict[str, dict]) -> dict[str, dict]:
    return {
        name: {
            metric: round(entry["policies"][GATED_POLICY][metric] - MARGIN, 6)
            for metric in GATED_METRICS
        }
        for name, entry in report.items()
    }


def print_table(report: dict[str, dict]) -> None:
    policies = registered_policies()
    head = f"{'scenario':<12}{'metric':<24}" + "".join(
        f"{p:>14}" for p in policies)
    print(head)
    print("-" * len(head))
    for name, entry in report.items():
        for metric in ("hp_completion_ratio", "frame_completion_ratio",
                       "goodput_ratio"):
            row = "".join(f"{entry['policies'][p][metric]:>14.3f}"
                          for p in policies)
            print(f"{name:<12}{metric:<24}{row}")


def gate(report: dict[str, dict], floors: dict[str, dict]) -> list[str]:
    """Compare the gated policy's ratios against the pinned floors."""
    failures: list[str] = []
    for name, metric_floors in floors.items():
        if name not in report:
            failures.append(f"{name}: scenario missing from this run")
            continue
        rows = report[name]["policies"][GATED_POLICY]
        for metric, floor in metric_floors.items():
            got = rows[metric]
            status = "ok" if got >= floor else "REGRESSION"
            print(f"  {name}.{metric}: {got:.3f} (floor {floor:.3f}) {status}")
            if got < floor:
                failures.append(
                    f"{name}.{metric}: {got:.3f} below floor {floor:.3f}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="run the quick tier only (CI smoke)")
    ap.add_argument("--json", metavar="PATH",
                    help="capture the report (+ gate floors) to PATH")
    ap.add_argument("--gate", metavar="PATH",
                    help="check the gated policy's ratios against the "
                         "floors pinned in PATH; exit 1 on regression")
    args = ap.parse_args(argv)

    cache: dict = {}
    tiers = ("quick",) if args.quick else (("quick", "full")
                                           if args.json else ("full",))
    reports = {t: run_tier(TIERS[t], cache) for t in tiers}
    for tier in tiers:
        print(f"== tier {tier} (n_frames={TIERS[tier]}) ==")
        print_table(reports[tier])

    if args.json:
        payload = {
            "meta": {
                "gated_policy": GATED_POLICY,
                "gated_metrics": list(GATED_METRICS),
                "margin": MARGIN,
                "tiers": {t: TIERS[t] for t in tiers},
            },
            "reports": reports,
            "floors": {t: floors_from(reports[t]) for t in tiers},
        }
        Path(args.json).write_text(json.dumps(payload, indent=1,
                                              sort_keys=True) + "\n")
        print(f"wrote {args.json}")

    if args.gate:
        pinned = json.loads(Path(args.gate).read_text())
        tier = "quick" if args.quick else "full"
        if tier not in pinned["floors"]:
            print(f"no '{tier}' floors in {args.gate}", file=sys.stderr)
            return 1
        print(f"== quality gate ({tier}, policy={GATED_POLICY}) ==")
        failures = gate(reports[tier], pinned["floors"][tier])
        if failures:
            print(f"QUALITY GATE FAILED ({len(failures)}):", file=sys.stderr)
            for f in failures:
                print(f"  {f}", file=sys.stderr)
            return 1
        print("quality gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
