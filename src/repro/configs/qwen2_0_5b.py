"""qwen2-0.5b [dense] — GQA, QKV bias [arXiv:2407.10671].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
"""
from __future__ import annotations

from dataclasses import replace

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    arch_type="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    source="arXiv:2407.10671",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=320,
        vocab_size=512, stages=(),
    )
