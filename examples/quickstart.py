"""Quickstart: train a reduced assigned architecture for a few steps, save a
checkpoint, and decode from it.

  PYTHONPATH=src python examples/quickstart.py [--arch smollm-135m] [--steps 20]

Every assigned architecture id works (``--arch deepseek-v3-671b`` trains the
reduced smoke variant of that family — same layer pattern, small dims).
"""
import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.configs import ARCH_IDS, get_smoke_config
from repro.configs.shapes import InputShape
from repro.data import train_batches
from repro.models import model as M
from repro.training import make_train_step
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.steps import make_prefill_step, make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt", default="/tmp/repro_quickstart.ckpt.npz")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    shape = InputShape("quickstart", seq_len=32, global_batch=4, kind="train")
    opt = AdamWConfig(lr=3e-4, warmup_steps=5, total_steps=args.steps)

    print(f"[1/3] training reduced {args.arch} "
          f"({cfg.n_layers}L d={cfg.d_model}) for {args.steps} steps")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_opt_state(opt, params)
    step = jax.jit(make_train_step(cfg, opt))
    batches = train_batches(cfg, shape)
    t0 = time.time()
    loss0 = None
    for i, batch in zip(range(args.steps), batches):
        params, opt_state, metrics = step(params, opt_state, batch)
        loss = float(metrics["loss"])
        loss0 = loss0 if loss0 is not None else loss
        if i % 5 == 0 or i == args.steps - 1:
            print(f"  step {i:3d} loss {loss:.4f} "
                  f"grad_norm {float(metrics['grad_norm']):.3f}")
    print(f"  {args.steps} steps in {time.time()-t0:.1f}s; "
          f"loss {loss0:.3f} -> {loss:.3f}")
    if not loss < loss0:
        print("  WARNING: loss did not improve", file=sys.stderr)

    print(f"[2/3] checkpoint round-trip -> {args.ckpt}")
    store.save(args.ckpt, params, {"arch": args.arch})
    params = store.restore(args.ckpt, params)

    print("[3/3] greedy decode from the trained weights")
    prompt = {"tokens": jnp.asarray([[1, 2, 3, 4, 5, 6, 7, 8]], jnp.int32)}
    if cfg.modality_embed_dim:
        n_mod = cfg.n_modality_tokens or 8
        prompt["modality_emb"] = jnp.zeros((1, n_mod, cfg.modality_embed_dim))
    cache_len = 64
    prefill = jax.jit(make_prefill_step(cfg, cache_len))
    serve = jax.jit(make_serve_step(cfg))
    tok, caches = prefill(params, prompt)
    out = [int(tok[0])]
    pos = prompt["tokens"].shape[1] + (cfg.n_modality_tokens
                                       if cfg.modality_embed_dim
                                       and not cfg.is_encoder_decoder else 0)
    tok = tok[:, None]
    for i in range(8):
        tok, caches = serve(params, caches, tok, jnp.asarray(pos + i,
                                                             jnp.int32))
        out.append(int(tok[0, 0]))
    print(f"  generated tokens: {out}")
    if os.path.isdir(args.ckpt):
        import shutil
        shutil.rmtree(args.ckpt)
    elif os.path.exists(args.ckpt):
        os.unlink(args.ckpt)
    print("done.")


if __name__ == "__main__":
    main()
