"""Calendar invariants: unit + hypothesis property tests."""
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.calendar import DeviceCalendar, LinkCalendar, NetworkState


def test_link_earliest_slot_empty():
    link = LinkCalendar()
    assert link.earliest_slot(1.0, 5.0) == 5.0


def test_link_slots_never_overlap_sequential():
    link = LinkCalendar()
    r1 = link.reserve_earliest(1.0, 0.0)
    r2 = link.reserve_earliest(1.0, 0.0)
    r3 = link.reserve_earliest(0.5, 0.0)
    res = sorted([r1, r2, r3], key=lambda r: r.t1)
    for a, b in zip(res, res[1:]):
        assert a.t2 <= b.t1 + 1e-9


@settings(max_examples=200, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.floats(0.01, 5.0),     # duration
            st.floats(0.0, 20.0),     # not_before
        ),
        min_size=1,
        max_size=30,
    )
)
def test_link_no_overlap_property(requests):
    """No two link reservations ever overlap, regardless of request order."""
    link = LinkCalendar()
    for dur, nb in requests:
        link.reserve_earliest(dur, nb)
    res = sorted(link._res, key=lambda r: r.t1)
    for a, b in zip(res, res[1:]):
        assert a.t2 <= b.t1 + 1e-9
    # and every reservation respects its not_before
    assert len(res) == len(requests)


@settings(max_examples=200, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.floats(0.0, 50.0),              # t1
            st.floats(0.1, 10.0),              # duration
            st.integers(1, 4),                 # cores
        ),
        min_size=1,
        max_size=40,
    )
)
def test_device_capacity_property(reqs):
    """fits() + reserve() never exceeds device capacity at any instant."""
    dev = DeviceCalendar(0, capacity=4)
    admitted = []
    for i, (t1, dur, cores) in enumerate(reqs):
        if dev.fits(t1, t1 + dur, cores):
            dev.reserve(t1, t1 + dur, cores, tag=i)
            admitted.append((t1, t1 + dur, cores))
    # sweep-line over all admitted intervals
    events = []
    for t1, t2, c in admitted:
        events.append((t1, c))
        events.append((t2, -c))
    events.sort()
    cur = 0
    for _, delta in events:
        cur += delta
        assert cur <= 4


def test_device_release_and_truncate():
    dev = DeviceCalendar(0, capacity=4)
    dev.reserve(0.0, 10.0, 4, tag="a")
    assert not dev.fits(5.0, 6.0, 1)
    dev.truncate("a", 5.0)
    assert dev.fits(5.0, 6.0, 4)
    dev.release("a")
    assert dev.fits(0.0, 10.0, 4)


def test_completion_times_sorted_unique():
    state = NetworkState(2)
    state.devices[0].reserve(0.0, 3.0, 2, "x")
    state.devices[1].reserve(0.0, 3.0, 2, "y")
    state.devices[0].reserve(1.0, 4.0, 2, "z")
    pts = state.completion_times(0.0, 10.0)
    assert pts == sorted(set(pts)) == [3.0, 4.0]
