"""Oracle correctness: backend agreement + the no-policy-beats-it invariant.

Two pillars (ISSUE 6 / DESIGN.md §13):

* **Differential**: on randomized tiny instances (<= 4 devices, <= 8
  tasks, seeded) the MILP encoding and the exhaustive branch-and-bound
  must agree *exactly* on the objective — the brute-force search is the
  oracle's own correctness oracle.  Both solutions must independently
  re-verify against the instance model.
* **Upper bound**: no registered slot-based policy may ever exceed the
  oracle's objective on its own instance.  A policy "winning" means the
  oracle's relaxation is wrong (its optimum is supposed to dominate every
  physically realisable placement).  Workstealers are excluded — they are
  processor-sharing disciplines without slot placements to score.

Instances are kept oracle-sized on purpose: random per-seed workload
profiles (random durations/pads), random pre-existing device occupancy
(non-evictable background tags), tight deadline windows.
"""
import random

import pytest

from repro.core.calendar import NetworkState
from repro.core.metrics import Metrics
from repro.core.network import NetworkConfig
from repro.core.oracle import (
    OracleInstance,
    OracleInstanceError,
    OraclePolicy,
    have_ortools,
)
from repro.core.policy import create_policy, registered_policies
from repro.core.profiles import TaskProfile, WorkloadSpec
from repro.core.task import (
    LowPriorityRequest,
    Priority,
    Task,
    reset_id_counters,
)

NOW = 5.0


def _random_setup(seed: int):
    """One seeded tiny scenario: a workload spec, background occupancy,
    and task specs (not yet materialised — each policy needs fresh Tasks)."""
    rng = random.Random(9000 + seed)
    n_devices = rng.randint(1, 4)
    lp2 = rng.uniform(2.0, 6.0)
    prof = TaskProfile(
        name="rnd",
        hp_exec=rng.uniform(0.5, 1.5),
        hp_pad=rng.uniform(0.02, 0.10),
        lp_exec={2: lp2, 4: lp2 * rng.uniform(0.55, 0.85)},
        lp_pad={2: rng.uniform(0.05, 0.3), 4: rng.uniform(0.05, 0.3)},
        input_bytes=rng.randint(8000, 64000),
        accuracy=rng.uniform(0.7, 1.0),
    )
    spec = WorkloadSpec(name="rnd", profiles={"rnd": prof},
                        default_type="rnd")
    net = NetworkConfig(workload=spec)
    background = []
    for d in range(n_devices):
        for b in range(rng.randint(0, 2)):
            t1 = NOW + rng.uniform(0.0, 6.0)
            background.append(
                (d, t1, t1 + rng.uniform(1.0, 5.0), rng.randint(1, 3),
                 f"bg{d}_{b}"))
    n_hp = rng.randint(0, 2)
    hp_deadlines = [NOW + prof.hp_exec + rng.uniform(0.2, 1.2)
                    for _ in range(n_hp)]
    hp_sources = [rng.randrange(n_devices) for _ in range(n_hp)]
    # <= 4 LP tasks with tight deadline slack: identical LP jobs create
    # symmetric subtrees the branch-and-bound cannot collapse, so the
    # exhaustive differential needs start grids of a handful of points
    n_lp = rng.randint(1, 4)
    lp_deadline = NOW + prof.lp_slot_time(4) + rng.uniform(0.3, 1.8)
    lp_source = rng.randrange(n_devices)
    return (net, n_devices, background, hp_sources, hp_deadlines,
            n_lp, lp_source, lp_deadline)


def _apply_background(state, background):
    """Reserve the pre-existing (non-evictable) occupancy into ``state``."""
    for d, t1, t2, cores, tag in background:
        state.devices[d].reserve(t1, t2, cores, tag)


def _materialise(setup):
    """Fresh NetworkState + fresh Task objects for one policy run."""
    (net, n_devices, background, hp_sources, hp_deadlines,
     n_lp, lp_source, lp_deadline) = setup
    reset_id_counters()
    state = NetworkState(n_devices)
    _apply_background(state, background)
    hp_tasks = [
        Task(priority=Priority.HIGH, source_device=src, deadline=dl,
             frame_id=i, task_type="rnd", created_at=NOW)
        for i, (src, dl) in enumerate(zip(hp_sources, hp_deadlines))
    ]
    req = LowPriorityRequest(source_device=lp_source, deadline=lp_deadline,
                             frame_id=99, n_tasks=n_lp, task_type="rnd",
                             created_at=NOW)
    req.make_tasks()
    return state, hp_tasks, req


def _instance(setup):
    state, hp_tasks, req = _materialise(setup)
    tasks = hp_tasks + list(req.tasks)
    net = setup[0]
    return OracleInstance.from_state(state, net, tasks, NOW), tasks


# --------------------------------------------------------------------- #
# Differential: MILP vs exhaustive branch-and-bound                     #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(20))
def test_milp_and_brute_agree_exactly(seed):
    setup = _random_setup(seed)
    inst, _ = _instance(setup)
    brute = inst.solve("brute")
    milp = inst.solve("milp")
    inst.verify(brute)
    inst.verify(milp)
    assert abs(brute.objective - milp.objective) < 1e-6, (
        f"backend disagreement: brute {brute.lex} ({brute.objective!r}) "
        f"vs milp {milp.lex} ({milp.objective!r})")
    # counts are integral parts of the objective: they must match exactly
    assert brute.lex[:2] == milp.lex[:2]


@pytest.mark.parametrize("seed", range(20))
def test_auto_backend_matches_brute(seed):
    setup = _random_setup(seed)
    inst, _ = _instance(setup)
    assert abs(inst.solve("auto").objective
               - inst.solve("brute").objective) < 1e-6


# --------------------------------------------------------------------- #
# Upper bound: no slot-based policy beats the oracle objective          #
# --------------------------------------------------------------------- #
def _slot_policies():
    names = []
    net = NetworkConfig()
    for name in registered_policies():
        p = create_policy(name, n_devices=2, net=net, metrics=Metrics())
        if not p.drives_execution:
            names.append(name)
    return names


@pytest.mark.parametrize("policy_name", _slot_policies())
@pytest.mark.parametrize("seed", range(12))
def test_no_policy_beats_the_oracle(policy_name, seed):
    setup = _random_setup(seed)
    net, n_devices = setup[0], setup[1]
    inst, _ = _instance(setup)
    optimum = inst.solve("auto")

    _, hp_tasks, req = _materialise(setup)
    policy = create_policy(policy_name, n_devices=n_devices, net=net,
                           metrics=Metrics())
    # mirror the pre-existing load into the policy's own state (scheduler
    # policies capture self.state at construction — swapping it is a no-op)
    _apply_background(policy.state, setup[2])
    for task in hp_tasks:
        policy.decide_hp(task, NOW)
    policy.decide_lp(req, NOW)
    score, lex = inst.score_tasks(hp_tasks + list(req.tasks))
    assert score <= optimum.objective + 1e-7, (
        f"{policy_name} scored {score!r} {lex} above the oracle optimum "
        f"{optimum.objective!r} {optimum.lex} — the oracle model is wrong")


def test_oracle_policy_attains_instance_optimum_single_request():
    """With no HP tasks and one LP request, the online oracle policy IS
    the instance solver — its committed placements must reach the
    instance objective exactly."""
    setup = _random_setup(3)
    net, n_devices = setup[0], setup[1]
    inst, _ = _instance(setup)
    optimum = inst.solve("auto")

    _, hp_tasks, req = _materialise(setup)
    policy = OraclePolicy(n_devices=n_devices, net=net, metrics=Metrics())
    _apply_background(policy.state, setup[2])
    for task in hp_tasks:                   # seed 3 has 0 HP tasks
        policy.decide_hp(task, NOW)
    if not hp_tasks:
        policy.decide_lp(req, NOW)
        score, lex = inst.score_tasks(list(req.tasks))
        assert abs(score - optimum.objective) < 1e-7
        assert lex[:2] == optimum.lex[:2]


# --------------------------------------------------------------------- #
# Size guards + optional backend gate                                   #
# --------------------------------------------------------------------- #
def test_oversized_instance_raises_oracle_instance_error():
    setup = _random_setup(0)
    state, hp_tasks, req = _materialise(setup)
    tasks = hp_tasks + list(req.tasks)
    with pytest.raises(OracleInstanceError):
        OracleInstance.from_state(state, setup[0], tasks, NOW, max_grid=1)


def test_cpsat_backend_is_feature_gated():
    setup = _random_setup(1)
    inst, _ = _instance(setup)
    if not have_ortools():
        with pytest.raises(OracleInstanceError, match="ortools"):
            inst.solve("cpsat")
    else:                                    # exercised by CI's cpsat job
        assert abs(inst.solve("cpsat").objective
                   - inst.solve("brute").objective) < 1e-6


@pytest.mark.skipif(not have_ortools(), reason="ortools not installed "
                    "(CI's non-blocking cpsat-oracle job installs it)")
@pytest.mark.parametrize("seed", range(4))
def test_cpsat_matches_brute_force_when_available(seed):
    inst, _ = _instance(_random_setup(seed + 10))
    cp = inst.solve("cpsat")
    brute = inst.solve("brute")
    assert abs(cp.objective - brute.objective) < 1e-6


def test_oracle_is_registered_slot_based_policy():
    assert "oracle" in registered_policies()
    p = create_policy("oracle", n_devices=2, net=NetworkConfig(),
                      metrics=Metrics())
    assert not p.drives_execution
