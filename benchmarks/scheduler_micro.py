"""Scheduler micro-benchmarks: wall-clock per allocation call vs network
load (the paper's §6.3 complexity discussion: HP ~ O(local tasks),
LP ~ O(total tasks^2))."""
from __future__ import annotations

import time

from repro.core.calendar import NetworkState
from repro.core.network import NetworkConfig
from repro.core.scheduler import PreemptionAwareScheduler
from repro.core.task import LowPriorityRequest, Priority, Task


def _loaded_state(n_devices: int, n_tasks: int, net: NetworkConfig):
    """A network with n_tasks LP reservations spread across devices/time."""
    state = NetworkState(n_devices)
    sched = PreemptionAwareScheduler(state, net, preemption=True)
    t = 0.0
    placed = 0
    while placed < n_tasks:
        req = LowPriorityRequest(source_device=placed % n_devices,
                                 deadline=t + 120.0, frame_id=placed,
                                 n_tasks=1)
        req.make_tasks()
        res = sched.allocate_low_priority(req, t)
        placed += 1
        if not res.allocations:
            t += 5.0
    return state, sched


def bench_scheduler_scaling(loads=(8, 32, 128), reps: int = 30):
    """Rows: (bench, load, metric, us_per_call)."""
    rows = []
    net = NetworkConfig()
    for load in loads:
        state, sched = _loaded_state(4, load, net)
        # HP allocation timing (fresh task each rep, rolled back after)
        t0 = time.perf_counter()
        for i in range(reps):
            task = Task(priority=Priority.HIGH, source_device=i % 4,
                        deadline=1e6, frame_id=i)
            res = sched.allocate_high_priority(task, 0.0)
            if res.allocation is not None:
                state.devices[task.device].release(task)
                for slot in res.allocation.link_slots:
                    state.link.cancel(slot)
        hp_us = (time.perf_counter() - t0) / reps * 1e6
        rows.append(("sched_micro", str(load), "hp_alloc_us", hp_us))

        t0 = time.perf_counter()
        for i in range(reps):
            req = LowPriorityRequest(source_device=i % 4, deadline=1e5,
                                     frame_id=i, n_tasks=1)
            req.make_tasks()
            sched.allocate_low_priority(req, 0.0)
        lp_us = (time.perf_counter() - t0) / reps * 1e6
        rows.append(("sched_micro", str(load), "lp_alloc_us", lp_us))
    return rows
