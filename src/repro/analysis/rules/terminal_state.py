"""Terminal-accounting rule: every terminal ``TaskState`` assignment must
happen inside a designated settle helper.

The Metrics partition invariant (tests/test_accounting_invariants.py:
every generated task lands in exactly one terminal summary bucket) can
only hold if every transition into a terminal state flows through a code
path that bumps — or feeds a ``Decision``/result list that downstream
bumps — the matching partition counter.  PR 6 flushed five silent leaks
out of exactly this shape: a ``task.state = TaskState.FAILED`` on a path
no counter ever saw.

``SETTLE_HELPERS`` is the audited registry: the functions whose
terminal transitions the accounting-invariant suite certifies end-to-end.
A NEW terminal assignment anywhere else is a finding — either route it
through a helper, extend the registry (and the accounting suite) in the
same change, or pragma the line with a justification.

Deliberately NOT certified: indirection (``setattr(task, "state", ...)``,
``state`` aliased through a variable) — the accounting-invariant suite
remains the runtime backstop; and non-terminal states (PENDING /
ALLOCATED / RUNNING / PREEMPTED transitions carry no partition counter).
"""
from __future__ import annotations

import ast
from typing import Iterator, Mapping, Optional

from ..engine import Finding, Module, Rule

TERMINAL_STATES = frozenset({"COMPLETED", "FAILED", "VIOLATED"})

#: relpath -> function qualnames audited as settle paths by
#: tests/test_accounting_invariants.py (directly bumping a partition
#: counter, or filling the Decision/result failure lists that
#: PolicyDispatcher._account_lp / submit_hp account downstream).
SETTLE_HELPERS: dict[str, frozenset[str]] = {
    "repro/core/policy.py": frozenset({
        "PolicyDispatcher.submit_hp",
        "PolicyDispatcher._account_lp",
        "PolicyDispatcher._violate",
        "PolicyDispatcher.task_finished",
        "CalendarPolicy.fail_device",
        "EDFOnlyPolicy.decide_lp_batch",
        "EDFOnlyPolicy.reallocate",
    }),
    "repro/core/scheduler.py": frozenset({
        "PreemptionAwareScheduler._reallocate_victims",
        "PreemptionAwareScheduler.allocate_low_priority",
        "PreemptionAwareScheduler.allocate_low_priority_batch",
        "PreemptionAwareScheduler.reallocate",
        "PreemptionAwareScheduler.settle_hp_orphans",
    }),
    "repro/core/workstealer.py": frozenset({
        "WorkstealingPolicy._kill_if_late",
        "WorkstealingPolicy._kick",
        "WorkstealingPolicy.finalize",
    }),
}


def _terminal_refs(node: ast.AST) -> Optional[str]:
    """First ``TaskState.<TERMINAL>`` reference inside an expression
    (covers conditional values like ``A if late else B``)."""
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Attribute) and sub.attr in TERMINAL_STATES
                and isinstance(sub.value, ast.Name)
                and sub.value.id == "TaskState"):
            return sub.attr
    return None


class TerminalStateRule(Rule):
    name = "terminal-state"
    description = (
        "terminal TaskState assignments outside the designated settle "
        "helpers (transitions the Metrics partition cannot have counted)"
    )

    def __init__(self,
                 settle: Optional[Mapping[str, frozenset[str]]] = None) -> None:
        self.settle = dict(SETTLE_HELPERS if settle is None else settle)

    def check(self, mod: Module) -> Iterator[Finding]:
        allowed = self.settle.get(mod.rel, frozenset())
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.Assign, ast.AnnAssign)):
                continue
            if node.value is None:
                continue
            state = _terminal_refs(node.value)
            if state is None:
                continue
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                if isinstance(t, ast.Attribute) and t.attr == "state":
                    qual = mod.qualname(node.lineno)
                    if qual not in allowed:
                        where = qual or "<module>"
                        yield Finding(
                            self.name, mod.rel, node.lineno, node.col_offset,
                            f"terminal assignment TaskState.{state} in "
                            f"{where}, which is not a designated settle "
                            "helper — the Metrics partition cannot have "
                            "counted this transition; route it through a "
                            "settle helper or extend SETTLE_HELPERS plus "
                            "tests/test_accounting_invariants.py together",
                            qual)
