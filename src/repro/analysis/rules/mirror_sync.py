"""Mirror-sync rules: the calendar's derived planes (array skyline, probe
plane, ``_LPMirror``) stay exact only if every buffer mutation flows
through the calendar mutation API (reserve / release / truncate / gc) and
every mutation path raises the probe plane's dirty mark.

Two rules:

* ``mirror-sync`` — outside the owning module, no direct writes to the
  protected buffer attributes and no mutator calls on a skyline/mirror
  reached through them.  A reservation spliced straight into ``dev._sky``
  (or a cleared ``_dirty`` set) leaves the probe plane answering from a
  stale mirror — the bug class PR 4/5 could only catch by fuzz
  differentials.
* ``dirty-notify`` — inside the owning module, any method of a
  dirty-mark-wired class (one defining ``_touch``) that mutates the
  probe-mirrored buffers (``_sky`` / ``_t2s``) must call ``self._touch()``
  in its own body.  Helpers whose callers notify carry a line pragma with
  the justification.

What these deliberately do NOT certify: reads (any module may query), and
aliasing through locals (``sky = dev._sky; sky.add(...)`` evades the
receiver-chain scan — the fuzz differentials remain the backstop for
exotic flows).
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..engine import Finding, Module, Rule

#: Buffer/wiring attributes owned by core/calendar.py.
PROTECTED_ATTRS = frozenset({
    "_sky", "_lp", "_t2s", "_dirty", "_notify", "_expiry", "_expiry_sink",
})

#: Method names that mutate a skyline / mirror / set they are called on.
MUTATORS = frozenset({
    "add", "append", "clear", "compact", "discard", "extend", "gc",
    "insert", "pop", "remove", "truncate", "update",
})

OWNER = "repro/core/calendar.py"


def _chain_has_protected(node: ast.AST) -> bool:
    """True if a Name/Attribute/Subscript chain traverses a protected attr."""
    while True:
        if isinstance(node, ast.Attribute):
            if node.attr in PROTECTED_ATTRS:
                return True
            node = node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        else:
            return False


class MirrorWriteRule(Rule):
    name = "mirror-sync"
    description = (
        "direct writes to skyline/probe-plane/_LPMirror buffers outside "
        "the calendar mutation API"
    )

    def __init__(self, owner: str = OWNER) -> None:
        self.owner = owner

    def applies_to(self, rel: str) -> bool:
        return rel != self.owner

    def check(self, mod: Module) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if _chain_has_protected(t):
                        yield Finding(
                            self.name, mod.rel, t.lineno, t.col_offset,
                            "direct write through a protected calendar "
                            "buffer attribute — mutate via the calendar "
                            "API (reserve/release/truncate/gc) so the "
                            "skyline, _LPMirror and probe plane stay in "
                            "sync", mod.qualname(t.lineno))
            elif isinstance(node, ast.Delete):
                for t in node.targets:
                    if _chain_has_protected(t):
                        yield Finding(
                            self.name, mod.rel, t.lineno, t.col_offset,
                            "delete through a protected calendar buffer "
                            "attribute — use the calendar mutation API",
                            mod.qualname(t.lineno))
            elif isinstance(node, ast.Call):
                func = node.func
                if (isinstance(func, ast.Attribute)
                        and func.attr in MUTATORS
                        and _chain_has_protected(func.value)):
                    yield Finding(
                        self.name, mod.rel, node.lineno, node.col_offset,
                        f"mutator call .{func.attr}() on a protected "
                        "calendar buffer — mutate via the calendar API "
                        "(reserve/release/truncate/gc), never the raw "
                        "skyline/mirror", mod.qualname(node.lineno))


def _is_self_attr(node: ast.AST, attr: str) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == attr
            and isinstance(node.value, ast.Name) and node.value.id == "self")


class DirtyNotifyRule(Rule):
    name = "dirty-notify"
    description = (
        "calendar mutation paths must raise the probe plane's dirty mark "
        "(self._touch()) in the mutating method's own body"
    )

    #: Probe-mirrored buffers: the plane re-reads these on a dirty mark.
    MIRRORED = ("_sky", "_t2s")
    #: self-methods that splice the mirrored buffers.
    SPLICERS = ("_t2s_insert", "_t2s_remove")

    def __init__(self, owner: str = OWNER) -> None:
        self.owner = owner

    def applies_to(self, rel: str) -> bool:
        return rel == self.owner

    def check(self, mod: Module) -> Iterator[Finding]:
        for cls in ast.walk(mod.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            methods = [n for n in cls.body
                       if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
            if not any(m.name == "_touch" for m in methods):
                continue                      # not a dirty-mark-wired class
            for m in methods:
                if m.name in ("_touch", "__init__"):
                    continue
                mutates = touches = False
                for node in ast.walk(m):
                    if isinstance(node, ast.Call):
                        f = node.func
                        if _is_self_attr(f, "_touch"):
                            touches = True
                        elif (isinstance(f, ast.Attribute)
                              and f.attr in MUTATORS
                              and any(_is_self_attr(f.value, a)
                                      for a in self.MIRRORED)):
                            mutates = True
                        elif any(_is_self_attr(f, s) for s in self.SPLICERS):
                            mutates = True
                    elif isinstance(node, (ast.Assign, ast.AugAssign,
                                           ast.AnnAssign)):
                        targets = (node.targets if isinstance(node, ast.Assign)
                                   else [node.target])
                        for t in targets:
                            base = t.value if isinstance(t, ast.Subscript) else t
                            if any(_is_self_attr(base, a)
                                   for a in self.MIRRORED):
                                mutates = True
                if mutates and not touches:
                    yield Finding(
                        self.name, mod.rel, m.lineno, m.col_offset,
                        f"{cls.name}.{m.name} mutates a probe-mirrored "
                        "buffer (_sky/_t2s) without calling self._touch() "
                        "— the probe plane would keep answering from a "
                        "stale mirror; notify here, or pragma the def "
                        "line if every caller notifies",
                        f"{cls.name}.{m.name}")
