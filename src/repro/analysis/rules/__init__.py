"""Shipped rule families (DESIGN.md §15 is the catalog).

Each module contributes one family; ``repro.analysis.engine.default_rules``
assembles the stable shipped order.
"""
from .determinism import SetIterRule, UnseededRngRule, WallClockRule
from .kernel_rules import JaxImportRule, PallasIndexRule
from .mirror_sync import DirtyNotifyRule, MirrorWriteRule
from .terminal_state import SETTLE_HELPERS, TerminalStateRule

__all__ = [
    "MirrorWriteRule",
    "DirtyNotifyRule",
    "TerminalStateRule",
    "SETTLE_HELPERS",
    "WallClockRule",
    "UnseededRngRule",
    "SetIterRule",
    "PallasIndexRule",
    "JaxImportRule",
]
