"""Corpus: deferred + type-only jax imports are allowed on the boundary."""
from typing import TYPE_CHECKING

if TYPE_CHECKING:
    import jax                             # good: type-only


def run(x):
    import jax                             # good: deferred into the function
    return jax, x
