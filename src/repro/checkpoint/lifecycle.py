"""Device-lifecycle checkpointing (DESIGN.md §16).

Round-trips the churn plane's state through the flat pytree store: the
per-device lifecycle codes (``DeviceLifecycle.value`` — the enum's
integer values ARE the wire encoding, never reorder them), the derived
alive mask, and the task ids of orphans whose recovery was still pending
when the snapshot was cut.  A restore mid-drain therefore resumes
recovery instead of silently forgetting the orphans: the driver gets the
pending ids back and re-runs its settle pass.

The tree rides the same ``store.save``/``store.restore`` machinery as
every other checkpoint, so shapes are always validated and dtypes refuse
to cast unless the caller opts in — a truncated mask or a float-smuggled
code array fails loudly, leaf-named.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import numpy as np

from ..core.calendar import DeviceLifecycle, NetworkState
from . import store

_CODES = np.array([m.value for m in DeviceLifecycle], dtype=np.int8)
_UP = np.int8(DeviceLifecycle.UP.value)


def lifecycle_tree(state: NetworkState,
                   pending_orphans: Sequence[int] = ()) -> dict[str, Any]:
    """Build the checkpoint pytree for ``state``'s lifecycle plane."""
    return {
        "alive_mask": state.alive_mask(),
        "lifecycle": state.lifecycle_codes(),
        "pending_orphans": np.asarray(sorted(pending_orphans),
                                      dtype=np.int64),
    }


def lifecycle_reference(n_devices: int, n_orphans: int) -> dict[str, Any]:
    """Shape/dtype skeleton ``store.restore`` validates against."""
    return {
        "alive_mask": jax.ShapeDtypeStruct((n_devices,), np.bool_),
        "lifecycle": jax.ShapeDtypeStruct((n_devices,), np.int8),
        "pending_orphans": jax.ShapeDtypeStruct((n_orphans,), np.int64),
    }


def save_lifecycle(path: str, state: NetworkState,
                   pending_orphans: Sequence[int] = (),
                   metadata: Optional[dict] = None) -> None:
    """Snapshot the lifecycle plane (+ pending orphan ids) at ``path``.

    ``n_devices``/``n_orphans`` land in the manifest metadata so a
    restore can size its reference tree without out-of-band knowledge.
    """
    tree = lifecycle_tree(state, pending_orphans)
    meta = dict(metadata or {})
    meta.update({
        "kind": "device_lifecycle",
        "n_devices": len(state.devices),
        "n_orphans": int(tree["pending_orphans"].shape[0]),
    })
    store.save(path, tree, metadata=meta)


def restore_lifecycle(path: str, state: NetworkState) -> list[int]:
    """Apply a lifecycle snapshot onto ``state``; returns the pending
    orphan task ids the driver must resume recovering.

    Validation beyond the store's shape/dtype checks: the snapshot must
    be a lifecycle checkpoint for a fleet of ``state``'s size, every
    code must be a known :class:`DeviceLifecycle` value, and the stored
    alive mask must agree with the codes (a disagreement means the
    payload was edited or torn — refuse rather than guess).
    """
    meta = store.load_metadata(path)
    if meta.get("kind") != "device_lifecycle":
        raise ValueError(
            f"{path}: not a device-lifecycle checkpoint "
            f"(kind={meta.get('kind')!r})")
    n_devices = meta.get("n_devices")
    if n_devices != len(state.devices):
        raise ValueError(
            f"{path}: checkpoint is for {n_devices} devices, state has "
            f"{len(state.devices)}")
    ref = lifecycle_reference(len(state.devices),
                              int(meta.get("n_orphans", 0)))
    tree = store.restore(path, ref)
    codes = tree["lifecycle"]
    if not np.isin(codes, _CODES).all():
        bad = sorted(set(codes.tolist()) - set(_CODES.tolist()))
        raise ValueError(f"{path}: unknown lifecycle codes {bad}")
    if not np.array_equal(tree["alive_mask"], codes == _UP):
        raise ValueError(
            f"{path}: alive_mask disagrees with lifecycle codes")
    state.apply_lifecycle_codes(codes)
    return tree["pending_orphans"].tolist()
