"""Metrics-accounting invariants: every task terminal, every counter adds up.

PR 5 fixed a *stranded-victim* bug — LP tasks evicted by a failed HP
admission were left in a non-terminal state and silently vanished from the
accounting.  This suite catches that whole class generically, for every
scenario family x every registered policy:

* **Terminal states** — after a run, every generated task sits in exactly
  one terminal state (COMPLETED / FAILED / VIOLATED); nothing is left
  PENDING, ALLOCATED, RUNNING or PREEMPTED.
* **Counter partition** — ``Metrics.summary()`` outcome counts partition
  the generated task set:

  - HP:  ``hp_generated == hp_completed + hp_failed_alloc +
    hp_failed_runtime``
  - LP:  ``lp_generated == lp_completed + lp_failed_alloc +
    lp_failed_runtime + realloc_failure``  (``realloc_failure`` is the
    terminal bucket for preempted tasks that never completed at all;
    a reallocated task that finishes late lands in ``lp_failed_runtime``)

* **State/counter agreement** — the COMPLETED task census equals the
  completed counters exactly.

Runs are deliberately small (reduced frame counts) but cover every trace
family the golden matrix uses, both preemption settings, and the mixed
heterogeneous workload.

The streaming path (serving/stream.py) adds a fifth terminal bucket —
**shed** — at the admission queue; the suite's partition extends to it for
every registered shed policy.
"""
from dataclasses import replace

import pytest

from repro.core.policy import registered_policies
from repro.core.task import Priority, TaskState, reset_id_counters
from repro.serving.stream import StreamingEngine, registered_shed_policies
from repro.sim.experiment import Runtime, ScenarioConfig
from repro.sim.openended import FirehoseConfig, firehose

TERMINAL = (TaskState.COMPLETED, TaskState.FAILED, TaskState.VIOLATED)

#: Small but structurally diverse scenario bases (name, cfg).  Every
#: registered policy is swept over each base.
BASES = {
    "uniform_p": ScenarioConfig("uniform_p", "uniform", "scheduler", True,
                                n_frames=40, seed=3),
    "weighted4_p": ScenarioConfig("weighted4_p", "weighted_4", "scheduler",
                                  True, n_frames=40, seed=5),
    "weighted4_np": ScenarioConfig("weighted4_np", "weighted_4", "scheduler",
                                   False, n_frames=40, seed=5),
    "mixed_p": ScenarioConfig("mixed_p", "uniform", "scheduler", True,
                              n_frames=30, seed=7, workload="mixed_edge"),
}


def _run(base: ScenarioConfig, policy: str) -> Runtime:
    rt = Runtime(replace(base, name=f"{base.name}_{policy}",
                         algorithm=policy))
    rt.run()
    return rt


@pytest.mark.parametrize("policy", registered_policies())
@pytest.mark.parametrize("base", sorted(BASES))
def test_every_task_reaches_exactly_one_terminal_state(base, policy):
    rt = _run(BASES[base], policy)
    hp_tasks = [f.hp_task for f in rt.frames if f.hp_task is not None]
    lp_tasks = [t for req in rt.requests for t in req.tasks]
    bad = [t for t in hp_tasks + lp_tasks if t.state not in TERMINAL]
    assert not bad, (
        f"{len(bad)} non-terminal task(s) after the run, e.g. "
        f"{bad[0].task_id} in state {bad[0].state} "
        f"(priority={bad[0].priority})")


@pytest.mark.parametrize("policy", registered_policies())
@pytest.mark.parametrize("base", sorted(BASES))
def test_summary_counts_partition_the_task_set(base, policy):
    rt = _run(BASES[base], policy)
    m = rt.metrics
    assert m.hp_generated == (
        m.hp_completed + m.hp_failed_alloc + m.hp_failed_runtime
    ), "HP counters do not partition the generated HP tasks"
    assert m.lp_generated == (
        m.lp_completed + m.lp_failed_alloc + m.lp_failed_runtime
        + m.realloc_failure
    ), "LP counters do not partition the generated LP tasks"
    # the summary exposes exactly these raw counts (the gate the goldens
    # replay), so the partition is auditable from the committed file too
    s = m.summary()
    for key in ("hp_completed", "hp_failed_alloc", "hp_failed_runtime",
                "lp_completed", "lp_failed_alloc", "lp_failed_runtime"):
        assert s[key] == getattr(m, key)


@pytest.mark.parametrize("policy", registered_policies())
@pytest.mark.parametrize("base", sorted(BASES))
def test_completed_census_matches_counters(base, policy):
    rt = _run(BASES[base], policy)
    m = rt.metrics
    hp_tasks = [f.hp_task for f in rt.frames if f.hp_task is not None]
    lp_tasks = [t for req in rt.requests for t in req.tasks]
    hp_done = sum(1 for t in hp_tasks if t.state == TaskState.COMPLETED)
    lp_done = sum(1 for t in lp_tasks if t.state == TaskState.COMPLETED)
    assert hp_done == m.hp_completed
    assert lp_done == m.lp_completed
    # census sanity: the generated counters match the object census
    assert len(hp_tasks) == m.hp_generated
    assert len(lp_tasks) == m.lp_generated
    assert all(t.priority == Priority.HIGH for t in hp_tasks)


# --------------------------------------------------------------------- #
# Streaming path: the shed bucket joins the partition                   #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("degrade", [False, True])
@pytest.mark.parametrize("shed", sorted(registered_shed_policies()))
@pytest.mark.parametrize("policy", ["scheduler", "edf_only"])
def test_streaming_partition_includes_shed_bucket(shed, policy, degrade):
    reset_id_counters()
    # degrade mode runs over the variant ladder (DESIGN.md §17): the
    # scheduler retries infeasible LP admissions down the ladder before
    # rejecting.  Degradation must never open a sixth terminal bucket —
    # a degraded task still ends COMPLETED / FAILED / shed like any other.
    eng = StreamingEngine(4, policy=policy, queue_capacity=16, shed=shed,
                          window=0.5,
                          workload="paper_ladder" if degrade else "paper",
                          policy_kwargs={"degrade": degrade})
    # paper-profile tasks at ~10x the rate 4 devices can sustain:
    # guarantees queue saturation, so every terminal bucket (including
    # shed) is hit
    cfg = FirehoseConfig(n_devices=4, rate=40.0, seed=13)
    report = eng.run(firehose(cfg, limit=1000))
    m = eng.metrics
    assert m.hp_shed + m.lp_shed > 0, "overload run must shed"
    assert m.hp_generated == (
        m.hp_completed + m.hp_failed_alloc + m.hp_failed_runtime + m.hp_shed
    ), "HP counters (with shed) do not partition the offered HP tasks"
    assert m.lp_generated == (
        m.lp_completed + m.lp_failed_alloc + m.lp_failed_runtime
        + m.realloc_failure + m.lp_shed
    ), "LP counters (with shed) do not partition the offered LP tasks"
    assert report["unresolved"] == 0
    assert report["in_flight"] == 0 and report["queued"] == 0
    # shed totals agree across the two accounting layers: Metrics counts
    # tasks, telemetry counts requests
    tel = eng.telemetry
    assert tel.shed_total == tel.shed_queue_full + tel.shed_expired
    assert tel.offered == m.hp_generated + m.lp_requests_total
    # accuracy accounting stays inside the partition: the accumulator
    # covers completed tasks only, each weighted by an accuracy in (0, 1]
    assert 0.0 <= m.lp_accuracy_completed <= m.lp_completed + 1e-9
    if not degrade:
        assert m.lp_degraded == 0 or shed == "degrade"
        assert not m.variant_admissions or shed == "degrade"


# --------------------------------------------------------------------- #
# Churn (DESIGN.md §16): orphans are absorbed, never a sixth bucket     #
# --------------------------------------------------------------------- #
def _run_with_churn(base: ScenarioConfig, policy: str) -> Runtime:
    """Run a scenario with device-lifecycle events pre-scheduled on the
    runtime's event queue: a hard failure mid-run, a drain, and a rejoin
    — driven through the same ``PolicyDispatcher.device_lost`` path the
    streaming engine uses, for EVERY registered policy (policies without
    calendars inherit the protocol's no-op lifecycle hooks)."""
    rt = Runtime(replace(base, name=f"{base.name}_{policy}_churn",
                         algorithm=policy))
    period = rt.net.frame_period
    n = base.n_frames
    rt.q.push(0.35 * n * period, lambda: rt.dispatcher.device_lost(1))
    rt.q.push(0.45 * n * period, lambda: rt.dispatcher.device_drained(2))
    rt.q.push(0.60 * n * period, lambda: rt.dispatcher.device_rejoined(1))
    rt.q.push(0.70 * n * period, lambda: rt.dispatcher.device_rejoined(2))
    rt.q.push(0.80 * n * period, lambda: rt.dispatcher.device_lost(0))
    rt.run()
    return rt


@pytest.mark.parametrize("policy", registered_policies())
@pytest.mark.parametrize("base", ["uniform_p", "weighted4_p"])
def test_churn_keeps_every_task_terminal(base, policy):
    rt = _run_with_churn(BASES[base], policy)
    hp_tasks = [f.hp_task for f in rt.frames if f.hp_task is not None]
    lp_tasks = [t for req in rt.requests for t in req.tasks]
    bad = [t for t in hp_tasks + lp_tasks if t.state not in TERMINAL]
    assert not bad, (
        f"{len(bad)} task(s) stranded non-terminal under churn, e.g. "
        f"{bad[0].task_id} in state {bad[0].state} "
        f"(priority={bad[0].priority})")


@pytest.mark.parametrize("policy", registered_policies())
@pytest.mark.parametrize("base", ["uniform_p", "weighted4_p"])
def test_churn_partition_has_no_orphan_bucket(base, policy):
    """Orphans land in the EXISTING buckets (recovered -> realloc_success
    then completed/failed at runtime; unrecoverable LP -> realloc_failure;
    non-re-admittable HP -> hp_failed_alloc): the partition equalities
    hold unchanged — orphans are not a sixth terminal bucket."""
    rt = _run_with_churn(BASES[base], policy)
    m = rt.metrics
    assert m.hp_generated == (
        m.hp_completed + m.hp_failed_alloc + m.hp_failed_runtime
    ), "HP counters do not partition the generated HP tasks under churn"
    assert m.lp_generated == (
        m.lp_completed + m.lp_failed_alloc + m.lp_failed_runtime
        + m.realloc_failure
    ), "LP counters do not partition the generated LP tasks under churn"
    if m.orphans_created:
        assert m.device_failures >= 1
        assert "orphans_created" in m.summary()


def test_settle_helper_registry_matches_the_audited_list():
    """The replint terminal-state registry and this suite co-evolve: a
    new settle helper must be certified here (its terminal transitions
    covered by the partition sweeps above) in the same change that
    registers it.  This pin makes forgetting one half a test failure."""
    from repro.analysis.rules.terminal_state import SETTLE_HELPERS
    audited = {
        "repro/core/policy.py": {
            "PolicyDispatcher.submit_hp",
            "PolicyDispatcher._account_lp",
            "PolicyDispatcher._violate",
            "PolicyDispatcher.task_finished",
            "CalendarPolicy.fail_device",         # orphan settle (PR 9)
            "EDFOnlyPolicy.decide_lp_batch",
            "EDFOnlyPolicy.reallocate",
        },
        "repro/core/scheduler.py": {
            "PreemptionAwareScheduler._reallocate_victims",
            "PreemptionAwareScheduler.allocate_low_priority",
            "PreemptionAwareScheduler.allocate_low_priority_batch",
            "PreemptionAwareScheduler.reallocate",
            "PreemptionAwareScheduler.settle_hp_orphans",  # orphan settle
        },
        "repro/core/workstealer.py": {
            "WorkstealingPolicy._kill_if_late",
            "WorkstealingPolicy._kick",
            "WorkstealingPolicy.finalize",
        },
    }
    assert {k: set(v) for k, v in SETTLE_HELPERS.items()} == audited


# --------------------------------------------------------------------- #
# Zero-churn differential: disabled churn is bit-identical to none      #
# --------------------------------------------------------------------- #
def test_disabled_churn_injector_runs_bit_identical_to_no_churn():
    """A ChurnConfig with every rate at zero yields an empty schedule
    (consuming zero randomness), and feeding that empty stream through
    ``run(churn=...)`` produces the byte-identical report of a run that
    never heard of churn — the goldens (regen_golden --check) therefore
    cover the churn-capable engine without regeneration."""
    from repro.sim.churn import ChurnConfig, ChurnInjector

    inj = ChurnInjector(ChurnConfig(n_devices=4))
    assert len(inj) == 0

    def go(churn):
        reset_id_counters()
        eng = StreamingEngine(4, queue_capacity=64, window=0.5)
        cfg = FirehoseConfig(n_devices=4, rate=10.0, seed=21)
        report = eng.run(firehose(cfg, limit=200), churn=churn)
        # wall-clock latency sketches are real time, not virtual
        report["metrics"] = {k: v for k, v in report["metrics"].items()
                             if not k.startswith("t_")}
        tel = report["telemetry"]
        for key in ("admission_latency_s",):
            tel.pop(key, None)
        return report

    base, wired = go(None), go(iter(inj))
    assert base == wired
    assert "churn" not in base["telemetry"], \
        "zero-churn snapshots must keep their historic key set"


# --------------------------------------------------------------------- #
# Ladder-disabled differential: the degrade machinery with no ladder    #
# (or no degrade flag) is bit-identical to the pre-ladder engine        #
# --------------------------------------------------------------------- #
def test_degrade_mode_on_ladder_free_workload_is_bit_identical():
    """With a ladder-free workload, degrade-before-reject has no rungs to
    retry (``range(1, 1)`` is empty), so enabling the flag must replay
    byte-identically — the goldens therefore cover the ladder-capable
    engine without regeneration (same pattern as the zero-churn
    differential above)."""
    def go(degrade):
        reset_id_counters()
        eng = StreamingEngine(4, queue_capacity=64, window=0.5,
                              policy_kwargs={"degrade": degrade})
        cfg = FirehoseConfig(n_devices=4, rate=10.0, seed=21)
        report = eng.run(firehose(cfg, limit=200))
        report["metrics"] = {k: v for k, v in report["metrics"].items()
                             if not k.startswith("t_")}
        report["telemetry"].pop("admission_latency_s", None)
        return report

    base, laddered = go(False), go(True)
    assert base == laddered
    assert "variant_admissions" not in base["metrics"], \
        "ladder-free summaries must keep their historic key set"


def test_degrade_flag_on_ladder_free_scenario_is_bit_identical():
    """Closed-workload counterpart: ``ScenarioConfig(degrade=True)`` over
    the paper workload replays the golden path bit-for-bit."""
    def go(degrade):
        rt = _run(replace(BASES["weighted4_p"], degrade=degrade),
                  "scheduler")
        return {k: v for k, v in rt.metrics.summary().items()
                if not k.startswith("t_")}

    assert go(False) == go(True)


def test_degrade_shrink_on_ladder_free_equals_farthest_deadline():
    """The ``degrade_shrink`` victim policy ranks victims exactly like
    ``farthest_deadline`` and can never shrink a ladder-free victim
    (``plan_shrink`` finds no deeper rung), so on the paper workload the
    two victim policies replay bit-identically."""
    def go(victim_policy):
        rt = _run(replace(BASES["weighted4_p"],
                          victim_policy=victim_policy), "scheduler")
        return {k: v for k, v in rt.metrics.summary().items()
                if not k.startswith("t_")}

    assert go("farthest_deadline") == go("degrade_shrink")
