"""jit'd wrapper: model-layout adapter for the flash attention kernel.

The model keeps activations as [B, T, H, D]; the kernel wants [B, H, T, D].
``use_pallas=False`` falls back to the oracle (the default inside the model
on this CPU-only container; the kernel path is exercised by the tests in
interpret mode and is the TPU target).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention
from .ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "use_pallas",
                                   "interpret"))
def mha_attention(
    q: jax.Array,            # [B, T, H, D]
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if use_pallas:
        t = q.shape[1]
        bq = bk = max(16, min(128, t))
        if t % bq == 0:
            o = flash_attention(qt, kt, vt, causal=causal, window=window,
                                bq=bq, bk=bk, interpret=interpret)
            return o.transpose(0, 2, 1, 3)
    return attention_ref(qt, kt, vt, causal=causal,
                         window=window).transpose(0, 2, 1, 3)
