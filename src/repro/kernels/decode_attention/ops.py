"""jit'd wrapper: decode attention against the model's cache layout
([B, S, KV, D] + positions row), GQA-aware."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import decode_attention
from .ref import decode_attention_ref


@partial(jax.jit, static_argnames=("window", "use_pallas", "interpret"))
def cached_decode_attention(
    q: jax.Array,            # [B, 1, H, D] (model layout, one token)
    cache_k: jax.Array,      # [B, S, KV, D]
    cache_v: jax.Array,
    positions: jax.Array,    # [B, S]
    pos,                     # scalar
    *,
    window: int = 0,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    q1 = q[:, 0]
    s = cache_k.shape[1]
    if use_pallas and s % 128 == 0:
        o = decode_attention(q1, cache_k, cache_v, positions, pos,
                             window=window, block_s=128, interpret=interpret)
    else:
        o = decode_attention_ref(q1, cache_k, cache_v, positions, pos,
                                 window=window)
    return o[:, None]
