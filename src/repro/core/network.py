"""Communication model: message sizes, throughput and time-slot padding.

All constants are the paper's benchmarked values (§5):
  high-priority allocation message : 700 B
  low-priority allocation message  : 2250 B
  state update                     : 550 B
  preemption message               : 550 B
  input (image) transfer           : 21500 B
Throughput was measured with iperf3 at system start-up (~16.3 MB/s with
preemption run, ~18.78 MB/s without); communication slots are padded with the
measured network jitter, processing slots with the benchmark std-dev (§3).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import cached_property
from typing import Optional

# Device lifecycle states (churn plane, DESIGN.md §16).  The states and the
# fail_device / drain_device / rejoin_device mutation API live on the
# calendar layer's NetworkState (core/calendar.py), next to the calendars
# they clear; re-exported here because this module is the network model's
# public face.
from .calendar import DeviceLifecycle, NetworkState  # noqa: F401
from .profiles import PAPER_TYPE, TaskProfile, WorkloadSpec, get_workload


@dataclass(frozen=True)
class MessageSizes:
    hp_alloc: int = 700
    lp_alloc: int = 2250
    state_update: int = 550
    preempt: int = 550
    input_transfer: int = 21500


@dataclass(frozen=True)
class NetworkConfig:
    """Timing model shared by the scheduler and the simulator."""

    throughput_bps: float = 16.3e6          # bytes/s, measured at start-up
    jitter_pad_s: float = 0.002             # comm slot padding (network jitter)
    msg: MessageSizes = field(default_factory=MessageSizes)

    # Benchmarked processing times on the RPi2B (§5) and their slot padding
    # (std-dev of the offline benchmark runs, §3).
    t_object_detect: float = 0.100          # stage 1, constant overhead
    t_hp: float = 0.980                     # stage 2, 1 core
    t_lp_2core: float = 16.862              # stage 3, 2-core horizontal split
    t_lp_4core: float = 11.611              # stage 3, 4-core horizontal split
    hp_pad_s: float = 0.050
    lp_pad_s: float = 0.400

    # Pipeline cadence (§5): derived from the minimum viable end-to-end time.
    frame_period: float = 18.86
    # HP deadline slack beyond detect+proc (paper: stage-2 deadline ~1 s;
    # must cover allocation + preemption-selection latency, §6.3).
    hp_deadline_slack: float = 0.45

    # Controller job-queue latencies (paper §3.3: blocking sequential request
    # processing; §6.3: HP alloc ~10 ms, LP alloc ~150 ms, preemption +
    # reallocation pushing HP paths toward ~300-400 ms under load).
    ctrl_hp_alloc_lat: float = 0.010
    ctrl_hp_preempt_extra: float = 0.040
    ctrl_lp_alloc_lat: float = 0.150
    ctrl_realloc_lat: float = 0.250

    # Contention-induced slowdown (paper §8 reports the 11.611 s benchmarked
    # 4-core task averaging ~14.5 s under middleware + concurrent-DNN load).
    # The paper's own 18.86 s frame period is derived so a 2-core task barely
    # fits its window, so the benchmarked times must already include typical
    # co-location; we model only *additional* contention, mildly:
    # exec = base * (1 + coef * other_busy_cores/capacity).
    lp_contention_coef: float = 0.05
    hp_contention_coef: float = 0.03

    # Heterogeneous workloads (core/profiles.py): a WorkloadSpec mapping task
    # types to per-(type x core config) benchmark profiles.  None (the
    # default) derives a single-profile spec from the paper constants above —
    # bit-for-bit the seed's timing model.
    workload: Optional[WorkloadSpec] = None

    @cached_property
    def spec(self) -> WorkloadSpec:
        """The active workload spec (derived from the paper constants when
        no explicit ``workload`` was given)."""
        if self.workload is not None:
            return self.workload
        return WorkloadSpec.from_paper_constants(
            t_hp=self.t_hp,
            hp_pad_s=self.hp_pad_s,
            t_lp_2core=self.t_lp_2core,
            t_lp_4core=self.t_lp_4core,
            lp_pad_s=self.lp_pad_s,
            input_bytes=self.msg.input_transfer,
            output_bytes=self.msg.state_update,
            hp_deadline_slack=self.hp_deadline_slack,
        )

    def profile(self, task_type: Optional[str] = None) -> TaskProfile:
        """The benchmark profile for a task type (None -> default type)."""
        return self.spec.profile(task_type)

    def profile_for(self, task) -> TaskProfile:
        """The profile a task actually runs at: its type's ladder rung
        selected by ``task.variant`` (DESIGN.md §17).  Variant 0 — every
        golden path — resolves to the base profile bit-identically."""
        prof = self.spec.profile(task.task_type)
        return prof.variant_profile(task.variant) if task.variant else prof

    def slot(self, n_bytes: int) -> float:
        """Duration of a padded link time-slot for an n-byte message."""
        return n_bytes / self.throughput_bps + self.jitter_pad_s

    def input_transfer_slot(self, task_type: Optional[str] = None) -> float:
        """Padded link-slot duration of one offload input transfer."""
        return self.slot(self.profile(task_type).input_bytes)

    def hp_proc_time(self, task_type: Optional[str] = None) -> float:
        return self.profile(task_type).hp_exec

    def lp_proc_time(self, cores: int,
                     task_type: Optional[str] = None) -> float:
        return self.profile(task_type).lp_proc_time(cores)

    def lp_slot_time(self, cores: int,
                     task_type: Optional[str] = None) -> float:
        return self.profile(task_type).lp_slot_time(cores)

    @property
    def hp_slot_time(self) -> float:
        return self.profile().hp_slot_time

    def hp_slot_time_for(self, task_type: Optional[str] = None) -> float:
        return self.profile(task_type).hp_slot_time

    @property
    def lp_core_options(self) -> tuple[int, ...]:
        """Viable horizontal-partitioning configs, minimum first (§3.2)."""
        return self.profile().core_options

    def lp_core_options_for(
        self, task_type: Optional[str] = None
    ) -> tuple[int, ...]:
        return self.profile(task_type).core_options

    def hp_deadline(self, request_time: float,
                    task_type: Optional[str] = None) -> float:
        return self.profile(task_type).hp_deadline(request_time)


def resolve_network(net: Optional[NetworkConfig],
                    workload_name: str) -> NetworkConfig:
    """The one place a runtime reconciles an (optional) explicit
    ``NetworkConfig`` with a scenario's named workload.

    * ``net is None``: build the config for the workload — ``"paper"``
      derives the spec from the config's own constants (so custom constants
      keep working), any other name resolves through the registry.
    * explicit ``net``: it wins (its constants AND its spec), but it must be
      able to answer every task type the named workload will generate —
      a mixed scenario handed a single-model net fails HERE with a clear
      error instead of deep inside the event loop when the first typed
      task asks for its profile.
    """
    if net is None:
        spec = (None if workload_name == PAPER_TYPE
                else get_workload(workload_name))
        return NetworkConfig(workload=spec)
    if workload_name != PAPER_TYPE:
        want = get_workload(workload_name)
        missing = [t for t in want.task_types if t not in net.spec.profiles]
        if missing:
            raise ValueError(
                f"explicit NetworkConfig carries workload "
                f"{net.spec.name!r}, which lacks task type(s) {missing} "
                f"required by scenario workload {workload_name!r}; pass "
                f"NetworkConfig(workload=get_workload({workload_name!r})) "
                "or drop the explicit net"
            )
    return net
