"""Corpus: pallas indices written the supported way."""
from jax.experimental import pallas as pl


def kernel(q_ref, o_ref, s, bk):
    row = pl.load(q_ref, (pl.ds(0, 1), pl.ds(0, 4)))        # good
    pl.store(o_ref, (pl.ds(s * bk, bk), slice(None)), row)  # good: arithmetic
    return pl.load(q_ref, (s + 1, pl.ds(0, 4)))             # good: not a literal
