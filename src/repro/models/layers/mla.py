"""Multi-head Latent Attention (DeepSeek-V2 arXiv:2405.04434, V3 2412.19437).

KV is compressed into a rank-``kv_lora_rank`` latent ``c_kv`` plus a single
shared RoPE key ``k_rope``; only those are cached (the MLA serving win: the
cache is ~(kv_rank + rope_dim) per token instead of 2 * H * head_dim).

Prefill uses the naive (expanded) form.  Decode uses the *absorbed* form:
W_uk is folded into the query and W_uv into the output projection, so
attention runs directly against the compressed cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..config import ModelConfig
from .common import apply_rope, dense_init, masked_softmax, rmsnorm, rmsnorm_axes, \
    rmsnorm_init, rope_cos_sin
from .attention import causal_mask


def mla_init(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.mla
    assert m is not None
    d, h = cfg.d_model, cfg.n_heads
    qd = m.nope_head_dim + m.rope_head_dim
    keys = jax.random.split(key, 8)
    p: dict = {}
    if m.q_lora_rank:
        p["wdq"] = dense_init(keys[0], d, m.q_lora_rank, dtype=dtype)
        p["q_norm"] = rmsnorm_init(m.q_lora_rank, dtype)
        p["wuq"] = dense_init(keys[1], m.q_lora_rank, h, qd, dtype=dtype)
    else:
        p["wq"] = dense_init(keys[1], d, h, qd, dtype=dtype)
    p["wdkv"] = dense_init(keys[2], d, m.kv_lora_rank + m.rope_head_dim, dtype=dtype)
    p["kv_norm"] = rmsnorm_init(m.kv_lora_rank, dtype)
    p["wuk"] = dense_init(keys[3], m.kv_lora_rank, h, m.nope_head_dim, dtype=dtype)
    p["wuv"] = dense_init(keys[4], m.kv_lora_rank, h, m.v_head_dim, dtype=dtype)
    p["wo"] = dense_init(keys[5], h * m.v_head_dim, d, dtype=dtype)
    return p


def mla_axes(cfg: ModelConfig) -> dict:
    m = cfg.mla
    assert m is not None
    a: dict = {}
    if m.q_lora_rank:
        a["wdq"] = ("embed", "q_rank")
        a["q_norm"] = rmsnorm_axes("q_rank")
        a["wuq"] = ("q_rank", "heads", "head_dim")
    else:
        a["wq"] = ("embed", "heads", "head_dim")
    a["wdkv"] = ("embed", "kv_rank_rope")
    a["kv_norm"] = rmsnorm_axes("kv_rank")
    a["wuk"] = ("kv_rank", "heads", "head_dim")
    a["wuv"] = ("kv_rank", "heads", "head_dim")
    a["wo"] = ("heads_flat", "embed")
    return a


def init_mla_cache(batch: int, length: int, cfg: ModelConfig, dtype) -> dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, length, m.kv_lora_rank), dtype=dtype),
        "k_rope": jnp.zeros((batch, length, m.rope_head_dim), dtype=dtype),
        "positions": jnp.full((batch, length), -1, dtype=jnp.int32),
    }


def mla_cache_axes() -> dict:
    return {
        "c_kv": ("batch", "cache", "kv_rank"),
        "k_rope": ("batch", "cache", "rope_dim"),
        "positions": ("batch", "cache"),
    }


def _queries(params: dict, x: jax.Array, cfg: ModelConfig, positions) -> tuple:
    """Return (q_nope [B,T,H,nd], q_rope [B,T,H,rd])."""
    m = cfg.mla
    if "wdq" in params:
        cq = rmsnorm(params["q_norm"], jnp.einsum("btd,dr->btr", x, params["wdq"]),
                     cfg.norm_eps)
        q = jnp.einsum("btr,rhk->bthk", cq, params["wuq"])
    else:
        q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim:]
    cos, sin = rope_cos_sin(positions, m.rope_head_dim, cfg.rope_theta)
    return q_nope, apply_rope(q_rope, cos, sin)


def _compress(params: dict, x: jax.Array, cfg: ModelConfig, positions) -> tuple:
    """Return (c_kv [B,S,R] normalised, k_rope [B,S,rd] roped)."""
    m = cfg.mla
    dkv = jnp.einsum("btd,dr->btr", x, params["wdkv"])
    c_kv = rmsnorm(params["kv_norm"], dkv[..., : m.kv_lora_rank], cfg.norm_eps)
    k_rope = dkv[..., m.kv_lora_rank:]
    cos, sin = rope_cos_sin(positions, m.rope_head_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return c_kv, k_rope


def mla_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    window: int = 0,
    cache: Optional[dict] = None,
) -> tuple[jax.Array, Optional[dict]]:
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.n_heads
    scale = jnp.asarray(m.nope_head_dim + m.rope_head_dim, jnp.float32) ** -0.5
    q_nope, q_rope = _queries(params, x, cfg, positions)

    if cache is None:
        # ---- naive (expanded) prefill form ------------------------------- #
        c_kv, k_rope = _compress(params, x, cfg, positions)
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wuk"])
        v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wuv"])
        scores = (
            jnp.einsum("bthk,bshk->bhts", q_nope, k_nope)
            + jnp.einsum("bthk,bsk->bhts", q_rope, k_rope)
        ) * scale
        mask = causal_mask(positions, positions, window)[None, None]
        w = masked_softmax(scores, mask)
        out = jnp.einsum("bhts,bshk->bthk", w.astype(v.dtype), v)
        y = jnp.einsum("bte,ed->btd", out.reshape(b, t, h * m.v_head_dim),
                       params["wo"])
        return y, None

    # ---- absorbed decode form (T == 1) ----------------------------------- #
    pos = positions[-1]
    cache_len = cache["c_kv"].shape[1]
    c_new, kr_new = _compress(params, x, cfg, positions)
    slot = jnp.where(window > 0, pos % cache_len, pos)
    new_cache = {
        "c_kv": jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_new, slot, 1),
        "k_rope": jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr_new, slot, 1),
        "positions": jax.lax.dynamic_update_slice_in_dim(
            cache["positions"], jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32),
            slot, 1),
    }
    c_kv, k_rope, stored = (
        new_cache["c_kv"], new_cache["k_rope"], new_cache["positions"]
    )
    # Absorb W_uk into q: q_abs [B,T,H,R]
    q_abs = jnp.einsum("bthk,rhk->bthr", q_nope, params["wuk"])
    scores = (
        jnp.einsum("bthr,bsr->bhts", q_abs, c_kv)
        + jnp.einsum("bthk,bsk->bhts", q_rope, k_rope)
    ) * scale
    valid = (stored >= 0) & (stored <= pos)
    if window > 0:
        valid &= stored > pos - window
    w = masked_softmax(scores, valid[:, None, None, :])
    ctx = jnp.einsum("bhts,bsr->bthr", w.astype(c_kv.dtype), c_kv)  # [B,1,H,R]
    out = jnp.einsum("bthr,rhk->bthk", ctx, params["wuv"])
    y = jnp.einsum("bte,ed->btd", out.reshape(b, t, h * m.v_head_dim), params["wo"])
    return y, new_cache
