"""Satellite: LP-sweep time-point dedup + the probe-counter hooks.

The scheduler's grid can receive the same completion instant from several
sources — multiple devices completing together, batch-created allocations
landing exactly on an existing grid point, upgrades re-pushing ends.  A
repeated time-point re-derives identical link windows and placement
answers, so skipping exact duplicates is provably decision-neutral; these
tests prove it empirically (identical decisions with the dedup disabled)
and show the probe counters registering the saved grid traffic.
"""
import pytest

from repro.core.calendar import NetworkState
from repro.core.network import NetworkConfig
from repro.core.scheduler import PreemptionAwareScheduler
from repro.core.task import LowPriorityRequest, Priority, Task, reset_id_counters


def _mk_sched(n_devices=4, dedup=True):
    net = NetworkConfig()
    state = NetworkState(n_devices)
    sched = PreemptionAwareScheduler(state, net, preemption=False)
    sched._dedup_grid = dedup
    return net, state, sched


def _placements(results):
    return [
        sorted((a.task.task_id, a.device, a.t_start, a.t_end, a.cores,
                a.offloaded) for a in res.allocations)
        + sorted(t.task_id for t in res.failed)
        for res in results
    ]


def test_dedup_iterator_skips_exact_duplicates_and_counts():
    _, _, sched = _mk_sched()
    out = list(sched._dedup(iter([1.0, 1.0, 2.0, 2.0, 2.0, 3.0])))
    assert out == [1.0, 2.0, 3.0]
    assert sched.grid_dups_skipped == 3


def test_probe_counters_track_sweep_work():
    net, state, sched = _mk_sched()
    req = LowPriorityRequest(source_device=0, deadline=120.0, frame_id=0,
                             n_tasks=2)
    req.make_tasks()
    res = sched.allocate_low_priority(req, 0.0)
    assert len(res.allocations) == 2
    assert sched.lp_probes >= 2                 # one placement probe per task
    assert sched.grid_rounds >= 1


def test_batch_push_dedup_skips_duplicate_completion_point():
    """Engineer an allocation whose t_end equals (bit-for-bit) a completion
    point already in the batch grid; the push-side dedup must skip it,
    counting the saved push, without changing any decision."""
    reset_id_counters()
    net, state, sched = _mk_sched(n_devices=4)
    # Predict the first batch allocation exactly: empty link, now=0 ->
    # msg slot at 0, local placement on the source device.
    msg_dur = net.slot(net.msg.lp_alloc)
    t_end = msg_dur + net.lp_slot_time(2)
    # A pre-existing reservation on ANOTHER device completing at that exact
    # instant puts the duplicate point into the initial grid.
    state.devices[3].reserve(1.0, t_end, 2, "preexisting")

    def run(dedup):
        reset_id_counters()
        net2, state2, sched2 = _mk_sched(n_devices=4, dedup=dedup)
        state2.devices[3].reserve(1.0, t_end, 2, "preexisting")
        reqs = []
        for i in range(3):
            r = LowPriorityRequest(source_device=i, deadline=120.0,
                                   frame_id=i, n_tasks=1)
            r.make_tasks()
            reqs.append(r)
        results = sched2.allocate_low_priority_batch(reqs, 0.0)
        return sched2, results

    sched_on, res_on = run(dedup=True)
    sched_off, res_off = run(dedup=False)
    # the engineered collision: the first allocation's (pre-upgrade) end hit
    # the pre-existing grid point bit-for-bit and its push was skipped
    assert sched_on.grid_dups_skipped >= 1
    assert sched_off.grid_dups_skipped == 0
    # ... decisions identical, with strictly less grid traffic
    assert _placements(res_on) == _placements(res_off)
    assert sched_on.grid_pushes < sched_off.grid_pushes
    assert sched_on.lp_probes <= sched_off.lp_probes


@pytest.mark.parametrize("seed", range(6))
def test_dedup_neutrality_on_random_batches(seed):
    """Randomized batches allocate identically with and without the dedup
    (exact-duplicate skipping can never change the sweep's outcome)."""
    import random

    rng = random.Random(400 + seed)
    spec = [(rng.randrange(6), 1 + rng.randrange(4),
             60.0 + 30.0 * rng.random()) for _ in range(12)]

    def run(dedup):
        reset_id_counters()
        net, state, sched = _mk_sched(n_devices=6, dedup=dedup)
        reqs = []
        for i, (src, n_tasks, dl) in enumerate(spec):
            r = LowPriorityRequest(source_device=src, deadline=dl,
                                   frame_id=i, n_tasks=n_tasks)
            r.make_tasks()
            reqs.append(r)
        results = sched.allocate_low_priority_batch(reqs, 0.0)
        return sched, results

    sched_on, res_on = run(True)
    sched_off, res_off = run(False)
    assert _placements(res_on) == _placements(res_off)
    assert sched_on.grid_pushes <= sched_off.grid_pushes
    assert sched_on.lp_probes == sched_off.lp_probes


def test_hp_path_untouched_by_counters():
    net, state, sched = _mk_sched()
    task = Task(priority=Priority.HIGH, source_device=0, deadline=1e6,
                frame_id=0)
    assert sched.allocate_high_priority(task, 0.0).success
    assert sched.lp_probes == 0 and sched.grid_rounds == 0
