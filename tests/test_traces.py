"""Trace generation reproduces the paper's Table 4 potential-task counts."""
import numpy as np
import pytest

from repro.sim.traces import TraceConfig, generate_trace, potential_counts

# Paper Table 4.
TABLE4 = {
    "uniform": (8640, 4320),
    "weighted_1": (9296, 4952),
    "weighted_2": (10372, 4915),
    "weighted_3": (12973, 4939),
    "weighted_4": (13941, 4901),
}


@pytest.mark.parametrize("name", list(TABLE4))
def test_table4_counts_within_tolerance(name):
    lp_target, hp_target = TABLE4[name]
    # average over seeds: expectation should match within sampling noise
    lps, hps = [], []
    for seed in range(5):
        tr = generate_trace(TraceConfig(name, seed=seed))
        c = potential_counts(tr)
        lps.append(c["potential_low_priority"])
        hps.append(c["potential_high_priority"])
    assert abs(np.mean(lps) - lp_target) / lp_target < 0.03
    assert abs(np.mean(hps) - hp_target) / hp_target < 0.03


def test_trace_shape_and_values():
    tr = generate_trace(TraceConfig("uniform", n_frames=100, n_devices=4))
    assert tr.shape == (100, 4)
    assert set(np.unique(tr)).issubset({-1, 0, 1, 2, 3, 4})


def test_trace_deterministic_per_seed():
    a = generate_trace(TraceConfig("weighted_3", seed=7))
    b = generate_trace(TraceConfig("weighted_3", seed=7))
    c = generate_trace(TraceConfig("weighted_3", seed=8))
    assert (a == b).all()
    assert (a != c).any()


def test_probabilities_normalised():
    for name in TABLE4:
        p = TraceConfig(name).probabilities()
        assert abs(p.sum() - 1.0) < 1e-9
        assert (p >= 0).all()


def test_trace_independent_of_pythonhashseed():
    """Regression: trace seeding once used hash(name) (PYTHONHASHSEED-
    randomised), silently changing every scenario's draw per process."""
    import subprocess
    import sys
    code = ("from repro.sim.traces import TraceConfig, generate_trace;"
            "import numpy as np;"
            "print(int(generate_trace(TraceConfig('uniform', 50, 4, 0)).sum()))")
    outs = set()
    for hs in ("0", "424242"):
        r = subprocess.run(
            [sys.executable, "-c", code],
            env={"PYTHONPATH": "src", "PYTHONHASHSEED": hs, "PATH": "/usr/bin:/bin"},
            capture_output=True, text=True, cwd="/root/repo")
        assert r.returncode == 0, r.stderr
        outs.add(r.stdout.strip())
    assert len(outs) == 1, outs
