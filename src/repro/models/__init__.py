from .config import (  # noqa: F401
    LayerDef,
    MambaConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    StageDef,
    XLSTMConfig,
)
from . import blocks, model, sharding  # noqa: F401
