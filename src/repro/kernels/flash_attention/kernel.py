"""Pallas TPU kernel: blocked online-softmax (flash) attention, causal or
sliding-window.

Grid: (B*H, nq).  Each program holds one Q block [bq, D] in VMEM plus the
full K/V for its head (streamed block-by-block with lax.fori_loop and
dynamic slices inside VMEM), carrying the online-softmax (m, l, acc) state in
registers.  bq and bk should be multiples of 128 on real TPUs so the QK^T
and PV matmuls are MXU-shaped; D is the head dim (lane-aligned at 128).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bk: int, causal: bool,
                  window: int, q_block: int):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)               # [bq, D]
    t_kv = k_ref.shape[1]
    bq, d = q.shape
    scale = d ** -0.5
    q_pos = qi * q_block + jax.lax.iota(jnp.int32, bq)

    nblocks = t_kv // bk

    def body(s, carry):
        m, l, acc = carry
        # Leading block axis indexed with pl.ds(0, 1) + squeeze, NOT a bare
        # Python int: interpret-mode discharge of pl.load rejects scalar int
        # indices ('int' object has no attribute 'shape').
        k = pl.load(k_ref, (pl.ds(0, 1), pl.ds(s * bk, bk), slice(None))
                    )[0].astype(jnp.float32)       # [bk, D]
        v = pl.load(v_ref, (pl.ds(0, 1), pl.ds(s * bk, bk), slice(None))
                    )[0].astype(jnp.float32)
        scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        k_pos = s * bk + jax.lax.iota(jnp.int32, bk)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
            if window > 0:
                mask &= k_pos[None, :] > q_pos[:, None] - window
        scores = jnp.where(mask, scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        p = jnp.exp(scores - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nblocks, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@partial(jax.jit,
         static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(
    q: jax.Array,                      # [B, H, T, D]
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, h, t, d = q.shape
    assert t % bq == 0 and t % bk == 0, "T must divide into blocks"
    qf = q.reshape(b * h, t, d)
    kf = k.reshape(b * h, t, d)
    vf = v.reshape(b * h, t, d)
    grid = (b * h, t // bq)
    out = pl.pallas_call(
        partial(_flash_kernel, bk=bk, causal=causal, window=window,
                q_block=bq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, t, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, t, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, t, d)
