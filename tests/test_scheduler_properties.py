"""Property tests: the scheduler's system invariants hold for arbitrary
interleaved HP/LP request streams (§4).

Invariants:
  I1  capacity: no device ever has core demand above its capacity.
  I2  deadlines: every committed allocation finishes by its task deadline.
  I3  link exclusivity: no two link reservations overlap (single shared AP).
  I4  priority: preemption only ever evicts LOW-priority tasks, and HP tasks
      always execute on their source device with exactly one core.
  I5  accounting: preemptions == metrics count; realloc successes+failures
      == number of victims.

(The seed repo used hypothesis here; the container image does not ship it,
so the streams are seeded-``random`` draws — same invariants.)
"""
import random

import pytest

from repro.core.calendar import NetworkState
from repro.core.network import NetworkConfig
from repro.core.scheduler import PreemptionAwareScheduler
from repro.core.task import LowPriorityRequest, Priority, Task

N_DEV = 4


def _random_events(rng: random.Random):
    n = rng.randint(1, 25)
    return [
        (
            rng.choice(["hp", "lp"]),
            rng.randrange(N_DEV),            # source device
            rng.uniform(0.0, 40.0),          # arrival offset
            rng.randint(1, 4),               # LP set size (ignored for HP)
        )
        for _ in range(n)
    ]


def _check_invariants(state: NetworkState, net: NetworkConfig) -> None:
    # I1 capacity
    for dev in state.devices:
        points = sorted({r.t1 for r in dev.reservations()}
                        | {r.t2 for r in dev.reservations()})
        for t1, t2 in zip(points, points[1:]):
            mid1, mid2 = t1 + 1e-9, t2 - 1e-9
            if mid1 < mid2:
                assert dev.max_usage(mid1, mid2) <= dev.capacity
    # I3 link exclusivity
    slots = sorted(state.link._res, key=lambda r: r.t1)
    for a, b in zip(slots, slots[1:]):
        assert a.t2 <= b.t1 + 1e-9, (a, b)


@pytest.mark.parametrize("preemption", [True, False])
@pytest.mark.parametrize("seed", range(20))
def test_scheduler_invariants_random_streams(seed, preemption):
    rng = random.Random(seed * 31 + preemption)
    events = _random_events(rng)
    state = NetworkState(N_DEV)
    net = NetworkConfig()
    sched = PreemptionAwareScheduler(state, net, preemption=preemption)
    m = sched.metrics
    victims = 0

    now = 0.0
    for kind, dev, dt, n in sorted(events, key=lambda e: e[2]):
        now = max(now, dt)
        if kind == "hp":
            task = Task(priority=Priority.HIGH, source_device=dev,
                        deadline=now + net.t_hp * 2 + 1.0, frame_id=0)
            res = sched.allocate_high_priority(task, now)
            if res.success:
                a = res.allocation
                # I4: local, single core; I2: deadline met
                assert a.device == dev and a.cores == 1
                assert a.t_end <= task.deadline + 1e-9
            for v in res.preempted:
                assert v.priority == Priority.LOW        # I4
            victims += len(res.preempted)
        else:
            req = LowPriorityRequest(
                source_device=dev, deadline=now + 80.0, frame_id=0,
                n_tasks=n)
            req.make_tasks()
            res = sched.allocate_low_priority(req, now)
            for a in res.allocations:
                assert a.t_end <= req.deadline + 1e-9    # I2
                assert a.cores in net.lp_core_options
        _check_invariants(state, net)

    assert m.preemptions == victims                      # I5
    assert m.realloc_success + m.realloc_failure == victims
    if not preemption:
        assert victims == 0


@pytest.mark.parametrize("seed", range(8))
def test_batch_admission_invariants_random_streams(seed):
    """The batch path upholds I1-I3 for random request bursts, and every
    task lands in exactly one of allocations/failed."""
    rng = random.Random(5000 + seed)
    state = NetworkState(N_DEV)
    net = NetworkConfig()
    sched = PreemptionAwareScheduler(state, net)
    now = rng.uniform(0.0, 10.0)
    reqs = []
    for _ in range(rng.randint(1, 12)):
        req = LowPriorityRequest(
            source_device=rng.randrange(N_DEV),
            deadline=now + rng.uniform(10.0, 90.0),
            frame_id=0, n_tasks=rng.randint(1, 4))
        req.make_tasks()
        reqs.append(req)
    results = sched.allocate_low_priority_batch(reqs, now)
    assert len(results) == len(reqs)
    for req, res in zip(reqs, results):
        assert len(res.allocations) + len(res.failed) == req.n_tasks
        for a in res.allocations:
            assert a.t_end <= req.deadline + 1e-9        # I2
            assert a.cores in net.lp_core_options
    _check_invariants(state, net)
