"""Workload-profile layer (core/profiles.py): the default spec must
reproduce the paper constants bit-for-bit, mixed specs must thread through
the whole stack, and the constructors/validators must fail loudly."""
import math
from dataclasses import replace

import pytest

from repro.core.network import NetworkConfig
from repro.core.profiles import (
    PAPER_TYPE,
    TaskProfile,
    WorkloadSpec,
    get_workload,
    registered_workloads,
    validate_workload_name,
)
from repro.serving.cost_model import CostModel, PhaseCost
from repro.sim.experiment import MIXED_SCENARIOS, SCENARIOS, ScenarioConfig, \
    run_scenario
from repro.sim.scenarios import LargeNConfig, generate_arrivals, run_large_n
from repro.sim.traces import TraceConfig, generate_trace, generate_type_trace


def _summary(metrics) -> dict:
    return {k: v for k, v in metrics.summary().items()
            if not k.startswith("t_")}


# --------------------------------------------------------------------- #
# Default-spec equivalence: the paper constants, bit-for-bit            #
# --------------------------------------------------------------------- #
def test_default_spec_mirrors_network_constants_exactly():
    net = NetworkConfig()
    prof = net.profile()
    assert prof.hp_exec == net.t_hp
    assert prof.hp_pad == net.hp_pad_s
    assert prof.lp_exec == {2: net.t_lp_2core, 4: net.t_lp_4core}
    assert prof.lp_pad == {2: net.lp_pad_s, 4: net.lp_pad_s}
    assert prof.input_bytes == net.msg.input_transfer
    assert prof.hp_deadline_slack == net.hp_deadline_slack
    assert net.lp_core_options == (2, 4)
    assert net.hp_slot_time == net.t_hp + net.hp_pad_s
    assert net.lp_slot_time(2) == net.t_lp_2core + net.lp_pad_s
    assert net.lp_slot_time(4) == net.t_lp_4core + net.lp_pad_s
    assert net.hp_deadline(10.0) == 10.0 + net.t_hp + net.hp_deadline_slack
    assert net.input_transfer_slot() == net.slot(net.msg.input_transfer)


def test_custom_constants_flow_into_derived_spec():
    net = NetworkConfig(t_hp=0.5, t_lp_2core=8.0, t_lp_4core=5.0,
                        lp_pad_s=0.1)
    assert net.profile().lp_exec == {2: 8.0, 4: 5.0}
    assert net.lp_slot_time(2) == 8.1
    assert net.hp_proc_time() == 0.5


@pytest.mark.parametrize("name", ["UPS", "WPS_4", "CPW", "DNPW"])
def test_explicit_paper_spec_reproduces_default_run(name):
    """Passing the paper WorkloadSpec explicitly must be indistinguishable
    from the derived default — the profile layer adds no arithmetic."""
    cfg = replace(SCENARIOS[name], n_frames=40)
    base = _summary(run_scenario(cfg))
    spec = WorkloadSpec.from_paper_constants()
    explicit = _summary(run_scenario(cfg, NetworkConfig(workload=spec)))
    assert base == explicit


# --------------------------------------------------------------------- #
# TaskProfile / WorkloadSpec validation                                 #
# --------------------------------------------------------------------- #
def test_profile_requires_lp_configs():
    with pytest.raises(ValueError, match="no LP core configurations"):
        TaskProfile("x", 1.0, 0.1, {}, {})


def test_profile_pad_configs_must_match():
    with pytest.raises(ValueError, match="lp_pad core configs"):
        TaskProfile("x", 1.0, 0.1, {2: 5.0}, {4: 0.1})


def test_profile_unknown_core_config_names_options():
    prof = TaskProfile("x", 1.0, 0.1, {2: 5.0, 4: 3.0}, {2: 0.1, 4: 0.1})
    with pytest.raises(ValueError, match=r"\[2, 4\]"):
        prof.lp_proc_time(3)


def test_profile_core_options_sorted_min_first():
    prof = TaskProfile("x", 1.0, 0.1, {8: 1.0, 2: 5.0, 4: 3.0},
                       {8: 0.1, 2: 0.1, 4: 0.1})
    assert prof.core_options == (2, 4, 8)
    assert prof.min_lp_slot_time == 5.1


def test_spec_unknown_task_type_names_available():
    spec = WorkloadSpec.from_paper_constants()
    with pytest.raises(ValueError, match="paper"):
        spec.profile("nope")


def test_spec_default_type_must_exist():
    prof = TaskProfile("a", 1.0, 0.1, {2: 5.0}, {2: 0.1})
    with pytest.raises(ValueError, match="default_type"):
        WorkloadSpec("w", {"a": prof}, default_type="b")


def test_spec_mix_weight_for_unknown_type_rejected():
    prof = TaskProfile("a", 1.0, 0.1, {2: 5.0}, {2: 0.1})
    with pytest.raises(ValueError, match="unknown task type"):
        WorkloadSpec("w", {"a": prof}, default_type="a", mix={"b": 1.0})


def test_partial_mix_shares_residual_equally():
    profs = {n: TaskProfile(n, 1.0, 0.1, {2: 5.0}, {2: 0.1})
             for n in ("a", "b", "c")}
    spec = WorkloadSpec("w", profs, default_type="a", mix={"a": 0.5})
    assert dict(spec.mix_weights()) == pytest.approx(
        {"a": 0.5, "b": 0.25, "c": 0.25})


def test_partial_mix_with_no_residual_rejected():
    profs = {n: TaskProfile(n, 1.0, 0.1, {2: 5.0}, {2: 0.1})
             for n in ("a", "b")}
    spec = WorkloadSpec("w", profs, default_type="a", mix={"a": 1.0})
    with pytest.raises(ValueError, match="residual"):
        spec.mix_weights()


def test_output_bytes_size_the_update_slot():
    """A profile's completion state-update is sized by ITS output_bytes,
    not the global msg.state_update (the paper profile's output_bytes IS
    msg.state_update, pinning the default world)."""
    from repro.core.calendar import NetworkState
    from repro.core.scheduler import PreemptionAwareScheduler
    from repro.core.task import LowPriorityRequest

    spec = WorkloadSpec.from_paper_constants().with_profile(
        TaskProfile("fat_out", 0.9, 0.05, {2: 16.0, 4: 11.0},
                    {2: 0.4, 4: 0.4}, output_bytes=550 * 40))
    net = NetworkConfig(workload=spec)
    sched = PreemptionAwareScheduler(NetworkState(2), net)

    def update_slot_len(task_type):
        req = LowPriorityRequest(source_device=0, deadline=100.0, frame_id=0,
                                 n_tasks=1, task_type=task_type)
        req.make_tasks()
        res = sched.allocate_low_priority(req, 0.0)
        upd = [s for s in res.allocations[0].link_slots
               if s.tag[0] == "update"]
        return upd[0].t2 - upd[0].t1

    assert update_slot_len(None) == pytest.approx(net.slot(550))
    assert update_slot_len("fat_out") == pytest.approx(net.slot(550 * 40))


def test_explicit_net_must_cover_scenario_workload():
    """A mixed scenario handed a single-model net fails loudly at setup,
    not deep inside the event loop (and run_large_n likewise)."""
    cfg = replace(MIXED_SCENARIOS["MPS"], n_frames=10)
    with pytest.raises(ValueError, match="lacks task type"):
        run_scenario(cfg, NetworkConfig())
    with pytest.raises(ValueError, match="lacks task type"):
        run_large_n(LargeNConfig(name="x", n_devices=4, duration=10.0,
                                 workload="mixed_edge"),
                    NetworkConfig())
    # a covering net is accepted
    from repro.core.profiles import get_workload as gw
    m = run_scenario(cfg, NetworkConfig(workload=gw("mixed_edge")))
    assert "task_types" in m.summary()


def test_mix_weights_normalised_and_deterministic():
    spec = get_workload("mixed_edge")
    weights = spec.mix_weights()
    assert weights == spec.mix_weights()
    assert math.isclose(sum(w for _, w in weights), 1.0)
    assert {t for t, _ in weights} == set(spec.task_types)


def test_workload_registry_round_trip():
    assert PAPER_TYPE in registered_workloads()
    assert "mixed_edge" in registered_workloads()
    with pytest.raises(ValueError, match="registered workloads"):
        validate_workload_name("nope")
    with pytest.raises(ValueError, match="registered workloads"):
        get_workload("nope")


def test_mixed_edge_profiles_have_distinct_deadlines():
    spec = get_workload("mixed_edge")
    assert spec.is_mixed and len(spec.task_types) == 3
    deadlines = {t: spec.profile(t).lp_deadline for t in spec.task_types}
    assert deadlines[PAPER_TYPE] is None          # frame-period fallback
    concrete = [d for d in deadlines.values() if d is not None]
    assert len(set(concrete)) == len(concrete) == 2
    # worst-case transfer drives the batch sweep's conservative skip
    assert spec.max_input_bytes_type == "detr_heavy"
    assert spec.min_lp_slot_time == spec.profile("mobile_lite").min_lp_slot_time


# --------------------------------------------------------------------- #
# from_cost_model: measured serving costs reach the scheduler           #
# --------------------------------------------------------------------- #
def _synthetic_cost() -> CostModel:
    cost = CostModel()
    cost.prefill[1] = PhaseCost(0.05, 0.005)
    cost.decode[2] = PhaseCost(0.02, 0.002)
    cost.decode[4] = PhaseCost(0.014, 0.0014)
    return cost


def test_from_cost_model_tabulates_per_degree_times():
    spec = WorkloadSpec.from_cost_model(_synthetic_cost(), lp_tokens=10)
    prof = spec.profile()
    assert prof.hp_exec == 0.05 and prof.hp_pad == 0.005
    assert prof.lp_exec == {2: 0.2, 4: 0.14}
    # per-degree padding: each degree's OWN std-dev (not degree 2's)
    assert prof.lp_pad[2] == pytest.approx(0.02)
    assert prof.lp_pad[4] == pytest.approx(0.014)
    assert prof.hp_deadline_slack == pytest.approx(0.025)


def test_from_cost_model_degree_subset_and_errors():
    spec = WorkloadSpec.from_cost_model(_synthetic_cost(), lp_tokens=5,
                                        degrees=(2,))
    assert spec.profile().core_options == (2,)
    with pytest.raises(ValueError, match="degree"):
        WorkloadSpec.from_cost_model(_synthetic_cost(), lp_tokens=5,
                                     degrees=(2, 8))


# --------------------------------------------------------------------- #
# Mixed workloads through the stack                                     #
# --------------------------------------------------------------------- #
def test_type_trace_deterministic_and_value_trace_unperturbed():
    tcfg = TraceConfig("uniform", 30, 4, 3)
    weights = get_workload("mixed_edge").mix_weights()
    types_a = generate_type_trace(tcfg, weights)
    types_b = generate_type_trace(tcfg, weights)
    assert (types_a == types_b).all()
    assert types_a.shape == (30, 4)
    assert set(types_a.ravel()) <= set(t for t, _ in weights)
    # the value stream must not depend on whether a type stream exists
    assert (generate_trace(tcfg) == generate_trace(tcfg)).all()


@pytest.mark.parametrize("name", sorted(MIXED_SCENARIOS))
def test_mixed_scenario_runs_all_types_end_to_end(name):
    m = run_scenario(replace(MIXED_SCENARIOS[name], n_frames=60))
    s = m.summary()
    assert "task_types" in s
    assert set(s["task_types"]) == {"paper", "mobile_lite", "detr_heavy"}
    for counts in s["task_types"].values():
        assert sum(counts.values()) > 0


def test_paper_scenario_summary_has_no_type_breakdown():
    m = run_scenario(replace(SCENARIOS["UPS"], n_frames=20))
    assert "task_types" not in m.summary()


def test_mixed_workload_unknown_name_rejected_early():
    with pytest.raises(ValueError, match="registered workloads"):
        ScenarioConfig("bad", "uniform", "scheduler", True, workload="nope")
    with pytest.raises(ValueError, match="registered workloads"):
        LargeNConfig(name="bad", workload="nope")


def test_large_n_mixed_arrivals_typed_and_default_untouched():
    base = LargeNConfig(name="t", n_devices=4, duration=30.0, seed=5)
    mixed = replace(base, workload="mixed_edge")
    plain = generate_arrivals(base)
    typed = generate_arrivals(mixed)
    # same seed => identical (t, device, set size) stream; types ride along
    assert [(a.t, a.device, a.n_lp_tasks) for a in plain] == \
        [(a.t, a.device, a.n_lp_tasks) for a in typed]
    assert all(a.task_type is None for a in plain)
    assert {a.task_type for a in typed} <= \
        {"paper", "mobile_lite", "detr_heavy"}
    assert len({a.task_type for a in typed}) > 1


def test_large_n_mixed_runs_end_to_end():
    cfg = LargeNConfig(name="mixed_small", n_devices=8, duration=25.0,
                       workload="mixed_edge", seed=2)
    s = run_large_n(cfg, batch_window=0.25)
    assert s["hp_admitted"] > 0
    assert s["lp_allocated"] > 0
