"""End-to-end behaviour of the reproduced system (reduced workloads)."""
import pytest

from repro.sim import SCENARIOS, ScenarioConfig, run_scenario


def small(name, **over):
    base = SCENARIOS[name]
    kw = dict(
        name=base.name, trace=base.trace, algorithm=base.algorithm,
        preemption=base.preemption, n_frames=200, seed=1,
    )
    kw.update(over)
    return ScenarioConfig(**kw)


@pytest.fixture(scope="module")
def ups():
    return run_scenario(small("UPS"))


@pytest.fixture(scope="module")
def unps():
    return run_scenario(small("UNPS"))


def test_preemption_rescues_high_priority(ups, unps):
    """Paper headline: ~99% HP completion with preemption vs ~72-82%."""
    assert ups.pct(ups.hp_completed, ups.hp_generated) > 97.0
    assert unps.pct(unps.hp_completed, unps.hp_generated) < 90.0


def test_preemption_increases_frames(ups, unps):
    assert ups.frames_completed >= unps.frames_completed


def test_preemption_costs_lp_per_request(ups, unps):
    """Preemption lowers LP set completion (paper §6.2/Fig 5)."""
    assert sum(unps.lp_request_fractions) / max(len(unps.lp_request_fractions), 1) >= \
        sum(ups.lp_request_fractions) / max(len(ups.lp_request_fractions), 1)


def test_preemption_generates_more_lp(ups, unps):
    """More HP completions spawn more LP tasks (paper Table 2)."""
    assert ups.lp_generated > unps.lp_generated


def test_no_preemption_means_no_preemptions(unps):
    assert unps.preemptions == 0
    assert unps.realloc_success == unps.realloc_failure == 0


def test_scheduler_beats_workstealers_on_frames():
    s = run_scenario(small("WPS_4"))
    d = run_scenario(small("DPW"))
    c = run_scenario(small("CPW"))
    assert s.frames_completed > d.frames_completed
    assert s.frames_completed > c.frames_completed


def test_workstealer_preemption_rescues_hp():
    d = run_scenario(small("DPW"))
    dn = run_scenario(small("DNPW"))
    assert d.pct(d.hp_completed, d.hp_generated) > 97.0
    assert dn.pct(dn.hp_completed, dn.hp_generated) < 95.0


def test_reallocation_rarely_succeeds(ups):
    """Paper Table 3: 0-2 successful reallocations per run."""
    assert ups.realloc_success <= 0.05 * max(ups.preemptions, 1) + 2


def test_metrics_accounting_consistent(ups):
    m = ups
    assert m.hp_completed + m.hp_failed_alloc + m.hp_failed_runtime <= \
        m.hp_generated
    assert m.lp_completed <= m.lp_allocated <= m.lp_generated
    assert m.lp_offloaded_completed <= m.lp_offloaded
    assert m.frames_completed <= m.frames_total


def test_determinism_same_seed():
    a = run_scenario(small("UPS"))
    b = run_scenario(small("UPS"))
    assert a.frames_completed == b.frames_completed
    assert a.preemptions == b.preemptions
    assert a.lp_completed == b.lp_completed
