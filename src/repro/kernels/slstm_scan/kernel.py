"""Pallas TPU kernel: sLSTM recurrent scan with the recurrent matrix R held
resident in VMEM across timesteps.

Motivation (EXPERIMENTS.md §Perf pair 2): the jnp `lax.scan` form re-streams
R ([4, dh, dh] — 4 MB at dh=512) from HBM every timestep: ~0.4 TB/step for
xlstm-1.3b train_4k, the dominant residual memory term after the pure-DP +
chunked-mLSTM changes.  A TPU kernel loads R once per (head, sequence) and
keeps the (h, c, n, m) state in VMEM scratch.

Grid: (H, n_t_blocks) — Pallas guarantees sequential grid iteration on TPU,
so the recurrent state lives in scratch refs that persist across the
t-block dimension.  Each program step streams one [B, Lb, 4, dh] slab of
input pre-activations through VMEM, runs Lb recurrent steps, and writes the
[B, Lb, dh] hidden-state slab.

Exponential-gating semantics match ``repro.models.layers.xlstm._slstm_step``
exactly (same stabiliser, same n-floor).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _slstm_kernel(wx_ref, r_ref, b_ref, o_ref,
                  h_ref, c_ref, n_ref, m_ref):
    tb = pl.program_id(1)

    @pl.when(tb == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.ones_like(n_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    r = r_ref[0].astype(jnp.float32)              # [4, dh, dh] — VMEM-resident
    bias = b_ref[0].astype(jnp.float32)           # [4, dh]
    lb = wx_ref.shape[2]

    def step(i, _):
        wx_t = wx_ref[0, :, i].astype(jnp.float32)        # [B, 4, dh]
        h = h_ref[...]
        rec = jnp.einsum("bk,gkj->bgj", h, r)             # [B, 4, dh]
        pre = wx_t + rec + bias[None]
        i_pre, f_pre = pre[:, 0], pre[:, 1]
        z_pre, o_pre = pre[:, 2], pre[:, 3]
        logf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(logf + m_ref[...], i_pre)
        i_eff = jnp.exp(i_pre - m_new)
        f_eff = jnp.exp(logf + m_ref[...] - m_new)
        z = jnp.tanh(z_pre)
        o = jax.nn.sigmoid(o_pre)
        c_new = f_eff * c_ref[...] + i_eff * z
        n_new = jnp.maximum(f_eff * n_ref[...] + i_eff, 1e-6)
        h_new = o * c_new / n_new
        h_ref[...] = h_new
        c_ref[...] = c_new
        n_ref[...] = n_new
        m_ref[...] = m_new
        o_ref[0, :, i] = h_new.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, lb, step, 0)


@partial(jax.jit, static_argnames=("block_t", "interpret"))
def slstm_scan(
    wx: jax.Array,             # [B, T, 4, H, dh] input pre-activations
    r: jax.Array,              # [4, H, dh, dh] recurrent weights
    b: jax.Array,              # [4, H, dh] bias
    *,
    block_t: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Returns hidden states hs [B, T, H, dh] (float32)."""
    bsz, t, four, h, dh = wx.shape
    assert four == 4
    n_pad = (-t) % block_t
    if n_pad:                  # padded steps run after every real step and
        wx = jnp.pad(wx, [(0, 0), (0, n_pad), (0, 0), (0, 0), (0, 0)])
    tp = t + n_pad
    # head-major layout so each program streams its own contiguous slabs
    wx_h = wx.transpose(3, 0, 1, 2, 4)                    # [H, B, T, 4, dh]
    r_h = r.swapaxes(0, 1)                                # [H, 4, dh, dh]
    b_h = b.swapaxes(0, 1)                                # [H, 4, dh]

    grid = (h, tp // block_t)
    out = pl.pallas_call(
        _slstm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bsz, block_t, 4, dh),
                         lambda i, j: (i, 0, j, 0, 0)),
            pl.BlockSpec((1, 4, dh, dh), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((1, 4, dh), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bsz, block_t, dh),
                               lambda i, j: (i, 0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((h, bsz, tp, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bsz, dh), jnp.float32),           # h
            pltpu.VMEM((bsz, dh), jnp.float32),           # c
            pltpu.VMEM((bsz, dh), jnp.float32),           # n
            pltpu.VMEM((bsz, dh), jnp.float32),           # m
        ],
        interpret=interpret,
    )(wx_h, r_h, b_h)
    return out[:, :, :t].transpose(1, 2, 0, 3)            # [B, T, H, dh]
