"""Time-slotted resource calendars for the shared link and device cores.

The controller allocates variable-length time-slots on every resource such
that no two tasks hold the same resource simultaneously (paper §3, "network
state").  The link is a unit-capacity resource; each edge device is a
capacity-C resource (C = 4 cores on the RPi2B).

Array-backed skyline rewrite (DESIGN.md §11)
--------------------------------------------
The seed implementation (kept as :mod:`repro.core.calendar_reference`)
answered every probe with an O(n) sweep over a flat reservation list.  PR 1
replaced it with coalesced piecewise-constant *skylines* stored in Python
lists, which made probes O(log n + window) but left two scaling sinks:

* every reservation still paid O(n) ``list.insert``/``del`` surgery on the
  breakpoint lists, and
* the LP algorithm still probed devices one at a time in Python — a full
  feasibility scan at 256+ devices was hundreds of interpreted method calls.

This module stores each skyline in **preallocated NumPy arrays** with
capacity doubling (``times``/``vals``, valid prefix length ``n``) and a
**buffered mutation log**: ``add`` appends a delta in O(1) and the next
query applies the whole buffer at once — a handful of deltas are spliced
in place (an O(n) C-level ``memmove`` instead of Python list surgery), a
large buffer (e.g. a pre-load burst) is merged in ONE vectorized rebuild
(``np.unique`` + ``np.add.at`` + ``cumsum``).  Queries are
``np.searchsorted`` point location plus C-level slice reductions, with a
per-segment prefix-sum array making ``integral`` O(1) after location.

On top of the per-device skylines sits :class:`_ProbePlane` — the
network-wide probe plane.  It mirrors every device's skyline into padded
2-D arrays (rows refreshed lazily via per-device dirty marks) so ONE
vectorized pass answers, for ALL devices at once:

* ``fits_mask(t1, t2, cores)``   — who can host this window,
* ``free_cores(t1, t2)``         — stacked free-core vector,
* ``loads(t1, t2)``              — stacked window loads (even spreading),
* ``earliest_fit(dur, t, c)``    — stacked first-fit starts (skip hints).

The scheduler consumes these vectors instead of looping devices in Python;
`argsort`/`argmin` replaces per-device comparisons.

Exactness contract (tests/test_calendar_equivalence.py,
tests/test_skyline_fuzz.py, tests/test_scenario_replay.py):

* all query answers are bit-identical to walking the coalesced skyline
  (returned instants are *existing breakpoints* or the query's own bounds,
  never derived arithmetic), so scheduling decisions replay byte-identical
  through the golden scenarios;
* ``times[:n]`` is strictly increasing with ``times[0] == -inf``; no two
  adjacent ``vals`` are equal (coalesced); the final segment always decays
  to 0 because every reservation is finite;
* after ``gc(now)``, answers are only defined for query windows with
  ``t >= now`` — this is also what makes :meth:`NetworkState.gc`'s lazy
  per-device skip exact: a device with no reservation ending at or before
  ``now`` is left untouched (its un-collapsed history is invisible to any
  legal query);
* EPS semantics match the reference: sub-EPS overlaps are ignored by
  queries, and ``earliest_slot`` accepts a gap of ``duration - EPS``.
"""
from __future__ import annotations

import enum
import heapq
import itertools
import math
from bisect import bisect_left
from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator, Optional

import numpy as np

from .task import Priority, Task

EPS = 1e-9
_INF = math.inf
_EMPTY_F = np.empty(0, dtype=np.float64)


class DeviceLifecycle(enum.Enum):
    """Churn lifecycle of one edge device (DESIGN.md §16).

    UP devices accept new placements.  DRAINING devices finish their
    in-flight reservations but take no new work.  DOWN devices are gone:
    the transition cleared their calendar and every in-flight reservation
    became an *orphan* (returned by :meth:`NetworkState.fail_device` for
    the recovery pass).  The integer values are the checkpoint encoding
    (checkpoint/lifecycle.py) — never reorder them.
    """

    UP = 0
    DRAINING = 1
    DOWN = 2


@dataclass
class Reservation:
    t1: float
    t2: float
    amount: int                    # cores (devices) or 1 (link)
    tag: object = None             # task id / message descriptor

    def overlaps(self, t1: float, t2: float) -> bool:
        return self.t1 < t2 - EPS and t1 < self.t2 - EPS


class _StepFn:
    """Coalesced piecewise-constant usage-over-time (the skyline).

    The live segments occupy ``times[lo:lo+n]`` / ``vals[lo:lo+n]`` of
    preallocated buffers — a *gap* layout with slack on BOTH sides.
    ``vals[lo+i]`` is the usage on ``[times[lo+i], times[lo+i+1])``; the
    last segment extends to +inf and ``times[lo]`` is always the −inf
    sentinel.  ``floor`` is the horizon set by :meth:`gc`: updates and
    queries are clamped to it, so collapsed history can never corrupt live
    segments.

    Why a gap layout: skyline mutations cluster near the *front* of the
    live window (new reservations start near controller time; gc trims
    exactly there).  An insert shifts whichever side is shorter — near the
    front that is a handful of elements instead of the whole tail — and
    :meth:`gc` collapses history by just advancing ``lo`` (O(1)).

    Mutations (``add``) buffer into ``_log`` and are applied by the next
    query: a small buffer is spliced segment-by-segment (C memmove of the
    short side), a big one (e.g. a pre-load burst) is merged in a single
    vectorized rebuild (``np.unique`` + ``np.add.at`` + ``cumsum``).
    """

    __slots__ = ("times", "vals", "lo", "n", "floor", "_log", "_aux_ok",
                 "_prefix")

    def __init__(self) -> None:
        self.times = np.full(16, _INF)
        self.vals = np.zeros(16, dtype=np.int64)
        self.lo = 4
        self.times[4] = -_INF
        self.n = 1
        self.floor = -_INF
        self._log: list[tuple[float, float, int]] = []
        self._aux_ok = False
        self._prefix = _EMPTY_F

    def _view(self) -> tuple[np.ndarray, np.ndarray]:
        lo, hi = self.lo, self.lo + self.n
        return self.times[lo:hi], self.vals[lo:hi]

    # -- updates --------------------------------------------------------- #
    def add(self, t1: float, t2: float, amount: int) -> None:
        """Add ``amount`` to the usage over [t1, t2) (negative to remove).
        O(1): buffered; applied by the next query/gc.

        A delta that exactly inverts one still buffered annihilates it
        instead — reserve-then-cancel churn (preemption victims, probe
        rollbacks) then never touches the arrays at all."""
        if t1 < self.floor:
            t1 = self.floor
        if t2 <= t1:
            return
        log = self._log
        if log:
            inv = (t1, t2, -amount)
            for k in range(len(log) - 1, max(len(log) - 9, -1), -1):
                if log[k] == inv:
                    del log[k]
                    return
        log.append((t1, t2, amount))

    def _flush(self) -> None:
        log = self._log
        if not log:
            return
        self._log = []
        self._aux_ok = False
        # Splice small buffers in place; a buffer big relative to the live
        # segment count (e.g. a pre-load burst) amortises better through the
        # single vectorized rebuild, whose cost is O((n + k) log(n + k)).
        if len(log) <= max(8, self.n // 16):
            for t1, t2, amount in log:
                self._apply_one(t1, t2, amount)
        else:
            self._rebuild(log)

    def _regap(self) -> None:
        """Re-centre the live window (and grow the buffers when cramped)."""
        n = self.n
        cap = self.times.shape[0]
        while cap < 2 * (n + 8):
            cap *= 2
        t = np.full(cap, _INF)
        v = np.zeros(cap, dtype=np.int64)
        lo = (cap - n) // 2
        t[lo : lo + n] = self.times[self.lo : self.lo + n]
        v[lo : lo + n] = self.vals[self.lo : self.lo + n]
        self.times, self.vals, self.lo = t, v, lo

    def _cut(self, t: float) -> int:
        """Ensure a breakpoint at exactly t; return its (global) index."""
        lo, n = self.lo, self.n
        times, vals = self.times, self.vals
        i = lo + int(times[lo : lo + n].searchsorted(t, side="right")) - 1
        if times[i] == t:
            return i
        hi = lo + n
        if i - lo < n // 2:                   # head side is shorter: shift it
            times[lo - 1 : i] = times[lo : i + 1]
            vals[lo - 1 : i] = vals[lo : i + 1]
            times[i] = t
            vals[i] = vals[i - 1]
            self.lo = lo - 1
            self.n = n + 1
            return i
        times[i + 2 : hi + 1] = times[i + 1 : hi]     # overlap-safe memmove
        vals[i + 2 : hi + 1] = vals[i + 1 : hi]
        times[i + 1] = t
        vals[i + 1] = vals[i]
        self.n = n + 1
        return i + 1

    def _delete_at(self, j: int) -> None:
        lo, n = self.lo, self.n
        if j - lo < n // 2:                   # shift the (shorter) head right
            self.times[lo + 1 : j + 1] = self.times[lo:j]
            self.vals[lo + 1 : j + 1] = self.vals[lo:j]
            self.lo = lo + 1
        else:
            hi = lo + n
            self.times[j : hi - 1] = self.times[j + 1 : hi]
            self.vals[j : hi - 1] = self.vals[j + 1 : hi]
            self.times[hi - 1] = _INF
        self.n = n - 1

    def _apply_one(self, t1: float, t2: float, amount: int) -> None:
        if self.lo < 2 or self.lo + self.n + 2 > self.times.shape[0]:
            self._regap()                     # room for two new breakpoints
        lo, n = self.lo, self.n
        times, vals = self.times, self.vals
        j = lo + int(times[lo : lo + n].searchsorted(t1, side="right"))
        # Fast path 1 — the interval lies strictly inside one segment (the
        # usual shape of a fresh reservation landing in a gap): splice both
        # breakpoints with a single shift; no coalescing is possible.
        if times[j - 1] != t1 and (j == lo + n or t2 < times[j]):
            v = int(vals[j - 1])
            hi = lo + n
            if j - lo <= n // 2:              # shift the (shorter) head
                times[lo - 2 : j - 2] = times[lo:j]
                vals[lo - 2 : j - 2] = vals[lo:j]
                self.lo = lo - 2
                j -= 2
            else:                             # shift the tail
                times[j + 2 : hi + 2] = times[j:hi]
                vals[j + 2 : hi + 2] = vals[j:hi]
            times[j] = t1
            times[j + 1] = t2
            vals[j] = v + amount
            vals[j + 1] = v
            self.n = n + 2
            return
        # Fast path 2 — the interval is exactly one existing segment (the
        # usual shape of a cancellation): adjust in place, then drop the
        # breakpoints that coalesce away.
        if times[j - 1] == t1 and j < lo + n and times[j] == t2:
            p = j - 1                         # the adjusted segment
            vals[p] += amount
            if vals[p + 1] == vals[p]:
                lo_pre = self.lo
                self._delete_at(p + 1)
                p += self.lo - lo_pre         # head-delete moved p right
            if self.lo < p and vals[p - 1] == vals[p]:
                self._delete_at(p)
            return
        i1 = self._cut(t1)
        lo_mid = self.lo
        i2 = self._cut(t2)                    # t2 > t1 => i2 > i1
        i1 -= lo_mid - self.lo                # 2nd cut's head-insert moved i1
        self.vals[i1:i2] += amount
        # Re-coalesce: only the two boundary pairs can merge — interior
        # neighbours moved by the same amount keep their inequality.
        if self.lo < i2 < self.lo + self.n and \
                self.vals[i2] == self.vals[i2 - 1]:
            lo_pre = self.lo
            self._delete_at(i2)
            i1 += self.lo - lo_pre            # head-delete moved i1 right
        if self.lo < i1 < self.lo + self.n and \
                self.vals[i1] == self.vals[i1 - 1]:
            self._delete_at(i1)

    def _rebuild(self, log: list[tuple[float, float, int]]) -> None:
        """Apply a whole mutation buffer in one vectorized merge."""
        old_t, old_v = self._view()
        t1s = np.fromiter((e[0] for e in log), np.float64, len(log))
        t2s = np.fromiter((e[1] for e in log), np.float64, len(log))
        amts = np.fromiter((e[2] for e in log), np.int64, len(log))
        bp = np.unique(np.concatenate((old_t, t1s, t2s)))
        base = old_v[np.searchsorted(old_t, bp, side="right") - 1]
        delta = np.zeros(bp.shape[0] + 1, dtype=np.int64)
        np.add.at(delta, np.searchsorted(bp, t1s), amts)
        np.subtract.at(delta, np.searchsorted(bp, t2s), amts)
        vals = base + np.cumsum(delta[:-1])
        keep = np.empty(bp.shape[0], dtype=bool)
        keep[0] = True
        np.not_equal(vals[1:], vals[:-1], out=keep[1:])
        bp, vals = bp[keep], vals[keep]
        m = bp.shape[0]
        cap = self.times.shape[0]
        while cap < 2 * (m + 8):
            cap *= 2
        t = np.full(cap, _INF)
        v = np.zeros(cap, dtype=np.int64)
        lo = (cap - m) // 2
        t[lo : lo + m] = bp
        v[lo : lo + m] = vals
        self.times, self.vals, self.lo, self.n = t, v, lo, m

    def gc(self, now: float) -> None:
        """Collapse all history before ``now`` into the sentinel segment —
        O(log n): the dead head is skipped by advancing ``lo``."""
        if now <= self.floor:
            return
        self._flush()
        self.floor = now
        lo, n = self.lo, self.n
        times = self.times
        i = lo + int(times[lo : lo + n].searchsorted(now, side="right")) - 1
        if i > lo:
            times[i] = -_INF        # segment covering ``now`` -> new sentinel
            self.lo = i
            self.n = n - (i - lo)
            self._aux_ok = False

    # -- queries --------------------------------------------------------- #
    def max_over(self, t1: float, t2: float) -> int:
        """Max usage over [t1, t2); 0 for empty windows."""
        if t2 <= t1:
            return 0
        self._flush()
        t, v = self._view()
        i1 = int(t.searchsorted(t1, side="right")) - 1
        i2 = int(t.searchsorted(t2, side="left"))
        return int(v[i1:i2].max())

    def exceeds(self, t1: float, t2: float, limit: int) -> bool:
        """True iff usage ever exceeds ``limit`` on [t1, t2)."""
        if t2 <= t1:
            return False
        self._flush()
        t, v = self._view()
        i1 = int(t.searchsorted(t1, side="right")) - 1
        i2 = int(t.searchsorted(t2, side="left"))
        return bool(v[i1:i2].max() > limit)

    def _aux(self) -> np.ndarray:
        """Per-segment prefix sums of usage mass (``integral`` in O(1)).

        ``_prefix[j]`` is the total usage-seconds of (window-local) segments
        0..j-1.  The sentinel segment (start −inf) and the final segment
        (end +inf, usage 0 by invariant) contribute 0, keeping the sums
        finite; boundary segments of a query window are corrected exactly
        in `integral`.
        """
        if self._aux_ok:
            return self._prefix
        t, v = self._view()
        n = self.n
        c = np.zeros(n)
        if n > 2:
            c[1 : n - 1] = v[1 : n - 1] * (t[2:] - t[1 : n - 1])
        self._prefix = np.concatenate(([0.0, 0.0], np.cumsum(c[1:])))
        self._aux_ok = True
        return self._prefix

    def integral(self, t1: float, t2: float) -> float:
        """Usage-seconds over [t1, t2) (the ``load`` of the window)."""
        if t2 <= t1:
            return 0.0
        self._flush()
        t, v = self._view()
        i1 = int(t.searchsorted(t1, side="right")) - 1
        i2 = int(t.searchsorted(t2, side="left"))
        if i2 - i1 == 1:                       # window inside one segment
            return float(v[i1] * (t2 - t1))
        p = self._aux()
        return float(
            v[i1] * (t[i1 + 1] - t1)                   # left boundary clip
            + (p[i2 - 1] - p[i1 + 1])                  # full interior segs
            + v[i2 - 1] * (t2 - t[i2 - 1])             # right boundary clip
        )

    def window_profile(self, t1: float, t2: float) -> tuple[np.ndarray, np.ndarray]:
        """The skyline restricted to [t1, t2): parallel ``(starts, vals)``
        arrays where ``vals[i]`` holds on ``[starts[i], starts[i+1])`` and
        the last segment runs to ``t2``.  ``starts[0] == t1`` exactly; an
        empty window returns two empty arrays.  Feeds the preemption
        plane's incremental refit grid (scheduler ``_HPWindowGrid``)."""
        if t2 <= t1:
            return _EMPTY_F, np.empty(0, dtype=np.int64)
        self._flush()
        t, v = self._view()
        i1 = int(t.searchsorted(t1, side="right")) - 1
        i2 = int(t.searchsorted(t2, side="left"))
        starts = t[i1:i2].copy()
        starts[0] = t1
        return starts, v[i1:i2].copy()

    def first_fit(self, duration: float, not_before: float, limit: int) -> float:
        """Earliest t >= not_before with usage <= limit over [t, t+duration).

        A *run* of consecutive segments all at or below ``limit`` hosts the
        slot if its total span reaches ``duration - EPS``; candidate starts
        are ``t`` itself and the first segment after each blocked one.

        The common case — the slot fits within the first few segments past
        ``not_before`` — resolves in a short scalar walk; only a genuinely
        congested horizon falls through to the vectorized run search.
        """
        if limit < 0:
            return _INF                        # cores can never fit
        self._flush()
        times, vals = self._view()
        n = self.n
        t = not_before if not_before > self.floor else self.floor
        i = int(times.searchsorted(t, side="right")) - 1
        # scalar fast path over the next few segments
        cand = t
        for _ in range(6):
            if vals[i] > limit:
                i += 1
                if i >= n:                    # unreachable: tail is free
                    return float(cand)
                cand = float(times[i])
            else:
                seg_end = float(times[i + 1]) if i + 1 < n else _INF
                if seg_end - cand >= duration - EPS:
                    return float(cand)
                i += 1
                if i >= n:
                    return float(cand)
        # vectorized run search over the whole tail (recomputes the walked
        # prefix — correctness needs the run containing ``t`` intact)
        i = int(times.searchsorted(t, side="right")) - 1
        v = vals[i:n]
        bad = np.flatnonzero(v > limit)
        if bad.size == 0:                      # whole tail free (ends +inf)
            return t
        if bad[0] != 0 and times[i + bad[0]] - t >= duration - EPS:
            return t                           # fits in the current run
        starts = times[i + bad + 1]            # run starts after each block
        ends = np.empty(bad.size)
        ends[:-1] = times[i + bad[1:]]
        ends[-1] = _INF                        # final run extends forever
        ok = ends - starts >= duration - EPS
        if bad.size > 1:                       # adjacent blocks: not a run
            ok[:-1] &= bad[1:] != bad[:-1] + 1
        return float(starts[int(np.argmax(ok))])


class LinkCalendar:
    """Unit-capacity calendar for the shared wireless link.

    ``earliest_slot`` is an O(log n + runs) skyline probe; ``gc`` retires
    only the slots that expired since the previous call (expiry min-heap).
    """

    def __init__(self) -> None:
        self._starts: list[float] = []          # sorted by t1, parallel to
        self._res: list[Reservation] = []       # the live reservation list
        self._expiry: list[tuple[float, int, Reservation]] = []
        self._seq = itertools.count()
        self._sky = _StepFn()

    def __len__(self) -> int:
        return len(self._res)

    def reservations(self) -> Iterable[Reservation]:
        return iter(self._res)

    def earliest_slot(self, duration: float, not_before: float) -> float:
        """Earliest t >= not_before such that [t, t+duration) is free."""
        return self._sky.first_fit(duration, not_before, 0)

    def usage_segments(self, t1: float, t2: float) -> tuple[np.ndarray, np.ndarray]:
        """Raw link-occupancy segments over [t1, t2) as ``(starts, vals)``
        arrays — NO EPS shrink, same contract as
        :meth:`DeviceCalendar.usage_segments`.  Zero-valued segments are
        free link time; the placement oracle (core/oracle.py) reads these
        to price transfer feasibility."""
        return self._sky.window_profile(t1, t2)

    def reserve(self, t1: float, t2: float, tag: object = None) -> Reservation:
        r = Reservation(t1, t2, 1, tag)
        idx = bisect_left(self._starts, t1)
        self._starts.insert(idx, t1)
        self._res.insert(idx, r)
        self._sky.add(t1, t2, 1)
        heapq.heappush(self._expiry, (t2, next(self._seq), r))
        return r

    def reserve_earliest(
        self, duration: float, not_before: float, tag: object = None
    ) -> Reservation:
        t1 = self.earliest_slot(duration, not_before)
        return self.reserve(t1, t1 + duration, tag)

    def _locate(self, res: Reservation) -> int:
        """Index of ``res`` in the live list, -1 if absent (O(log n + dups))."""
        idx = bisect_left(self._starts, res.t1)
        while idx < len(self._res) and self._starts[idx] == res.t1:
            if self._res[idx] is res or self._res[idx] == res:
                return idx
            idx += 1
        return -1

    def cancel(self, res: Reservation) -> None:
        """Remove a reservation; cancelling twice (or a foreign/expired slot)
        is a no-op."""
        idx = self._locate(res)
        if idx < 0:
            return
        r = self._res[idx]
        del self._res[idx]
        del self._starts[idx]
        self._sky.add(r.t1, r.t2, -1)

    def gc(self, now: float) -> None:
        """Retire slots with t2 <= now.  Amortised O(log n) per dead slot."""
        heap = self._expiry
        while heap and heap[0][0] <= now:
            _, _, r = heapq.heappop(heap)
            idx = self._locate(r)
            if idx >= 0 and self._res[idx].t2 <= now:
                del self._res[idx]
                del self._starts[idx]
        self._sky.gc(now)


class _LPMirror:
    """Array mirror of one device's LP-tagged reservations — the preemption
    plane's conflict-candidate columns.

    The HP eviction loop used to rebuild a Python list of conflicting LP
    reservations per iteration (O(reservations) interpreted work per
    victim).  This mirror keeps the candidates as stacked NumPy columns
    (``t1`` / ``t2`` / ``amount`` over rows ``[0, m)`` plus a parallel
    ``tasks`` ref list), so conflict enumeration is ONE overlap mask and
    victim ranking ONE masked argmin per iteration.

    Exactness contract (tests/test_preemption_plane.py):

    * rows preserve the ``DeviceCalendar._res`` dict's insertion order — a
      re-reserved tag moves to the END, exactly like the dict — so a masked
      first-tie argmin reproduces ``min()``-over-iteration tie-breaks
      bit-for-bit;
    * the mirror is synced by the calendar's own mutation hooks (reserve /
      release / truncate / gc), never rebuilt per admission; removal only
      clears the ``alive`` bit, keeping surviving rows' order stable
      (compaction runs between admissions, in :meth:`compact`);
    * task deadlines are NOT mirrored — they are gathered live per
      admission, because callers may legally mutate ``task.deadline`` after
      reserving.
    """

    __slots__ = ("t1", "t2", "amount", "alive", "tasks", "rows", "m", "dead")

    def __init__(self, cap: int = 16) -> None:
        self.t1 = np.empty(cap)
        self.t2 = np.empty(cap)
        self.amount = np.empty(cap, dtype=np.int64)
        self.alive = np.zeros(cap, dtype=bool)
        self.tasks: list[Optional[Task]] = []   # parallel refs, len == m
        self.rows: dict[int, int] = {}          # task_id -> row
        self.m = 0                              # append cursor
        self.dead = 0

    @staticmethod
    def tracks(tag: object) -> bool:
        return isinstance(tag, Task) and tag.priority == Priority.LOW

    def add(self, r: Reservation) -> None:
        m = self.m
        if m == self.t1.shape[0]:
            grow = max(16, m)
            self.t1 = np.concatenate((self.t1, np.empty(grow)))
            self.t2 = np.concatenate((self.t2, np.empty(grow)))
            self.amount = np.concatenate(
                (self.amount, np.empty(grow, dtype=np.int64)))
            self.alive = np.concatenate(
                (self.alive, np.zeros(grow, dtype=bool)))
        task: Task = r.tag
        self.t1[m], self.t2[m], self.amount[m] = r.t1, r.t2, r.amount
        self.alive[m] = True
        self.tasks.append(task)
        self.rows[task.task_id] = m
        self.m = m + 1

    def discard(self, tag: object) -> None:
        if not isinstance(tag, Task):
            return
        row = self.rows.pop(tag.task_id, None)
        if row is None:
            return
        self.alive[row] = False
        self.tasks[row] = None
        self.dead += 1

    def truncate(self, tag: object, t_end: float) -> None:
        if not isinstance(tag, Task):
            return
        row = self.rows.get(tag.task_id)
        if row is not None:
            self.t2[row] = t_end

    def gc(self, now: float) -> None:
        """Drop rows whose reservations the calendar's gc retired
        (``t2 <= now``) — one vectorized sweep, not per-row Python."""
        if not self.m:
            return
        for row in np.flatnonzero(self.alive[: self.m]
                                  & (self.t2[: self.m] <= now)):
            task = self.tasks[row]
            self.rows.pop(task.task_id, None)
            self.tasks[row] = None
            self.alive[row] = False
            self.dead += 1

    def compact(self) -> None:
        """Squeeze out dead rows (order-preserving); amortised O(1) — runs
        only from the accessor, never inside an eviction loop."""
        if self.dead <= 32 or self.dead * 2 <= self.m:
            return
        keep = np.flatnonzero(self.alive[: self.m])
        n = keep.shape[0]
        self.t1[:n] = self.t1[keep]
        self.t2[:n] = self.t2[keep]
        self.amount[:n] = self.amount[keep]
        self.alive[:n] = True
        self.alive[n:] = False
        self.tasks = [self.tasks[i] for i in keep]
        self.rows = {t.task_id: i for i, t in enumerate(self.tasks)}
        self.m, self.dead = n, 0


class DeviceCalendar:
    """Capacity-C calendar for one edge device's cores.

    Core-usage queries go through the array skyline; ``completion_times``
    reads a searchsorted window of the sorted ``_t2s`` array; reservation
    identity (reserve / release / truncate by tag) stays a dict, which the
    preemption path also uses to enumerate conflict candidates.

    ``_t2s`` is copy-on-write: every mutation allocates a fresh array, so a
    reference taken by :meth:`NetworkState.iter_completion_times` is an
    immutable snapshot for free.  ``_notify`` (wired by ``NetworkState``)
    marks the device dirty for the probe plane on every mutation.
    """

    def __init__(self, device: int, capacity: int = 4) -> None:
        self.device = device
        self.capacity = capacity
        self.lifecycle = DeviceLifecycle.UP
        self._res: dict[object, Reservation] = {}
        self._sky = _StepFn()
        self._t2s: np.ndarray = _EMPTY_F        # sorted completion times
        self._expiry: list[tuple[float, int, object]] = []
        self._seq = itertools.count()
        self._notify: Optional[Callable[[int], None]] = None
        self._expiry_sink: Optional[list] = None     # NetworkState's gc heap
        self._lp: Optional[_LPMirror] = None         # preemption-plane mirror

    def __len__(self) -> int:
        return len(self._res)

    @property
    def is_up(self) -> bool:
        """True when the device accepts new placements (UP — DRAINING and
        DOWN devices are both closed to admission)."""
        return self.lifecycle is DeviceLifecycle.UP

    def reservations(self) -> Iterable[Reservation]:
        return self._res.values()

    def _touch(self) -> None:
        cb = self._notify
        if cb is not None:
            cb(self.device)

    # -- queries (all O(log n + slice)) ----------------------------------- #
    def max_usage(self, t1: float, t2: float) -> int:
        # Shrink by EPS so sub-EPS boundary overlaps are ignored, matching
        # Reservation.overlaps() in the reference implementation.
        return self._sky.max_over(t1 + EPS, t2 - EPS)

    def free_cores(self, t1: float, t2: float) -> int:
        return self.capacity - self.max_usage(t1, t2)

    def fits(self, t1: float, t2: float, cores: int) -> bool:
        return not self._sky.exceeds(t1 + EPS, t2 - EPS, self.capacity - cores)

    def load(self, t1: float, t2: float) -> float:
        """Reserved core-seconds overlapping [t1, t2) (for even spreading)."""
        return self._sky.integral(t1, t2)

    def earliest_fit(self, duration: float, not_before: float, cores: int) -> float:
        """Earliest t >= not_before where ``cores`` fit for ``duration``."""
        return self._sky.first_fit(duration, not_before, self.capacity - cores)

    def usage_segments(self, t1: float, t2: float) -> tuple[np.ndarray, np.ndarray]:
        """Raw core-usage segments over [t1, t2) as ``(starts, vals)``
        arrays — NO EPS shrink; callers pick their own window semantics.
        The preemption plane's refit grid (scheduler ``_HPWindowGrid``)
        builds on this with its left bound already EPS-shifted and an
        extended right horizon, so a max over its segments equals
        :meth:`max_usage` of any EPS-shrunk window inside the span."""
        return self._sky.window_profile(t1, t2)

    def lp_mirror(self) -> _LPMirror:
        """The device's LP-reservation mirror (preemption plane), built
        lazily by backfilling from the live reservation dict in insertion
        order; once built, the mutation hooks keep it in sync."""
        lp = self._lp
        if lp is None:
            lp = self._lp = _LPMirror()
            for r in self._res.values():
                if _LPMirror.tracks(r.tag):
                    lp.add(r)
        else:
            lp.compact()
        return lp

    def completion_times(self, after: float, before: float) -> list[float]:
        a = self._t2s
        lo = int(a.searchsorted(after + EPS, side="right"))
        hi = int(a.searchsorted(before - EPS, side="left"))
        if hi <= lo:
            return []
        return [t for t, _ in itertools.groupby(a[lo:hi].tolist())]

    # -- updates ---------------------------------------------------------- #
    def _t2s_insert(self, t2: float) -> None:  # replint: disable=dirty-notify (caller notifies)
        # manual splice: np.insert/np.delete carry ~10x Python overhead
        a = self._t2s
        i = int(a.searchsorted(t2))
        b = np.empty(a.shape[0] + 1)
        b[:i] = a[:i]
        b[i] = t2
        b[i + 1 :] = a[i:]
        self._t2s = b

    def _t2s_remove(self, t2: float) -> None:  # replint: disable=dirty-notify (caller notifies)
        a = self._t2s
        i = int(a.searchsorted(t2))
        if i < a.shape[0] and a[i] == t2:
            b = np.empty(a.shape[0] - 1)
            b[:i] = a[:i]
            b[i:] = a[i + 1 :]
            self._t2s = b

    def reserve(self, t1: float, t2: float, cores: int, tag: object) -> Reservation:
        prev = self._res.pop(tag, None)
        if prev is not None:                    # re-reserving a tag replaces it
            self._remove_interval(prev)
        r = Reservation(t1, t2, cores, tag)
        self._res[tag] = r
        if self._lp is not None and _LPMirror.tracks(tag):
            self._lp.add(r)
        self._sky.add(t1, t2, cores)
        self._t2s_insert(t2)
        heapq.heappush(self._expiry, (t2, next(self._seq), tag))
        if self._expiry_sink is not None:
            heapq.heappush(self._expiry_sink, (t2, self.device))
        self._touch()
        return r

    def _remove_interval(self, r: Reservation) -> None:  # replint: disable=dirty-notify (caller notifies)
        if self._lp is not None:
            self._lp.discard(r.tag)
        self._sky.add(r.t1, r.t2, -r.amount)
        self._t2s_remove(r.t2)

    def release(self, tag: object) -> Optional[Reservation]:
        r = self._res.pop(tag, None)
        if r is not None:
            self._remove_interval(r)
            self._touch()
        return r

    def get(self, tag: object) -> Optional[Reservation]:
        return self._res.get(tag)

    def truncate(self, tag: object, t_end: float) -> None:
        """Shorten a reservation (early completion / violation).  Truncating
        to (or before) its start removes it entirely."""
        r = self._res.get(tag)
        if r is None:
            return
        if t_end <= r.t1 + EPS:
            self._res.pop(tag)
            self._remove_interval(r)
            self._touch()
            return
        if t_end >= r.t2:
            return
        self._sky.add(t_end, r.t2, -r.amount)
        self._t2s_remove(r.t2)
        self._t2s_insert(t_end)
        r.t2 = t_end
        if self._lp is not None:
            self._lp.truncate(tag, t_end)
        heapq.heappush(self._expiry, (t_end, next(self._seq), tag))
        if self._expiry_sink is not None:
            heapq.heappush(self._expiry_sink, (t_end, self.device))
        self._touch()

    def gc(self, now: float) -> None:
        """Retire reservations with t2 <= now; O(log n) per retirement.

        In-flight reservations straddling ``now`` keep their full remaining
        interval; their pre-``now`` history is collapsed by the skyline."""
        heap, res = self._expiry, self._res
        while heap and heap[0][0] <= now:
            t2, _, tag = heapq.heappop(heap)
            r = res.get(tag)
            if r is None:
                continue
            if r.t2 <= now:
                del res[tag]
            elif r.t2 != t2:
                # stale entry (tag was truncated/re-reserved); re-index
                heapq.heappush(heap, (r.t2, next(self._seq), tag))
        a = self._t2s
        lo = int(a.searchsorted(now, side="right"))
        if lo:
            self._t2s = a[lo:].copy()
        self._sky.gc(now)
        if self._lp is not None:
            self._lp.gc(now)
        self._touch()

    def clear(self) -> None:
        """Wipe every reservation (device loss, or a rejoin after one):
        fresh skyline, empty expiry heap, dropped mirrors.  Stale entries
        this device left in the ``NetworkState`` gc heap stay behind and
        resolve as no-ops when popped."""
        self._res.clear()
        self._sky = _StepFn()
        self._t2s = _EMPTY_F
        self._expiry = []
        self._lp = None
        self._touch()


class _ProbePlane:
    """The network-wide probe plane: every device skyline mirrored into
    padded 2-D arrays so one vectorized pass answers a probe for ALL
    devices at once.

    ``times`` is (D, W+1) — one +inf spare column so "next breakpoint"
    gathers never run off the row; ``vals`` is (D, W); rows are refreshed
    lazily from the per-device dirty set maintained by ``NetworkState``.
    Padding (+inf times, 0 vals) is self-neutralising in every query, so no
    per-row trimming is needed.

    Exactness: every vector entry is bit-identical to the corresponding
    scalar ``DeviceCalendar`` query — returned instants are existing
    breakpoints or the probe's own bounds, window maxima are integer
    reductions over the same segments (tests/test_probe_plane.py,
    tests/test_skyline_fuzz.py).
    """

    def __init__(self, state: "NetworkState") -> None:
        self._state = state
        self._d = len(state.devices)
        self.capacity = np.fromiter((dev.capacity for dev in state.devices),
                                    np.int64, self._d)
        self._w = 8                             # skyline columns
        self._t = 8                             # completion-time columns
        self._ff_cache: dict[tuple, tuple] = {}
        self._bc: dict[int, np.ndarray] = {}    # cores -> blocked-count prefix
        self._alloc()

    def _alloc(self) -> None:
        d, w, t = self._d, self._w, self._t
        self.alive = np.fromiter(
            (dev.lifecycle is DeviceLifecycle.UP
             for dev in self._state.devices), np.bool_, d)
        self.times = np.full((d, w + 1), _INF)  # +1 spare col: "next" gathers
        self.vals = np.zeros((d, w), dtype=np.int64)
        self.prefix = np.zeros((d, w + 1))      # per-row usage-mass prefixes
        self.t2pad = np.full((d, t), _INF)      # per-device completion times
        self.nseg = np.ones(d, dtype=np.int64)  # live segments per row
        self._rowmax = np.full(d, -_INF)        # last breakpoint per row
        self._tmax = -_INF                      # ... and its global max
        self._col = np.arange(w)
        self._rows = np.arange(d)
        self._bc.clear()

    @staticmethod
    def _round_up(need: int, have: int) -> int:
        while have < need:
            have += max(8, have // 2)           # 1.5x growth, 8-col floor
        return have

    def _row_prefix(self, idx: int, n: int) -> None:
        """Per-row usage-mass prefix (``loads`` in O(1) after location).

        ``prefix[d, j]`` is the total usage-seconds of (row-local) segments
        0..j-1; the sentinel segment (start −inf) and the final segment
        (end +inf, usage 0 by invariant) contribute 0, keeping the sums
        finite — query boundary segments are corrected exactly in `loads`.
        """
        trow = self.times[idx]
        with np.errstate(invalid="ignore"):      # 0 * inf at the two ends
            c = self.vals[idx, :n] * (trow[1 : n + 1] - trow[:n])
        c[0] = 0.0
        c[n - 1] = 0.0
        p = self.prefix[idx]
        np.cumsum(c, out=p[1 : n + 1])
        p[n + 1 :] = p[n]

    def _refresh(self) -> None:
        dirty = self._state._dirty
        if not dirty:
            return
        devices = self._state.devices
        need_w = need_t = 0
        for idx in dirty:  # replint: disable=determinism-set-iter (max-reduction over rows; order-independent)
            dev = devices[idx]
            sf = dev._sky
            sf._flush()
            if sf.n > need_w:
                need_w = sf.n
            if dev._t2s.shape[0] > need_t:
                need_t = dev._t2s.shape[0]
        if need_w > self._w or need_t > self._t:
            self._w = self._round_up(need_w, self._w)
            self._t = self._round_up(need_t, self._t)
            self._alloc()
            dirty = range(self._d)               # every row needs a rebuild
        times, vals, t2pad = self.times, self.vals, self.t2pad
        for idx in dirty:
            dev = devices[idx]
            self.alive[idx] = dev.lifecycle is DeviceLifecycle.UP
            sf = dev._sky
            st, sv = sf._view()
            n = sf.n
            times[idx, :n] = st
            times[idx, n:] = _INF
            vals[idx, :n] = sv
            vals[idx, n:] = 0
            self.nseg[idx] = n
            self._rowmax[idx] = st[n - 1]
            self._row_prefix(idx, n)
            for cores, bc in self._bc.items():   # keep limit tables in sync
                np.cumsum(vals[idx] > self.capacity[idx] - cores,
                          out=bc[idx, 1:])
            t2s = dev._t2s
            m = t2s.shape[0]
            t2pad[idx, :m] = t2s
            t2pad[idx, m:] = _INF
        self._tmax = float(self._rowmax.max())
        self._ff_cache.clear()
        self._state._dirty.clear()

    def _count_below(self, x: float, strict: bool) -> np.ndarray:
        """Per-row count of breakpoints below ``x`` (the location pass).

        Probe windows start near the gc'd front of every row, so the count
        almost always resolves within the first few columns — try a short
        front slice first and widen to the full mirror only when some row
        saturates it."""
        if x > self._tmax:              # beyond every breakpoint: all count
            return self.nseg
        t = self.times
        k = 16
        if k < t.shape[1]:
            head = t[:, :k]
            c = np.count_nonzero(head < x if strict else head <= x, axis=1)
            sat = np.flatnonzero(c == k)
            if sat.size == 0:
                return c
            if sat.size <= 32:          # escalate just the saturated rows
                side = "left" if strict else "right"
                for r in sat:
                    c[r] = t[r].searchsorted(x, side=side)
                return c
        return np.count_nonzero(t < x if strict else t <= x, axis=1)

    def _blocked_counts(self, cores: int) -> np.ndarray:
        """``bc[d, j]``: how many of row d's first j segments cannot host
        ``cores`` more cores.  A window fits iff its count delta is zero —
        integer-exact, O(1) per row after location."""
        bc = self._bc.get(cores)
        if bc is None:
            bc = np.zeros((self._d, self._w + 1), dtype=np.int64)
            np.cumsum(self.vals > (self.capacity - cores)[:, None],
                      axis=1, out=bc[:, 1:])
            self._bc[cores] = bc
        return bc

    # -- vectorized probes ------------------------------------------------ #
    def max_usage(self, t1: float, t2: float) -> np.ndarray:
        """Stacked ``DeviceCalendar.max_usage`` (EPS-shrunk window).

        After the location pass, the reduction runs only over the column
        strip any device's window actually touches — typically a handful of
        columns, not the full mirror width."""
        a, b = t1 + EPS, t2 - EPS
        if b <= a:
            return np.zeros(self._d, dtype=np.int64)
        w = self._w
        i1 = self._count_below(a, strict=False) - 1
        i2 = self._count_below(b, strict=True)
        j0, j1 = int(i1.min()), int(i2.max())
        col = self._col[j0:j1]
        mask = (col >= i1[:, None]) & (col < i2[:, None])
        return np.where(mask, self.vals[:, j0:j1], 0).max(axis=1)

    def free_cores(self, t1: float, t2: float) -> np.ndarray:
        return self.capacity - self.max_usage(t1, t2)

    def fits_mask(self, t1: float, t2: float, cores: int) -> np.ndarray:
        """Stacked ``DeviceCalendar.fits`` — integer-exact via the per-cores
        blocked-count prefixes: a window hosts ``cores`` more cores iff it
        spans zero blocked segments.  Non-UP rows are masked out: admission
        must never place onto a DRAINING/DOWN device (with every device UP
        the mask is all-ones, so churn-free answers are bit-identical)."""
        a, b = t1 + EPS, t2 - EPS
        if b <= a:
            return self.alive.copy()
        i1 = self._count_below(a, strict=False) - 1
        i2 = self._count_below(b, strict=True)
        bc = self._blocked_counts(cores)
        rows = self._rows
        return (bc[rows, i2] == bc[rows, i1]) & self.alive

    def loads(self, t1: float, t2: float) -> np.ndarray:
        """Stacked ``DeviceCalendar.load`` over [t1, t2): locate the window
        per row, then the per-row usage-mass prefixes answer the interior in
        O(1) — only the two boundary segments need exact clipping."""
        if t2 <= t1:
            return np.zeros(self._d)
        rows = self._rows
        t = self.times
        i1 = self._count_below(t1, strict=False) - 1
        i2m = self._count_below(t2, strict=True) - 1  # last segment in window
        v = self.vals
        p = self.prefix
        v1 = v[rows, i1]
        with np.errstate(invalid="ignore"):      # 0*inf in discarded branch
            single = v1 * (t2 - t1)              # window inside one segment
            full = (v1 * (t[rows, i1 + 1] - t1)          # left boundary clip
                    + (p[rows, i2m] - p[rows, i1 + 1])   # full interior segs
                    + v[rows, i2m] * (t2 - t[rows, i2m]))  # right clip
            return np.where(i2m == i1, single, full)

    def earliest_fit(self, duration: float, not_before: float,
                     cores: int) -> np.ndarray:
        """Stacked ``DeviceCalendar.earliest_fit`` (first-fit run search).

        Requires ``not_before`` at or after every device's gc horizon — the
        scheduler only probes at or after controller time, which satisfies
        it by construction.  The (cores, duration)-keyed tables — blocked
        mask, each run's end, and the feasible run-start columns — survive
        until the next mutation, so the LP sweep's repeated skip-hint probes
        pay only the location pass.
        """
        w, col, rows = self._w, self._col, self._rows
        t = self.times[:, :w]
        key = (cores, duration)
        tab = self._ff_cache.get(key)
        if tab is None:
            limit = (self.capacity - cores)[:, None]
            bad = self.vals > limit
            # next blocked segment at or after each column (w = "none")
            idx = np.where(bad, col, w)
            nb = np.minimum.accumulate(idx[:, ::-1], axis=1)[:, ::-1]
            run_end = np.take_along_axis(self.times, nb, axis=1)
            prev_bad = np.zeros_like(bad)
            prev_bad[:, 1:] = bad[:, :-1]
            with np.errstate(invalid="ignore"):  # inf-inf in padded columns
                ok_col = ~bad & prev_bad & (run_end - t >= duration - EPS)
            tab = self._ff_cache[key] = (bad, run_end, ok_col)
        bad, run_end, ok_col = tab
        i0 = self._count_below(not_before, strict=False) - 1
        # candidate 1: ``not_before`` itself, inside its (good) run
        use_t = ~bad[rows, i0] & (run_end[rows, i0] - not_before
                                  >= duration - EPS)
        # candidate 2: the first feasible run start past ``not_before``
        ok = ok_col & (col > i0[:, None])
        j = ok.argmax(axis=1)
        res = np.where(use_t, not_before, t[rows, j])
        # rows that can never host ``cores`` (capacity too small) have no
        # candidate at all — match the scalar first_fit's +inf guard
        # instead of leaking the argmax-of-nothing -inf sentinel.  Non-UP
        # rows are masked to +inf the same way: a DRAINING/DOWN device
        # never offers a start instant to admission.
        return np.where((self.capacity < cores) | ~self.alive, _INF, res)

    # -- completion-time plane -------------------------------------------- #
    def completion_array(self, after: float, before: float) -> np.ndarray:
        """Sorted unique completion points in (after, before), network-wide,
        in one vectorized select + ``np.unique`` merge."""
        return _unique_window(self.t2pad, after, before)


def _unique_window(t2pad: np.ndarray, after: float, before: float) -> np.ndarray:
    """Sorted unique values of ``t2pad`` strictly inside the EPS-shrunk
    window (after + EPS, before - EPS) — exclusive on both sides, exactly
    like the per-device bisect windows (+inf padding is never selected)."""
    pts = t2pad[(t2pad > after + EPS) & (t2pad < before - EPS)]
    if pts.size == 0:
        return pts
    return np.unique(pts)


@dataclass
class ProbeWindow:
    """One ``probe_plane(t1, t2)`` snapshot: stacked per-device vectors."""

    t1: float
    t2: float
    free_cores: np.ndarray                      # (D,) ints
    loads: np.ndarray                           # (D,) usage-seconds
    _capacity: np.ndarray
    alive: Optional[np.ndarray] = None          # (D,) bool (None: all UP)

    def fits(self, cores: int) -> np.ndarray:
        """(D,) bool mask: which devices can host ``cores`` over the window
        (non-UP devices never fit)."""
        mask = self.free_cores >= cores
        if self.alive is not None:
            mask &= self.alive
        return mask


@dataclass
class NetworkState:
    """The controller's perception of all network resources (paper §3)."""

    n_devices: int
    capacity: int = 4
    link: LinkCalendar = field(default_factory=LinkCalendar)
    devices: list[DeviceCalendar] = field(default_factory=list)

    def __post_init__(self) -> None:
        if not self.devices:
            self.devices = [
                DeviceCalendar(d, self.capacity) for d in range(self.n_devices)
            ]
        self._dirty: set[int] = set(range(len(self.devices)))
        self._plane: Optional[_ProbePlane] = None
        # Global device-expiry heap: every reservation/truncation registers
        # (t2, device), so gc touches only devices that actually have
        # something to retire — O(expirations), not O(devices).
        self._expiry: list[tuple[float, int]] = []
        for d in self.devices:
            d._notify = self._dirty.add
            d._expiry_sink = self._expiry
            if d._expiry:               # pre-populated device handed in
                heapq.heappush(self._expiry, (d._expiry[0][0], d.device))

    def probe_plane(self, t1: Optional[float] = None,
                    t2: Optional[float] = None):
        """The vectorized network-wide probe plane.

        Without arguments, returns the (lazily refreshed) :class:`_ProbePlane`
        for window-parameterised probes — ``fits_mask`` / ``free_cores`` /
        ``loads`` / ``earliest_fit`` each answer for every device in one
        vectorized pass.  With a window, returns a :class:`ProbeWindow`
        snapshot carrying the stacked free-core and load vectors for
        [t1, t2).
        """
        plane = self._plane
        if plane is None:
            plane = self._plane = _ProbePlane(self)
        plane._refresh()
        if t1 is None:
            return plane
        return ProbeWindow(t1, t2, plane.free_cores(t1, t2),
                           plane.loads(t1, t2), plane.capacity, plane.alive)

    def completion_times(self, after: float, before: float) -> list[float]:
        """Sorted unique completion time-points in (after, before), network
        wide — the LP algorithm's §4 search grid, merged in one vectorized
        select + ``np.unique`` over the probe plane's completion mirror."""
        plane = self.probe_plane()
        return plane.completion_array(after, before).tolist()

    def iter_completion_times(self, after: float, before: float) -> Iterator[float]:
        """Lazy variant of :meth:`completion_times`: same sorted unique
        points, but all windowing/merge work is deferred until a point is
        actually consumed — the LP sweep usually allocates at the first
        time-point, so most grids cost O(D) reference grabs and nothing
        else.

        The snapshot is taken at CALL time: the per-device ``_t2s`` arrays
        are copy-on-write (every mutation allocates a fresh array), so
        holding the references IS an immutable capture — reservations
        committed while iterating can never perturb the grid (the seed's
        snapshot semantics)."""
        snap = [d._t2s for d in self.devices]

        def merge() -> Iterator[float]:
            pts = np.concatenate(snap) if snap else _EMPTY_F
            pts = pts[(pts > after + EPS) & (pts < before - EPS)]
            if pts.size:
                yield from np.unique(pts).tolist()

        return merge()

    def total_allocated_tasks(self) -> int:
        return sum(len(d) for d in self.devices)

    # -- device lifecycle (churn plane, DESIGN.md §16) ------------------ #
    def alive_mask(self) -> np.ndarray:
        """(D,) bool: which devices accept new placements (UP only)."""
        return np.fromiter(
            (d.lifecycle is DeviceLifecycle.UP for d in self.devices),
            np.bool_, len(self.devices))

    def lifecycle_codes(self) -> np.ndarray:
        """(D,) int8 lifecycle codes (the checkpoint encoding —
        checkpoint/lifecycle.py round-trips this array)."""
        return np.fromiter(
            (d.lifecycle.value for d in self.devices),
            np.int8, len(self.devices))

    def apply_lifecycle_codes(self, codes) -> None:
        """Restore per-device lifecycles from :meth:`lifecycle_codes`.

        A device restored as DOWN gets its calendar cleared (a DOWN device
        by invariant holds no reservations); every changed device is
        dirty-marked so the probe plane's alive mask refreshes."""
        codes = np.asarray(codes)
        if codes.shape != (len(self.devices),):
            raise ValueError(
                f"lifecycle codes shape {codes.shape} != "
                f"({len(self.devices)},)")
        for dev, code in zip(self.devices, codes.tolist()):
            lc = DeviceLifecycle(int(code))
            if lc is dev.lifecycle:
                continue
            if lc is DeviceLifecycle.DOWN:
                dev.clear()
            dev.lifecycle = lc
            self._dirty.add(dev.device)

    def fail_device(self, idx: int, now: float) -> list[Task]:
        """Hard-fail device ``idx``: mark it DOWN, clear its calendar, and
        return every in-flight task it was hosting (the *orphans*, sorted
        by task id for a deterministic recovery order).

        Finished work is retired first (``gc``), so only reservations still
        running at — or starting after — ``now`` orphan.  Link slots,
        dispatcher exec events, and terminal accounting for the orphans are
        the policy layer's job (scheduler ``fail_device`` / policy
        ``fail_device``); this method only mutates the calendar plane."""
        dev = self.devices[idx]
        dev.gc(now)
        orphans = [r.tag for r in dev.reservations()
                   if isinstance(r.tag, Task)]
        dev.clear()
        dev.lifecycle = DeviceLifecycle.DOWN
        self._dirty.add(idx)
        orphans.sort(key=lambda t: t.task_id)
        return orphans

    def drain_device(self, idx: int) -> None:
        """Gracefully drain device ``idx``: no new placements, but every
        in-flight reservation runs to completion (no orphans)."""
        dev = self.devices[idx]
        if dev.lifecycle is DeviceLifecycle.DOWN:
            raise ValueError(f"device {idx} is DOWN; rejoin before draining")
        dev.lifecycle = DeviceLifecycle.DRAINING
        self._dirty.add(idx)

    def rejoin_device(self, idx: int) -> None:
        """Bring device ``idx`` back to UP.  A DOWN device rejoins with a
        cleared calendar (its pre-failure reservations were orphaned at the
        failure); cancelling a drain keeps the calendar — nothing was lost."""
        dev = self.devices[idx]
        if dev.lifecycle is DeviceLifecycle.DOWN:
            dev.clear()                 # defensive: fail_device cleared it
        dev.lifecycle = DeviceLifecycle.UP
        self._dirty.add(idx)

    def gc(self, now: float) -> None:
        """Garbage-collect every resource to ``now``.

        Lazy per-device skip via the global expiry heap: a device with no
        registered expiry at or before ``now`` provably has nothing to
        retire (every ``_t2s``/dead-dict entry has a matching heap key), so
        it is left untouched — its un-collapsed history is invisible to
        queries at or after ``now``.  This turns the former O(D)
        per-admission sweep into O(devices-with-expirations)."""
        self.link.gc(now)
        heap = self._expiry
        if not heap or heap[0][0] > now:
            return
        devices = self.devices
        seen: set[int] = set()
        while heap and heap[0][0] <= now:
            _, idx = heapq.heappop(heap)
            seen.add(idx)
        for idx in sorted(seen):       # pinned order: heap re-pushes below
            d = devices[idx]
            d.gc(now)
            # Re-register the device's next expiry: keeps it tracked even
            # when its remaining reservations predate attachment to this
            # NetworkState (duplicates are deduped by ``seen``).
            if d._expiry:
                heapq.heappush(heap, (d._expiry[0][0], idx))
