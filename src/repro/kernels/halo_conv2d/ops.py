"""jit'd wrapper: full halo-partitioned conv block = overlapping-tile gather
(the border 'exchange') + Pallas per-tile VMEM kernel + reassembly.

``halo_conv_block(x, weights, tiles=(2, 2))`` == ``ref.conv_block_ref`` for
any tiling — the tile count is the paper's 2-core / 4-core configuration
knob.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import halo_conv_block_tiles
from .ref import conv_block_ref


def _extract_tiles(xp: jax.Array, n_th: int, n_tw: int, th: int, tw: int,
                   r: int) -> jax.Array:
    """xp [N, H + 2r, W + 2r, C] -> [N * n_th * n_tw, th + 2r, tw + 2r, C]."""
    n = xp.shape[0]
    c = xp.shape[-1]
    out = []
    for i in range(n_th):
        for j in range(n_tw):
            out.append(
                jax.lax.dynamic_slice(
                    xp, (0, i * th, j * tw, 0),
                    (n, th + 2 * r, tw + 2 * r, c))
            )
    return jnp.stack(out, axis=1).reshape(n * n_th * n_tw, th + 2 * r,
                                          tw + 2 * r, c)


@partial(jax.jit, static_argnames=("tiles", "leaky", "interpret"))
def halo_conv_block(
    x: jax.Array,                        # [N, H, W, Cin]
    weights: tuple[jax.Array, ...],
    *,
    tiles: tuple[int, int] = (2, 2),
    leaky: float = 0.1,
    interpret: bool = True,
) -> jax.Array:
    n, h, w, _ = x.shape
    n_th, n_tw = tiles
    assert h % n_th == 0 and w % n_tw == 0, "tile counts must divide H, W"
    th, tw = h // n_th, w // n_tw
    r = len(weights)
    xp = jnp.pad(x, [(0, 0), (r, r), (r, r), (0, 0)])
    tl = _extract_tiles(xp, n_th, n_tw, th, tw, r)
    yt = halo_conv_block_tiles(tl, tuple(weights), tile_h=th, tile_w=tw,
                               leaky=leaky, interpret=interpret)
    cout = yt.shape[-1]
    yt = yt.reshape(n, n_th, n_tw, th, tw, cout)
    return yt.transpose(0, 1, 3, 2, 4, 5).reshape(n, h, w, cout)


def halo_conv_block_ref(x, weights, leaky: float = 0.1):
    return conv_block_ref(x, list(weights), leaky)
