"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelisable)
and sLSTM (scalar memory, sequential scan), both with exponential gating and
the max-state stabiliser.

The mLSTM has two mathematically equivalent forms:
  * parallel (training/prefill): an attention-like T x T decay-masked form;
  * recurrent (decode): C_t = f'_t C_{t-1} + i'_t v_t k_t^T.
A property test asserts the two agree (tests/test_xlstm_equivalence.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..config import ModelConfig
from .common import dense_init, groupnorm_heads, silu


# =========================================================================== #
# mLSTM                                                                       #
# =========================================================================== #


def mlstm_init(key, cfg: ModelConfig, dtype) -> dict:
    xc = cfg.xlstm
    assert xc is not None
    d = cfg.d_model
    di = int(xc.proj_factor_mlstm * d)
    h = cfg.n_heads
    dh = di // h
    keys = jax.random.split(key, 8)
    return {
        "up_proj": dense_init(keys[0], d, 2 * di, dtype=dtype),
        "conv_w": dense_init(keys[1], xc.conv_kernel, di, dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype=dtype),
        "wq": dense_init(keys[2], di, h, dh, dtype=dtype),
        "wk": dense_init(keys[3], di, h, dh, dtype=dtype),
        "wv": dense_init(keys[4], di, h, dh, dtype=dtype),
        "w_i": dense_init(keys[5], di, h, dtype=jnp.float32),
        "w_f": dense_init(keys[6], di, h, dtype=jnp.float32),
        "b_i": jnp.zeros((h,), jnp.float32),
        "b_f": jnp.full((h,), 3.0, jnp.float32),   # forget-gate bias > 0
        "skip": jnp.ones((di,), dtype=dtype),
        "down_proj": dense_init(keys[7], di, d, dtype=dtype),
    }


def mlstm_axes(cfg: ModelConfig) -> dict:
    return {
        "up_proj": ("embed", "d_inner2"),
        "conv_w": ("conv", "d_inner"),
        "conv_b": ("d_inner",),
        "wq": ("d_inner", "heads", "head_dim"),
        "wk": ("d_inner", "heads", "head_dim"),
        "wv": ("d_inner", "heads", "head_dim"),
        "w_i": ("d_inner", "heads"),
        "w_f": ("d_inner", "heads"),
        "b_i": ("heads",),
        "b_f": ("heads",),
        "skip": ("d_inner",),
        "down_proj": ("d_inner", "embed"),
    }


def init_mlstm_cache(batch: int, cfg: ModelConfig, dtype) -> dict:
    xc = cfg.xlstm
    di = int(xc.proj_factor_mlstm * cfg.d_model)
    h = cfg.n_heads
    dh = di // h
    return {
        "conv": jnp.zeros((batch, xc.conv_kernel - 1, di), dtype=dtype),
        "c": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_cache_axes() -> dict:
    return {
        "conv": ("batch", "conv", "d_inner"),
        "c": ("batch", "heads", "head_dim", "head_dim2"),
        "n": ("batch", "heads", "head_dim"),
        "m": ("batch", "heads"),
    }


def _conv_causal(w, b, x: jax.Array, prior: Optional[jax.Array]) -> jax.Array:
    k = w.shape[0]
    if prior is None:
        prior = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prior, x], axis=1)
    return sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k)) + b


def _qkv_gates(params, xi: jax.Array):
    q = jnp.einsum("bti,ihk->bthk", xi, params["wq"])
    k = jnp.einsum("bti,ihk->bthk", xi, params["wk"])
    v = jnp.einsum("bti,ihk->bthk", xi, params["wv"])
    xf = xi.astype(jnp.float32)
    i_pre = jnp.einsum("bti,ih->bth", xf, params["w_i"]) + params["b_i"]
    f_pre = jnp.einsum("bti,ih->bth", xf, params["w_f"]) + params["b_f"]
    return q, k, v, i_pre, f_pre


def _mlstm_chunked(q, k, v, i_pre, f_pre, chunk: int,
                   state: Optional[tuple] = None):
    """Chunkwise-parallel mLSTM (§Perf): O(T·chunk) score blocks + a
    recurrent (C, n, m) carry between chunks — the linear-attention chunk
    form, mathematically identical to the naive T x T decay-masked form
    (the stabiliser ``m_t = max_{s<=t} a_{t,s}`` is tracked exactly through
    the chunk recursion).

    q/k/v [B,T,H,K]; i_pre/f_pre [B,T,H] float32.
    Returns (h_out [B,T,H,K] float32, m_t [B,T,H], final_state).
    """
    b, t, h, dh = q.shape
    n_pad = (-t) % chunk
    if n_pad:
        pad = [(0, 0), (0, n_pad), (0, 0)]
        q, k, v = (jnp.pad(a, pad + [(0, 0)]) for a in (q, k, v))
        i_pre = jnp.pad(i_pre, pad)
        f_pre = jnp.pad(f_pre, pad)
    tp = t + n_pad
    nb = tp // chunk
    scale = jnp.asarray(dh, jnp.float32) ** -0.5

    def per_chunk(carry, inp):
        C, n, m_prev = carry                              # [B,H,K,K] [B,H,K] [B,H]
        qc, kc, vc, ic, fc = inp                          # [B,L,H,K] / [B,L,H]
        qc = qc.astype(jnp.float32) * scale
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        logf = jax.nn.log_sigmoid(fc)                     # [B,L,H]
        cum = jnp.cumsum(logf, axis=1)                    # inclusive
        u = ic - cum                                      # i_s - cum_s
        w = jnp.maximum(m_prev[:, None],
                        jax.lax.cummax(u, axis=1))        # [B,L,H]
        m_t = cum + w                                     # row-max stabiliser
        # intra-chunk: D[t,s] = exp(u_s - w_t) for s<=t
        L = qc.shape[1]
        causal = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
        D = jnp.where(causal, jnp.exp(u[:, None, :, :] - w[:, :, None, :]), 0.0)
        scores = jnp.einsum("blhk,bshk->blsh", qc, kc) * D
        num = jnp.einsum("blsh,bshk->blhk", scores, vc)
        den = scores.sum(axis=2)                          # [B,L,H]
        # inter-chunk (from carried state)
        g = jnp.exp(m_prev[:, None] - w)                  # [B,L,H]
        qg = qc * g[..., None]
        num = num + jnp.einsum("blhk,bhkj->blhj", qg, C)
        den = den + jnp.einsum("blhk,bhk->blh", qg, n)
        h_out = num / (jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
                       + 1e-6)
        # state update to end of chunk
        w_L = w[:, -1]                                    # [B,H]
        F = cum[:, -1]
        m_new = F + w_L
        coeff = jnp.exp(u - w_L[:, None])                 # [B,L,H]
        C_new = (jnp.exp(m_prev - w_L)[..., None, None] * C
                 + jnp.einsum("bsh,bshk,bshj->bhkj", coeff, kc, vc))
        n_new = (jnp.exp(m_prev - w_L)[..., None] * n
                 + jnp.einsum("bsh,bshk->bhk", coeff, kc))
        return (C_new, n_new, m_new), (h_out, m_t)

    if state is None:
        state = (
            jnp.zeros((b, h, dh, dh), jnp.float32),
            jnp.zeros((b, h, dh), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32),
        )

    def to_chunks(a):
        return a.reshape((a.shape[0], nb, chunk) + a.shape[2:]).swapaxes(0, 1)

    final_state, (hs, ms) = jax.lax.scan(
        per_chunk, state,
        tuple(to_chunks(a) for a in (q, k, v, i_pre, f_pre)))
    hs = hs.swapaxes(0, 1).reshape(b, tp, h, dh)[:, :t]
    ms = ms.swapaxes(0, 1).reshape(b, tp, h)[:, :t]
    return hs, ms, final_state


def mlstm_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    cache: Optional[dict] = None,
) -> tuple[jax.Array, Optional[dict]]:
    di = params["skip"].shape[0]
    up = jnp.einsum("btd,de->bte", x, params["up_proj"])
    xi_raw, z = up[..., :di], up[..., di:]

    if cache is None and cfg.mlstm_chunk and x.shape[1] > cfg.mlstm_chunk:
        xi = silu(_conv_causal(params["conv_w"], params["conv_b"], xi_raw, None))
        q, k, v, i_pre, f_pre = _qkv_gates(params, xi)
        hout, _, _ = _mlstm_chunked(q, k, v, i_pre, f_pre, cfg.mlstm_chunk)
        new_cache = None
    elif cache is None:
        xi = silu(_conv_causal(params["conv_w"], params["conv_b"], xi_raw, None))
        q, k, v, i_pre, f_pre = _qkv_gates(params, xi)
        b, t, h, dh = q.shape
        logf = jax.nn.log_sigmoid(f_pre)                      # [B,T,H]
        cum = jnp.cumsum(logf, axis=1)
        # a[t, s] = sum_{j=s+1..t} logf_j + logi_s  (t >= s)
        amat = cum[:, :, None, :] - cum[:, None, :, :] + i_pre[:, None, :, :]
        # [B, Tq, Ts, H]
        causal = jnp.tril(jnp.ones((t, t), bool))[None, :, :, None]
        amat = jnp.where(causal, amat, -jnp.inf)
        m = jnp.max(amat, axis=2, keepdims=True)              # [B,T,1,H]
        dmat = jnp.exp(amat - m)                               # stabilised
        scale = jnp.asarray(dh, jnp.float32) ** -0.5
        scores = jnp.einsum("bthk,bshk->btsh", q.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        sd = scores * dmat
        norm = jnp.maximum(jnp.abs(sd.sum(axis=2)), jnp.exp(-m[:, :, 0]))
        hout = jnp.einsum("btsh,bshk->bthk", sd, v.astype(jnp.float32))
        hout = hout / (norm[..., None] + 1e-6)
        new_cache = None
    else:
        conv_win = jnp.concatenate([cache["conv"], xi_raw], axis=1)
        xi = silu(
            jnp.einsum("bki,ki->bi", conv_win, params["conv_w"])
            + params["conv_b"]
        )[:, None, :]
        q, k, v, i_pre, f_pre = _qkv_gates(params, xi)
        b, _, h, dh = q.shape
        logf = jax.nn.log_sigmoid(f_pre[:, 0])                # [B,H]
        logi = i_pre[:, 0]
        m_new = jnp.maximum(logf + cache["m"], logi)
        f_eff = jnp.exp(logf + cache["m"] - m_new)            # [B,H]
        i_eff = jnp.exp(logi - m_new)
        kf = k[:, 0].astype(jnp.float32)
        vf = v[:, 0].astype(jnp.float32)
        c_new = (
            f_eff[..., None, None] * cache["c"]
            + i_eff[..., None, None] * kf[..., :, None] * vf[..., None, :]
        )
        n_new = f_eff[..., None] * cache["n"] + i_eff[..., None] * kf
        scale = jnp.asarray(dh, jnp.float32) ** -0.5
        qf = q[:, 0].astype(jnp.float32) * scale
        num = jnp.einsum("bhk,bhkj->bhj", qf, c_new)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n_new)), jnp.exp(-m_new)
        )
        hout = (num / (den[..., None] + 1e-6))[:, None]       # [B,1,H,dh]
        new_cache = {"conv": conv_win[:, 1:], "c": c_new, "n": n_new, "m": m_new}

    hout = groupnorm_heads(hout).astype(x.dtype)
    b, t = x.shape[:2]
    hflat = hout.reshape(b, t, di) + params["skip"] * xi
    y = hflat * silu(z)
    return jnp.einsum("bti,id->btd", y, params["down_proj"]), new_cache


# =========================================================================== #
# sLSTM                                                                      #
# =========================================================================== #


def slstm_init(key, cfg: ModelConfig, dtype) -> dict:
    xc = cfg.xlstm
    assert xc is not None
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    keys = jax.random.split(key, 4)
    df = int(xc.ffn_proj_factor * d)
    return {
        "w": dense_init(keys[0], d, 4, h, dh, dtype=dtype),       # i,f,z,o
        "r": (dh ** -0.5 * jax.random.normal(keys[1], (4, h, dh, dh))).astype(dtype),
        "b": jnp.concatenate(
            [jnp.zeros((1, h, dh)), jnp.full((1, h, dh), 3.0),
             jnp.zeros((2, h, dh))], axis=0).astype(jnp.float32),
        "ffn_gate": dense_init(keys[2], d, 2 * df, dtype=dtype),
        "ffn_down": dense_init(keys[3], df, d, dtype=dtype),
    }


def slstm_axes(cfg: ModelConfig) -> dict:
    return {
        "w": ("embed", "gates", "heads", "head_dim"),
        "r": ("gates", "heads", "head_dim", "head_dim2"),
        "b": ("gates", "heads", "head_dim"),
        "ffn_gate": ("embed", "ff"),
        "ffn_down": ("ff", "embed"),
    }


def init_slstm_cache(batch: int, cfg: ModelConfig, dtype) -> dict:
    h = cfg.n_heads
    dh = cfg.d_model // h
    return {
        "h": jnp.zeros((batch, h, dh), jnp.float32),
        "c": jnp.zeros((batch, h, dh), jnp.float32),
        "n": jnp.ones((batch, h, dh), jnp.float32),
        "m": jnp.zeros((batch, h, dh), jnp.float32),
    }


def slstm_cache_axes() -> dict:
    return {
        "h": ("batch", "heads", "head_dim"),
        "c": ("batch", "heads", "head_dim"),
        "n": ("batch", "heads", "head_dim"),
        "m": ("batch", "heads", "head_dim"),
    }


def _slstm_step(params, state, wx_t):
    """state (h,c,n,m) each [B,H,dh]; wx_t [B,4,H,dh] input pre-activations."""
    h, c, n, m = state
    rec = jnp.einsum("bhk,ghkj->bghj", h.astype(params["r"].dtype), params["r"])
    pre = wx_t.astype(jnp.float32) + rec.astype(jnp.float32) + params["b"]
    i_pre, f_pre, z_pre, o_pre = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    i_eff = jnp.exp(i_pre - m_new)
    f_eff = jnp.exp(logf + m - m_new)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c_new = f_eff * c + i_eff * z
    n_new = jnp.maximum(f_eff * n + i_eff, 1e-6)
    h_new = o * c_new / n_new
    return (h_new, c_new, n_new, m_new)


def slstm_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    cache: Optional[dict] = None,
) -> tuple[jax.Array, Optional[dict]]:
    b, t, d = x.shape
    h_heads = cfg.n_heads
    dh = d // h_heads
    wx = jnp.einsum("btd,dghk->btghk", x, params["w"])        # [B,T,4,H,dh]

    if cache is None:
        state = (
            jnp.zeros((b, h_heads, dh), jnp.float32),
            jnp.zeros((b, h_heads, dh), jnp.float32),
            jnp.ones((b, h_heads, dh), jnp.float32),
            jnp.zeros((b, h_heads, dh), jnp.float32),
        )

        def step(state, wx_t):
            new = _slstm_step(params, state, wx_t)
            return new, new[0]

        _, hs = jax.lax.scan(step, state, wx.swapaxes(0, 1))
        hs = hs.swapaxes(0, 1)                                 # [B,T,H,dh]
        new_cache = None
    else:
        state = (cache["h"], cache["c"], cache["n"], cache["m"])
        new = _slstm_step(params, state, wx[:, 0])
        hs = new[0][:, None]
        new_cache = {"h": new[0], "c": new[1], "n": new[2], "m": new[3]}

    hs = groupnorm_heads(hs).astype(x.dtype).reshape(b, t, d)
    y = x + hs                                                 # residual core
    # gated FFN (proj factor 4/3)
    gu = jnp.einsum("btd,de->bte", y, params["ffn_gate"])
    df = gu.shape[-1] // 2
    y2 = silu(gu[..., :df]) * gu[..., df:]
    return jnp.einsum("btf,fd->btd", y2, params["ffn_down"]), new_cache
