"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437].

61L d_model=7168 128H (GQA kv=128) d_ff=2048 (per routed expert)
vocab=129280, MoE 256e top-8.  First 3 layers are dense (d_ff=18432 per the
V3 paper); the remaining 58 are MoE.  MLA: kv_lora=512, q_lora=1536,
rope=64, nope=128, v=128.  Sigmoid (aux-free-style) router.
"""
from __future__ import annotations

from dataclasses import replace

from ..models.config import LayerDef, MLAConfig, ModelConfig, MoEConfig, StageDef

_DENSE_FF = 18432      # V3 paper value for the 3 dense layers

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=_DENSE_FF,
    vocab_size=129280,
    head_dim=192,                       # nope 128 + rope 64
    stages=(
        StageDef((LayerDef("mla", "dense"),), 3),
        StageDef((LayerDef("mla", "moe"),), 58),
    ),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                  router="sigmoid"),
    mtp_depth=1,                        # multi-token prediction head
    source="arXiv:2412.19437",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        head_dim=48, d_ff=256, vocab_size=512,
        stages=(
            StageDef((LayerDef("mla", "dense"),), 1),
            StageDef((LayerDef("mla", "moe"),), 1),
        ),
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=16,
                      nope_head_dim=32, v_head_dim=32),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=64, n_shared=1,
                      router="sigmoid"),
        mtp_depth=0,
    )
