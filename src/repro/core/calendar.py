"""Time-slotted resource calendars for the shared link and device cores.

The controller allocates variable-length time-slots on every resource such
that no two tasks hold the same resource simultaneously (paper §3, "network
state").  The link is a unit-capacity resource; each edge device is a
capacity-C resource (C = 4 cores on the RPi2B).

Scalability rewrite (DESIGN.md §2)
----------------------------------
The seed implementation (kept as :mod:`repro.core.calendar_reference`)
answered every probe with an O(n) sweep over a flat reservation list, where
n is the number of *live reservations on the resource*, and garbage-collected
with a full O(n) rescan per admission call.  At the paper's scale (4 devices,
1296 frames) that is invisible; at 64-256 devices with thousands of in-flight
tasks it dominates admission latency, because the LP algorithm (§4) probes
``fits``/``load`` once per candidate device per completion time-point.

This module replaces the flat lists with three incremental structures:

1. ``_StepFn`` — a coalesced piecewise-constant *skyline* of resource usage,
   stored as parallel sorted arrays ``times[i]``/``vals[i]`` (usage is
   ``vals[i]`` on ``[times[i], times[i+1])``).  Point location is a single
   ``bisect`` (O(log n)); range queries (``max_usage``, ``fits``,
   ``free_cores``, ``load``) then touch only the k segments intersecting the
   query window — O(log n + k), with k bounded by the number of tasks
   *overlapping the window*, not the total task count.  Adjacent segments
   with equal usage are merged on every update, so a fully packed busy run
   (the link's steady state) collapses to ONE segment and
   ``earliest_slot`` skips it in O(1) instead of walking every reservation
   in the run.
2. Per-device sorted completion-time arrays (``_t2s``) — ``completion_times``
   becomes a bisect-windowed slice instead of a scan of every reservation;
   :meth:`NetworkState.completion_times` lazily merges the per-device sorted
   slices with ``heapq.merge`` (O(k log D) for k points across D devices).
3. Expiry min-heaps — ``gc(now)`` pops only reservations that actually died
   since the previous call (amortised O(log n) each) instead of rescanning
   everything; the step function truncates its history in one splice.

Invariants (checked by tests/test_calendar.py and the differential suite in
tests/test_calendar_equivalence.py):

* ``times`` is strictly increasing with ``times[0] == -inf``; no two adjacent
  ``vals`` are equal (coalesced); the final segment always decays to 0
  because every reservation is finite.
* After ``gc(now)``, answers are only defined for query windows with
  ``t >= now`` (history before ``now`` is collapsed into the sentinel
  segment).  This matches how the scheduler uses the calendars: it always
  garbage-collects to the current controller time before probing.
* EPS semantics match the reference: sub-EPS overlaps are ignored by
  queries, and ``earliest_slot`` accepts a gap of ``duration - EPS``.
"""
from __future__ import annotations

import heapq
import itertools
import math
from bisect import bisect_left, bisect_right, insort
from dataclasses import dataclass, field
from typing import Iterable, Iterator, Optional

EPS = 1e-9
_INF = math.inf


@dataclass
class Reservation:
    t1: float
    t2: float
    amount: int                    # cores (devices) or 1 (link)
    tag: object = None             # task id / message descriptor

    def overlaps(self, t1: float, t2: float) -> bool:
        return self.t1 < t2 - EPS and t1 < self.t2 - EPS


class _StepFn:
    """Coalesced piecewise-constant usage-over-time (the skyline).

    ``vals[i]`` is the usage on ``[times[i], times[i+1])``; the last segment
    extends to +inf.  ``floor`` is the horizon set by :meth:`gc`: updates
    and queries are clamped to it, so collapsed history can never corrupt
    live segments.
    """

    __slots__ = ("times", "vals", "floor")

    def __init__(self) -> None:
        self.times: list[float] = [-_INF]
        self.vals: list[int] = [0]
        self.floor: float = -_INF

    # -- updates --------------------------------------------------------- #
    def _cut(self, t: float) -> int:
        """Ensure a breakpoint at exactly t; return its segment index."""
        i = bisect_right(self.times, t) - 1
        if self.times[i] == t:
            return i
        self.times.insert(i + 1, t)
        self.vals.insert(i + 1, self.vals[i])
        return i + 1

    def add(self, t1: float, t2: float, amount: int) -> None:
        """Add ``amount`` to the usage over [t1, t2) (negative to remove)."""
        if t1 < self.floor:
            t1 = self.floor
        if t2 <= t1:
            return
        i1 = self._cut(t1)
        i2 = self._cut(t2)                    # t2 > t1 => i2 > i1, i1 stable
        for i in range(i1, i2):
            self.vals[i] += amount
        # re-coalesce around the touched range (keeps the arrays minimal)
        j = max(i1, 1)
        hi = i2
        while j <= hi and j < len(self.times):
            if self.vals[j] == self.vals[j - 1]:
                del self.times[j]
                del self.vals[j]
                hi -= 1
            else:
                j += 1

    def gc(self, now: float) -> None:
        """Collapse all history before ``now`` into the sentinel segment."""
        if now <= self.floor:
            return
        self.floor = now
        i = bisect_right(self.times, now) - 1
        if i > 0:
            v = self.vals[i]
            del self.times[1 : i + 1]
            del self.vals[1 : i + 1]
            self.vals[0] = v

    # -- queries --------------------------------------------------------- #
    def max_over(self, t1: float, t2: float) -> int:
        """Max usage over [t1, t2); 0 for empty windows."""
        if t2 <= t1:
            return 0
        times, vals = self.times, self.vals
        i = bisect_right(times, t1) - 1
        m = vals[i]
        i += 1
        n = len(times)
        while i < n and times[i] < t2:
            if vals[i] > m:
                m = vals[i]
            i += 1
        return m

    def exceeds(self, t1: float, t2: float, limit: int) -> bool:
        """True iff usage ever exceeds ``limit`` on [t1, t2) (early exit)."""
        if t2 <= t1:
            return False
        times, vals = self.times, self.vals
        i = bisect_right(times, t1) - 1
        if vals[i] > limit:
            return True
        i += 1
        n = len(times)
        while i < n and times[i] < t2:
            if vals[i] > limit:
                return True
            i += 1
        return False

    def integral(self, t1: float, t2: float) -> float:
        """Usage-seconds over [t1, t2) (the ``load`` of the window)."""
        if t2 <= t1:
            return 0.0
        times, vals = self.times, self.vals
        i = bisect_right(times, t1) - 1
        n = len(times)
        total = 0.0
        while i < n and times[i] < t2:
            if vals[i]:
                a = times[i] if times[i] > t1 else t1
                b = times[i + 1] if i + 1 < n and times[i + 1] < t2 else t2
                total += vals[i] * (b - a)
            i += 1
        return total

    def first_fit(self, duration: float, not_before: float, limit: int) -> float:
        """Earliest t >= not_before with usage <= limit over [t, t+duration).

        Because the skyline is coalesced, a contiguous busy run — no matter
        how many reservations it packs — is a single segment and is skipped
        in O(1).
        """
        times, vals = self.times, self.vals
        t = not_before if not_before > self.floor else self.floor
        i = bisect_right(times, t) - 1
        n = len(times)
        cand = t
        while True:
            if vals[i] > limit:
                i += 1
                if i >= n:              # unreachable: final segment is free
                    return cand
                cand = times[i]
            else:
                seg_end = times[i + 1] if i + 1 < n else _INF
                if seg_end - cand >= duration - EPS:
                    return cand
                i += 1


class LinkCalendar:
    """Unit-capacity calendar for the shared wireless link.

    ``earliest_slot`` is an O(log n + runs) skyline walk; ``gc`` retires only
    the slots that expired since the previous call (expiry min-heap).
    """

    def __init__(self) -> None:
        self._starts: list[float] = []          # sorted by t1, parallel to
        self._res: list[Reservation] = []       # the live reservation list
        self._expiry: list[tuple[float, int, Reservation]] = []
        self._seq = itertools.count()
        self._sky = _StepFn()

    def __len__(self) -> int:
        return len(self._res)

    def reservations(self) -> Iterable[Reservation]:
        return iter(self._res)

    def earliest_slot(self, duration: float, not_before: float) -> float:
        """Earliest t >= not_before such that [t, t+duration) is free."""
        return self._sky.first_fit(duration, not_before, 0)

    def reserve(self, t1: float, t2: float, tag: object = None) -> Reservation:
        r = Reservation(t1, t2, 1, tag)
        idx = bisect_left(self._starts, t1)
        self._starts.insert(idx, t1)
        self._res.insert(idx, r)
        self._sky.add(t1, t2, 1)
        heapq.heappush(self._expiry, (t2, next(self._seq), r))
        return r

    def reserve_earliest(
        self, duration: float, not_before: float, tag: object = None
    ) -> Reservation:
        t1 = self.earliest_slot(duration, not_before)
        return self.reserve(t1, t1 + duration, tag)

    def _locate(self, res: Reservation) -> int:
        """Index of ``res`` in the live list, -1 if absent (O(log n + dups))."""
        idx = bisect_left(self._starts, res.t1)
        while idx < len(self._res) and self._starts[idx] == res.t1:
            if self._res[idx] is res or self._res[idx] == res:
                return idx
            idx += 1
        return -1

    def cancel(self, res: Reservation) -> None:
        """Remove a reservation; cancelling twice (or a foreign/expired slot)
        is a no-op."""
        idx = self._locate(res)
        if idx < 0:
            return
        r = self._res[idx]
        del self._res[idx]
        del self._starts[idx]
        self._sky.add(r.t1, r.t2, -1)

    def gc(self, now: float) -> None:
        """Retire slots with t2 <= now.  Amortised O(log n) per dead slot."""
        heap = self._expiry
        while heap and heap[0][0] <= now:
            _, _, r = heapq.heappop(heap)
            idx = self._locate(r)
            if idx >= 0 and self._res[idx].t2 <= now:
                del self._res[idx]
                del self._starts[idx]
        self._sky.gc(now)


class DeviceCalendar:
    """Capacity-C calendar for one edge device's cores.

    Core-usage queries go through the skyline; ``completion_times`` reads a
    bisect-window of the sorted ``_t2s`` array; reservation identity
    (reserve / release / truncate by tag) stays a dict, which the preemption
    path also uses to enumerate conflict candidates.
    """

    def __init__(self, device: int, capacity: int = 4) -> None:
        self.device = device
        self.capacity = capacity
        self._res: dict[object, Reservation] = {}
        self._sky = _StepFn()
        self._t2s: list[float] = []             # sorted completion times
        self._expiry: list[tuple[float, int, object]] = []
        self._seq = itertools.count()

    def __len__(self) -> int:
        return len(self._res)

    def reservations(self) -> Iterable[Reservation]:
        return self._res.values()

    # -- queries (all O(log n + segments-in-window)) ---------------------- #
    def max_usage(self, t1: float, t2: float) -> int:
        # Shrink by EPS so sub-EPS boundary overlaps are ignored, matching
        # Reservation.overlaps() in the reference implementation.
        return self._sky.max_over(t1 + EPS, t2 - EPS)

    def free_cores(self, t1: float, t2: float) -> int:
        return self.capacity - self.max_usage(t1, t2)

    def fits(self, t1: float, t2: float, cores: int) -> bool:
        return not self._sky.exceeds(t1 + EPS, t2 - EPS, self.capacity - cores)

    def load(self, t1: float, t2: float) -> float:
        """Reserved core-seconds overlapping [t1, t2) (for even spreading)."""
        return self._sky.integral(t1, t2)

    def earliest_fit(self, duration: float, not_before: float, cores: int) -> float:
        """Earliest t >= not_before where ``cores`` fit for ``duration``."""
        return self._sky.first_fit(duration, not_before, self.capacity - cores)

    def completion_times(self, after: float, before: float) -> list[float]:
        lo = bisect_right(self._t2s, after + EPS)
        hi = bisect_left(self._t2s, before - EPS, lo)
        return [t for t, _ in itertools.groupby(self._t2s[lo:hi])]

    def _completion_window(self, after: float, before: float) -> list[float]:
        """Sorted (possibly duplicated) slice for NetworkState's k-way merge."""
        lo = bisect_right(self._t2s, after + EPS)
        hi = bisect_left(self._t2s, before - EPS, lo)
        return self._t2s[lo:hi]

    # -- updates ---------------------------------------------------------- #
    def reserve(self, t1: float, t2: float, cores: int, tag: object) -> Reservation:
        prev = self._res.pop(tag, None)
        if prev is not None:                    # re-reserving a tag replaces it
            self._remove_interval(prev)
        r = Reservation(t1, t2, cores, tag)
        self._res[tag] = r
        self._sky.add(t1, t2, cores)
        insort(self._t2s, t2)
        heapq.heappush(self._expiry, (t2, next(self._seq), tag))
        return r

    def _remove_interval(self, r: Reservation) -> None:
        self._sky.add(r.t1, r.t2, -r.amount)
        i = bisect_left(self._t2s, r.t2)
        if i < len(self._t2s) and self._t2s[i] == r.t2:
            del self._t2s[i]

    def release(self, tag: object) -> Optional[Reservation]:
        r = self._res.pop(tag, None)
        if r is not None:
            self._remove_interval(r)
        return r

    def get(self, tag: object) -> Optional[Reservation]:
        return self._res.get(tag)

    def truncate(self, tag: object, t_end: float) -> None:
        """Shorten a reservation (early completion / violation).  Truncating
        to (or before) its start removes it entirely."""
        r = self._res.get(tag)
        if r is None:
            return
        if t_end <= r.t1 + EPS:
            self._res.pop(tag)
            self._remove_interval(r)
            return
        if t_end >= r.t2:
            return
        self._sky.add(t_end, r.t2, -r.amount)
        i = bisect_left(self._t2s, r.t2)
        if i < len(self._t2s) and self._t2s[i] == r.t2:
            del self._t2s[i]
        insort(self._t2s, t_end)
        r.t2 = t_end
        heapq.heappush(self._expiry, (t_end, next(self._seq), tag))

    def gc(self, now: float) -> None:
        """Retire reservations with t2 <= now; O(log n) per retirement.

        In-flight reservations straddling ``now`` keep their full remaining
        interval; their pre-``now`` history is collapsed by the skyline."""
        heap, res = self._expiry, self._res
        while heap and heap[0][0] <= now:
            t2, _, tag = heapq.heappop(heap)
            r = res.get(tag)
            if r is None:
                continue
            if r.t2 <= now:
                del res[tag]
            elif r.t2 != t2:
                # stale entry (tag was truncated/re-reserved); re-index
                heapq.heappush(heap, (r.t2, next(self._seq), tag))
        lo = bisect_right(self._t2s, now)
        if lo:
            del self._t2s[:lo]
        self._sky.gc(now)


@dataclass
class NetworkState:
    """The controller's perception of all network resources (paper §3)."""

    n_devices: int
    capacity: int = 4
    link: LinkCalendar = field(default_factory=LinkCalendar)
    devices: list[DeviceCalendar] = field(default_factory=list)

    def __post_init__(self) -> None:
        if not self.devices:
            self.devices = [
                DeviceCalendar(d, self.capacity) for d in range(self.n_devices)
            ]

    def completion_times(self, after: float, before: float) -> list[float]:
        """Sorted unique completion time-points in (after, before), network
        wide — the LP algorithm's §4 search grid.  k-way merge of per-device
        pre-sorted windows: O(k log D) for k points over D devices."""
        windows = [
            w for d in self.devices if (w := d._completion_window(after, before))
        ]
        if not windows:
            return []
        if len(windows) == 1:
            return [t for t, _ in itertools.groupby(windows[0])]
        return [t for t, _ in itertools.groupby(heapq.merge(*windows))]

    def iter_completion_times(self, after: float, before: float) -> Iterator[float]:
        """Lazy variant of :meth:`completion_times`: yields the same sorted
        unique points, but pays O(log D) per *consumed* point instead of
        merging the whole window up front.  The LP sweep usually allocates
        within the first few time-points, so most of the merge never runs.

        The device windows are snapshot slices taken EAGERLY, at call time —
        not at first ``next()`` — so reservations committed while iterating
        do not perturb the grid (the seed's snapshot semantics; a lazily
        snapshotting generator would let the first sweep round's commits
        leak into the grid)."""
        windows = [
            w for d in self.devices if (w := d._completion_window(after, before))
        ]
        heap = [(w[0], i, 0) for i, w in enumerate(windows)]
        heapq.heapify(heap)

        def merge() -> Iterator[float]:
            last = None
            while heap:
                v, i, p = heapq.heappop(heap)
                if v != last:
                    last = v
                    yield v
                p += 1
                w = windows[i]
                if p < len(w):
                    heapq.heappush(heap, (w[p], i, p))

        return merge()

    def total_allocated_tasks(self) -> int:
        return sum(len(d) for d in self.devices)

    def gc(self, now: float) -> None:
        self.link.gc(now)
        for d in self.devices:
            d.gc(now)
