"""The four assigned input shapes.

Decode shapes lower ``serve_step`` (ONE new token against a KV/state cache of
``seq_len``), not ``train_step``.  ``long_500k`` requires sub-quadratic
attention: SSM/hybrid archs run natively; attention archs run a
sliding-window KV-cache variant (window = cfg.long_context_window) — see
DESIGN.md §8.2 (Shape/skip policy).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
