"""Preemption-aware serving engine — the paper's scheduler as a first-class
TPU-serving feature.

Mapping (DESIGN.md §3):
  edge device (4 cores)      -> pod slice with C shard-units
  HP stage-2 classifier      -> interactive prefill request (latency SLO)
  LP stage-3 DNN set         -> background batch-decode jobs (offloadable)
  2-/4-core partitioning     -> 2-/4-way model-parallel degree
  shared 802.11n link        -> inter-slice interconnect (token/KV transfer)
  preempt + reallocate       -> evict decode job between steps, requeue

Two preemption modes:
  lose_work=True   paper-faithful: a preempted job loses all progress.
  lose_work=False  beyond-paper: decode state (KV cache) stays resident in
                   HBM, so a resumed job continues from its last token.

The engine runs in *virtual time* driven by the same time-slotted calendars
as the reproduction (we have one CPU, not a pod), while the actual token
generation is REAL jax compute — scheduling decisions and deadline outcomes
come from the calendar; logits come from the model.

Scheduling is pluggable (DESIGN.md §9): the ``policy`` argument resolves
through the policy registry, so the engine drives any *slot-based*
registered discipline ("scheduler", "edf_only", "no_offload", ...) through
the same ``PolicyDispatcher`` admission/execution loop as the sim.
Execution-driving policies (the workstealers' processor sharing) have no
reserved slots to pin real compute to and are rejected with a clear error.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..core.metrics import Metrics
from ..core.network import NetworkConfig
from ..core.policy import DispatchClient, PolicyDispatcher, create_policy
from ..core.profiles import WorkloadSpec
from ..core.task import LowPriorityRequest, Priority, Task, TaskState
from ..models.config import ModelConfig
from ..sim.events import EventQueue
from ..training.steps import make_prefill_step, make_serve_step
from .cost_model import CostModel
from .stream import validate_submission

_rid = itertools.count()


def engine_network_config(cost: CostModel, lp_tokens: int,
                          link_gbps: float = 40.0,
                          workload: Optional[WorkloadSpec] = None,
                          ) -> NetworkConfig:
    """Build the time-slot model from measured step costs (the paper derives
    slot lengths from offline benchmarks + std-dev padding; we do the same
    from the CostModel).  The 'link' is the inter-slice interconnect; message
    sizes keep the paper's control-plane values, with the input transfer
    sized as a prompt's KV handoff.

    The timing model is a real :class:`WorkloadSpec` (built from ``cost``
    via ``WorkloadSpec.from_cost_model`` unless an explicit multi-model
    ``workload`` is given) rather than constants folded into the three
    legacy fields: per-degree slot padding is each degree's own measured
    std-dev, and a mixed spec serves several model profiles from one
    engine.  The default profile's numbers are mirrored into the legacy
    scalar fields for direct readers."""
    spec = workload if workload is not None else WorkloadSpec.from_cost_model(
        cost, lp_tokens=lp_tokens, name="serve")
    prof = spec.profile()
    degs = prof.core_options
    return NetworkConfig(
        throughput_bps=link_gbps * 1e9 / 8,
        jitter_pad_s=1e-4,
        t_hp=prof.hp_exec,
        t_lp_2core=prof.lp_exec.get(2, prof.lp_exec[degs[0]]),
        t_lp_4core=prof.lp_exec.get(4, prof.lp_exec[degs[-1]]),
        hp_pad_s=prof.hp_pad,
        lp_pad_s=prof.lp_pad[degs[0]],
        t_object_detect=0.0,
        frame_period=max(prof.lp_exec[degs[0]] * 1.1, 1e-3),
        hp_deadline_slack=prof.hp_deadline_slack,
        workload=spec,
    )


@dataclass(eq=False)                      # identity equality: the prompt is
class ServeRequest:                       # a jax array (dataclass __eq__
                                          # would compare it elementwise)
    prompt: Any                          # [1, T] int32 tokens
    max_new_tokens: int
    priority: Priority
    deadline: float                      # virtual-time deadline
    home_slice: int
    # Workload-profile key (core/profiles.py): which model profile sizes
    # this request's slots.  None = the engine workload's default profile.
    task_type: Optional[str] = None
    arrival: float = 0.0
    rid: int = field(default_factory=lambda: next(_rid))
    # results
    tokens_out: list[int] = field(default_factory=list)
    state: str = "pending"               # pending|running|done|failed|preempted
    completed_at: float = -1.0
    n_preemptions: int = 0
    task: Optional[Task] = None


class _ServingClient(DispatchClient):
    """Dispatcher hooks for the engine (real compute, request bookkeeping)."""

    def __init__(self, eng: "PreemptiveServingEngine") -> None:
        self.eng = eng

    def on_start(self, task: Task) -> None:
        self.eng._run_compute(task)

    def on_hp_complete(self, task: Task) -> None:
        self.eng._finish_request(task)

    def on_lp_complete(self, task: Task) -> None:
        self.eng.metrics.lp_requests_completed += 1
        self.eng._finish_request(task)

    def on_preempt(self, task: Task) -> None:
        eng = self.eng
        req = eng._by_task.get(task)
        if req is None:
            return
        req.n_preemptions += 1
        req.state = "preempted"
        if eng.lose_work:
            eng._decode_state.pop(req.rid, None)
            req.tokens_out = []

    def on_admit_fail(self, task: Task) -> None:
        eng = self.eng
        req = eng._by_task.get(task)
        if req is None:
            return
        req.state = "failed"
        eng.done.append(req)

    def on_device_lost(self, task: Task) -> None:
        # The slice holding this request's decode state died: unlike a
        # preemption under lose_work=False, the resident KV cache is gone
        # with the hardware, so a recovered orphan always restarts.
        eng = self.eng
        req = eng._by_task.get(task)
        if req is None:
            return
        req.n_preemptions += 1
        req.state = "preempted"
        eng._decode_state.pop(req.rid, None)
        req.tokens_out = []


class PreemptiveServingEngine:
    """Priority/deadline/preemption-aware engine over N slices."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        cost: CostModel,
        *,
        n_slices: int = 4,
        units_per_slice: int = 4,
        preemption: bool = True,
        lose_work: bool = True,
        cache_len: int = 256,
        net: Optional[NetworkConfig] = None,
        victim_policy: str = "farthest_deadline",
        policy: str = "scheduler",
    ) -> None:
        self.cfg = cfg
        self.params = params
        self.cost = cost
        self.cache_len = cache_len
        self.lose_work = lose_work
        self.q = EventQueue()
        self.metrics = Metrics("serving")
        self.net = net or NetworkConfig()
        self.policy = create_policy(
            policy,
            n_devices=n_slices,
            net=self.net,
            capacity=units_per_slice,
            preemption=preemption,
            victim_policy=victim_policy,
            metrics=self.metrics,
        )
        if self.policy.drives_execution:
            raise ValueError(
                f"policy {policy!r} drives its own execution model; the "
                "serving engine requires a slot-based policy (reserved "
                "[t_start, t_end) windows to pin real compute to)"
            )
        # slice calendars (tests and cost probes read occupancy off this)
        self.state = getattr(self.policy, "state", None)
        self.dispatcher = PolicyDispatcher(
            self.policy, self.q, self.net, self.metrics,
            client=_ServingClient(self), exact_slots=True,
        )
        self._prefill = jax.jit(make_prefill_step(cfg, cache_len))
        self._serve = jax.jit(make_serve_step(cfg))
        self._by_task: dict[Task, ServeRequest] = {}
        self._decode_state: dict[int, tuple] = {}   # rid -> (caches, last, pos)
        self.done: list[ServeRequest] = []

    # ------------------------------------------------------------------ #
    # Submission                                                          #
    # ------------------------------------------------------------------ #
    def submit(self, req: ServeRequest) -> None:
        validate_submission(
            priority=req.priority, deadline=req.deadline, now=self.q.now,
            max_new_tokens=req.max_new_tokens, task_type=req.task_type,
            spec=self.net.spec)
        req.arrival = self.q.now
        self.q.push(self.q.now, lambda: self._admit(req))

    def submit_batch(self, reqs: list[ServeRequest]) -> None:
        """Admit a burst of requests at the same virtual instant.

        LP requests go through the policy's batch decision (one sweep across
        the whole burst — DESIGN.md §4.3); HP requests keep per-request
        admission, since each may preempt and must observe the link state its
        predecessors left behind.
        """
        lp = [r for r in reqs if r.priority == Priority.LOW]
        for r in reqs:
            if r.priority == Priority.HIGH:
                self.submit(r)
        if lp:
            for r in lp:
                validate_submission(
                    priority=r.priority, deadline=r.deadline, now=self.q.now,
                    max_new_tokens=r.max_new_tokens, task_type=r.task_type,
                    spec=self.net.spec)
                r.arrival = self.q.now
            self.q.push(self.q.now, lambda: self._admit_lp_batch(lp))

    def _make_lp(self, req: ServeRequest, now: float) -> LowPriorityRequest:
        """Wrap a serve request as a one-task LP request and register it."""
        self.metrics.lp_generated += 1
        self.metrics.lp_requests_total += 1
        lp = LowPriorityRequest(
            source_device=req.home_slice, deadline=req.deadline,
            frame_id=req.rid, n_tasks=1, task_type=req.task_type,
            created_at=now)
        lp.make_tasks()
        task = lp.tasks[0]
        self._by_task[task] = req
        req.task = task
        return lp

    def _admit_lp_batch(self, reqs: list[ServeRequest]) -> None:
        now = self.q.now
        lps = [self._make_lp(req, now) for req in reqs]
        self.dispatcher.submit_lp_batch(lps)

    def _admit(self, req: ServeRequest) -> None:
        now = self.q.now
        if req.priority == Priority.HIGH:
            task = Task(priority=req.priority, source_device=req.home_slice,
                        deadline=req.deadline, frame_id=req.rid,
                        task_type=req.task_type)
            req.task = task
            self._by_task[task] = req
            self.metrics.hp_generated += 1
            self.dispatcher.submit_hp(task)
        else:
            self.dispatcher.submit_lp(self._make_lp(req, now))

    # ------------------------------------------------------------------ #
    # Execution (real compute at virtual-time slot boundaries)            #
    # ------------------------------------------------------------------ #
    def _run_compute(self, task: Task) -> None:
        """The reserved slot began: run the request's actual jax compute."""
        req = self._by_task[task]
        req.state = "running"
        if req.priority == Priority.HIGH:
            nxt, _ = self._prefill(self.params, {"tokens": req.prompt})
            req.tokens_out = [int(nxt[0])]
        else:
            # run prefill now (or resume), decode tokens as the slot elapses
            if req.rid in self._decode_state and not self.lose_work:
                caches, last, pos = self._decode_state[req.rid]
            else:
                req.tokens_out = []
                nxt, caches = self._prefill(self.params,
                                            {"tokens": req.prompt})
                last = nxt[:, None]
                pos = req.prompt.shape[1]
                req.tokens_out.append(int(nxt[0]))
            remaining = req.max_new_tokens - len(req.tokens_out)
            for _ in range(remaining):
                last, caches = self._serve(self.params, caches, last,
                                           jnp.asarray(pos, jnp.int32))
                req.tokens_out.append(int(last[0, 0]))
                pos += 1
            self._decode_state[req.rid] = (caches, last, pos)

    def _finish_request(self, task: Task) -> None:
        req = self._by_task[task]
        req.state = "done"
        req.completed_at = self.q.now
        self._decode_state.pop(req.rid, None)
        self.done.append(req)

    # ------------------------------------------------------------------ #
    # Slice churn (DESIGN.md §16)                                        #
    # ------------------------------------------------------------------ #
    def fail_slice(self, idx: int):
        """A pod slice died mid-run: its in-flight requests orphan, lose
        their resident decode state, and recover elsewhere (or fail)."""
        return self.dispatcher.device_lost(idx)

    def drain_slice(self, idx: int) -> None:
        self.dispatcher.device_drained(idx)

    def rejoin_slice(self, idx: int) -> None:
        self.dispatcher.device_rejoined(idx)

    # ------------------------------------------------------------------ #
    def run(self, until: Optional[float] = None) -> Metrics:
        self.q.run(until)
        for req in self._by_task.values():
            if req.state in ("pending", "preempted", "running") and \
                    req not in self.done:
                if req.task is not None and \
                        req.task.state == TaskState.FAILED:
                    req.state = "failed"
        return self.metrics
