"""jit'd wrapper: sLSTM scan over model-layout inputs, Pallas or oracle."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import slstm_scan
from .ref import slstm_scan_ref


@partial(jax.jit, static_argnames=("use_pallas", "block_t", "interpret"))
def slstm_hidden_states(
    wx: jax.Array,            # [B, T, 4, H, dh] gate pre-activations (x @ w)
    r: jax.Array,             # [4, H, dh, dh]
    b: jax.Array,             # [4, H, dh]
    *,
    use_pallas: bool = True,
    block_t: int = 128,
    interpret: bool = True,
) -> jax.Array:
    if use_pallas:
        return slstm_scan(wx, r, b, block_t=block_t, interpret=interpret)
    return slstm_scan_ref(wx, r, b)
