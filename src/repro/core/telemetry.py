"""Incremental serving telemetry: flat-memory sketches for open-ended runs.

The closed-workload paths accumulate raw per-call latency lists
(``Metrics.t_hp_initial`` et al.) and post-process them with
``np.percentile`` — fine for 1296 frames, unbounded for a firehose.  This
module provides the streaming engine's telemetry substrate (DESIGN.md §14):
every structure here is **fixed-size by construction**, so a soak run of
millions of requests holds the same few tens of kilobytes of telemetry at
request 10^7 as at request 10^3 (the RSS-flatness gate in
``benchmarks/soak.py`` leans on this).

* :class:`LogHistogram` — a log-bucketed quantile sketch (HDR-histogram
  style): geometric bucket edges with growth factor ``g`` over a fixed
  ``[lo, hi)`` range, counts in one preallocated int64 array.  Recording is
  O(log buckets) (one ``searchsorted``); quantile queries are one cumsum
  over the fixed array.  **Error bound**: a value is returned as its
  bucket's geometric midpoint, so every quantile estimate is within a
  multiplicative ``sqrt(g)`` of some true sample in that quantile's bucket
  — relative error ≤ ``sqrt(g) - 1`` (≈ 1% at the default g = 1.02),
  independent of how many values were recorded.  Min/max/sum/count are
  tracked exactly.
* :class:`RingSampler` — a fixed-capacity ring of ``(t, value)`` samples
  (queue depths, RSS readings): keeps the most recent ``capacity``.
* :class:`SloTracker` — per-task-type attained/missed SLO counters
  (bounded by the number of task types).
* :class:`BoundedSeries` — a list-compatible sink used to cap the
  ``Metrics`` latency lists on the streaming path: ``append`` feeds a
  sketch plus a bounded recent-window deque instead of growing a list.
* :class:`StreamTelemetry` — the composite the streaming engine records
  into, with a JSON-friendly ``snapshot()``.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Any, Iterable, Optional

import numpy as np


class LogHistogram:
    """Fixed-size log-bucketed quantile sketch over ``[lo, hi)``.

    Values below ``lo`` land in the underflow bucket (reported as ``lo``),
    values at or above ``hi`` in the overflow bucket (reported as ``hi``) —
    both still count toward quantile ranks, so saturation shows up as a
    pinned tail rather than a silent drop.
    """

    __slots__ = ("lo", "hi", "growth", "_edges", "_counts", "count",
                 "total", "vmin", "vmax")

    def __init__(self, lo: float = 1e-7, hi: float = 1e5,
                 growth: float = 1.02) -> None:
        if not (lo > 0.0 and hi > lo and growth > 1.0):
            raise ValueError("LogHistogram requires 0 < lo < hi, growth > 1")
        self.lo, self.hi, self.growth = lo, hi, growth
        n = int(math.ceil(math.log(hi / lo) / math.log(growth)))
        # interior bucket edges lo*g^1 .. lo*g^(n-1); bucket 0 = underflow
        # [0, lo), bucket n+1 = overflow [hi, inf)
        self._edges = lo * np.power(growth, np.arange(n + 1))
        self._counts = np.zeros(n + 2, dtype=np.int64)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    @property
    def nbytes(self) -> int:
        """Fixed allocation size (proven flat in tests/test_telemetry.py)."""
        return self._edges.nbytes + self._counts.nbytes

    def record(self, value: float) -> None:
        idx = int(np.searchsorted(self._edges, value, side="right"))
        self._counts[idx] += 1
        self.count += 1
        self.total += value
        if value < self.vmin:
            self.vmin = value
        if value > self.vmax:
            self.vmax = value

    def record_many(self, values: Iterable[float]) -> None:
        arr = np.asarray(list(values) if not isinstance(values, np.ndarray)
                         else values, dtype=np.float64)
        if arr.size == 0:
            return
        idx = np.searchsorted(self._edges, arr, side="right")
        np.add.at(self._counts, idx, 1)
        self.count += int(arr.size)
        self.total += float(arr.sum())
        self.vmin = min(self.vmin, float(arr.min()))
        self.vmax = max(self.vmax, float(arr.max()))

    def quantile(self, q: float) -> float:
        """The bucket-midpoint estimate of the ``q``-quantile (0 <= q <= 1);
        0.0 for an empty sketch.  Exact min/max are used for the extreme
        buckets so q=0/q=1 report true extremes."""
        if self.count == 0:
            return 0.0
        rank = q * (self.count - 1)
        cum = np.cumsum(self._counts)
        idx = int(np.searchsorted(cum, rank, side="right"))
        return self._bucket_value(idx)

    def quantiles(self, qs: Iterable[float]) -> list[float]:
        if self.count == 0:
            return [0.0 for _ in qs]
        cum = np.cumsum(self._counts)
        return [self._bucket_value(int(np.searchsorted(
            cum, q * (self.count - 1), side="right"))) for q in qs]

    def _bucket_value(self, idx: int) -> float:
        edges = self._edges
        if idx <= 0:                       # underflow [0, lo)
            return min(self.lo, max(self.vmin, 0.0))
        if idx >= len(edges):              # overflow [hi, inf)
            return max(self.hi, self.vmax)
        # geometric midpoint of [edges[idx-1], edges[idx]) — clamp into the
        # exactly-tracked extremes so tiny samples don't over-report
        mid = math.sqrt(edges[idx - 1] * edges[idx])
        return float(min(max(mid, self.vmin), self.vmax))

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def merge(self, other: "LogHistogram") -> None:
        """Fold another sketch with identical geometry into this one."""
        if (other.lo, other.hi, other.growth) != \
                (self.lo, self.hi, self.growth):
            raise ValueError("cannot merge LogHistograms with different "
                             "geometry (lo/hi/growth)")
        self._counts += other._counts
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)

    def snapshot(self) -> dict[str, float]:
        p50, p99, p999 = self.quantiles((0.50, 0.99, 0.999))
        return {
            "count": self.count,
            "mean": self.mean,
            "p50": p50,
            "p99": p99,
            "p999": p999,
            "max": self.vmax if self.count else 0.0,
        }


class RingSampler:
    """Fixed-capacity ring buffer of ``(t, value)`` samples."""

    __slots__ = ("_t", "_v", "_n", "_i", "capacity", "total_samples")

    def __init__(self, capacity: int = 512) -> None:
        if capacity <= 0:
            raise ValueError("RingSampler capacity must be positive")
        self.capacity = capacity
        self._t = np.zeros(capacity, dtype=np.float64)
        self._v = np.zeros(capacity, dtype=np.float64)
        self._n = 0                   # live sample count (<= capacity)
        self._i = 0                   # next write slot
        self.total_samples = 0        # lifetime count (overwrites included)

    def sample(self, t: float, value: float) -> None:
        self._t[self._i] = t
        self._v[self._i] = value
        self._i = (self._i + 1) % self.capacity
        self._n = min(self._n + 1, self.capacity)
        self.total_samples += 1

    def values(self) -> np.ndarray:
        """Live samples' values, oldest first."""
        if self._n < self.capacity:
            return self._v[:self._n].copy()
        return np.concatenate((self._v[self._i:], self._v[:self._i]))

    def times(self) -> np.ndarray:
        if self._n < self.capacity:
            return self._t[:self._n].copy()
        return np.concatenate((self._t[self._i:], self._t[:self._i]))

    def __len__(self) -> int:
        return self._n

    def snapshot(self) -> dict[str, float]:
        v = self.values()
        if v.size == 0:
            return {"count": 0, "mean": 0.0, "max": 0.0, "last": 0.0}
        return {
            "count": self.total_samples,
            "mean": float(v.mean()),
            "max": float(v.max()),
            "last": float(v[-1]),
        }


class SloTracker:
    """Per-task-type SLO attainment: attained (completed before deadline)
    vs missed (failed at admission, shed, or overran).  Bounded by the
    number of task types in the workload."""

    __slots__ = ("_counts",)

    def __init__(self) -> None:
        self._counts: dict[str, list[int]] = {}   # type -> [attained, missed]

    def record(self, task_type: Optional[str], attained: bool) -> None:
        row = self._counts.setdefault(task_type or "default", [0, 0])
        row[0 if attained else 1] += 1

    def attainment(self, task_type: Optional[str] = None) -> float:
        row = self._counts.get(task_type or "default")
        if row is None or (row[0] + row[1]) == 0:
            return 0.0
        return row[0] / (row[0] + row[1])

    def snapshot(self) -> dict[str, dict[str, float]]:
        out = {}
        for t, (ok, miss) in sorted(self._counts.items()):
            total = ok + miss
            out[t] = {
                "attained": ok,
                "missed": miss,
                "attainment_pct": round(100.0 * ok / total, 2) if total
                else 0.0,
            }
        return out


class BoundedSeries:
    """A list-compatible latency sink with O(1) memory.

    The scheduler appends wall-clock samples to ``Metrics`` list fields
    (``t_hp_initial`` …); on the streaming path those lists are swapped for
    this: ``append`` feeds a :class:`LogHistogram` and a bounded
    recent-window deque.  ``len``/``bool`` reflect the lifetime count;
    iteration yields only the recent window (so ``statistics.mean`` over it
    is a windowed mean — the exact lifetime mean is ``.mean()``).
    """

    __slots__ = ("sketch", "recent")

    def __init__(self, sketch: Optional[LogHistogram] = None,
                 window: int = 256) -> None:
        self.sketch = sketch if sketch is not None else LogHistogram()
        self.recent: deque = deque(maxlen=window)

    def append(self, value: float) -> None:
        self.sketch.record(value)
        self.recent.append(value)

    def extend(self, values: Iterable[float]) -> None:
        for v in values:
            self.append(v)

    def mean(self) -> float:
        return self.sketch.mean

    def __len__(self) -> int:
        return self.sketch.count

    def __bool__(self) -> bool:
        return self.sketch.count > 0

    def __iter__(self):
        return iter(self.recent)


class StreamTelemetry:
    """The streaming engine's composite telemetry (DESIGN.md §14).

    * ``admission`` — wall-clock seconds per admission decision (HP
      per-request; LP batched, recorded as the batch's amortised share).
    * ``e2e`` — *virtual-time* end-to-end latency of completed requests
      (completion − arrival, includes queueing delay).
    * ``queue_depth`` — sampled once per admission window.
    * ``slo`` — per-task-type attainment over all terminal requests.
    * shed counters by reason (``queue_full`` / ``expired``) plus degrade
      and backpressure-signal counters.

    Everything is fixed-size; ``snapshot()`` is JSON-ready.
    """

    def __init__(self, *, depth_samples: int = 512) -> None:
        # admission latencies are wall-clock seconds: 100 ns .. 100 s
        self.admission = LogHistogram(lo=1e-7, hi=1e2)
        # e2e latencies are virtual seconds: 1 ms .. ~28 h
        self.e2e = LogHistogram(lo=1e-3, hi=1e5)
        self.queue_depth = RingSampler(depth_samples)
        self.slo = SloTracker()
        self.shed_queue_full = 0
        self.shed_expired = 0
        self.degraded = 0
        self.soft_signals = 0
        self.offered = 0
        self.admitted_hp = 0
        self.admitted_lp = 0
        self.windows = 0
        # Churn plane (DESIGN.md §16): recovery latency of re-placed
        # orphans — virtual seconds from the device-loss instant to the
        # replacement slot's start (how long the orphaned work stalls).
        # Fixed-size like every other sketch; empty without churn.
        self.recovery_delay = LogHistogram(lo=1e-4, hi=1e5)
        self.devices_failed = 0
        self.devices_drained = 0
        self.devices_rejoined = 0
        self.orphans_seen = 0
        self.orphans_recovered = 0

    @property
    def shed_total(self) -> int:
        return self.shed_queue_full + self.shed_expired

    def snapshot(self) -> dict[str, Any]:
        out = {
            "offered": self.offered,
            "admitted_hp": self.admitted_hp,
            "admitted_lp": self.admitted_lp,
            "windows": self.windows,
            "shed_total": self.shed_total,
            "shed_queue_full": self.shed_queue_full,
            "shed_expired": self.shed_expired,
            "degraded": self.degraded,
            "soft_signals": self.soft_signals,
            "admission_latency_s": self.admission.snapshot(),
            "e2e_latency_s": self.e2e.snapshot(),
            "queue_depth": self.queue_depth.snapshot(),
            "slo": self.slo.snapshot(),
        }
        if self.devices_failed or self.devices_drained or self.devices_rejoined:
            # Present only under churn: churn-free snapshots keep their
            # historic key set (byte-compared by the zero-churn
            # differential in tests/test_accounting_invariants.py).
            out["churn"] = {
                "devices_failed": self.devices_failed,
                "devices_drained": self.devices_drained,
                "devices_rejoined": self.devices_rejoined,
                "orphans_seen": self.orphans_seen,
                "orphans_recovered": self.orphans_recovered,
                "recovery_delay_s": self.recovery_delay.snapshot(),
            }
        return out
