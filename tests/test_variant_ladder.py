"""Variant ladder (DESIGN.md §17): degradation as a scheduling dimension.

Covers the ladder end to end:

* **profiles** — ladder construction/validation, ``variant_profile``
  clamping, the variant-0 equivalence invariant;
* **task** — the deprecated one-bit ``degraded`` view over ``variant``;
* **scheduler** — degrade-before-reject settle retries and the
  ``degrade_shrink`` victim policy (degrade-instead-of-evict);
* **serving** — the degrade shed policy walking a real ladder;
* **oracle** — variant option columns: the optimum degrades exactly when
  a completion (or a better accuracy-earliness product) is bought;
* **storm** — the degrade_storm gate scenario: strictly higher
  accuracy-weighted goodput at equal-or-better HP completion.
"""
import pytest

from repro.core.calendar import NetworkState
from repro.core.metrics import Metrics
from repro.core.network import resolve_network
from repro.core.oracle import OracleInstance
from repro.core.profiles import (
    TaskProfile,
    VariantSpec,
    get_workload,
)
from repro.core.scheduler import PreemptionAwareScheduler
from repro.core.task import (
    LowPriorityRequest,
    Priority,
    Task,
    TaskState,
    reset_id_counters,
)

LADDER = "paper_ladder"


def _scheduler(n_devices=1, capacity=4, workload=LADDER, **kw):
    net = resolve_network(None, workload)
    m = Metrics("ladder")
    state = NetworkState(n_devices, capacity=capacity)
    return PreemptionAwareScheduler(state, net, metrics=m, **kw), net, m, \
        state


def _lp(source=0, deadline=100.0, frame_id=0, n_tasks=1):
    req = LowPriorityRequest(source_device=source, deadline=deadline,
                             frame_id=frame_id, n_tasks=n_tasks)
    req.make_tasks()
    return req


# --------------------------------------------------------------------- #
# Profiles: ladder construction + the variant-0 equivalence invariant   #
# --------------------------------------------------------------------- #
def test_ladder_profiles_validate_and_derive_rungs():
    spec = get_workload(LADDER)
    prof = spec.profile(None)
    assert prof.n_variants == 3
    assert len(prof.ladder) == 3
    # variant 0 IS the base profile object (bit-identical stats)
    assert prof.variant_profile(0) is prof
    prev = prof
    for v in range(1, prof.n_variants):
        rung = prof.variant_profile(v)
        assert rung.name == f"{prof.name}@{v}"
        assert rung.accuracy <= prev.accuracy
        assert set(rung.lp_exec) == set(prof.lp_exec)
        for c in prof.core_options:
            assert rung.lp_slot_time(c) <= prev.lp_slot_time(c)
        assert rung.input_bytes <= prof.input_bytes
        prev = rung
    # past-bottom clamps to the deepest rung
    bottom = prof.variant_profile(prof.n_variants - 1)
    assert prof.variant_profile(99) is bottom


def test_ladder_free_profile_resolves_every_variant_to_itself():
    prof = get_workload("paper").profile(None)
    assert prof.n_variants == 1
    for v in (0, 1, 7):
        assert prof.variant_profile(v) is prof


def test_ladder_validation_rejects_non_monotone_rungs():
    base = get_workload("paper").profile(None)

    def bad(spec):
        return TaskProfile(
            name="bad", hp_exec=base.hp_exec, hp_pad=base.hp_pad,
            lp_exec=dict(base.lp_exec), lp_pad=dict(base.lp_pad),
            variants=(spec,),
        )

    with pytest.raises(ValueError, match="accuracy"):
        bad(VariantSpec(accuracy=1.5, lp_exec=dict(base.lp_exec),
                        lp_pad=dict(base.lp_pad)))
    with pytest.raises(ValueError, match="monotone"):
        # a rung SLOWER than the base is not a degradation
        bad(VariantSpec(
            accuracy=0.9,
            lp_exec={c: t * 2.0 for c, t in base.lp_exec.items()},
            lp_pad=dict(base.lp_pad)))
    with pytest.raises(ValueError, match="core config"):
        # a rung must keep the base core-configuration set
        bad(VariantSpec(accuracy=0.9, lp_exec={2: 1.0}, lp_pad={2: 0.1}))


def test_task_degraded_property_is_a_view_over_variant():
    t = Task(priority=Priority.LOW, source_device=0, deadline=1.0,
             frame_id=0)
    assert t.variant == 0 and not t.degraded
    t.variant = 2
    assert t.degraded
    t.degraded = False
    assert t.variant == 0
    t.degraded = True
    assert t.variant == 1          # legacy one-bit degrade = rung 1
    t.variant = 2
    t.degraded = True              # setting True never UN-degrades
    assert t.variant == 2


def test_network_profile_for_resolves_the_admitted_rung():
    net = resolve_network(None, LADDER)
    t = Task(priority=Priority.LOW, source_device=0, deadline=1.0,
             frame_id=0)
    base = net.profile(None)
    assert net.profile_for(t) is base
    t.variant = 1
    assert net.profile_for(t).name == f"{base.name}@1"


# --------------------------------------------------------------------- #
# Scheduler: degrade-before-reject                                      #
# --------------------------------------------------------------------- #
def test_degrade_before_reject_admits_at_a_deeper_rung():
    reset_id_counters()
    sched, net, m, _ = _scheduler(degrade=True)
    prof = net.profile(None)
    # Saturate [0, ~17.3) with two 2-core sets, then offer a request whose
    # deadline fits only a rung-1 slot appended after them.
    sched.allocate_low_priority(_lp(n_tasks=2), 0.0)
    deadline = prof.lp_slot_time(2) + \
        prof.variant_profile(1).lp_slot_time(4) + 1.0
    res = sched.allocate_low_priority(_lp(deadline=deadline, frame_id=1),
                                      0.0)
    assert not res.failed and len(res.allocations) == 1
    task = res.allocations[0].task
    assert task.variant >= 1
    assert task.state is TaskState.ALLOCATED
    assert m.lp_degraded == 1
    assert res.allocations[0].t_end <= deadline + 1e-9


def test_degrade_before_reject_still_rejects_past_the_ladder_bottom():
    reset_id_counters()
    sched, net, m, _ = _scheduler(degrade=True)
    sched.allocate_low_priority(_lp(n_tasks=2), 0.0)
    # deadline shorter than even the deepest rung's minimum slot: reject
    res = sched.allocate_low_priority(_lp(deadline=1.0, frame_id=1), 0.0)
    assert len(res.failed) == 1
    task = res.failed[0]
    assert task.state is TaskState.FAILED
    assert task.variant == 0, \
        "a failed retry must restore the original variant"


def test_degrade_disabled_rejects_where_the_ladder_would_admit():
    reset_id_counters()
    sched, net, m, _ = _scheduler(degrade=False)
    prof = net.profile(None)
    sched.allocate_low_priority(_lp(n_tasks=2), 0.0)
    deadline = prof.lp_slot_time(2) + \
        prof.variant_profile(1).lp_slot_time(4) + 1.0
    res = sched.allocate_low_priority(_lp(deadline=deadline, frame_id=1),
                                      0.0)
    assert len(res.failed) == 1
    assert m.lp_degraded == 0


# --------------------------------------------------------------------- #
# Scheduler: degrade-instead-of-evict (degrade_shrink victim policy)    #
# --------------------------------------------------------------------- #
def _shrink_setup():
    """The shrink geometry: a victim holding a FUTURE slot whose tail
    blocks the earliest HP window the backlogged link allows."""
    reset_id_counters()
    sched, net, m, state = _scheduler(victim_policy="degrade_shrink")
    # W fills [0, ~17.3) on the only device; V queues behind it.
    sched.allocate_low_priority(_lp(n_tasks=2), 0.0)
    rv = sched.allocate_low_priority(_lp(deadline=200.0, frame_id=1), 0.0)
    victim = rv.allocations[0].task
    assert victim.t_start > 5.0, "victim must hold a future slot"
    # Preempt messages cannot leave before the backlog clears, so the HP
    # window lands inside the victim's TAIL — where a rung-1 truncation
    # clears it.
    state.link.reserve(0.0, victim.t_start + 9.5, ("backlog", 0))
    hp = Task(priority=Priority.HIGH, source_device=0, frame_id=2,
              deadline=victim.t_start + 14.0, created_at=5.0)
    return sched, net, m, victim, hp


def test_degrade_shrink_truncates_the_victim_in_place():
    sched, net, m, victim, hp = _shrink_setup()
    old_end = victim.t_end
    res = sched.allocate_high_priority(hp, 5.0)
    assert res.success
    assert m.degrade_shrinks == 1
    assert victim.variant == 1
    assert victim.state is TaskState.ALLOCATED
    assert victim.t_end < old_end
    # the truncated footprint is exactly the rung-1 slot at the SAME cores
    rung = net.profile(None).variant_profile(1)
    assert victim.t_end == pytest.approx(
        victim.t_start + rung.lp_slot_time(victim.cores))
    # the shrunk victim rides the preempted/reallocations pair so the
    # dispatcher cancels its stale exec event and re-arms the new slot
    assert victim in res.preempted
    assert any(a.task is victim and a.t_end == victim.t_end
               for a in res.reallocations)
    # no eviction happened: nothing went PREEMPTED or FAILED
    assert m.preemptions == 0


def test_degrade_shrink_falls_back_to_eviction_without_a_ladder():
    reset_id_counters()
    sched, net, m, state = _scheduler(workload="paper",
                                      victim_policy="degrade_shrink")
    sched.allocate_low_priority(_lp(n_tasks=2), 0.0)
    rv = sched.allocate_low_priority(_lp(deadline=200.0, frame_id=1), 0.0)
    victim = rv.allocations[0].task
    state.link.reserve(0.0, victim.t_start + 9.5, ("backlog", 0))
    hp = Task(priority=Priority.HIGH, source_device=0, frame_id=2,
              deadline=victim.t_start + 14.0, created_at=5.0)
    res = sched.allocate_high_priority(hp, 5.0)
    assert res.success
    assert m.degrade_shrinks == 0
    assert m.preemptions >= 1, "ladder-free profiles must evict as before"


# --------------------------------------------------------------------- #
# Oracle: variant option columns                                        #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ["brute", "milp"])
def test_oracle_degrades_exactly_when_it_buys_a_completion(backend):
    reset_id_counters()
    net = resolve_network(None, LADDER)
    state = NetworkState(1, capacity=4)
    prof = net.profile(None)
    # Deadline admits the rung-1 slot at 4 cores but not the base slot at
    # any cores: the optimum must place the task degraded.
    tight = prof.variant_profile(1).lp_slot_time(4) + 0.5
    task = Task(priority=Priority.LOW, source_device=0, deadline=tight,
                frame_id=0)
    inst = OracleInstance.from_state(state, net, [task], 0.0)
    sol = inst.solve(backend)
    assert sol.completed == 1
    chosen = sol.placements[0]
    assert chosen.variant >= 1
    assert chosen.accuracy < prof.accuracy
    inst.verify(sol)
    # With a loose deadline the same instance stays at variant 0: the
    # goodput tiebreak prefers the (earlier-finishing, higher-accuracy)
    # product only when it wins — at equal start, deeper rungs finish
    # earlier but pay accuracy, and the base rung must still be on offer.
    loose = Task(priority=Priority.LOW, source_device=0, deadline=100.0,
                 frame_id=1)
    inst2 = OracleInstance.from_state(state, net, [loose], 0.0)
    sol2 = inst2.solve(backend)
    assert sol2.completed == 1
    assert {o.variant for o in inst2.options if o.job == 0} >= {0, 1, 2}
    inst2.verify(sol2)


def test_oracle_score_tasks_uses_the_admitted_rung():
    reset_id_counters()
    net = resolve_network(None, LADDER)
    state = NetworkState(1, capacity=4)
    prof = net.profile(None)
    task = Task(priority=Priority.LOW, source_device=0, deadline=100.0,
                frame_id=0)
    inst = OracleInstance.from_state(state, net, [task], 0.0)
    rung = prof.variant_profile(1)
    # commit a rung-1 placement by hand and score it
    task.state = TaskState.ALLOCATED
    task.t_start, task.cores = 0.0, 4
    task.t_end = rung.lp_slot_time(4)
    task.variant = 1
    _, (hp, total, good) = inst.score_tasks([task])
    assert (hp, total) == (0, 1)
    frac = 1.0 - (task.t_end - 0.0) / inst.span
    assert good == pytest.approx(rung.accuracy * frac)


# --------------------------------------------------------------------- #
# Serving: the degrade shed policy walks the real ladder                #
# --------------------------------------------------------------------- #
def test_stream_degrade_shed_walks_the_ladder_then_exhausts():
    from repro.serving.stream import StreamingEngine, StreamRequest

    eng = StreamingEngine(2, workload=LADDER, shed="degrade")
    req = StreamRequest(priority=Priority.LOW, deadline=10.0, n_tasks=2)
    prof = eng.net.profile(None)
    policy = eng.shed_policy
    costs = []
    while policy.degrade(req, eng):
        costs.append(req.est_cost)
    assert req.variant == prof.n_variants - 1, \
        "the walk must stop at the ladder bottom"
    assert costs == sorted(costs, reverse=True), \
        "each rung must shrink the estimated cost"
    assert eng.metrics.lp_degraded == prof.n_variants - 1


def test_stream_request_degraded_property_mirrors_task_semantics():
    from repro.serving.stream import StreamRequest

    req = StreamRequest(priority=Priority.LOW, deadline=1.0)
    assert not req.degraded and req.variant == 0
    req.degraded = True
    assert req.variant == 1
    req.variant = 2
    req.degraded = True
    assert req.variant == 2
    req.degraded = False
    assert req.variant == 0


# --------------------------------------------------------------------- #
# Metrics: conditional summary keys                                     #
# --------------------------------------------------------------------- #
def test_ladder_summary_keys_appear_only_when_the_ladder_fires():
    m = Metrics("x")
    m.lp_generated = 10
    m.lp_completed = 5
    m.lp_accuracy_completed = 5.0
    assert "variant_admissions" not in m.summary()
    assert "accuracy_goodput_pct" not in m.summary()
    m.variant_admissions[1] += 3
    s = m.summary()
    assert s["variant_admissions"] == {"1": 3}
    assert s["degrade_shrinks"] == 0
    assert s["accuracy_goodput_pct"] == pytest.approx(50.0)


# --------------------------------------------------------------------- #
# The storm gate: the acceptance pin, in-suite                          #
# --------------------------------------------------------------------- #
def test_degrade_storm_smoke_gate_holds():
    """The PR's acceptance property: under a saturating degrade storm,
    degrade-before-reject achieves STRICTLY higher accuracy-weighted
    goodput than reject-only at equal-or-better HP completion.  CI runs
    the same gate standalone (``python -m repro.sim.degrade_storm``)."""
    from repro.sim.degrade_storm import STORM_SCENARIOS, run_storm, \
        storm_gate

    cfg = STORM_SCENARIOS["smoke"]
    result = run_storm(cfg)
    assert storm_gate(result, cfg) == []
    assert result["degrade"]["lp_degraded"] > 0
    assert result["awg_gain_pct"] >= cfg.min_awg_gain_pct
    assert result["hp_delta_pct"] >= -cfg.hp_slack_pct
