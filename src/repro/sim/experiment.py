"""Discrete-event reproduction of the paper's RPi2B testbed experiments (§5/§6).

One frame-generation runtime drives ANY scheduling discipline registered in
the policy registry (``core/policy.py``) — the paper's preemption-aware
scheduler, both workstealer baselines, and the beyond-paper ``edf_only`` /
``no_offload`` baselines — each with and without the preemption mechanism.
``ScenarioConfig.algorithm`` resolves through the registry, so adding a new
discipline requires no edits to this module.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Optional

from ..core.metrics import Metrics
from ..core.network import NetworkConfig, resolve_network
from ..core.policy import DispatchClient, PolicyDispatcher, create_policy, \
    registered_policies
from ..core.profiles import PAPER_TYPE, validate_workload_name
from ..core.scheduler import VICTIM_POLICIES
from ..core.task import (
    Frame,
    LowPriorityRequest,
    Priority,
    Task,
    TaskState,
    reset_id_counters,
)
from .events import EventQueue
from .traces import TRACE_FAMILIES, TraceConfig, generate_trace, \
    generate_type_trace, validate_trace_name


@dataclass(frozen=True)
class ScenarioConfig:
    name: str
    trace: str                       # "uniform" | "weighted_1".."weighted_4" | "ratio_P"
    algorithm: str                   # any name in core.policy.registered_policies()
    preemption: bool
    n_frames: int = 1296
    n_devices: int = 4
    seed: int = 0
    exec_noise: bool = True
    hp_noise_sigma: float = 0.02
    lp_noise_sigma: float = 0.20
    # "farthest_deadline" (paper §4) | "weakest_set" (paper §8 proposal,
    # beyond-paper — see EXPERIMENTS.md §Beyond-paper scheduling)
    victim_policy: str = "farthest_deadline"
    # Controller-side LP batching (beyond-paper, DESIGN.md §4.3): LP requests
    # arriving within this window are admitted through ONE batch sweep
    # (`decide_lp_batch`).  0 = the paper's per-request path.
    lp_batch_window: float = 0.0
    # Workload spec name (core/profiles.py registry, DESIGN.md §10):
    # "paper" is the seed's single-model pipeline; "mixed_edge" interleaves
    # three model profiles with distinct benchmarks and deadlines.
    workload: str = PAPER_TYPE
    # Degrade-before-reject admission (DESIGN.md §17): on LP infeasibility
    # the scheduler retries down the task type's variant ladder before
    # emitting a rejection.  Off by default so every committed golden stays
    # bit-identical; only the calendar scheduler honours it (edf_only and
    # the workstealers absorb and ignore the knob).
    degrade: bool = False

    def __post_init__(self) -> None:
        if self.algorithm not in registered_policies():
            raise ValueError(
                f"unknown algorithm {self.algorithm!r}; registered policies: "
                + ", ".join(registered_policies())
            )
        validate_trace_name(self.trace)
        validate_workload_name(self.workload)
        if self.victim_policy not in VICTIM_POLICIES:
            raise ValueError(
                f"unknown victim_policy {self.victim_policy!r}; expected one "
                "of: " + ", ".join(VICTIM_POLICIES)
            )


# The paper's evaluated scenarios (Table 1 legend).
SCENARIOS: dict[str, ScenarioConfig] = {
    "UPS": ScenarioConfig("UPS", "uniform", "scheduler", True),
    "UNPS": ScenarioConfig("UNPS", "uniform", "scheduler", False),
    "WPS_1": ScenarioConfig("WPS_1", "weighted_1", "scheduler", True),
    "WPS_2": ScenarioConfig("WPS_2", "weighted_2", "scheduler", True),
    "WPS_3": ScenarioConfig("WPS_3", "weighted_3", "scheduler", True),
    "WPS_4": ScenarioConfig("WPS_4", "weighted_4", "scheduler", True),
    "WNPS_4": ScenarioConfig("WNPS_4", "weighted_4", "scheduler", False),
    "DPW": ScenarioConfig("DPW", "weighted_4", "decentral_ws", True),
    "DNPW": ScenarioConfig("DNPW", "weighted_4", "decentral_ws", False),
    "CPW": ScenarioConfig("CPW", "weighted_4", "central_ws", True),
    "CNPW": ScenarioConfig("CNPW", "weighted_4", "central_ws", False),
    # beyond-paper: the paper's §8 set-aware victim-selection proposal
    "UPS_SET": ScenarioConfig("UPS_SET", "uniform", "scheduler", True,
                              victim_policy="weakest_set"),
    "WPS_4_SET": ScenarioConfig("WPS_4_SET", "weighted_4", "scheduler", True,
                                victim_policy="weakest_set"),
    "WPS_3_SET": ScenarioConfig("WPS_3_SET", "weighted_3", "scheduler", True,
                                victim_policy="weakest_set"),
}

# Beyond-paper: heterogeneous fleets (core/profiles.py "mixed_edge" — the
# paper's model interleaved with a light mobile classifier and a heavy
# detection transformer, each with its own benchmark table, transfer sizes
# and LP deadline).  Kept out of ``SCENARIOS`` so the paper's Table-1 set
# stays exactly the published legend; golden-replayed all the same.
MIXED_SCENARIOS: dict[str, ScenarioConfig] = {
    "MPS": ScenarioConfig("MPS", "uniform", "scheduler", True,
                          workload="mixed_edge"),
    "MNPS": ScenarioConfig("MNPS", "uniform", "scheduler", False,
                           workload="mixed_edge"),
    "MPS_W4": ScenarioConfig("MPS_W4", "weighted_4", "scheduler", True,
                             workload="mixed_edge"),
}


class _SimClient(DispatchClient):
    """Dispatcher hooks for the discrete-event sim (noise model, frames)."""

    def __init__(self, rt: "Runtime") -> None:
        self.rt = rt

    def exec_time(self, task: Task, busy_frac: float) -> float:
        return self.rt.exec_time(task, busy_frac)

    def on_hp_complete(self, task: Task) -> None:
        frame = self.rt.frames_by_hp[task]
        if frame.trace_value >= 1:
            self.rt.issue_lp_request(frame)


class Runtime:
    """Frame generation + metric finalisation shared by all policies."""

    def __init__(self, cfg: ScenarioConfig, net: Optional[NetworkConfig] = None):
        self.cfg = cfg
        # An explicit net wins but must cover the workload's task types
        # (resolve_network raises early on a mismatch).
        self.net = resolve_network(net, cfg.workload)
        self.q = EventQueue()
        self.metrics = Metrics(cfg.name)
        self.rng = random.Random(cfg.seed * 7919 + 17)
        self.frames: list[Frame] = []
        self.requests: list[LowPriorityRequest] = []
        self.frames_by_hp: dict[Task, Frame] = {}
        self.policy = create_policy(
            cfg.algorithm,
            n_devices=cfg.n_devices,
            net=self.net,
            preemption=cfg.preemption,
            victim_policy=cfg.victim_policy,
            metrics=self.metrics,
            degrade=cfg.degrade,
        )
        self.dispatcher = PolicyDispatcher(
            self.policy, self.q, self.net, self.metrics,
            client=_SimClient(self),
            lp_batch_window=cfg.lp_batch_window,
            rng=self.rng,
            exec_noise=cfg.exec_noise,
            hp_noise_sigma=cfg.hp_noise_sigma,
            lp_noise_sigma=cfg.lp_noise_sigma,
        )

    # -- execution-time noise + contention model -------------------------- #
    def exec_time(self, task: Task, busy_frac: float = 0.0) -> float:
        # profile_for resolves the task's admitted ladder rung (variant 0 =
        # the base profile, the historic behaviour for every golden run).
        prof = self.net.profile_for(task)
        if task.priority == Priority.HIGH:
            base, sigma, coef = prof.hp_exec, self.cfg.hp_noise_sigma, \
                self.net.hp_contention_coef
        else:
            base, sigma, coef = prof.lp_proc_time(task.cores), \
                self.cfg.lp_noise_sigma, self.net.lp_contention_coef
        t = base * (1.0 + coef * busy_frac)
        if self.cfg.exec_noise:
            t += self.rng.gauss(0.0, sigma)
        return max(0.05, t)

    # -- frame pipeline -------------------------------------------------- #
    def run(self) -> Metrics:
        reset_id_counters()
        trace_cfg = TraceConfig(self.cfg.trace, self.cfg.n_frames,
                                self.cfg.n_devices, self.cfg.seed)
        trace = generate_trace(trace_cfg)
        # Mixed workloads: an independent, equally deterministic draw assigns
        # each device-frame its task type (single-profile specs skip the
        # draw entirely, so the paper scenarios' random streams are
        # untouched).
        spec = self.net.spec
        types = (generate_type_trace(trace_cfg, spec.mix_weights())
                 if spec.is_mixed else None)
        period = self.net.frame_period
        # Hosts start as staggered pairs (paper §3) with random per-device offset.
        offsets = [
            self.rng.uniform(0.0, 1.0) + (period / 2 if d >= self.cfg.n_devices // 2 else 0.0)
            for d in range(self.cfg.n_devices)
        ]
        fid = 0
        for k in range(self.cfg.n_frames):
            for d in range(self.cfg.n_devices):
                t = offsets[d] + k * period
                self._spawn_frame(t, d, int(trace[k, d]), fid,
                                  None if types is None else str(types[k, d]))
                fid += 1
        self.q.run()
        return self._finalize()

    def _spawn_frame(self, t: float, device: int, value: int, fid: int,
                     task_type: Optional[str] = None) -> None:
        # Per-type LP deadline (a mixed workload's profiles carry their own
        # relative deadlines); the paper profile falls back to the frame
        # period, exactly the seed behaviour.
        prof = self.net.profile(task_type)
        rel_deadline = (prof.lp_deadline if prof.lp_deadline is not None
                        else self.net.frame_period)
        frame = Frame(device, t, value, fid, deadline=t + rel_deadline,
                      task_type=task_type)
        self.frames.append(frame)

        def gen() -> None:
            self.metrics.frames_total += 1
            if frame.trace_value == -1:
                return
            self.metrics.hp_generated += 1
            # stage 1 object detection = constant overhead before the HP request
            self.q.push(self.q.now + self.net.t_object_detect,
                        lambda: self._hp_request(frame))

        self.q.push(t, gen)

    def _hp_request(self, frame: Frame) -> None:
        now = self.q.now
        task = Task(
            priority=Priority.HIGH,
            source_device=frame.device,
            deadline=self.net.hp_deadline(now, frame.task_type),
            frame_id=frame.frame_id,
            task_type=frame.task_type,
            created_at=now,
        )
        frame.hp_task = task
        self.frames_by_hp[task] = frame
        self.dispatcher.submit_hp(task)

    def issue_lp_request(self, frame: Frame) -> None:
        """Called when a frame's HP task completes with value>=1."""
        req = LowPriorityRequest(
            source_device=frame.device,
            deadline=frame.deadline,
            frame_id=frame.frame_id,
            n_tasks=frame.trace_value,
            task_type=frame.task_type,
            created_at=self.q.now,
        )
        req.make_tasks()
        frame.lp_request = req
        self.requests.append(req)
        self.metrics.lp_generated += req.n_tasks
        self.metrics.lp_requests_total += 1
        # request message transit to the controller
        self.q.push(self.q.now + self.net.slot(self.net.msg.lp_alloc),
                    lambda: self.dispatcher.submit_lp(req))

    def _finalize(self) -> Metrics:
        m = self.metrics
        self.dispatcher.finalize()
        for frame in self.frames:
            if frame.completed:
                m.frames_completed += 1
        for req in self.requests:
            done = sum(1 for t in req.tasks if t.state == TaskState.COMPLETED)
            m.lp_request_fractions.append(done / req.n_tasks)
            if done == req.n_tasks:
                m.lp_requests_completed += 1
        return m


def run_scenario(cfg: ScenarioConfig, net: Optional[NetworkConfig] = None) -> Metrics:
    return Runtime(cfg, net).run()
