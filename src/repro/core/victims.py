"""Victim selection shared by the preemption mechanism and the baselines.

Every discipline that preempts — the paper's scheduler (§4) and the
workstealer baselines (§8 "rash" processor sharing) — ranks candidate
victims by the same two policies:

* ``farthest_deadline``  the paper's rule: evict the conflicting LP task
                         whose deadline is farthest away (it has the most
                         slack to be reallocated elsewhere).
* ``weakest_set``        the §8 future-work proposal: prefer the victim
                         whose request set is least likely to complete
                         anyway (fewest healthy siblings), tie-break by
                         farthest deadline.

Two equivalent forms live here so the scalar disciplines and the
vectorized preemption plane provably agree:

* :func:`victim_sort_key` / :func:`select_victim` — the scalar rule; a
  smaller key is a more preferred victim, and ``min()`` keeps the FIRST
  minimum in iteration order (dict insertion order for the calendars, the
  running-dict order for the workstealers).
* :func:`rank_victims` — the one-pass vectorized equivalent over stacked
  candidate columns.  ``np.argmin`` also returns the first minimum, so as
  long as rows are stored in the same iteration order the two forms pick
  bit-identical victims (tests/test_preemption_plane.py fuzzes this).
"""
from __future__ import annotations

from typing import Callable, Iterable, Optional

import numpy as np

from .task import Task, TaskState

#: Task states that count as "on track" for a request set's health (the
#: numerator of ``weakest_set``'s set-health fraction).
GOOD_STATES = (TaskState.COMPLETED, TaskState.ALLOCATED, TaskState.RUNNING)


def victim_sort_key(
    task: Task, policy: str,
    set_health: Optional[Callable[[Task], float]] = None,
) -> tuple:
    """Scalar victim key: smaller = preferred victim (used with min())."""
    if policy == "weakest_set":
        health = set_health(task) if set_health is not None else 1.0
        return (health, -task.deadline)
    return (-task.deadline,)


def select_victim(
    tasks: Iterable[Task], policy: str = "farthest_deadline",
    set_health: Optional[Callable[[Task], float]] = None,
) -> Task:
    """Most-preferred victim; ties keep the FIRST candidate in iteration
    order (``min()`` semantics — the contract the vectorized ranking
    reproduces)."""
    return min(tasks, key=lambda t: victim_sort_key(t, policy, set_health))


def rank_victims(
    mask: np.ndarray, deadlines: np.ndarray,
    healths: Optional[np.ndarray] = None,
) -> int:
    """One-pass vectorized victim ranking over stacked candidate columns.

    ``mask`` selects the live conflicting rows (must be non-empty);
    ``deadlines`` is the per-row deadline column; ``healths`` the per-row
    set-health column for ``weakest_set`` (None = ``farthest_deadline``).
    Returns the row index of the victim, with exactly ``min()``'s
    first-tie semantics: among the healthiest-tie rows (if any), the
    farthest deadline wins, and remaining ties go to the LOWEST row index
    (np.argmin returns the first minimum).
    """
    key = np.where(mask, -deadlines, np.inf)
    if healths is not None:
        h = np.where(mask, healths, np.inf)
        key = np.where(h == h.min(), key, np.inf)
    return int(np.argmin(key))
