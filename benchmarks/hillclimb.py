"""Perf-hillclimb driver (§Perf): lower one (arch x shape x mesh) combo with
sharding/lowering overrides, print the roofline terms and the largest
collective instructions so each hypothesis -> change -> measure cycle is one
command.

Usage (examples):
  PYTHONPATH=src python -m benchmarks.hillclimb --arch llava-next-34b \
      --shape decode_32k --unroll                    # baseline
  PYTHONPATH=src python -m benchmarks.hillclimb --arch llava-next-34b \
      --shape decode_32k --unroll --rule embed=none  # no-FSDP variant
  ... --out results/hillclimb.jsonl --tag no_fsdp
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse            # noqa: E402
import json                # noqa: E402
import re                  # noqa: E402
import time                # noqa: E402
from collections import Counter  # noqa: E402

from repro.configs import ARCH_IDS                  # noqa: E402
from repro.configs.shapes import SHAPES             # noqa: E402
from repro.launch.build import lower_combo          # noqa: E402
from repro.launch.hlo_analysis import (             # noqa: E402
    _INSTR_RE,
    _group_size,
    _shape_bytes,
    analytic_model_flops,
    roofline_from_compiled,
)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.sharding import RuleSet           # noqa: E402


def parse_rules(pairs: list[str]) -> dict:
    out = {}
    for p in pairs:
        name, _, axis = p.partition("=")
        if axis in ("none", "None", ""):
            out[name] = None
        elif "+" in axis:
            out[name] = tuple(axis.split("+"))
        else:
            out[name] = axis
    return out


def top_collectives(hlo_text: str, k: int = 12) -> list[tuple]:
    """Largest collective instructions: (wire_bytes, count, kind, shape)."""
    agg: Counter = Counter()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m is None or "-done(" in line:
            continue
        shape_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        n = max(2, _group_size(line))
        wire = {"all-gather": size * (n - 1) / n,
                "all-reduce": 2 * size * (n - 1) / n,
                "reduce-scatter": size * (n - 1),
                "all-to-all": size * (n - 1) / n}.get(kind, size)
        agg[(kind, shape_str.strip(), n)] += int(wire)
    rows = [(b, kind, shape, n) for (kind, shape, n), b in agg.items()]
    return sorted(rows, reverse=True)[:k]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--shape", required=True, choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scan for exact cost analysis")
    ap.add_argument("--rule", action="append", default=[],
                    metavar="logical=axis", help="override a sharding rule, "
                    "e.g. embed=none, ff=model, batch=pod+data")
    ap.add_argument("--no-seq-shard", action="store_true",
                    help="disable seq sharding for small batch")
    ap.add_argument("--no-cache-seq-shard", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--set", action="append", default=[], dest="cfg_sets",
                    metavar="field=int", help="override an int ModelConfig "
                    "field, e.g. mlstm_chunk=256, attn_chunk=512")
    ap.add_argument("--pad-heads", type=int, default=0, metavar="MULT",
                    help="pad q/kv head counts to a multiple (head-parallel "
                    "attention sharding)")
    ap.add_argument("--moe-group-size", type=int, default=256)
    ap.add_argument("--dtype", default="bfloat16")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--out", default=None)
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    rules = RuleSet(
        shard_cache_seq_when_b1=not args.no_cache_seq_shard,
        shard_seq_when_small_batch=not args.no_seq_shard,
    ).with_overrides(**parse_rules(args.rule))

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    t0 = time.time()
    with mesh:
        combo = lower_combo(args.arch, args.shape, mesh,
                            dtype=args.dtype, ruleset=rules,
                            moe_group_size=args.moe_group_size,
                            remat=not args.no_remat,
                            pad_heads=args.pad_heads,
                            cfg_updates={k: int(v) for k, _, v in
                                         (s.partition("=") for s in
                                          args.cfg_sets)},
                            unroll=True if args.unroll else 1)
        t1 = time.time()
        compiled = combo.lowered.compile()
        t_compile = time.time() - t1
        hlo = compiled.as_text()
        mf = analytic_model_flops(combo.cfg, SHAPES[args.shape])
        roof = roofline_from_compiled(compiled, combo.chips, hlo, mf)
        mem = compiled.memory_analysis()
        bytes_per_dev = sum(
            int(getattr(mem, a, 0) or 0)
            for a in ("argument_size_in_bytes", "temp_size_in_bytes",
                      "output_size_in_bytes")) if mem is not None else 0

    s = roof.summary()
    print(f"== {args.arch} x {args.shape} "
          f"mesh={'x'.join(map(str, mesh.devices.shape))} tag={args.tag} "
          f"unroll={args.unroll} (lower {t1-t0:.0f}s compile {t_compile:.0f}s)")
    print(f"  compute_s    {s['compute_s']:.6g}")
    print(f"  memory_s     {s['memory_s']:.6g}")
    print(f"  collective_s {s['collective_s']:.6g}   <- bottleneck: "
          f"{s['bottleneck']}")
    print(f"  useful_flops_ratio {s['useful_flops_ratio']:.4f}   "
          f"bytes/dev {bytes_per_dev/1e9:.2f} GB   "
          f"n_collectives {s['n_collectives']}")
    print(f"  by kind: { {k: f'{v/1e9:.2f}GB' for k, v in s['collectives_by_kind'].items()} }")
    print("  top collectives (wire bytes, kind, result shape, group):")
    for b, kind, shape, n in top_collectives(hlo, args.top):
        shape = re.sub(r"\s+", " ", shape)[:90]
        print(f"    {b/1e9:10.3f} GB  {kind:18s} g={n:<4d} {shape}")

    if args.out:
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "x".join(map(str, mesh.devices.shape)),
               "tag": args.tag, "unrolled": bool(args.unroll),
               "rules": args.rule, "dtype": args.dtype,
               "no_remat": args.no_remat, "pad_heads": args.pad_heads,
               "cfg_sets": args.cfg_sets,
               "moe_group_size": args.moe_group_size,
               "bytes_per_device": bytes_per_dev,
               "roofline": s}
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
