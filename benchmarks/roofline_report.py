"""Roofline table assembly from dry-run artifacts (results/*.jsonl).

Reads the recorded dry-run/roofline jsonl files and emits per-(arch, shape,
mesh) rows: the three terms, the dominant bottleneck, MODEL_FLOPS and the
useful-flops ratio — EXPERIMENTS.md §Roofline is generated from this.
"""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load_records(*names: str) -> list[dict]:
    recs = []
    for name in names:
        path = os.path.join(RESULTS, name)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            for line in f:
                recs.append(json.loads(line))
    # de-dup on (arch, shape, mesh, unrolled) keeping the last occurrence
    seen: dict = {}
    for r in recs:
        key = (r["arch"], r["shape"], r["mesh"], r.get("unrolled", False))
        seen[key] = r
    return list(seen.values())


def roofline_rows(prefer_unrolled: bool = True) -> list[tuple]:
    # precedence: f32 methodology runs > unrolled bf16 > scan bf16
    # (see EXPERIMENTS.md methodology notes)
    recs = load_records("dryrun_full.jsonl", "roofline_unrolled.jsonl",
                        "roofline_f32.jsonl")
    by_combo: dict = {}

    def rank(r):
        return (r.get("dtype") == "float32", bool(r.get("unrolled")))

    for r in recs:
        if r.get("status") != "ok":
            continue
        key = (r["arch"], r["shape"], r["mesh"])
        cur = by_combo.get(key)
        if cur is None or (prefer_unrolled and rank(r) > rank(cur)):
            by_combo[key] = r
    rows = []
    for (arch, shape, mesh), r in sorted(by_combo.items()):
        ro = r["roofline"]
        rows.append((
            arch, shape, mesh,
            ro["compute_s"], ro["memory_s"], ro["collective_s"],
            ro["bottleneck"], ro.get("model_flops", 0.0),
            ro.get("useful_flops_ratio", 0.0),
            r.get("total_bytes_per_device", 0),
            bool(r.get("unrolled", False)),
            r.get("dtype", "bfloat16"),
        ))
    return rows


def print_table() -> None:
    rows = roofline_rows()
    hdr = ("arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
           "bottleneck", "model_flops", "useful_ratio", "bytes_per_dev",
           "unrolled", "dtype")
    print(",".join(hdr))
    for row in rows:
        print(",".join(
            f"{v:.6g}" if isinstance(v, float) else str(v) for v in row))


if __name__ == "__main__":
    print_table()
