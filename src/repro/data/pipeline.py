"""Synthetic data pipeline: deterministic token/embedding batch streams.

Provides (a) host-side numpy batch iterators for training loops and
(b) ``input_specs`` used by the dry-run: ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.shapes import InputShape
from ..models.config import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    # Zipf-ish unigram distribution so the CE has realistic structure.
    zipf_a: float = 1.2


def _token_probs(vocab: int, a: float) -> np.ndarray:
    w = 1.0 / np.power(np.arange(1, vocab + 1), a)
    return w / w.sum()


def _modality_len(cfg: ModelConfig, shape: InputShape) -> int:
    if not cfg.modality_embed_dim:
        return 0
    if cfg.is_encoder_decoder:
        return shape.seq_len                # audio frames == seq_len
    return min(cfg.n_modality_tokens, max(shape.seq_len // 2, 1))


def text_len(cfg: ModelConfig, shape: InputShape) -> int:
    """Text tokens for a full-sequence step (total seq budget minus any
    prepended modality tokens for decoder-only multimodal archs)."""
    if cfg.modality_embed_dim and not cfg.is_encoder_decoder:
        return shape.seq_len - _modality_len(cfg, shape)
    return shape.seq_len


def train_batches(
    cfg: ModelConfig,
    shape: InputShape,
    data: Optional[DataConfig] = None,
    batch_override: Optional[int] = None,
) -> Iterator[dict]:
    """Infinite iterator of numpy training batches."""
    data = data or DataConfig()
    rng = np.random.default_rng(data.seed)
    probs = _token_probs(cfg.vocab_size, data.zipf_a)
    b = batch_override or shape.global_batch
    t = text_len(cfg, shape)
    s_mod = _modality_len(cfg, shape)
    while True:
        tokens = rng.choice(cfg.vocab_size, size=(b, t), p=probs).astype(np.int32)
        batch = {
            "tokens": tokens,
            "labels": np.concatenate(
                [tokens[:, 1:], np.full((b, 1), -1, np.int32)], axis=1),
        }
        if s_mod:
            batch["modality_emb"] = rng.standard_normal(
                (b, s_mod, cfg.modality_embed_dim), dtype=np.float32)
        yield batch


def input_specs(cfg: ModelConfig, shape: InputShape,
                cache_len: Optional[int] = None) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the step kind."""
    b = shape.global_batch
    f32 = jnp.dtype(cfg.activation_dtype)
    if shape.kind in ("train", "prefill"):
        t = text_len(cfg, shape)
        spec = {
            "tokens": jax.ShapeDtypeStruct((b, t), jnp.int32),
        }
        if shape.kind == "train":
            spec["labels"] = jax.ShapeDtypeStruct((b, t), jnp.int32)
        if cfg.modality_embed_dim:
            spec["modality_emb"] = jax.ShapeDtypeStruct(
                (b, _modality_len(cfg, shape), cfg.modality_embed_dim), f32)
        return spec
    # decode: ONE token + position scalar (caches are built separately)
    return {
        "token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
