"""Corpus: terminal-state assignments outside the settle registry."""
from repro.core.task import TaskState


def leak(task, late):
    task.state = TaskState.FAILED                       # BAD
    task.state = (TaskState.VIOLATED if late
                  else TaskState.COMPLETED)             # BAD: conditional RHS
    task.state = TaskState.RUNNING                      # good: non-terminal
