"""Pytree checkpointing: flat .npz payload + JSON manifest.

No external deps (orbax unavailable offline).  Leaves are addressed by their
jax.tree_util key-path string; restore validates structure against a
reference tree (shapes + dtypes) so partial/corrupt checkpoints fail loudly.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: Any, metadata: Optional[dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    manifest = {
        "leaves": {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in flat.items()
        },
        "metadata": metadata or {},
    }
    tmp = os.path.join(path, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, os.path.join(path, "manifest.json"))


def load_metadata(path: str) -> dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["metadata"]


def restore(path: str, reference: Any) -> Any:
    """Restore into the structure of ``reference`` (a pytree of arrays or
    ShapeDtypeStructs)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(reference)
    leaves = []
    for path_elems, ref in paths:
        key = jax.tree_util.keystr(path_elems)
        if key not in manifest["leaves"]:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != expected {ref.shape}")
        leaves.append(arr.astype(ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def exists(path: str) -> bool:
    return os.path.exists(os.path.join(path, "manifest.json"))
