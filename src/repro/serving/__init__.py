from .cost_model import CostModel, PhaseCost, analytic_cost_model, measure_cost_model  # noqa: F401
from .engine import PreemptiveServingEngine, ServeRequest, engine_network_config  # noqa: F401
