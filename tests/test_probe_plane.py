"""Unit tests for the vectorized network-wide probe plane (calendar.py).

The fuzz suite (test_skyline_fuzz.py) proves scalar/vector agreement on
random states; this file pins the edge cases that make the plane correct:
multi-segment runs in the first-fit search, lazy dirty-row refresh, mirror
growth, the deep-window location shortcuts, and the per-cores blocked-count
tables staying in sync across mutations.
"""
import numpy as np
import pytest

from repro.core.calendar import NetworkState


def test_earliest_fit_spans_multi_segment_runs():
    """A free run made of SEVERAL coalesced segments (usage 1 then 2, limit
    2) must host a slot that no single segment could — the per-segment
    formulation of first-fit would miss it."""
    st = NetworkState(1)
    dev = st.devices[0]
    dev.reserve(0.0, 10.0, 1, "a")            # usage 1 on [0, 10)
    dev.reserve(5.0, 10.0, 1, "b")            # usage 2 on [5, 10)
    dev.reserve(10.0, 12.0, 4, "block")       # blocked [10, 12)
    plane = st.probe_plane()
    # 2 cores (limit 2): the run [0, 10) spans two segments (1 then 2)
    assert float(plane.earliest_fit(9.0, 0.0, 2)[0]) == 0.0
    assert dev.earliest_fit(9.0, 0.0, 2) == 0.0
    # 3 cores (limit 1): only [0, 5) qualifies, too short for 9s
    assert float(plane.earliest_fit(9.0, 0.0, 3)[0]) == 12.0
    assert dev.earliest_fit(9.0, 0.0, 3) == 12.0
    # ... but long enough for 4s
    assert float(plane.earliest_fit(4.0, 0.0, 3)[0]) == 0.0


def test_earliest_fit_infeasible_capacity_returns_inf():
    """A device whose capacity can never host the request must answer +inf
    exactly like the scalar first_fit guard — not the -inf sentinel."""
    from repro.core.calendar import DeviceCalendar

    st = NetworkState(2, devices=[DeviceCalendar(0, capacity=2),
                                  DeviceCalendar(1, capacity=4)])
    st.n_devices = 2
    plane = st.probe_plane()
    starts = plane.earliest_fit(1.0, 0.0, 3)
    assert float(starts[0]) == float("inf")
    assert float(starts[0]) == st.devices[0].earliest_fit(1.0, 0.0, 3)
    assert float(starts[1]) == 0.0


def test_refresh_tracks_mutations_lazily():
    st = NetworkState(3)
    plane = st.probe_plane()
    assert plane.fits_mask(0.0, 5.0, 4).all()              # all free
    st.devices[1].reserve(0.0, 5.0, 4, "x")
    # the plane instance is stale until the next probe_plane() call
    plane = st.probe_plane()
    assert list(plane.fits_mask(0.0, 5.0, 1)) == [True, False, True]
    st.devices[1].release("x")
    plane = st.probe_plane()
    assert list(plane.fits_mask(0.0, 5.0, 1)) == [True, True, True]


def test_plane_growth_past_initial_width():
    """More live segments than the initial mirror width forces a regrow of
    every row; answers must be unaffected."""
    st = NetworkState(2)
    dev = st.devices[0]
    for i in range(40):                        # disjoint slots: 80+ segments
        dev.reserve(2.0 * i, 2.0 * i + 1.0, 1, i)
    plane = st.probe_plane()
    assert plane._w >= 40
    assert bool(plane.fits_mask(0.0, 1.0, 4)[0]) is False
    assert bool(plane.fits_mask(1.0, 2.0, 4)[0]) is True
    assert float(plane.loads(0.0, 80.0)[0]) == pytest.approx(40.0)
    assert float(plane.loads(0.0, 80.0)[1]) == 0.0


def test_location_shortcut_beyond_horizon():
    """Windows ending past every breakpoint take the O(1) tmax shortcut and
    must still agree with the scalar answers."""
    st = NetworkState(2)
    st.devices[0].reserve(0.0, 10.0, 2, "a")
    st.devices[1].reserve(3.0, 7.0, 4, "b")
    plane = st.probe_plane()
    deadline = 1e6                             # far beyond tmax
    loads = plane.loads(0.0, deadline)
    assert float(loads[0]) == pytest.approx(st.devices[0].load(0.0, deadline))
    assert float(loads[1]) == pytest.approx(st.devices[1].load(0.0, deadline))
    assert list(plane.fits_mask(0.0, deadline, 1)) == [
        st.devices[0].fits(0.0, deadline, 1),
        st.devices[1].fits(0.0, deadline, 1),
    ]


def test_location_escalates_past_saturated_front():
    """A row with more than 16 breakpoints before the window end saturates
    the front-slice count and must escalate exactly."""
    st = NetworkState(2)
    dev = st.devices[0]
    for i in range(30):
        dev.reserve(i * 1.0, i * 1.0 + 0.5, 1, i)   # 60 breakpoints
    dev.reserve(50.0, 60.0, 4, "tail")
    st.devices[1].reserve(49.0, 62.0, 2, "other")
    plane = st.probe_plane()
    # window end (55) lies deep past >16 breakpoints of row 0
    assert list(plane.fits_mask(48.0, 55.0, 1)) == [
        st.devices[0].fits(48.0, 55.0, 1),
        st.devices[1].fits(48.0, 55.0, 1),
    ]
    assert float(plane.loads(48.0, 55.0)[0]) == pytest.approx(
        st.devices[0].load(48.0, 55.0))


def test_blocked_count_tables_follow_mutations():
    st = NetworkState(2)
    plane = st.probe_plane()
    assert plane.fits_mask(0.0, 5.0, 2).all()          # builds the table
    st.devices[0].reserve(0.0, 5.0, 4, "x")            # dirty row 0
    plane = st.probe_plane()                           # row-wise bc update
    assert list(plane.fits_mask(0.0, 5.0, 2)) == [False, True]
    st.devices[0].truncate("x", 2.0)
    plane = st.probe_plane()
    assert list(plane.fits_mask(2.0, 5.0, 2)) == [True, True]
    assert list(plane.fits_mask(0.0, 5.0, 2)) == [False, True]


def test_probe_window_snapshot():
    st = NetworkState(2)
    st.devices[0].reserve(0.0, 4.0, 3, "a")
    win = st.probe_plane(0.0, 4.0)
    assert list(win.free_cores) == [1, 4]
    assert list(win.fits(2)) == [False, True]
    assert float(win.loads[0]) == pytest.approx(12.0)
    assert win.t1 == 0.0 and win.t2 == 4.0


def test_empty_window_semantics():
    st = NetworkState(2)
    st.devices[0].reserve(0.0, 4.0, 4, "a")
    plane = st.probe_plane()
    # empty/inverted windows fit everything, load nothing (scalar parity)
    assert plane.fits_mask(2.0, 2.0, 4).all()
    assert (plane.loads(3.0, 3.0) == 0.0).all()
    assert (plane.free_cores(2.0, 2.0) == np.array([4, 4])).all()


def test_completion_array_matches_completion_times():
    st = NetworkState(3)
    st.devices[0].reserve(0.0, 5.0, 1, "a")
    st.devices[1].reserve(1.0, 5.0, 1, "b")    # duplicate point 5.0
    st.devices[2].reserve(2.0, 7.0, 1, "c")
    plane = st.probe_plane()
    assert plane.completion_array(0.0, 10.0).tolist() == [5.0, 7.0]
    assert st.completion_times(0.0, 10.0) == [5.0, 7.0]
    assert list(st.iter_completion_times(0.0, 10.0)) == [5.0, 7.0]
    # the lazy grid is a call-time snapshot: later commits don't perturb it
    it = st.iter_completion_times(0.0, 10.0)
    st.devices[0].reserve(3.0, 6.0, 1, "late")
    assert list(it) == [5.0, 7.0]
