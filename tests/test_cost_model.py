"""Direct unit coverage for serving/cost_model.py (previously exercised
only indirectly through the engine): analytic construction, real measured
steps on the smallest config, degree selection, and the error contract."""
import pytest

from repro.configs import get_smoke_config
from repro.serving.cost_model import (
    CostModel,
    PhaseCost,
    analytic_cost_model,
    measure_cost_model,
)

EFF_RATIO = 11.611 / 16.862


# --------------------------------------------------------------------- #
# analytic_cost_model                                                   #
# --------------------------------------------------------------------- #
def test_analytic_cost_model_shape_and_std_frac():
    cm = analytic_cost_model({2: 0.4, 4: 0.25, 8: 0.18},
                             prefill_s=0.9, std_frac=0.1)
    assert cm.degrees == (2, 4, 8)
    assert cm.prefill[1].mean_s == 0.9
    assert cm.prefill[1].std_s == pytest.approx(0.09)
    for deg, t in {2: 0.4, 4: 0.25, 8: 0.18}.items():
        assert cm.decode[deg].mean_s == t
        assert cm.decode[deg].std_s == pytest.approx(t * 0.1)
        assert cm.decode[deg].padded == pytest.approx(t * 1.1)


def test_analytic_cost_model_default_std_frac():
    cm = analytic_cost_model({2: 1.0}, prefill_s=0.5)
    assert cm.decode[2].std_s == pytest.approx(0.05)


# --------------------------------------------------------------------- #
# error contract: unknown degrees raise ValueError naming the options   #
# --------------------------------------------------------------------- #
def _synthetic() -> CostModel:
    cm = CostModel()
    cm.prefill[1] = PhaseCost(0.05, 0.005)
    cm.decode[2] = PhaseCost(0.02, 0.002)
    cm.decode[4] = PhaseCost(0.014, 0.0014)
    return cm


def test_lp_exec_time_unknown_degree_lists_available():
    cm = _synthetic()
    with pytest.raises(ValueError, match=r"degree 3.*\[2, 4\]"):
        cm.lp_exec_time(3, 10)
    with pytest.raises(ValueError, match=r"\[2, 4\]"):
        cm.lp_slot_time(8, 10)


def test_hp_exec_time_unknown_degree_lists_available():
    cm = _synthetic()
    with pytest.raises(ValueError, match=r"degree 2.*\[1\]"):
        cm.hp_exec_time(2)
    with pytest.raises(ValueError, match="prefill"):
        cm.hp_slot_time(4)


def test_empty_cost_model_error_message():
    with pytest.raises(ValueError, match="none"):
        CostModel().lp_exec_time(2, 1)


# --------------------------------------------------------------------- #
# measure_cost_model: real timed steps on the smallest config           #
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def measured():
    cfg = get_smoke_config("smollm-135m")
    return measure_cost_model(cfg, prompt_len=8, cache_len=16, reps=1)


def test_measure_cost_model_smallest_config(measured):
    assert measured.degrees == (2, 4)
    assert measured.prefill[1].mean_s > 0.0
    assert measured.decode[2].mean_s > 0.0
    # paper-calibrated efficiency curve anchors degree 4 off degree 2
    assert measured.decode[4].mean_s == pytest.approx(
        measured.decode[2].mean_s * EFF_RATIO)
    assert measured.decode[4].std_s == pytest.approx(
        measured.decode[2].std_s * EFF_RATIO)


def test_measure_cost_model_honors_degrees():
    cfg = get_smoke_config("smollm-135m")
    cm = measure_cost_model(cfg, prompt_len=8, cache_len=16, reps=1,
                            degrees=(2, 8))
    assert cm.degrees == (2, 8)
    # two doublings from the degree-2 anchor
    assert cm.decode[8].mean_s == pytest.approx(
        cm.decode[2].mean_s * EFF_RATIO ** 2)


@pytest.mark.parametrize("bad", [(), (0,), (2, 2), (2, -4), (2.5,)])
def test_measure_cost_model_rejects_bad_degrees(bad):
    cfg = get_smoke_config("smollm-135m")
    with pytest.raises(ValueError):
        measure_cost_model(cfg, prompt_len=8, cache_len=16, reps=1,
                           degrees=bad)
