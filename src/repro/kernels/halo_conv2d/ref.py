"""Pure-jnp oracle for the halo-partitioned conv block (paper §3.2).

The paper horizontally partitions YoloV2 conv blocks: the input feature map
is split into spatial tiles, each tile processed through consecutive conv
layers with its halo (expansion border), and only tile borders are exchanged
at block boundaries.  The oracle is a plain SAME-padded conv stack — the
Pallas kernel must produce identical results for any tiling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_valid(x: jax.Array, w: jax.Array) -> jax.Array:
    """x [N, H, W, Cin], w [kh, kw, Cin, Cout], stride 1, VALID padding."""
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv_block_ref(x: jax.Array, weights: list[jax.Array],
                   leaky_slope: float = 0.1) -> jax.Array:
    """A YoloV2-style block: n consecutive 3x3 convs + leaky ReLU.

    Block-level padding semantics (fused tile partitioning, Zhao et al.
    DeepThings): the image is zero-padded ONCE by the block's total halo
    radius and the convs run VALID, so intermediate halo values carry
    through the block.  This is what makes the result exactly independent
    of the tiling (the paper's 2-core vs 4-core configurations)."""
    r = len(weights)
    x = jnp.pad(x, [(0, 0), (r, r), (r, r), (0, 0)])
    for w in weights:
        x = conv2d_valid(x, w)
        x = jnp.where(x >= 0, x, leaky_slope * x)
    return x


def maxpool2x2_ref(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )
