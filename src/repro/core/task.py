"""Task, request and frame abstractions for the three-stage pipeline.

The paper (§3) considers a three-stage waste-classification pipeline:
  stage 1: object detection (constant overhead, always local, not scheduled)
  stage 2: low-complexity classifier  -> HIGH priority, local-only, 1 core
  stage 3: set of 1..4 high-complexity DNN tasks -> LOW priority, offloadable,
           horizontally partitioned over 2 or 4 cores.
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Optional

_task_ids = itertools.count()
_request_ids = itertools.count()


def reset_id_counters() -> None:
    """Reset global id counters (between experiment runs, for determinism)."""
    global _task_ids, _request_ids
    _task_ids = itertools.count()
    _request_ids = itertools.count()


class Priority(enum.IntEnum):
    HIGH = 0   # stage-2 low-complexity classifier
    LOW = 1    # stage-3 high-complexity DNN


class TaskState(enum.Enum):
    PENDING = "pending"          # created, not yet allocated
    ALLOCATED = "allocated"      # controller reserved resources
    RUNNING = "running"          # execution started on a device
    COMPLETED = "completed"      # finished within its deadline
    PREEMPTED = "preempted"      # evicted by a high-priority task
    FAILED = "failed"            # could not be (re)allocated / missed deadline
    VIOLATED = "violated"        # overran its reserved slot at runtime


@dataclass
class Task:
    """A single schedulable unit (stage-2 classifier or one stage-3 DNN)."""

    priority: Priority
    source_device: int
    deadline: float
    frame_id: int
    request_id: Optional[int] = None       # LP tasks belong to a request set
    # Workload-profile key (core/profiles.py): which benchmark table sizes
    # this task's slots.  None = the workload spec's default profile (the
    # paper's single-model pipeline needs no annotations).
    task_type: Optional[str] = None
    task_id: int = field(default_factory=lambda: next(_task_ids))
    state: TaskState = TaskState.PENDING
    # Variant-ladder rung (core/profiles.py, DESIGN.md §17): index into the
    # task type's degradation ladder.  0 = the full-accuracy base profile
    # (every closed-workload golden path); a positive index resolves through
    # TaskProfile.variant_profile to a cheaper rung, and pins the
    # scheduler's core-upgrade pass off.  For ladder-free profiles a
    # positive index keeps the base exec stats — exactly the legacy one-bit
    # degrade semantics.
    variant: int = 0
    # Filled in by the scheduler on allocation:
    device: Optional[int] = None
    cores: int = 0
    t_start: float = 0.0
    t_end: float = 0.0
    offloaded: bool = False
    preempt_count: int = 0
    created_at: float = 0.0

    @property
    def is_high(self) -> bool:
        return self.priority == Priority.HIGH

    @property
    def degraded(self) -> bool:
        """Deprecated one-bit view of the variant ladder: any rung below
        variant 0 counts as degraded (pre-ladder callers keep working)."""
        return self.variant > 0

    @degraded.setter
    def degraded(self, flag: bool) -> None:
        if flag:
            self.variant = max(self.variant, 1)
        else:
            self.variant = 0

    def __hash__(self) -> int:
        return self.task_id

    def __eq__(self, other: object) -> bool:
        return isinstance(other, Task) and other.task_id == self.task_id


@dataclass
class LowPriorityRequest:
    """A set of stage-3 DNN tasks spawned by one completed stage-2 task.

    The request only counts as complete when *every* task in the set completes
    before the request deadline (paper §4, §6 'set completion').
    """

    source_device: int
    deadline: float
    frame_id: int
    n_tasks: int
    created_at: float = 0.0
    task_type: Optional[str] = None        # workload-profile key (see Task)
    request_id: int = field(default_factory=lambda: next(_request_ids))
    tasks: list[Task] = field(default_factory=list)

    def make_tasks(self) -> list[Task]:
        self.tasks = [
            Task(
                priority=Priority.LOW,
                source_device=self.source_device,
                deadline=self.deadline,
                frame_id=self.frame_id,
                request_id=self.request_id,
                task_type=self.task_type,
                created_at=self.created_at,
            )
            for _ in range(self.n_tasks)
        ]
        return self.tasks

    @property
    def completed(self) -> bool:
        return bool(self.tasks) and all(
            t.state == TaskState.COMPLETED for t in self.tasks
        )


@dataclass
class Frame:
    """One sampled conveyor-belt frame on one device.

    trace_value semantics (paper §5):
      -1        no object detected (nothing scheduled; frame trivially complete)
       0        HP task only
       1..4     HP task, then an LP request with that many DNN tasks
    """

    device: int
    gen_time: float
    trace_value: int
    frame_id: int
    deadline: float
    task_type: Optional[str] = None        # workload-profile key (see Task)
    hp_task: Optional[Task] = None
    lp_request: Optional[LowPriorityRequest] = None

    @property
    def completed(self) -> bool:
        if self.trace_value == -1:
            return True
        if self.hp_task is None or self.hp_task.state != TaskState.COMPLETED:
            return False
        if self.trace_value == 0:
            return True
        return self.lp_request is not None and self.lp_request.completed
