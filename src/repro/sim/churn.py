"""Seeded churn injection: device failure / drain / rejoin event streams.

The churn plane (DESIGN.md §16) turns the static device fleet into a
lossy one: devices hard-fail (calendar lost, in-flight work orphaned),
drain (no new admissions, in-flight work runs out) and rejoin (cleared
calendar, admissible again).  This module generates those lifecycle
events as a *deterministic, precomputed schedule* — two runs with the
same :class:`ChurnConfig` produce the identical event list, and a config
with every rate at zero produces the empty list **without constructing a
generator at all**, so a churn-free run consumes zero randomness and
stays bit-identical to a run that never imported this module (the
zero-churn differential in ``tests/test_accounting_invariants.py`` pins
this).

Failures and drains arrive as a merged Poisson process at ``fail_rate +
drain_rate`` events per virtual second over ``[start, start+duration)``,
each picking a uniformly random currently-UP victim; ``max_down_frac``
caps the simultaneously-lost fraction (a capped draw still consumes its
random numbers, so the cap changes *which* events fire, never the
stream's alignment).  With ``rejoin=True`` every lost device schedules
its rejoin ``rejoin_delay`` seconds later — rejoins are emitted even
past the horizon so the fleet converges back to fully-UP.  Link
degradation is a third Poisson stream of ``link_rate`` events per
second, each occupying the shared link for ``link_duration`` seconds
(drivers reserve a duty-cycle slot on the link calendar — offloads queue
behind it, exactly like a burst of competing transfers).
"""
from __future__ import annotations

import heapq
import math
import random
import zlib
from dataclasses import dataclass
from typing import Iterator, Sequence


@dataclass(frozen=True)
class ChurnEvent:
    """One lifecycle event at virtual time ``t``.

    ``kind`` is one of ``"fail"``, ``"drain"``, ``"rejoin"`` (``device``
    is the target) or ``"link"`` (``device`` is a per-event sequence
    number; ``duration`` is the degradation slot length in seconds).
    """

    t: float
    kind: str
    device: int
    duration: float = 0.0


@dataclass(frozen=True)
class ChurnConfig:
    """A seeded churn schedule (all rates in events per virtual second)."""

    name: str = "churn"
    n_devices: int = 64
    fail_rate: float = 0.0          # hard failures / s (network-wide)
    drain_rate: float = 0.0         # graceful drains / s (network-wide)
    rejoin: bool = True             # lost devices come back
    rejoin_delay: float = 2.0       # seconds from loss to rejoin
    link_rate: float = 0.0          # link-degradation events / s
    link_duration: float = 0.05     # seconds the link stays occupied
    start: float = 0.0              # first instant churn may fire
    duration: float = 10.0          # churn window length
    max_down_frac: float = 0.5      # cap on simultaneously-lost fraction
    seed: int = 0

    def __post_init__(self) -> None:
        if self.n_devices < 1:
            raise ValueError("n_devices must be >= 1")
        for f in ("fail_rate", "drain_rate", "link_rate"):
            if getattr(self, f) < 0.0:
                raise ValueError(f"{f} must be >= 0")
        if self.rejoin_delay <= 0.0:
            raise ValueError("rejoin_delay must be positive")
        if self.link_duration < 0.0:
            raise ValueError("link_duration must be >= 0")
        if self.duration < 0.0:
            raise ValueError("duration must be >= 0")
        if not (0.0 < self.max_down_frac <= 1.0):
            raise ValueError("max_down_frac must be in (0, 1]")

    @property
    def enabled(self) -> bool:
        return self.fail_rate > 0.0 or self.drain_rate > 0.0 \
            or self.link_rate > 0.0


def churn_schedule(cfg: ChurnConfig) -> list[ChurnEvent]:
    """Precompute the full time-sorted event list for ``cfg``.

    Returns ``[]`` for a disabled config without touching any RNG.
    """
    if not cfg.enabled:
        return []
    # name-salted seed, crc32 not hash() (stable across PYTHONHASHSEED) —
    # the same per-stream independence trick sim/traces.py uses
    rng = random.Random(cfg.seed ^ zlib.crc32(cfg.name.encode()))
    events: list[ChurnEvent] = []
    # UP pool with O(1) swap-pop removal (a list, not a set: the replint
    # determinism rule bans set iteration in decision paths, and victim
    # draws must not depend on set ordering anyway)
    up = list(range(cfg.n_devices))
    pos = {d: i for i, d in enumerate(up)}
    n_down = 0
    max_down = max(1, int(cfg.n_devices * cfg.max_down_frac))
    rejoins: list[tuple[float, int]] = []       # heap of (t, device)
    total = cfg.fail_rate + cfg.drain_rate
    end = cfg.start + cfg.duration
    inf = math.inf
    t_churn = cfg.start + rng.expovariate(total) if total > 0.0 else inf
    t_link = (cfg.start + rng.expovariate(cfg.link_rate)
              if cfg.link_rate > 0.0 else inf)
    link_seq = 0
    while True:
        t_rej = rejoins[0][0] if rejoins else inf
        tc = t_churn if t_churn < end else inf
        tl = t_link if t_link < end else inf
        if t_rej <= tc and t_rej <= tl:
            if not rejoins:
                break                            # every stream exhausted
            tr, dev = heapq.heappop(rejoins)
            events.append(ChurnEvent(t=tr, kind="rejoin", device=dev))
            pos[dev] = len(up)
            up.append(dev)
            n_down -= 1
        elif tc <= tl:
            # merged fail/drain arrival; a draw suppressed by the down-cap
            # (or an empty UP pool) still consumes its random numbers
            is_fail = rng.random() < cfg.fail_rate / total
            i = rng.randrange(len(up)) if up else -1
            if i >= 0 and n_down < max_down:
                dev = up[i]
                last = up[-1]
                up[i] = last
                pos[last] = i
                up.pop()
                del pos[dev]
                n_down += 1
                events.append(ChurnEvent(
                    t=t_churn, kind="fail" if is_fail else "drain",
                    device=dev))
                if cfg.rejoin:
                    heapq.heappush(
                        rejoins, (t_churn + cfg.rejoin_delay, dev))
            t_churn += rng.expovariate(total)
        else:
            events.append(ChurnEvent(
                t=t_link, kind="link", device=link_seq,
                duration=cfg.link_duration))
            link_seq += 1
            t_link += rng.expovariate(cfg.link_rate)
    return events


class ChurnInjector:
    """A precomputed, replayable churn event stream.

    Thin iterable over :func:`churn_schedule` — drivers either iterate it
    (``StreamingEngine.run(churn=...)``) or index ``.events`` directly
    (``run_large_n`` pushes them onto its event heap).  Disabled configs
    yield nothing and consumed zero randomness.
    """

    def __init__(self, cfg: ChurnConfig) -> None:
        self.cfg = cfg
        self.events: list[ChurnEvent] = churn_schedule(cfg)

    def __iter__(self) -> Iterator[ChurnEvent]:
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    @property
    def enabled(self) -> bool:
        return bool(self.events)

    def counts(self) -> dict[str, int]:
        """Event counts by kind (diagnostics / test assertions)."""
        out = {"fail": 0, "drain": 0, "rejoin": 0, "link": 0}
        for ev in self.events:
            out[ev.kind] += 1
        return out


def merge_schedules(
        schedules: Sequence[Sequence[ChurnEvent]]) -> list[ChurnEvent]:
    """Merge several time-sorted event lists into one (stable by t)."""
    return list(heapq.merge(*schedules, key=lambda ev: ev.t))
