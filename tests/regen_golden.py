#!/usr/bin/env python
"""Deterministic golden-scenario regeneration with a reviewable diff.

``tests/data/golden_scenarios.json`` pins ``Metrics.summary()`` for every
golden scenario (``SCENARIOS`` + ``MIXED_SCENARIOS`` at a reduced frame
count).  When behaviour changes *intentionally* — new summary keys, an
accounting fix — the goldens must be regenerated, and the regeneration
must be reviewable: this helper replays every scenario, prints a
structured per-scenario diff (added / removed / changed keys with old and
new values), and rewrites the file.

Usage::

    PYTHONPATH=src python tests/regen_golden.py            # regen + diff
    PYTHONPATH=src python tests/regen_golden.py --check    # diff only;
                                                           # exit 1 on drift

``--check`` never writes — it is the "would a regen change anything?"
probe (useful before concluding a behaviour change is accounting-only).
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import replace
from pathlib import Path

GOLDEN = Path(__file__).parent / "data" / "golden_scenarios.json"


def _summary(metrics) -> dict:
    """Deterministic slice of Metrics.summary() (drop wall-clock timings)."""
    return {k: v for k, v in metrics.summary().items()
            if not k.startswith("t_")}


def compute_summaries(n_frames: int) -> dict[str, dict]:
    """Replay every golden scenario at ``n_frames`` (import deferred so the
    module is importable without PYTHONPATH side effects)."""
    from repro.sim import run_scenario
    from repro.sim.experiment import MIXED_SCENARIOS, SCENARIOS
    scenarios = {**SCENARIOS, **MIXED_SCENARIOS}
    return {
        name: _summary(run_scenario(replace(cfg, n_frames=n_frames)))
        for name, cfg in scenarios.items()
    }


def diff_summaries(old: dict[str, dict], new: dict[str, dict]) -> list[str]:
    """Structured, line-per-change diff between two golden summary maps."""
    lines: list[str] = []
    for name in sorted(set(old) | set(new)):
        if name not in old:
            lines.append(f"+ scenario {name}: NEW ({len(new[name])} keys)")
            continue
        if name not in new:
            lines.append(f"- scenario {name}: REMOVED")
            continue
        o, n = old[name], new[name]
        for key in sorted(set(o) | set(n)):
            if key not in o:
                lines.append(f"  {name}.{key}: + {n[key]!r}")
            elif key not in n:
                lines.append(f"  {name}.{key}: - {o[key]!r}")
            elif o[key] != n[key]:
                lines.append(f"  {name}.{key}: {o[key]!r} -> {n[key]!r}")
    return lines


def regen(check_only: bool = False) -> int:
    """Regenerate the goldens; returns the number of changed lines."""
    data = json.loads(GOLDEN.read_text())
    new = compute_summaries(data["n_frames"])
    lines = diff_summaries(data.get("summaries", {}), new)
    if lines:
        header = ("golden drift (not written)" if check_only
                  else "golden changes")
        print(f"{header} — {len(lines)} line(s):")
        for line in lines:
            print(line)
    else:
        print("goldens unchanged")
    if not check_only and lines:
        data["summaries"] = new
        GOLDEN.write_text(json.dumps(data, indent=1, sort_keys=True))
        print(f"wrote {GOLDEN}")
    return len(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="diff only; exit 1 when a regen would change "
                         "the goldens, write nothing")
    args = ap.parse_args(argv)
    changed = regen(check_only=args.check)
    return 1 if (args.check and changed) else 0


if __name__ == "__main__":
    sys.exit(main())
