"""Calendar invariants: unit tests + seeded randomized property tests.

(The seed repo used hypothesis here; the container image does not ship it,
so the property tests are plain seeded-``random`` sweeps — same invariants,
deterministic corpus.)
"""
import random

import pytest

from repro.core.calendar import DeviceCalendar, LinkCalendar, NetworkState, Reservation


def test_link_earliest_slot_empty():
    link = LinkCalendar()
    assert link.earliest_slot(1.0, 5.0) == 5.0


def test_link_slots_never_overlap_sequential():
    link = LinkCalendar()
    r1 = link.reserve_earliest(1.0, 0.0)
    r2 = link.reserve_earliest(1.0, 0.0)
    r3 = link.reserve_earliest(0.5, 0.0)
    res = sorted([r1, r2, r3], key=lambda r: r.t1)
    for a, b in zip(res, res[1:]):
        assert a.t2 <= b.t1 + 1e-9


@pytest.mark.parametrize("seed", range(20))
def test_link_no_overlap_property(seed):
    """No two link reservations ever overlap, regardless of request order."""
    rng = random.Random(seed)
    link = LinkCalendar()
    n = rng.randint(1, 30)
    for _ in range(n):
        link.reserve_earliest(rng.uniform(0.01, 5.0), rng.uniform(0.0, 20.0))
    res = sorted(link._res, key=lambda r: r.t1)
    for a, b in zip(res, res[1:]):
        assert a.t2 <= b.t1 + 1e-9
    assert len(res) == n


@pytest.mark.parametrize("seed", range(20))
def test_device_capacity_property(seed):
    """fits() + reserve() never exceeds device capacity at any instant."""
    rng = random.Random(1000 + seed)
    dev = DeviceCalendar(0, capacity=4)
    admitted = []
    for i in range(rng.randint(1, 40)):
        t1 = rng.uniform(0.0, 50.0)
        dur = rng.uniform(0.1, 10.0)
        cores = rng.randint(1, 4)
        if dev.fits(t1, t1 + dur, cores):
            dev.reserve(t1, t1 + dur, cores, tag=i)
            admitted.append((t1, t1 + dur, cores))
    # sweep-line over all admitted intervals
    events = []
    for t1, t2, c in admitted:
        events.append((t1, c))
        events.append((t2, -c))
    events.sort()
    cur = 0
    for _, delta in events:
        cur += delta
        assert cur <= 4


def test_device_release_and_truncate():
    dev = DeviceCalendar(0, capacity=4)
    dev.reserve(0.0, 10.0, 4, tag="a")
    assert not dev.fits(5.0, 6.0, 1)
    dev.truncate("a", 5.0)
    assert dev.fits(5.0, 6.0, 4)
    dev.release("a")
    assert dev.fits(0.0, 10.0, 4)


def test_completion_times_sorted_unique():
    state = NetworkState(2)
    state.devices[0].reserve(0.0, 3.0, 2, "x")
    state.devices[1].reserve(0.0, 3.0, 2, "y")
    state.devices[0].reserve(1.0, 4.0, 2, "z")
    pts = state.completion_times(0.0, 10.0)
    assert pts == sorted(set(pts)) == [3.0, 4.0]
    assert list(state.iter_completion_times(0.0, 10.0)) == pts


# --------------------------------------------------------------------- #
# Edge cases of the skyline implementation                              #
# --------------------------------------------------------------------- #
def test_link_cancel_nonexistent_is_noop():
    link = LinkCalendar()
    r = link.reserve_earliest(1.0, 0.0)
    ghost = Reservation(50.0, 51.0, 1, "ghost")      # never reserved
    link.cancel(ghost)
    assert len(link) == 1
    link.cancel(r)
    assert len(link) == 0
    link.cancel(r)                                    # double-cancel: no-op
    assert len(link) == 0
    assert link.earliest_slot(1.0, 0.0) == 0.0


def test_device_release_nonexistent_is_noop():
    dev = DeviceCalendar(0)
    assert dev.release("ghost") is None
    dev.reserve(0.0, 5.0, 2, "a")
    assert dev.release("ghost") is None
    assert dev.max_usage(0.0, 5.0) == 2


def test_device_gc_keeps_inflight_reservation():
    """gc(now) with a reservation straddling `now` must keep its remaining
    interval fully counted."""
    dev = DeviceCalendar(0, capacity=4)
    dev.reserve(0.0, 10.0, 3, tag="run")
    dev.reserve(0.0, 2.0, 1, tag="done")
    dev.gc(5.0)
    assert len(dev) == 1                       # "done" retired, "run" alive
    assert dev.get("run") is not None
    assert dev.max_usage(5.0, 10.0) == 3
    assert dev.fits(5.0, 10.0, 1)
    assert not dev.fits(5.0, 10.0, 2)
    # the straddler can still be released after gc
    dev.release("run")
    assert dev.fits(5.0, 10.0, 4)
    assert dev.max_usage(5.0, 10.0) == 0


def test_link_gc_keeps_inflight_slot():
    link = LinkCalendar()
    r = link.reserve(0.0, 10.0, "xfer")
    link.reserve(0.0, 1.0, "done")
    link.gc(5.0)
    assert len(link) == 1
    assert link.earliest_slot(1.0, 5.0) == pytest.approx(10.0)
    link.cancel(r)
    assert link.earliest_slot(1.0, 5.0) == 5.0


def test_truncate_to_before_start_removes():
    dev = DeviceCalendar(0)
    dev.reserve(5.0, 10.0, 2, tag="a")
    dev.truncate("a", 3.0)                     # before t1 -> gone entirely
    assert dev.get("a") is None
    assert len(dev) == 0
    assert dev.max_usage(0.0, 20.0) == 0
    assert dev.completion_times(0.0, 20.0) == []


def test_truncate_exactly_at_start_removes():
    dev = DeviceCalendar(0)
    dev.reserve(5.0, 10.0, 2, tag="a")
    dev.truncate("a", 5.0)
    assert dev.get("a") is None
    assert dev.max_usage(0.0, 20.0) == 0


def test_truncate_beyond_end_is_noop():
    dev = DeviceCalendar(0)
    dev.reserve(5.0, 10.0, 2, tag="a")
    dev.truncate("a", 12.0)
    r = dev.get("a")
    assert r is not None and r.t2 == 10.0
    assert dev.completion_times(0.0, 20.0) == [10.0]


def test_reserve_same_tag_replaces():
    """Re-reserving a tag replaces the old interval (dict-overwrite
    semantics of the seed implementation)."""
    dev = DeviceCalendar(0, capacity=4)
    dev.reserve(0.0, 10.0, 4, tag="a")
    dev.reserve(20.0, 30.0, 2, tag="a")
    assert len(dev) == 1
    assert dev.max_usage(0.0, 10.0) == 0       # old interval fully released
    assert dev.max_usage(20.0, 30.0) == 2
    assert dev.completion_times(0.0, 50.0) == [30.0]


def test_skyline_coalesces_after_churn():
    """Reserve/release churn must not leak breakpoints (the skyline stays
    minimal, which is what keeps queries O(log n + window))."""
    dev = DeviceCalendar(0, capacity=4)
    for i in range(200):
        dev.reserve(float(i % 7), float(i % 7) + 1.5, 1 + i % 2, tag=i)
    for i in range(200):
        dev.release(i)
    assert dev.max_usage(0.0, 100.0) == 0
    assert dev._sky.n == 1                     # fully coalesced to sentinel
    assert len(dev._t2s) == 0


def test_device_load_matches_manual_integral():
    dev = DeviceCalendar(0, capacity=4)
    dev.reserve(0.0, 10.0, 2, "a")             # 20 core-s
    dev.reserve(5.0, 15.0, 1, "b")             # 10 core-s
    assert dev.load(0.0, 15.0) == pytest.approx(30.0)
    assert dev.load(0.0, 5.0) == pytest.approx(10.0)
    assert dev.load(5.0, 10.0) == pytest.approx(15.0)
    assert dev.load(20.0, 30.0) == 0.0


def test_earliest_fit_device():
    dev = DeviceCalendar(0, capacity=4)
    dev.reserve(0.0, 10.0, 4, "full")
    dev.reserve(10.0, 20.0, 2, "half")
    assert dev.earliest_fit(1.0, 0.0, 4) == pytest.approx(20.0)
    assert dev.earliest_fit(1.0, 0.0, 2) == pytest.approx(10.0)
    assert dev.earliest_fit(1.0, 12.0, 2) == pytest.approx(12.0)
