"""Corpus: pragma scoping — suppression is per-line, not per-file."""
import time


def stamped():
    t0 = time.time()  # replint: disable=determinism-wallclock (corpus: attested telemetry)
    t1 = time.time()                       # BAD: pragma above does not reach here
    return t0, t1


def all_off():
    return time.time()  # replint: disable=all
