from .kernel import slstm_scan
from .ops import slstm_hidden_states
from .ref import slstm_scan_ref

__all__ = ["slstm_scan", "slstm_hidden_states", "slstm_scan_ref"]
