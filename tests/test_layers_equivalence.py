"""Mixer-level equivalence properties: each recurrent decode form must match
its parallel training form (the core correctness invariant of every cache)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.configs import get_smoke_config
from repro.models.layers import mamba as Mb
from repro.models.layers import mla as L
from repro.models.layers import xlstm as X
from repro.models.layers import attention as A


def test_mlstm_parallel_equals_recurrent():
    cfg = get_smoke_config("xlstm-1.3b")
    p = X.mlstm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 11, cfg.d_model))
    y_par, _ = X.mlstm_apply(p, x, cfg)
    cache = X.init_mlstm_cache(2, cfg, jnp.float32)
    outs = []
    for t in range(11):
        y, cache = X.mlstm_apply(p, x[:, t:t + 1], cfg, cache=cache)
        outs.append(y)
    y_rec = jnp.concatenate(outs, 1)
    assert_allclose(np.asarray(y_par), np.asarray(y_rec), atol=2e-5,
                    rtol=2e-4)


def test_slstm_scan_equals_step():
    cfg = get_smoke_config("xlstm-1.3b")
    p = X.slstm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model))
    y_scan, _ = X.slstm_apply(p, x, cfg)
    cache = X.init_slstm_cache(2, cfg, jnp.float32)
    outs = []
    for t in range(9):
        y, cache = X.slstm_apply(p, x[:, t:t + 1], cfg, cache=cache)
        outs.append(y)
    assert_allclose(np.asarray(y_scan), np.asarray(jnp.concatenate(outs, 1)),
                    atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("t", [5, 17, 40])
def test_mamba_chunked_scan_equals_step(t, monkeypatch):
    """Chunked associative scan == sequential recurrence, incl. chunk pads."""
    monkeypatch.setattr(Mb, "CHUNK", 16)
    cfg = get_smoke_config("jamba-1.5-large-398b")
    p = Mb.mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, t, cfg.d_model))
    y_par, _ = Mb.mamba_apply(p, x, cfg)
    cache = Mb.init_mamba_cache(2, cfg, jnp.float32)
    outs = []
    for i in range(t):
        y, cache = Mb.mamba_apply(p, x[:, i:i + 1], cfg, cache=cache)
        outs.append(y)
    assert_allclose(np.asarray(y_par), np.asarray(jnp.concatenate(outs, 1)),
                    atol=3e-5, rtol=3e-4)


@pytest.mark.parametrize("qlora", [0, 48])
def test_mla_absorbed_decode_equals_naive(qlora):
    from dataclasses import replace
    cfg = get_smoke_config("deepseek-v3-671b")
    cfg = replace(cfg, mla=replace(cfg.mla, q_lora_rank=qlora))
    p = L.mla_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model))
    y_naive, _ = L.mla_apply(p, x, cfg, positions=jnp.arange(9))
    cache = L.init_mla_cache(2, 16, cfg, jnp.float32)
    outs = []
    for t in range(9):
        y, cache = L.mla_apply(p, x[:, t:t + 1], cfg,
                               positions=jnp.asarray([t]), cache=cache)
        outs.append(y)
    assert_allclose(np.asarray(y_naive), np.asarray(jnp.concatenate(outs, 1)),
                    atol=2e-5, rtol=2e-4)


def test_mla_cache_is_compressed():
    """The MLA serving win: cache stores rank-R latents, not H*D keys."""
    cfg = get_smoke_config("deepseek-v3-671b")
    cache = L.init_mla_cache(1, 64, cfg, jnp.float32)
    mla_bytes = sum(np.prod(v.shape) for k, v in cache.items()
                    if k != "positions")
    full_kv_bytes = 2 * 64 * cfg.n_heads * cfg.resolved_head_dim
    assert mla_bytes < 0.35 * full_kv_bytes


def test_gqa_attention_window_equals_full_when_window_large():
    cfg = get_smoke_config("qwen2-0.5b")
    p = A.attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    pos = jnp.arange(8)
    y_full, _ = A.attn_apply(p, x, cfg, positions=pos, window=0)
    y_win, _ = A.attn_apply(p, x, cfg, positions=pos, window=100)
    assert_allclose(np.asarray(y_full), np.asarray(y_win), atol=1e-6)


@pytest.mark.parametrize("chunk,t", [(4, 16), (8, 11), (5, 17)])
def test_mlstm_chunked_equals_naive(chunk, t):
    """Chunkwise-parallel mLSTM (§Perf) is exactly the naive T x T form
    (same stabiliser semantics), including ragged final chunks."""
    from dataclasses import replace
    cfg = get_smoke_config("xlstm-1.3b")
    p = X.mlstm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, t, cfg.d_model))
    y_naive, _ = X.mlstm_apply(p, x, cfg)
    y_chunk, _ = X.mlstm_apply(p, x, replace(cfg, mlstm_chunk=chunk))
    assert_allclose(np.asarray(y_naive), np.asarray(y_chunk), atol=2e-5,
                    rtol=2e-4)
