"""Soak benchmark: sustained streaming traffic with an RSS-flatness gate.

Pushes an open-ended firehose (``sim/openended.py``) through the
streaming engine (``serving/stream.py``) at steady state and verifies the
process footprint stays flat — the admission queue is bounded, terminal
requests are dropped as they settle, and all telemetry is fixed-size
sketches, so RSS at request 10^6 must match RSS at request 10^5.

Default configuration is the trajectory point committed as
``BENCH_7.json``: **1M requests over a 1024-device network**.  ``--smoke``
is the CI tier (50k requests, 64 devices) gated on RSS flatness and p99
admission latency.  ``--churn`` layers a seeded device-churn schedule
(DESIGN.md §16) on top — failures, drains, rejoins — and additionally
gates on the orphan-recovery ratio; the committed churn-tier trajectory
point is ``BENCH_9.json``.

The timing model is a serve-style profile (tens-of-ms tasks, multi-GB/s
link), not the paper's RPi2B constants: the paper's 16.3 MB/s link with
2 ms jitter padding caps the *whole network* at ~245 admissions/s, which
would make a 10^6-request soak mostly idle virtual time.  The scheduling
machinery exercised is identical.

Usage:
    PYTHONPATH=src python benchmarks/soak.py [--smoke] [--gate]
        [--requests N] [--devices N] [--rate R] [--window W] [--queue N]
        [--shed NAME] [--policy NAME] [--seed N] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import resource
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.network import NetworkConfig  # noqa: E402
from repro.core.profiles import TaskProfile, WorkloadSpec  # noqa: E402
from repro.core.task import reset_id_counters  # noqa: E402
from repro.serving.stream import StreamingEngine  # noqa: E402
from repro.sim.churn import ChurnConfig, ChurnInjector  # noqa: E402
from repro.sim.openended import FirehoseConfig, firehose  # noqa: E402

_PAGE = resource.getpagesize()

# RSS-flatness gate: after warmup, late-half mean RSS may exceed the
# early-half mean by at most max(RSS_ABS_MB, RSS_REL * early).  The
# absolute floor absorbs allocator noise (arena growth, event-heap
# high-water) on small runs.
RSS_ABS_MB = 32.0
RSS_REL = 0.10
# CI smoke gate on p99 per-request admission latency (wall-clock).  The
# 64-device scheduler admits in ~50-100 us; 50 ms is ~3 orders of
# headroom for noisy shared runners while still catching an O(n) or
# leak-driven collapse.
P99_ADMISSION_GATE_S = 0.050
# Churn-tier gate (DESIGN.md §16): under sustained device churn the run
# must still re-place at least this fraction of orphaned work.  The
# global ratio includes inherently-unrecoverable HP orphans (HP is
# source-local: the orphan of a hard-failed source can never re-admit),
# so the floor sits well below 1.0.
CHURN_RECOVERY_FLOOR = 0.25
# Expected fraction of the fleet hard-failing / draining over the churn
# tier's active span (the middle 80% of the run's virtual horizon).
CHURN_FAIL_FRAC = 0.10
CHURN_DRAIN_FRAC = 0.05


def rss_bytes() -> float:
    """Current (not peak) resident set size via /proc/self/statm."""
    with open("/proc/self/statm") as fh:
        return float(fh.read().split()[1]) * _PAGE


def soak_network() -> NetworkConfig:
    """Serve-style timing model: sub-second tasks, a 5 GB/s shared link."""
    prof = TaskProfile(
        name="serve",
        hp_exec=0.020, hp_pad=0.002,
        lp_exec={2: 0.200, 4: 0.120},
        lp_pad={2: 0.010, 4: 0.008},
        input_bytes=21500, output_bytes=550,
        hp_deadline_slack=0.50,
        lp_deadline=5.0,
    )
    spec = WorkloadSpec(name="soak_serve", profiles={"serve": prof},
                        default_type="serve")
    return NetworkConfig(throughput_bps=5e9, jitter_pad_s=2e-5,
                         workload=spec)


def churn_schedule_for(requests: int, devices: int, rate: float,
                       seed: int) -> ChurnInjector:
    """Seeded churn sized to the soak run: CHURN_FAIL_FRAC of the fleet
    hard-fails (and CHURN_DRAIN_FRAC drains) across the middle 80% of
    the run's virtual horizon, everything rejoining after 2 s."""
    horizon = requests / rate
    span = 0.8 * horizon
    return ChurnInjector(ChurnConfig(
        name="soak_churn", n_devices=devices,
        fail_rate=CHURN_FAIL_FRAC * devices / span,
        drain_rate=CHURN_DRAIN_FRAC * devices / span,
        rejoin=True, rejoin_delay=2.0,
        start=0.1 * horizon, duration=span, seed=seed))


def run_soak(
    *,
    requests: int,
    devices: int,
    rate: float,
    window: float,
    queue: int,
    shed: str,
    policy: str,
    seed: int,
    churn: bool = False,
    progress: bool = True,
) -> dict:
    reset_id_counters()
    eng = StreamingEngine(
        devices, net=soak_network(), policy=policy,
        queue_capacity=queue, shed=shed, window=window)
    cfg = FirehoseConfig(
        name="soak", n_devices=devices, rate=rate,
        lp_fraction=0.4, lp_set_sizes=(1, 2, 3, 4), seed=seed)
    injector = churn_schedule_for(requests, devices, rate, seed) \
        if churn else None

    expected_windows = max(1, int(requests / (rate * window)))
    stride = max(1, expected_windows // 256)
    rss_series: list[float] = []
    windows_seen = [0]

    def on_window(e: StreamingEngine) -> None:
        windows_seen[0] += 1
        if windows_seen[0] % stride == 0:
            rss_series.append(rss_bytes())
            if progress and len(rss_series) % 32 == 0:
                t = e.telemetry
                print(f"#   offered={t.offered:>9d} shed={t.shed_total:>7d} "
                      f"depth={e.queue.live:>5d} rss={rss_series[-1]/2**20:7.1f} MB",
                      flush=True)

    rss_series.append(rss_bytes())
    t0 = time.perf_counter()
    report = eng.run(firehose(cfg, limit=requests), on_window=on_window,
                     churn=iter(injector) if injector is not None else None)
    wall = time.perf_counter() - t0
    rss_series.append(rss_bytes())

    # flatness: drop the first quarter (warmup — calendars, heaps and
    # sketches reach steady state), compare early-half vs late-half means
    tail = rss_series[len(rss_series) // 4:]
    half = max(1, len(tail) // 2)
    early = sum(tail[:half]) / half
    late = sum(tail[-half:]) / half
    growth = late - early
    allowed = max(RSS_ABS_MB * 2**20, RSS_REL * early)

    m, tel = report["metrics"], report["telemetry"]
    adm, e2e = tel["admission_latency_s"], tel["e2e_latency_s"]
    slo = tel["slo"]
    attain = (sum(r["attained"] for r in slo.values())
              / max(1, sum(r["attained"] + r["missed"] for r in slo.values())))
    orphans = m.get("orphans_created", 0)
    recovered = m.get("orphans_recovered", 0)
    return {
        "config": f"{devices}dev_{requests}req_{shed}_{policy}"
                  + ("_churn" if churn else ""),
        "report": report,
        "churn": churn,
        "churn_events": len(injector) if injector is not None else 0,
        "devices_failed": m.get("device_failures", 0),
        "devices_drained": m.get("device_drains", 0),
        "devices_rejoined": m.get("device_rejoins", 0),
        "orphans_created": orphans,
        "orphans_recovered": recovered,
        "recovery_ratio": (recovered / orphans) if orphans else 1.0,
        "requests": requests,
        "wall_s": wall,
        "req_per_s_wall": requests / wall if wall > 0 else 0.0,
        "virtual_s": eng.q.now,
        "hp_completion_pct": m["hp_completion_pct"],
        "lp_completion_pct": m["lp_completion_pct"],
        "slo_attainment_pct": 100.0 * attain,
        "shed_total": tel["shed_total"],
        "shed_pct": 100.0 * tel["shed_total"] / max(1, tel["offered"]),
        "degraded": tel["degraded"],
        "windows": tel["windows"],
        "admission_p50_us": adm["p50"] * 1e6,
        "admission_p99_us": adm["p99"] * 1e6,
        "admission_p999_us": adm["p999"] * 1e6,
        "e2e_p50_s": e2e["p50"],
        "e2e_p99_s": e2e["p99"],
        "e2e_p999_s": e2e["p999"],
        "queue_depth_max": tel["queue_depth"]["max"],
        "unresolved": report["unresolved"],
        "rss_early_mb": early / 2**20,
        "rss_late_mb": late / 2**20,
        "rss_growth_mb": growth / 2**20,
        "rss_allowed_mb": allowed / 2**20,
        "rss_flat": growth <= allowed,
        "rss_samples": len(rss_series),
    }


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--requests", type=int, default=1_000_000)
    ap.add_argument("--devices", type=int, default=1024)
    ap.add_argument("--rate", type=float, default=None,
                    help="arrivals per virtual second "
                         "(default: 4.8 * devices)")
    ap.add_argument("--window", type=float, default=0.05)
    ap.add_argument("--queue", type=int, default=8192)
    ap.add_argument("--shed", default="reject_cheapest")
    ap.add_argument("--policy", default="scheduler")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI tier: 50k requests over 64 devices")
    ap.add_argument("--churn", action="store_true",
                    help="churn tier (DESIGN.md §16): inject seeded device "
                         "failures/drains/rejoins; with --gate, also gate "
                         "on orphan recovery")
    ap.add_argument("--gate", action="store_true",
                    help="exit non-zero on RSS growth or p99 admission "
                         "latency beyond the gates (with --churn: also on "
                         "the orphan-recovery floor)")
    ap.add_argument("--json", metavar="PATH", default=None)
    args = ap.parse_args()

    if args.smoke:
        args.requests = min(args.requests, 50_000)
        args.devices = min(args.devices, 64)
    rate = args.rate if args.rate is not None else 4.8 * args.devices

    print(f"# soak: {args.requests} requests, {args.devices} devices, "
          f"rate={rate:g}/s, window={args.window}s, queue={args.queue}, "
          f"shed={args.shed}, policy={args.policy}"
          f"{', churn tier' if args.churn else ''}", flush=True)
    res = run_soak(
        requests=args.requests, devices=args.devices, rate=rate,
        window=args.window, queue=args.queue, shed=args.shed,
        policy=args.policy, seed=args.seed, churn=args.churn)

    skip = {"report", "config"}
    for k, v in res.items():
        if k in skip:
            continue
        print(f"# {k:>22s} = {v:.3f}" if isinstance(v, float)
              else f"# {k:>22s} = {v}")

    if args.json:
        rows = [{"bench": "soak", "config": res["config"],
                 "metric": k, "value": round(v, 4) if isinstance(v, float)
                 else v}
                for k, v in res.items()
                if k not in skip and isinstance(v, (int, float))]
        doc = {
            "meta": {
                "benchmark": "soak",
                "machine": platform.machine(),
                "python": platform.python_version(),
                "quick": bool(args.smoke),
                "requests": args.requests,
                "devices": args.devices,
                "rate": rate,
                "window_s": args.window,
                "queue_capacity": args.queue,
                "shed": args.shed,
                "policy": args.policy,
                "seed": args.seed,
                "total_wall_s": round(res["wall_s"], 1),
            },
            "rows": rows,
        }
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {len(rows)} rows to {args.json}")

    if args.gate:
        failures = []
        if not res["rss_flat"]:
            failures.append(
                f"RSS grew {res['rss_growth_mb']:.1f} MB "
                f"(allowed {res['rss_allowed_mb']:.1f} MB)")
        if res["admission_p99_us"] > P99_ADMISSION_GATE_S * 1e6:
            failures.append(
                f"p99 admission latency {res['admission_p99_us']:.0f} us "
                f"> {P99_ADMISSION_GATE_S * 1e6:.0f} us")
        if res["unresolved"]:
            failures.append(f"{res['unresolved']} unresolved tasks")
        if args.churn:
            if res["devices_failed"] == 0:
                failures.append("churn tier fired zero device failures")
            if res["recovery_ratio"] < CHURN_RECOVERY_FLOOR:
                failures.append(
                    f"recovery_ratio {res['recovery_ratio']:.3f} < "
                    f"floor {CHURN_RECOVERY_FLOOR}")
        if failures:
            print("# GATE FAIL: " + "; ".join(failures))
            sys.exit(1)
        print("# GATE PASS: RSS flat, admission p99 within bound"
              + (", orphan recovery above floor" if args.churn else ""))


if __name__ == "__main__":
    main()
