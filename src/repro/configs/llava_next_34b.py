"""llava-next-34b [vlm] — anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.  Decoder-only LM
consuming projected vision-patch embeddings.  The ViT encoder + anyres tile
splitter is a STUB per the brief: ``input_specs()`` supplies precomputed
patch embeddings (dim 1024, up to 5 tiles x 576 patches = 2880 tokens
prepended to the text); the projector + LM are real.
"""
from __future__ import annotations

from dataclasses import replace

from ..models.config import ModelConfig

N_IMAGE_TOKENS = 2880       # anyres: base tile + 4 crops, 576 patches each

CONFIG = ModelConfig(
    name="llava-next-34b",
    arch_type="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    modality="vision",
    modality_embed_dim=1024,
    n_modality_tokens=N_IMAGE_TOKENS,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, stages=(), modality_embed_dim=64,
        n_modality_tokens=8,
    )
