"""Dense SwiGLU FFN and Mixture-of-Experts with capacity-based dispatch.

MoE follows the GShard/Switch group-wise dispatch adapted for TPU: tokens are
split into groups of ``group_size``; each group routes top-k with per-group
expert capacity C = ceil(k * group_size / E * capacity_factor).  Dispatch and
combine are einsums against a [G, T, E, C] one-hot — this shards cleanly on
(data x model) meshes and keeps the HLO static.  Overflow tokens fall through
to the residual (plus shared experts when present).

Routers: "softmax" (classic) or "sigmoid" (DeepSeek-V3 style scores with
top-k renormalisation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..config import ModelConfig, MoEConfig
from .common import dense_init, swiglu


# --------------------------------------------------------------------------- #
# Dense FFN                                                                   #
# --------------------------------------------------------------------------- #


def ffn_init(key, d_model: int, d_ff: int, dtype) -> dict:
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "wg": dense_init(kg, d_model, d_ff, dtype=dtype),
        "wu": dense_init(ku, d_model, d_ff, dtype=dtype),
        "wd": dense_init(kd, d_ff, d_model, dtype=dtype),
    }


def ffn_axes() -> dict:
    return {"wg": ("embed", "ff"), "wu": ("embed", "ff"), "wd": ("ff", "embed")}


def ffn_apply(params: dict, x: jax.Array) -> jax.Array:
    gate = jnp.einsum("btd,df->btf", x, params["wg"])
    up = jnp.einsum("btd,df->btf", x, params["wu"])
    return jnp.einsum("btf,fd->btd", swiglu(gate, up), params["wd"])


# --------------------------------------------------------------------------- #
# MoE                                                                         #
# --------------------------------------------------------------------------- #


def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.moe
    assert m is not None
    d, f, e = cfg.d_model, m.d_expert, m.n_experts
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "router": dense_init(kr, d, e, dtype=jnp.float32),   # router in f32
        "wg": (scale * jax.random.normal(kg, (e, d, f))).astype(dtype),
        "wu": (scale * jax.random.normal(ku, (e, d, f))).astype(dtype),
        "wd": (f ** -0.5 * jax.random.normal(kd, (e, f, d))).astype(dtype),
    }
    if m.n_shared:
        p["shared"] = ffn_init(ks, d, m.d_expert * m.n_shared, dtype)
    return p


def moe_axes(cfg: ModelConfig) -> dict:
    m = cfg.moe
    assert m is not None
    a = {
        "router": ("embed", "experts"),
        "wg": ("experts", "embed", "expert_ff"),
        "wu": ("experts", "embed", "expert_ff"),
        "wd": ("experts", "expert_ff", "embed"),
    }
    if m.n_shared:
        a["shared"] = ffn_axes()
    return a


def _route(m: MoEConfig, logits: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """logits [..., E] -> (topk_weight [..., k], topk_idx [..., k], probs)."""
    if m.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        w, idx = jax.lax.top_k(scores, m.top_k)
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)
        probs = scores / (jnp.sum(scores, axis=-1, keepdims=True) + 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, m.top_k)
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)
    return w, idx, probs


def moe_apply(
    params: dict,
    x: jax.Array,                    # [B, T, d]
    cfg: ModelConfig,
    group_size: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B,T,d], aux_loss scalar)."""
    m = cfg.moe
    assert m is not None
    b, t, d = x.shape
    n_tok = b * t
    g_sz = min(group_size, n_tok)
    # pad token count to a multiple of the group size
    n_pad = (-n_tok) % g_sz
    flat = x.reshape(n_tok, d)
    if n_pad:
        flat = jnp.concatenate([flat, jnp.zeros((n_pad, d), x.dtype)], axis=0)
    g = flat.shape[0] // g_sz
    xg = flat.reshape(g, g_sz, d)

    logits = jnp.einsum("gtd,de->gte", xg, params["router"].astype(xg.dtype))
    weights, idx, probs = _route(m, logits.astype(jnp.float32))

    e = m.n_experts
    cap = max(1, int(m.top_k * g_sz / e * m.capacity_factor + 0.9999))

    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)          # [g,t,k,E]
    # position of each (token, k) within its expert's buffer, scan over tokens
    pos = jnp.cumsum(onehot.reshape(g, g_sz * m.top_k, e), axis=1) - 1.0
    pos = pos.reshape(g, g_sz, m.top_k, e)
    keep = (pos < cap) & (onehot > 0)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    dispatch = jnp.einsum("gtke,gtkec->gtec", onehot * keep, pos_oh)
    combine = jnp.einsum("gtk,gtke,gtkec->gtec", weights, onehot * keep, pos_oh)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(xg.dtype), xg)
    h = swiglu(
        jnp.einsum("gecd,edf->gecf", xe, params["wg"]),
        jnp.einsum("gecd,edf->gecf", xe, params["wu"]),
    )
    ye = jnp.einsum("gecf,efd->gecd", h, params["wd"])
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(xg.dtype), ye)

    y = y.reshape(-1, d)[:n_tok].reshape(b, t, d)

    # Switch-style load balance aux loss: E * sum_e f_e * p_e
    frac = jnp.mean(onehot[..., 0, :] if m.top_k == 1 else onehot.sum(2), axis=(0, 1))
    frac = frac / m.top_k
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = m.n_experts * jnp.sum(frac * pmean) * m.router_aux_weight

    if m.n_shared:
        y = y + ffn_apply(params["shared"], x)
    return y, aux
