from .store import exists, load_metadata, restore, save  # noqa: F401
from .lifecycle import (  # noqa: F401
    lifecycle_reference,
    lifecycle_tree,
    restore_lifecycle,
    save_lifecycle,
)
