# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from .calendar import DeviceCalendar, LinkCalendar, NetworkState, Reservation
from .metrics import Metrics
from .network import MessageSizes, NetworkConfig
from .scheduler import (
    Allocation,
    HPResult,
    LPResult,
    PreemptionAwareScheduler,
)
from .task import Frame, LowPriorityRequest, Priority, Task, TaskState

__all__ = [
    "Allocation",
    "DeviceCalendar",
    "Frame",
    "HPResult",
    "LinkCalendar",
    "LowPriorityRequest",
    "LPResult",
    "MessageSizes",
    "Metrics",
    "NetworkConfig",
    "NetworkState",
    "PreemptionAwareScheduler",
    "Priority",
    "Reservation",
    "Task",
    "TaskState",
]
