"""Large-N scenario suite: scaling the paper's workload beyond 4 devices.

The paper's evaluation (§5/§6) stops at four RPi2B devices and 1296 frames.
The ROADMAP north-star is a production-scale serving system, so this module
generates parameterised workloads for **4 -> 256+ devices** and drives the
scheduler end-to-end over them (admission -> time-slotted occupancy ->
expiry), measuring the controller's *wall-clock admission latency* — the
quantity the O(log n) calendar rewrite (DESIGN.md §2) is meant to keep off
the critical path.

Three arrival families (DESIGN.md §5.2):

* ``poisson``     — independent per-device Poisson HP arrivals; a fraction
                    of HP tasks spawns an LP set (the steady-state regime).
* ``bursty``      — on/off modulated Poisson: burst phases at ``burst_factor``
                    times the base rate separated by near-idle phases
                    (arrival correlation stresses the batch-admission path).
* ``adversarial`` — synchronised waves: every device emits an HP task at the
                    same instant, immediately followed by the wave's LP sets;
                    maximises link contention and preemption pressure
                    (worst case for a shared single-AP network, paper §3).
* ``preempt_storm`` — the preemption-adversarial family (DESIGN.md §12):
                    a saturation phase packs every device with max-size LP
                    sets, then synchronised HP-only bursts aim at the loaded
                    devices every ``wave_period`` — each burst admission has
                    to walk the eviction/reallocation path, which is what
                    ``bench_preemption`` (benchmarks/scheduler_micro.py)
                    measures across the 4 -> 1024 tier ladder.

HP:LP mix sweeps ride on ``lp_fraction`` (the probability that an HP arrival
spawns an LP set); ``sweep_mix`` builds the standard ratio ladder.

The driver deliberately runs at the *admission* level rather than through
``sim.experiment.Runtime``: execution noise and completion bookkeeping are
orthogonal to scheduler scalability, and at 256 devices the discrete-event
runtime would dominate the measurement we care about.  The scheduler still
sees a fully live network state — allocations occupy the calendars until
their slots expire, exactly as in the full simulation.
"""
from __future__ import annotations

import heapq
import time as _time
from dataclasses import dataclass, replace
from typing import Iterable, Optional, Sequence

import numpy as np

from ..core.calendar import NetworkState
from ..core.metrics import Metrics
from ..core.network import NetworkConfig, resolve_network
from ..core.profiles import PAPER_TYPE, get_workload, validate_workload_name
from ..core.scheduler import PreemptionAwareScheduler
from ..core.task import LowPriorityRequest, Priority, Task, reset_id_counters

ARRIVAL_KINDS = ("poisson", "bursty", "adversarial", "preempt_storm")

#: The standard device-count ladder.  The 1024 tier exists to exercise the
#: vectorized probe plane (calendar.py) well past the paper's four devices —
#: admission latency there is dominated by stacked NumPy passes, not by
#: per-device Python loops, so the controller keeps up with a four-digit
#: fleet (benchmarks/scheduler_micro.py reports the measured latencies).
LARGE_N_TIERS = (4, 16, 64, 256, 1024)


@dataclass(frozen=True)
class Arrival:
    """One scheduling trigger: an HP task, optionally spawning an LP set."""

    t: float
    device: int
    n_lp_tasks: int          # 0 = HP only; >0 = HP followed by an LP set
    task_type: Optional[str] = None    # workload-profile key (mixed fleets)


@dataclass(frozen=True)
class LargeNConfig:
    """A parameterised large-network workload.

    ``hp_rate`` is per-device HP arrivals per second; with the RPi2B timing
    model one HP task occupies one core for ~1 s, and each LP task occupies
    2 cores for ~17 s, so utilisation scales roughly as
    ``hp_rate * (1 + lp_fraction * E[set size] * 34 / capacity)``.
    """

    name: str
    n_devices: int = 64                      # 4 .. 256+
    duration: float = 300.0                  # seconds of arrivals
    arrival: str = "poisson"                 # poisson | bursty | adversarial
    hp_rate: float = 0.05                    # HP arrivals / device / second
    lp_fraction: float = 0.6                 # P(HP arrival spawns an LP set)
    lp_set_sizes: tuple[int, ...] = (1, 2, 3, 4)
    lp_deadline: float = 120.0               # LP deadline relative to arrival
    lp_delay: float = 1.1                    # stage-2 latency before LP request
    burst_factor: float = 6.0                # bursty: peak/base rate ratio
    burst_len: float = 10.0                  # bursty: burst phase length (s)
    idle_len: float = 30.0                   # bursty: idle phase length (s)
    wave_period: float = 8.0                 # adversarial: seconds between waves
    seed: int = 0
    # Workload spec name (core/profiles.py): "paper" = the single-model
    # seed workload; "mixed_edge" interleaves three model profiles with
    # their own benchmark tables, transfer sizes and LP deadlines.
    workload: str = PAPER_TYPE

    def __post_init__(self) -> None:
        if self.arrival not in ARRIVAL_KINDS:
            raise ValueError(f"unknown arrival family: {self.arrival}")
        validate_workload_name(self.workload)


def sweep_devices(
    base: LargeNConfig, sizes: Sequence[int] = LARGE_N_TIERS
) -> list[LargeNConfig]:
    """Device-count ladder with per-size names (4 -> 1024 by default)."""
    return [replace(base, name=f"{base.name}_n{n}", n_devices=n) for n in sizes]


def sweep_mix(
    base: LargeNConfig, fractions: Sequence[float] = (0.0, 0.25, 0.5, 0.75, 1.0)
) -> list[LargeNConfig]:
    """HP:LP ratio ladder (lp_fraction = share of HP arrivals spawning sets)."""
    return [
        replace(base, name=f"{base.name}_mix{int(f * 100)}", lp_fraction=f)
        for f in fractions
    ]


def generate_arrivals(cfg: LargeNConfig) -> list[Arrival]:
    """Deterministic (seeded) arrival stream, sorted by time."""
    rng = np.random.default_rng(cfg.seed * 9973 + cfg.n_devices)
    pick_type = _type_picker(cfg)
    out: list[Arrival] = []
    if cfg.arrival == "adversarial":
        n_waves = max(1, int(cfg.duration / cfg.wave_period))
        for w in range(n_waves):
            t = w * cfg.wave_period
            for d in range(cfg.n_devices):
                out.append(Arrival(t, d, _lp_size(cfg, rng), pick_type()))
        return out

    if cfg.arrival == "preempt_storm":
        # Saturation phase: every device receives a jittered train of
        # max-size LP sets inside the first wave period, filling its
        # calendar.  Burst phases: synchronised HP-only arrivals at EVERY
        # device — aimed exactly at the saturated calendars, so each one
        # exercises eviction + victim reallocation.
        sat_end = min(cfg.wave_period, cfg.duration)
        for d in range(cfg.n_devices):
            t = float(rng.uniform(0.0, 0.5 * sat_end))
            while t < sat_end:
                out.append(Arrival(t, d, max(cfg.lp_set_sizes), pick_type()))
                t += float(rng.exponential(sat_end / 4.0))
        n_waves = max(1, int((cfg.duration - sat_end) / cfg.wave_period))
        for w in range(n_waves):
            t = sat_end + w * cfg.wave_period
            if t >= cfg.duration:   # every family stays inside [0, duration)
                break
            for d in range(cfg.n_devices):
                out.append(Arrival(t, d, 0, pick_type()))
        out.sort(key=lambda a: (a.t, a.device))
        return out

    for d in range(cfg.n_devices):
        t = 0.0
        while True:
            rate = cfg.hp_rate
            if cfg.arrival == "bursty":
                period = cfg.burst_len + cfg.idle_len
                in_burst = (t % period) < cfg.burst_len
                rate = cfg.hp_rate * (cfg.burst_factor if in_burst else 0.1)
            t += float(rng.exponential(1.0 / max(rate, 1e-9)))
            if t >= cfg.duration:
                break
            out.append(Arrival(t, d, _lp_size(cfg, rng), pick_type()))
    out.sort(key=lambda a: (a.t, a.device))
    return out


def _type_picker(cfg: LargeNConfig):
    """Per-arrival task-type draw for mixed workloads.  Single-profile
    specs return a constant None picker WITHOUT consuming randomness, so
    the paper-workload arrival streams are bit-identical to before; mixed
    specs draw from a dedicated rng (never the arrival-time rng)."""
    spec = get_workload(cfg.workload)
    if not spec.is_mixed:
        return lambda: None
    weights = spec.mix_weights()
    names = [t for t, _ in weights]
    p = np.asarray([w for _, w in weights])
    trng = np.random.default_rng(cfg.seed * 7907 + cfg.n_devices + 1)
    return lambda: str(names[int(trng.choice(len(names), p=p))])


def _lp_size(cfg: LargeNConfig, rng: np.random.Generator) -> int:
    if cfg.lp_fraction <= 0.0 or float(rng.random()) >= cfg.lp_fraction:
        return 0
    return int(rng.choice(cfg.lp_set_sizes))


def run_large_n(
    cfg: LargeNConfig,
    net: Optional[NetworkConfig] = None,
    *,
    batch_window: float = 0.0,
    preemption: bool = True,
    preemption_plane: bool = True,
    state: Optional[object] = None,
    churn: Optional[Iterable] = None,
) -> dict:
    """Drive the scheduler over the scenario's arrival stream, end to end.

    ``batch_window > 0`` buffers LP requests arriving within the window and
    admits each buffer through ``allocate_low_priority_batch`` (the
    controller-side batching mode); ``0`` admits per request like the paper.
    ``state`` lets benchmarks substitute ``ReferenceNetworkState`` so old and
    new calendars run the *same* workload; ``preemption_plane=False`` forces
    the scalar eviction loop (the preemption plane's differential
    reference — ``bench_preemption`` runs both over identical storms).
    ``churn`` is an optional time-sorted stream of
    :class:`~repro.sim.churn.ChurnEvent` records merged into the
    controller event heap (``None`` executes zero churn code, keeping
    churn-free runs bit-identical).

    Returns a summary dict with admission counts and wall-clock admission
    latency statistics (microseconds per call).
    """
    # An explicit net wins but must cover the workload's task types
    # (resolve_network raises early on a mismatch).
    net = resolve_network(net, cfg.workload)
    reset_id_counters()
    st = state if state is not None else NetworkState(cfg.n_devices)
    metrics = Metrics(cfg.name)
    sched = PreemptionAwareScheduler(st, net, preemption=preemption,
                                     metrics=metrics,
                                     preemption_plane=preemption_plane)
    arrivals = generate_arrivals(cfg)

    hp_ok = hp_fail = lp_ok = lp_fail = 0
    buffer: list[LowPriorityRequest] = []

    def tally_lp(results) -> None:
        nonlocal lp_ok, lp_fail
        for res in results:
            lp_ok += len(res.allocations)
            lp_fail += len(res.failed)

    # Chronological controller event stream (the calendars require monotone
    # `now`): HP admission at arrival time; the LP request materialises
    # ``lp_delay`` later (stage-2 latency); in batching mode a flush event
    # closes ``batch_window`` after the first buffered request.
    HP, LP, FLUSH, CHURN = 0, 1, 2, 3
    seq = 0
    heap: list[tuple[float, int, int, object]] = []
    for a in arrivals:
        heap.append((a.t, seq, HP, a))
        seq += 1
    if churn is not None:
        for ev in churn:
            heap.append((ev.t, seq, CHURN, ev))
            seq += 1
    heapq.heapify(heap)
    flush_pending = False

    t_wall = _time.perf_counter()
    while heap:
        now, _, kind, payload = heapq.heappop(heap)
        if kind == HP:
            a = payload
            hp = Task(priority=Priority.HIGH, source_device=a.device,
                      deadline=net.hp_deadline(now, a.task_type), frame_id=0,
                      task_type=a.task_type, created_at=now)
            if sched.allocate_high_priority(hp, now).success:
                hp_ok += 1
            else:
                hp_fail += 1
            if a.n_lp_tasks > 0:
                heapq.heappush(heap, (now + cfg.lp_delay, seq, LP, a))
                seq += 1
        elif kind == LP:
            a = payload
            # Per-type relative deadline when the profile declares one
            # (mixed fleets), else the scenario-wide lp_deadline.
            prof = net.profile(a.task_type)
            rel_dl = (prof.lp_deadline if prof.lp_deadline is not None
                      else cfg.lp_deadline)
            req = LowPriorityRequest(source_device=a.device,
                                     deadline=now + rel_dl,
                                     frame_id=0, n_tasks=a.n_lp_tasks,
                                     task_type=a.task_type,
                                     created_at=now)
            req.make_tasks()
            if batch_window > 0.0:
                buffer.append(req)
                if not flush_pending:
                    flush_pending = True
                    heapq.heappush(heap, (now + batch_window, seq, FLUSH, None))
                    seq += 1
            else:
                tally_lp([sched.allocate_low_priority(req, now)])
        elif kind == FLUSH:
            flush_pending = False
            if buffer:
                tally_lp(sched.allocate_low_priority_batch(buffer, now))
                buffer = []
        else:                                      # CHURN (DESIGN.md §16)
            ev = payload
            if ev.kind == "fail":
                orphans, _ = sched.fail_device(ev.device, now)
                sched.settle_hp_orphans(orphans, now)
            elif ev.kind == "drain":
                sched.drain_device(ev.device, now)
            elif ev.kind == "rejoin":
                sched.rejoin_device(ev.device, now)
            elif ev.kind == "link" and ev.duration > 0.0:
                st.link.reserve(now, now + ev.duration, ("churn", ev.device))
    wall = _time.perf_counter() - t_wall

    hp_lat = metrics.t_hp_initial + metrics.t_hp_preempt
    out = {
        "scenario": cfg.name,
        "arrival": cfg.arrival,
        "n_devices": cfg.n_devices,
        "n_arrivals": len(arrivals),
        "hp_admitted": hp_ok,
        "hp_failed": hp_fail,
        "lp_allocated": lp_ok,
        "lp_failed": lp_fail,
        "preemptions": metrics.preemptions,
        "realloc_success": metrics.realloc_success,
        "realloc_failure": metrics.realloc_failure,
        "hp_alloc_us_mean": _us_mean(hp_lat),
        "hp_alloc_us_p99": _us_pct(hp_lat, 99),
        # preemption-path admissions only (the quantity bench_preemption
        # compares between the vectorized plane and the scalar loop)
        "hp_preempt_us_mean": _us_mean(metrics.t_hp_preempt),
        "n_hp_preempt": len(metrics.t_hp_preempt),
        "lp_alloc_us_mean": _us_mean(metrics.t_lp_alloc),
        "lp_alloc_us_p99": _us_pct(metrics.t_lp_alloc, 99),
        "wall_s": wall,
    }
    if metrics.device_failures or metrics.device_drains \
            or metrics.device_rejoins:
        # churn runs only: churn-free summaries keep their historic key set
        out["device_failures"] = metrics.device_failures
        out["device_drains"] = metrics.device_drains
        out["device_rejoins"] = metrics.device_rejoins
        out["orphans_created"] = metrics.orphans_created
        out["orphans_recovered"] = metrics.orphans_recovered
    return out


def _us_mean(xs: list[float]) -> float:
    return 1e6 * sum(xs) / len(xs) if xs else 0.0


def _us_pct(xs: list[float], q: float) -> float:
    return 1e6 * float(np.percentile(xs, q)) if xs else 0.0
