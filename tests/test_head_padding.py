"""Head-padding (§Perf) equivalence: the padded-head model is numerically
identical to the original — zero padded-query rows are annihilated by zero
output-projection rows, and duplicated kv heads reproduce the original GQA
grouping exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.models.head_padding import (
    _q_slot_map,
    pad_attn_params,
    pad_heads_config,
    padded_head_counts,
)


def _gqa_cfg():
    # h=6, kv=2, group=3; pad to multiple 4 -> kv'=4, r=2, g'=2, h'=8
    cfg = get_smoke_config("llava-next-34b")
    from dataclasses import replace
    return replace(cfg, n_heads=6, n_kv_heads=2,
                   head_dim=cfg.resolved_head_dim)


def test_padded_head_counts():
    assert padded_head_counts(56, 8, 16) == (64, 16)
    assert padded_head_counts(14, 2, 16) == (16, 16)
    assert padded_head_counts(9, 3, 16) == (48, 48)
    assert padded_head_counts(6, 2, 4) == (8, 4)


def test_q_slot_map_covers_all_heads():
    for (h, kv, mult) in [(56, 8, 16), (14, 2, 16), (6, 2, 4), (9, 3, 16)]:
        h_p, kv_p = padded_head_counts(h, kv, mult)
        qmap = _q_slot_map(h, kv, h_p, kv_p)
        assert len(qmap) == h_p
        used = [s for s in qmap if s >= 0]
        assert sorted(used) == list(range(h))       # each orig head once
        # every valid q slot attends a copy of its original kv head
        r, g, g_p = kv_p // kv, h // kv, h_p // kv_p
        for slot, src in enumerate(qmap):
            if src >= 0:
                assert (slot // g_p) // r == src // g


@pytest.mark.parametrize("mult", [4, 8])
def test_forward_equivalence(mult):
    cfg = _gqa_cfg()
    cfg_p = pad_heads_config(cfg, mult)
    assert cfg_p.n_heads % mult == 0 and cfg_p.n_kv_heads % mult == 0
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    params_p = pad_attn_params(params, cfg, cfg_p)

    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                     cfg.vocab_size),
        "modality_emb": jax.random.normal(
            jax.random.PRNGKey(2), (2, cfg.n_modality_tokens,
                                    cfg.modality_embed_dim), jnp.float32),
    }
    logits, _ = M.forward(params, cfg, batch)
    logits_p, _ = M.forward(params_p, cfg_p, batch)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_p),
                               rtol=2e-5, atol=2e-5)


def test_decode_equivalence():
    cfg = _gqa_cfg()
    cfg_p = pad_heads_config(cfg, 4)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    params_p = pad_attn_params(params, cfg, cfg_p)

    prompt = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                     cfg.vocab_size),
        "modality_emb": jax.random.normal(
            jax.random.PRNGKey(2), (1, cfg.n_modality_tokens,
                                    cfg.modality_embed_dim), jnp.float32),
    }
    cache_len = 32
    logits, caches = M.prefill(params, cfg, prompt, cache_len)
    logits_p, caches_p = M.prefill(params_p, cfg_p, prompt, cache_len)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_p),
                               rtol=2e-5, atol=2e-5)

    pos = prompt["tokens"].shape[1] + cfg.n_modality_tokens
    tok = jnp.argmax(logits[:, -1:], -1)
    for step in range(3):
        out, caches = M.decode_step(params, cfg, caches, tok,
                                    jnp.asarray(pos + step, jnp.int32))
        out_p, caches_p = M.decode_step(params_p, cfg_p, caches_p, tok,
                                        jnp.asarray(pos + step, jnp.int32))
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_p),
                                   rtol=2e-5, atol=2e-5)
        tok = jnp.argmax(out[:, -1:] if out.ndim == 3 else out, -1)
        if tok.ndim == 1:
            tok = tok[:, None]


def test_mla_config_is_noop():
    from repro.configs import get_config
    cfg = get_config("deepseek-v2-236b")
    assert pad_heads_config(cfg, 16) is cfg
