# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from .calendar import DeviceCalendar, LinkCalendar, NetworkState, Reservation
from .metrics import Metrics
from .network import MessageSizes, NetworkConfig
from .profiles import (
    PAPER_TYPE,
    TaskProfile,
    WorkloadSpec,
    get_workload,
    register_workload,
    registered_workloads,
)
from .policy import (
    Decision,
    DecisionStatus,
    PolicyDispatcher,
    SchedulingPolicy,
    create_policy,
    register_policy,
    registered_policies,
)
from .scheduler import (
    Allocation,
    HPResult,
    LPResult,
    PreemptionAwareScheduler,
    VICTIM_POLICIES,
)
from .task import Frame, LowPriorityRequest, Priority, Task, TaskState

__all__ = [
    "Allocation",
    "Decision",
    "DecisionStatus",
    "DeviceCalendar",
    "Frame",
    "HPResult",
    "LinkCalendar",
    "LowPriorityRequest",
    "LPResult",
    "MessageSizes",
    "Metrics",
    "NetworkConfig",
    "NetworkState",
    "PAPER_TYPE",
    "PolicyDispatcher",
    "PreemptionAwareScheduler",
    "Priority",
    "Reservation",
    "SchedulingPolicy",
    "Task",
    "TaskProfile",
    "TaskState",
    "VICTIM_POLICIES",
    "WorkloadSpec",
    "create_policy",
    "get_workload",
    "register_policy",
    "register_workload",
    "registered_policies",
    "registered_workloads",
]
