import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and record memory / cost / collective analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
      --out results/dryrun.jsonl

The XLA_FLAGS line above MUST stay the first statement: jax locks the device
count on first init, and the dry-run needs 512 placeholder CPU devices.
"""
import argparse            # noqa: E402
import json                # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402

import jax                 # noqa: E402

from ..configs import ARCH_IDS                      # noqa: E402
from ..configs.shapes import SHAPES                 # noqa: E402
from .build import lower_combo                      # noqa: E402
from .hlo_analysis import analytic_model_flops, roofline_from_compiled  # noqa: E402
from .mesh import make_production_mesh              # noqa: E402


def run_one(arch: str, shape_name: str, multi_pod: bool,
            verbose: bool = True, unroll: bool = False, **combo_kw) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "multi_pod": multi_pod,
        "chips": int(mesh.devices.size),
        "unrolled": unroll,
    }
    t0 = time.time()
    try:
        with mesh:
            combo = lower_combo(arch, shape_name, mesh, unroll=unroll,
                                **combo_kw)
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = combo.lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)

            mem = compiled.memory_analysis()
            if mem is not None:
                for attr in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                ):
                    val = getattr(mem, attr, None)
                    if val is not None:
                        rec[attr] = int(val)
                rec["total_bytes_per_device"] = sum(
                    rec.get(a, 0)
                    for a in ("argument_size_in_bytes", "temp_size_in_bytes",
                              "output_size_in_bytes")
                )
            hlo = compiled.as_text()
            from ..configs.shapes import SHAPES as _SH
            mf = analytic_model_flops(combo.cfg, _SH[shape_name])
            roof = roofline_from_compiled(compiled, rec["chips"], hlo, mf)
            rec["roofline"] = roof.summary()
            rec["status"] = "ok"
    except Exception as e:                            # noqa: BLE001
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    if verbose:
        status = rec["status"]
        extra = (
            f"bottleneck={rec['roofline']['bottleneck']}"
            if status == "ok" else rec.get("error", "")[:120]
        )
        print(f"[dryrun] {arch:24s} {shape_name:12s} "
              f"mesh={rec['mesh']:8s} {status:4s} "
              f"({rec['total_s']:.0f}s) {extra}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scans (exact cost analysis, slow)")
    ap.add_argument("--dtype", default="bfloat16",
                    help="float32 avoids the CPU backend's bf16->f32 "
                    "emulation converts (roofline methodology runs)")
    ap.add_argument("--baseline", action="store_true",
                    help="paper-faithful divisibility-only sharding "
                    "(disables the §Perf seq-shard cache fallback)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    combos: list[tuple[str, str, bool]] = []
    # explicit --arch/--shape filters always win; --all (or omission)
    # sweeps the unfiltered axis
    archs = (args.arch,) if args.arch else ARCH_IDS
    shapes = (args.shape,) if args.shape else tuple(SHAPES)
    meshes = (False, True) if args.both_meshes else (args.multi_pod,)
    for mp in meshes:
        for a in archs:
            for s in shapes:
                combos.append((a, s, mp))

    out_f = open(args.out, "a") if args.out else None
    from ..models.sharding import RuleSet                 # noqa: E402
    ruleset = RuleSet(seq_shard_cache_fallback=not args.baseline)
    n_ok = 0
    for arch, shape, mp in combos:
        rec = run_one(arch, shape, mp, unroll=args.unroll,
                      dtype=args.dtype, ruleset=ruleset)
        rec["dtype"] = args.dtype
        rec["baseline_rules"] = args.baseline
        n_ok += rec["status"] == "ok"
        if out_f:
            slim = {k: v for k, v in rec.items() if k != "traceback"}
            out_f.write(json.dumps(slim) + "\n")
            out_f.flush()
    print(f"[dryrun] {n_ok}/{len(combos)} combos compiled OK")
    if n_ok != len(combos):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
