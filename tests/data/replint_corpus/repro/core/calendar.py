"""Corpus: dirty-notify polarities.  This relpath IS the owning module,
so mirror-sync skips it and dirty-notify applies."""


class GoodCalendar:
    def _touch(self):
        pass

    def reserve(self, t):                  # good: mutates AND notifies
        self._sky.add(t)
        self._touch()

    def release(self, t):                  # BAD: mutates _t2s, never notifies
        self._t2s.remove(t)

    def splice(self, t):                   # BAD: calls a splicer, never notifies
        self._t2s_insert(t)

    def _t2s_insert(self, t):  # replint: disable=dirty-notify (caller notifies)
        self._sky.add(t)

    def query(self, t):                    # good: read-only
        return t in self._sky


class NotWired:
    """No ``_touch`` — not dirty-mark-wired, so the rule stays silent."""

    def mutate(self, t):
        self._sky.add(t)
