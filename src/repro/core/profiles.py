"""Workload profiles: per-(task type x core configuration) resource demands.

The paper derives every task's resource requirement from offline benchmarks
of each (task type x core configuration) and pads the reserved slots with the
benchmark std-dev (§3, §5).  The seed reproduction collapsed that table to
three global constants (``t_hp`` / ``t_lp_2core`` / ``t_lp_4core`` on
``NetworkConfig``), which froze every scenario into the paper's single
waste-classification model.  This module restores the table:

* :class:`TaskProfile` — one task type's benchmarked demands: stage-2 (HP)
  exec mean + slot padding, per-core-configuration stage-3 (LP) exec means +
  paddings, input/output transfer sizes, and optional per-type deadlines.
* :class:`WorkloadSpec` — a named mapping of task *types* to profiles plus
  arrival mix weights, with constructors from the paper's constants
  (``from_paper_constants`` — the default, bit-for-bit identical to the seed
  behaviour) and from a measured/analytic serving cost model
  (``from_cost_model`` — how ``serving/cost_model.py`` step times reach the
  scheduler).
* a small registry (``register_workload`` / ``get_workload``) so scenario
  configs can name a workload the way they name traces and policies.

Everything downstream (scheduler, policies, sim, serving engine) asks
``NetworkConfig.profile(task_type)`` for durations instead of reading the
three globals; ``task_type=None`` resolves to the spec's default profile, so
the paper's single-model world needs no annotations anywhere.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Mapping, Optional

#: The default task type: the paper's waste-classification pipeline.
PAPER_TYPE = "paper"


@dataclass(frozen=True)
class VariantSpec:
    """One degraded rung of a task type's variant ladder (DESIGN.md §17).

    A rung swaps the model for a cheaper variant of itself: lower benchmark
    accuracy, faster per-core-configuration exec stats, and (optionally)
    smaller transfer sizes.  Rung costs must be monotone non-increasing down
    the ladder — enforced by :class:`TaskProfile` at construction — so every
    skip-hint lower bound that holds for a rung also holds for the rungs
    below it.  ``input_bytes``/``output_bytes`` of ``None`` inherit the base
    profile's sizes.
    """

    accuracy: float
    lp_exec: Mapping[int, float]         # cores -> stage-3 exec mean, seconds
    lp_pad: Mapping[int, float]          # cores -> stage-3 slot padding
    input_bytes: Optional[int] = None    # None -> inherit the base profile
    output_bytes: Optional[int] = None   # None -> inherit the base profile


@dataclass(frozen=True, eq=False)
class TaskProfile:
    """Offline-benchmarked resource demands for one task type.

    ``lp_exec`` / ``lp_pad`` map a core configuration (the paper's 2-/4-core
    horizontal split; the TPU adaptation's model-parallel degree) to the
    benchmarked stage-3 execution mean and its slot padding (std-dev).
    ``input_bytes`` sizes the offload input transfer; ``output_bytes`` the
    completion state-update message.  ``lp_deadline`` optionally overrides
    the workload-level relative deadline for this type's LP sets (None =
    use the scenario's frame period), giving mixed workloads per-model
    deadlines.
    """

    name: str
    hp_exec: float                       # stage-2 exec mean (1 core), seconds
    hp_pad: float                        # HP slot padding (benchmark std-dev)
    lp_exec: Mapping[int, float]         # cores -> stage-3 exec mean, seconds
    lp_pad: Mapping[int, float]          # cores -> stage-3 slot padding
    input_bytes: int = 21500             # offload input transfer size
    output_bytes: int = 550              # completion state-update size
    hp_deadline_slack: float = 0.45      # HP deadline beyond detect+proc
    lp_deadline: Optional[float] = None  # per-type relative LP deadline
    #: Benchmarked model accuracy in (0, 1] — weights the oracle's goodput
    #: tiebreak and the quality report's accuracy-weighted goodput metric.
    #: The paper's single-model world keeps the neutral 1.0.
    accuracy: float = 1.0
    #: Degradation ladder (DESIGN.md §17): ordered cheaper rungs BELOW this
    #: profile.  This profile itself is variant 0, so an empty tuple (the
    #: default) is the ladder-free world — bit-identical to every committed
    #: golden.  Rung ``i`` resolves through :meth:`variant_profile` to a
    #: derived profile named ``"{name}@{i}"``.
    variants: tuple[VariantSpec, ...] = ()

    def __post_init__(self) -> None:
        if not self.lp_exec:
            raise ValueError(
                f"profile {self.name!r} declares no LP core configurations"
            )
        if set(self.lp_pad) != set(self.lp_exec):
            raise ValueError(
                f"profile {self.name!r}: lp_pad core configs "
                f"{sorted(self.lp_pad)} != lp_exec core configs "
                f"{sorted(self.lp_exec)}"
            )
        object.__setattr__(self, "lp_exec",
                           dict(sorted(self.lp_exec.items())))
        object.__setattr__(self, "lp_pad",
                           {c: self.lp_pad[c] for c in self.lp_exec})
        object.__setattr__(self, "variants", tuple(self.variants))
        object.__setattr__(self, "_ladder", self._build_ladder())

    def _build_ladder(self) -> tuple["TaskProfile", ...]:
        """Derive one full :class:`TaskProfile` per rung, validating that
        accuracy and cost are monotone non-increasing down the ladder and
        that rungs keep the base core-configuration set (so an in-place
        degrade-shrink can always re-use the victim's core count)."""
        derived: list[TaskProfile] = []
        prev: TaskProfile = self
        for i, v in enumerate(self.variants, start=1):
            if not (0.0 < v.accuracy <= 1.0):
                raise ValueError(
                    f"profile {self.name!r} variant {i}: accuracy "
                    f"{v.accuracy} outside (0, 1]"
                )
            if v.accuracy > prev.accuracy:
                raise ValueError(
                    f"profile {self.name!r} variant {i}: accuracy "
                    f"{v.accuracy} exceeds the rung above ({prev.accuracy}) "
                    "— ladders must be monotone non-increasing"
                )
            if set(v.lp_exec) != set(self.lp_exec):
                raise ValueError(
                    f"profile {self.name!r} variant {i}: core configs "
                    f"{sorted(v.lp_exec)} != base configs "
                    f"{sorted(self.lp_exec)} — rungs must benchmark the "
                    "base profile's core configurations"
                )
            vp = TaskProfile(
                name=f"{self.name}@{i}",
                hp_exec=self.hp_exec,
                hp_pad=self.hp_pad,
                lp_exec=dict(v.lp_exec),
                lp_pad=dict(v.lp_pad),
                input_bytes=(self.input_bytes if v.input_bytes is None
                             else v.input_bytes),
                output_bytes=(self.output_bytes if v.output_bytes is None
                              else v.output_bytes),
                hp_deadline_slack=self.hp_deadline_slack,
                lp_deadline=self.lp_deadline,
                accuracy=v.accuracy,
            )
            for cores in vp.core_options:
                if vp.lp_slot_time(cores) > prev.lp_slot_time(cores):
                    raise ValueError(
                        f"profile {self.name!r} variant {i}: slot time at "
                        f"{cores} cores ({vp.lp_slot_time(cores):.3f}s) "
                        f"exceeds the rung above "
                        f"({prev.lp_slot_time(cores):.3f}s) — ladders must "
                        "be monotone non-increasing"
                    )
            if vp.input_bytes > self.input_bytes:
                raise ValueError(
                    f"profile {self.name!r} variant {i}: input_bytes "
                    f"{vp.input_bytes} exceeds the base {self.input_bytes} "
                    "— a degraded transfer may not grow"
                )
            derived.append(vp)
            prev = vp
        return tuple(derived)

    @property
    def n_variants(self) -> int:
        """Ladder depth including variant 0 (this profile itself)."""
        return 1 + len(self.variants)

    @property
    def ladder(self) -> tuple["TaskProfile", ...]:
        """The full ladder, variant 0 (self) first."""
        return (self,) + self._ladder

    def variant_profile(self, variant: int = 0) -> "TaskProfile":
        """The profile for one ladder rung.  Variant 0 is this profile;
        indices past the bottom clamp to the last rung.  Ladder-free
        profiles answer every index with themselves — which is exactly the
        legacy one-bit ``Task.degraded`` semantics (same exec stats, the
        upgrade pass pinned off)."""
        if variant <= 0 or not self._ladder:
            return self
        return self._ladder[min(variant, len(self._ladder)) - 1]

    @property
    def core_options(self) -> tuple[int, ...]:
        """Viable core configurations, minimum first (§3.2)."""
        return tuple(self.lp_exec)

    def lp_proc_time(self, cores: int) -> float:
        try:
            return self.lp_exec[cores]
        except KeyError:
            raise ValueError(
                f"profile {self.name!r}: unsupported LP core configuration "
                f"{cores}; benchmarked configs: {list(self.lp_exec)}"
            ) from None

    def lp_slot_time(self, cores: int) -> float:
        return self.lp_proc_time(cores) + self.lp_pad[cores]

    @property
    def hp_slot_time(self) -> float:
        return self.hp_exec + self.hp_pad

    def hp_deadline(self, request_time: float) -> float:
        return request_time + self.hp_exec + self.hp_deadline_slack

    @property
    def min_lp_slot_time(self) -> float:
        """Minimum-configuration slot duration (skip-hint lower bounds)."""
        return self.lp_slot_time(self.core_options[0])


@dataclass
class WorkloadSpec:
    """A named set of task-type profiles plus their arrival mix.

    ``mix`` holds relative arrival weights per task type (only meaningful
    for mixed workloads; single-profile specs never consult it).  The
    ``default_type`` profile answers every un-annotated task
    (``task_type=None``), which is how the paper's single-model scenarios
    run unchanged.
    """

    name: str
    profiles: dict[str, TaskProfile] = field(default_factory=dict)
    default_type: str = PAPER_TYPE
    mix: dict[str, float] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.profiles:
            raise ValueError(f"workload {self.name!r} has no profiles")
        if self.default_type not in self.profiles:
            raise ValueError(
                f"workload {self.name!r}: default_type "
                f"{self.default_type!r} not among profiles "
                f"{sorted(self.profiles)}"
            )
        for t in self.mix:
            if t not in self.profiles:
                raise ValueError(
                    f"workload {self.name!r}: mix weight for unknown task "
                    f"type {t!r}; profiles: {sorted(self.profiles)}"
                )

    # ------------------------------------------------------------------ #
    def profile(self, task_type: Optional[str] = None) -> TaskProfile:
        """The profile for ``task_type`` (None -> the default profile)."""
        if task_type is None:
            task_type = self.default_type
        try:
            return self.profiles[task_type]
        except KeyError:
            raise ValueError(
                f"workload {self.name!r}: unknown task type {task_type!r}; "
                f"available: {', '.join(sorted(self.profiles))}"
            ) from None

    @property
    def task_types(self) -> tuple[str, ...]:
        return tuple(sorted(self.profiles))

    @property
    def is_mixed(self) -> bool:
        return len(self.profiles) > 1

    @property
    def min_lp_slot_time(self) -> float:
        """Network-wide minimum-config slot duration lower bound (valid for
        every task type AND every ladder rung — degraded variants only ever
        get cheaper; used by the scheduler's skip-hint pruning)."""
        return min(v.min_lp_slot_time
                   for p in self.profiles.values() for v in p.ladder)

    @property
    def has_ladder(self) -> bool:
        """True when any profile carries degraded rungs (DESIGN.md §17)."""
        return any(p.n_variants > 1 for p in self.profiles.values())

    @property
    def max_input_bytes_type(self) -> str:
        """Task type with the largest offload input (worst-case transfer —
        the conservative bound for round-level time-point skipping)."""
        return max(self.profiles,
                   key=lambda t: (self.profiles[t].input_bytes, t))

    def mix_weights(self) -> tuple[tuple[str, float], ...]:
        """(task_type, probability) pairs, normalised, deterministic order.
        No weights at all -> uniform.  A partial mix must leave residual
        probability (< 1 total) for the omitted types, which share it
        equally; a partial mix that already spends >= 1 raises, so an
        omitted type can never be silently dropped from the arrival
        stream."""
        types = self.task_types
        if not self.mix:
            w = {t: 1.0 for t in types}
        else:
            missing = [t for t in types if t not in self.mix]
            w = {t: float(self.mix[t]) for t in types if t in self.mix}
            if any(v < 0.0 for v in w.values()):
                raise ValueError(
                    f"workload {self.name!r}: negative mix weight"
                )
            explicit = sum(w.values())
            if missing:
                residual = 1.0 - explicit
                if residual <= 0.0:
                    raise ValueError(
                        f"workload {self.name!r}: mix spends {explicit} "
                        f"leaving no residual probability for unweighted "
                        f"task type(s) {missing}; weight them explicitly "
                        "or keep the explicit weights below 1.0"
                    )
                for t in missing:
                    w[t] = residual / len(missing)
            elif explicit <= 0.0:
                raise ValueError(f"workload {self.name!r}: mix sums to zero")
        total = sum(w.values())
        return tuple((t, w[t] / total) for t in types)

    def with_profile(self, profile: TaskProfile,
                     weight: float = 1.0) -> "WorkloadSpec":
        """A new spec with ``profile`` added (or replaced) under its name."""
        profiles = dict(self.profiles)
        profiles[profile.name] = profile
        mix = dict(self.mix) if self.mix else {
            t: w for t, w in self.mix_weights()
        }
        mix[profile.name] = weight
        return WorkloadSpec(self.name, profiles, self.default_type, mix)

    # ------------------------------------------------------------------ #
    # Constructors                                                       #
    # ------------------------------------------------------------------ #
    @classmethod
    def from_paper_constants(
        cls,
        *,
        t_hp: float = 0.980,
        hp_pad_s: float = 0.050,
        t_lp_2core: float = 16.862,
        t_lp_4core: float = 11.611,
        lp_pad_s: float = 0.400,
        input_bytes: int = 21500,
        output_bytes: int = 550,
        hp_deadline_slack: float = 0.45,
        name: str = PAPER_TYPE,
    ) -> "WorkloadSpec":
        """The paper's single-model workload (§5 benchmark table).  Built
        from the same constants ``NetworkConfig`` carries, so the default
        spec reproduces the seed's timing model bit-for-bit."""
        profile = TaskProfile(
            name=name,
            hp_exec=t_hp,
            hp_pad=hp_pad_s,
            lp_exec={2: t_lp_2core, 4: t_lp_4core},
            lp_pad={2: lp_pad_s, 4: lp_pad_s},
            input_bytes=input_bytes,
            output_bytes=output_bytes,
            hp_deadline_slack=hp_deadline_slack,
        )
        return cls(name=name, profiles={name: profile}, default_type=name)

    @classmethod
    def from_cost_model(
        cls,
        cost,                                   # serving.cost_model.CostModel
        *,
        lp_tokens: int,
        name: str = "serve",
        degrees: Optional[tuple[int, ...]] = None,
        input_bytes: int = 21500,
        output_bytes: int = 550,
        hp_deadline_slack: Optional[float] = None,
        lp_deadline: Optional[float] = None,
    ) -> "WorkloadSpec":
        """Build a single-type spec from a measured or analytic serving cost
        model (duck-typed: anything with ``prefill``/``decode`` per-degree
        :class:`PhaseCost` maps works).  The LP task is a ``lp_tokens``-token
        decode; per-degree slot padding is that degree's measured std-dev
        scaled by the token count — the paper's per-configuration padding
        rather than the seed's single global pad."""
        degs = tuple(degrees) if degrees is not None else tuple(sorted(cost.decode))
        if not degs:
            raise ValueError("cost model exposes no decode degrees")
        missing = [d for d in degs if d not in cost.decode]
        if missing:
            raise ValueError(
                f"cost model has no decode degree(s) {missing}; measured "
                f"degrees: {sorted(cost.decode)}"
            )
        prefill = cost.prefill[min(cost.prefill)]
        profile = TaskProfile(
            name=name,
            hp_exec=prefill.mean_s,
            hp_pad=prefill.std_s,
            lp_exec={d: cost.decode[d].mean_s * lp_tokens for d in degs},
            lp_pad={d: cost.decode[d].std_s * lp_tokens for d in degs},
            input_bytes=input_bytes,
            output_bytes=output_bytes,
            hp_deadline_slack=(prefill.mean_s * 0.5
                               if hp_deadline_slack is None
                               else hp_deadline_slack),
            lp_deadline=lp_deadline,
        )
        return cls(name=name, profiles={name: profile}, default_type=name)


# ====================================================================== #
# Registry                                                               #
# ====================================================================== #
_WORKLOADS: dict[str, Callable[[], WorkloadSpec]] = {}


def register_workload(name: str, factory: Callable[[], WorkloadSpec]) -> None:
    if name in _WORKLOADS:
        raise ValueError(f"workload {name!r} already registered")
    _WORKLOADS[name] = factory


def registered_workloads() -> tuple[str, ...]:
    return tuple(sorted(_WORKLOADS))


def get_workload(name: str) -> WorkloadSpec:
    try:
        factory = _WORKLOADS[name]
    except KeyError:
        raise ValueError(
            f"unknown workload {name!r}; registered workloads: "
            + ", ".join(registered_workloads())
        ) from None
    return factory()


def validate_workload_name(name: str) -> None:
    if name not in _WORKLOADS:
        raise ValueError(
            f"unknown workload {name!r}; registered workloads: "
            + ", ".join(registered_workloads())
        )


# ---------------------------------------------------------------------- #
# Built-in workloads                                                     #
# ---------------------------------------------------------------------- #
def _mixed_edge() -> WorkloadSpec:
    """A heterogeneous edge fleet: the paper's waste-classification model
    interleaved with a lightweight mobile classifier and a heavy detection
    transformer, each with its own benchmark table, transfer sizes and LP
    deadline (the DNN-partitioning setting in PAPERS.md: per-model profiles,
    not one global constant)."""
    paper = get_workload(PAPER_TYPE).profile()
    mobile = TaskProfile(
        name="mobile_lite",
        hp_exec=0.310, hp_pad=0.020,
        # light classifier: near-linear 2->4 scaling, tiny transfers
        lp_exec={2: 5.730, 4: 3.105}, lp_pad={2: 0.150, 4: 0.150},
        input_bytes=9200, output_bytes=550,
        hp_deadline_slack=0.30,
        lp_deadline=12.5,                 # tighter than the 18.86 s frame
        accuracy=0.81,                    # light model: cheaper but weaker
    )
    detr = TaskProfile(
        name="detr_heavy",
        hp_exec=1.450, hp_pad=0.080,
        # heavy detection head: poor 2->4 scaling, large feature-map input
        lp_exec={2: 26.410, 4: 19.884}, lp_pad={2: 0.600, 4: 0.600},
        input_bytes=64500, output_bytes=1100,
        hp_deadline_slack=0.70,
        lp_deadline=42.0,                 # looser: batch-analytics tier
        accuracy=0.94,                    # heavy head: strongest model
    )
    return WorkloadSpec(
        name="mixed_edge",
        profiles={p.name: p for p in (paper, mobile, detr)},
        default_type=PAPER_TYPE,
        mix={PAPER_TYPE: 0.5, "mobile_lite": 0.3, "detr_heavy": 0.2},
    )


def _paper_ladder() -> WorkloadSpec:
    """The paper's pipeline with a two-rung degradation ladder (DESIGN.md
    §17): variant 0 is the published benchmark table bit-for-bit; the rungs
    below are a distilled and a heavily-quantized variant of the same model
    (faster, smaller inputs, lower accuracy — the imprecise-computation
    setting of Yao et al. in PAPERS.md).  The scenario of choice for the
    ``degrade_storm`` family and the quality report's ladder column."""
    from dataclasses import replace

    base = WorkloadSpec.from_paper_constants().profile()
    laddered = replace(base, variants=(
        # distilled: ~55% of the base exec, keeps most of the accuracy
        VariantSpec(accuracy=0.92,
                    lp_exec={2: 9.120, 4: 6.270},
                    lp_pad={2: 0.250, 4: 0.250},
                    input_bytes=12800),
        # int8-quantized: ~25% of the base exec, accuracy floor
        VariantSpec(accuracy=0.78,
                    lp_exec={2: 4.310, 4: 2.985},
                    lp_pad={2: 0.150, 4: 0.150},
                    input_bytes=6400),
    ))
    return WorkloadSpec(name="paper_ladder",
                        profiles={laddered.name: laddered},
                        default_type=PAPER_TYPE)


def _mixed_edge_ladder() -> WorkloadSpec:
    """``mixed_edge`` with ladders on the two heavy types: the paper model
    gets the ``paper_ladder`` rungs, the detection transformer a single
    pruned rung; the already-light mobile classifier stays single-variant
    (mixed ladder depths exercise the clamp-to-bottom path)."""
    from dataclasses import replace

    spec = _mixed_edge()
    paper = _paper_ladder().profile()
    detr = replace(spec.profiles["detr_heavy"], variants=(
        VariantSpec(accuracy=0.87,
                    lp_exec={2: 14.820, 4: 10.450},
                    lp_pad={2: 0.350, 4: 0.350},
                    input_bytes=32200),
    ))
    profiles = dict(spec.profiles)
    profiles[paper.name] = paper
    profiles[detr.name] = detr
    return WorkloadSpec(name="mixed_edge_ladder", profiles=profiles,
                        default_type=spec.default_type, mix=dict(spec.mix))


register_workload(PAPER_TYPE, WorkloadSpec.from_paper_constants)
register_workload("mixed_edge", _mixed_edge)
register_workload("paper_ladder", _paper_ladder)
register_workload("mixed_edge_ladder", _mixed_edge_ladder)
