"""Corpus: outside the jax-free boundary a module-level jax import is fine."""
import jax


def show(x):
    return jax, x
