"""Discrete-event reproduction of the paper's RPi2B testbed experiments (§5/§6).

Three backends share one frame-generation runtime:
  * ``scheduler``     — the paper's preemption-aware time-slotted scheduler
  * ``central_ws``    — centralised workstealer baseline (global job queue)
  * ``decentral_ws``  — decentralised workstealer baseline (per-device queues,
                        random polling)
each with and without the preemption mechanism.
"""
from __future__ import annotations

import random
from collections import deque
from dataclasses import dataclass
from typing import Optional

from ..core.calendar import NetworkState
from ..core.metrics import Metrics
from ..core.network import NetworkConfig
from ..core.scheduler import Allocation, PreemptionAwareScheduler
from ..core.task import (
    Frame,
    LowPriorityRequest,
    Priority,
    Task,
    TaskState,
    reset_id_counters,
)
from .events import Event, EventQueue
from .traces import TraceConfig, generate_trace


@dataclass(frozen=True)
class ScenarioConfig:
    name: str
    trace: str                       # "uniform" | "weighted_1".."weighted_4"
    algorithm: str                   # "scheduler" | "central_ws" | "decentral_ws"
    preemption: bool
    n_frames: int = 1296
    n_devices: int = 4
    seed: int = 0
    exec_noise: bool = True
    hp_noise_sigma: float = 0.02
    lp_noise_sigma: float = 0.20
    # "farthest_deadline" (paper §4) | "weakest_set" (paper §8 proposal,
    # beyond-paper — see EXPERIMENTS.md §Beyond-paper scheduling)
    victim_policy: str = "farthest_deadline"
    # Controller-side LP batching (beyond-paper, DESIGN.md §4.3): LP requests
    # arriving within this window are admitted through ONE batch sweep
    # (`allocate_low_priority_batch`).  0 = the paper's per-request path.
    lp_batch_window: float = 0.0


# The paper's evaluated scenarios (Table 1 legend).
SCENARIOS: dict[str, ScenarioConfig] = {
    "UPS": ScenarioConfig("UPS", "uniform", "scheduler", True),
    "UNPS": ScenarioConfig("UNPS", "uniform", "scheduler", False),
    "WPS_1": ScenarioConfig("WPS_1", "weighted_1", "scheduler", True),
    "WPS_2": ScenarioConfig("WPS_2", "weighted_2", "scheduler", True),
    "WPS_3": ScenarioConfig("WPS_3", "weighted_3", "scheduler", True),
    "WPS_4": ScenarioConfig("WPS_4", "weighted_4", "scheduler", True),
    "WNPS_4": ScenarioConfig("WNPS_4", "weighted_4", "scheduler", False),
    "DPW": ScenarioConfig("DPW", "weighted_4", "decentral_ws", True),
    "DNPW": ScenarioConfig("DNPW", "weighted_4", "decentral_ws", False),
    "CPW": ScenarioConfig("CPW", "weighted_4", "central_ws", True),
    "CNPW": ScenarioConfig("CNPW", "weighted_4", "central_ws", False),
    # beyond-paper: the paper's §8 set-aware victim-selection proposal
    "UPS_SET": ScenarioConfig("UPS_SET", "uniform", "scheduler", True,
                              victim_policy="weakest_set"),
    "WPS_4_SET": ScenarioConfig("WPS_4_SET", "weighted_4", "scheduler", True,
                                victim_policy="weakest_set"),
    "WPS_3_SET": ScenarioConfig("WPS_3_SET", "weighted_3", "scheduler", True,
                                victim_policy="weakest_set"),
}


class Runtime:
    """Frame generation + metric finalisation shared by all backends."""

    def __init__(self, cfg: ScenarioConfig, net: Optional[NetworkConfig] = None):
        self.cfg = cfg
        self.net = net or NetworkConfig()
        self.q = EventQueue()
        self.metrics = Metrics(cfg.name)
        self.rng = random.Random(cfg.seed * 7919 + 17)
        self.frames: list[Frame] = []
        self.requests: list[LowPriorityRequest] = []
        # The controller processes requests in a blocking sequential fashion
        # (paper §3.3); allocation latency delays decisions in sim-time.
        self.ctrl_busy_until = 0.0
        self.backend = _make_backend(self)

    def controller_job(self, latency: float, fn) -> None:
        """Run `fn` once the sequential controller reaches this job."""
        start = max(self.q.now, self.ctrl_busy_until)
        self.ctrl_busy_until = start + latency
        self.q.push(self.ctrl_busy_until, fn)

    def charge_controller(self, latency: float) -> None:
        self.ctrl_busy_until = max(self.q.now, self.ctrl_busy_until) + latency

    # -- execution-time noise + contention model -------------------------- #
    def exec_time(self, task: Task, busy_frac: float = 0.0) -> float:
        if task.priority == Priority.HIGH:
            base, sigma, coef = self.net.t_hp, self.cfg.hp_noise_sigma, \
                self.net.hp_contention_coef
        else:
            base, sigma, coef = self.net.lp_proc_time(task.cores), \
                self.cfg.lp_noise_sigma, self.net.lp_contention_coef
        t = base * (1.0 + coef * busy_frac)
        if self.cfg.exec_noise:
            t += self.rng.gauss(0.0, sigma)
        return max(0.05, t)

    # -- frame pipeline -------------------------------------------------- #
    def run(self) -> Metrics:
        reset_id_counters()
        trace = generate_trace(
            TraceConfig(self.cfg.trace, self.cfg.n_frames, self.cfg.n_devices,
                        self.cfg.seed)
        )
        period = self.net.frame_period
        # Hosts start as staggered pairs (paper §3) with random per-device offset.
        offsets = [
            self.rng.uniform(0.0, 1.0) + (period / 2 if d >= self.cfg.n_devices // 2 else 0.0)
            for d in range(self.cfg.n_devices)
        ]
        fid = 0
        for k in range(self.cfg.n_frames):
            for d in range(self.cfg.n_devices):
                t = offsets[d] + k * period
                self._spawn_frame(t, d, int(trace[k, d]), fid)
                fid += 1
        self.q.run()
        return self._finalize()

    def _spawn_frame(self, t: float, device: int, value: int, fid: int) -> None:
        frame = Frame(device, t, value, fid, deadline=t + self.net.frame_period)
        self.frames.append(frame)

        def gen() -> None:
            self.metrics.frames_total += 1
            if frame.trace_value == -1:
                return
            self.metrics.hp_generated += 1
            # stage 1 object detection = constant overhead before the HP request
            self.q.push(self.q.now + self.net.t_object_detect,
                        lambda: self.backend.hp_request(frame))

        self.q.push(t, gen)

    def issue_lp_request(self, frame: Frame) -> None:
        """Called by backends when a frame's HP task completes with value>=1."""
        req = LowPriorityRequest(
            source_device=frame.device,
            deadline=frame.deadline,
            frame_id=frame.frame_id,
            n_tasks=frame.trace_value,
            created_at=self.q.now,
        )
        req.make_tasks()
        frame.lp_request = req
        self.requests.append(req)
        self.metrics.lp_generated += req.n_tasks
        self.metrics.lp_requests_total += 1
        # request message transit to the controller
        self.q.push(self.q.now + self.net.slot(self.net.msg.lp_alloc),
                    lambda: self.backend.lp_request(req))

    def _finalize(self) -> Metrics:
        m = self.metrics
        self.backend.finalize()
        for frame in self.frames:
            if frame.completed:
                m.frames_completed += 1
        for req in self.requests:
            done = sum(1 for t in req.tasks if t.state == TaskState.COMPLETED)
            m.lp_request_fractions.append(done / req.n_tasks)
            if done == req.n_tasks:
                m.lp_requests_completed += 1
        return m


def _make_backend(rt: Runtime):
    if rt.cfg.algorithm == "scheduler":
        return SchedulerBackend(rt)
    if rt.cfg.algorithm == "central_ws":
        return WorkstealerBackend(rt, central=True)
    if rt.cfg.algorithm == "decentral_ws":
        return WorkstealerBackend(rt, central=False)
    raise ValueError(f"unknown algorithm {rt.cfg.algorithm}")


# ====================================================================== #
# Scheduler backend (the paper's system)                                 #
# ====================================================================== #
class SchedulerBackend:
    def __init__(self, rt: Runtime) -> None:
        self.rt = rt
        self.state = NetworkState(rt.cfg.n_devices)
        self.sched = PreemptionAwareScheduler(
            self.state,
            rt.net,
            preemption=rt.cfg.preemption,
            metrics=rt.metrics,
            on_preempt=self._on_preempt,
            victim_policy=rt.cfg.victim_policy,
        )
        self._exec_events: dict[Task, Event] = {}
        self._frames_by_hp: dict[Task, Frame] = {}
        self._via_preemption: set[Task] = set()
        self._lp_buffer: list[LowPriorityRequest] = []
        self._lp_flush_armed = False

    # -- requests --------------------------------------------------------- #
    def hp_request(self, frame: Frame) -> None:
        now = self.rt.q.now
        task = Task(
            priority=Priority.HIGH,
            source_device=frame.device,
            deadline=self.rt.net.hp_deadline(now),
            frame_id=frame.frame_id,
            created_at=now,
        )
        frame.hp_task = task
        self._frames_by_hp[task] = frame
        res = self.sched.allocate_high_priority(task, now)
        if not res.success:
            task.state = TaskState.FAILED
            self.rt.metrics.hp_failed_alloc += 1
            return
        if res.preempted:
            self._via_preemption.add(task)
        self._schedule_exec(res.allocation)
        for re in res.reallocations:
            self._schedule_exec(re)

    def lp_request(self, req: LowPriorityRequest) -> None:
        window = self.rt.cfg.lp_batch_window
        if window <= 0.0:
            self._account_lp(self.sched.allocate_low_priority(req, self.rt.q.now))
            return
        # batching mode: buffer, admit every request of the window together
        self._lp_buffer.append(req)
        if not self._lp_flush_armed:
            self._lp_flush_armed = True
            self.rt.q.push(self.rt.q.now + window, self._flush_lp_batch)

    def _flush_lp_batch(self) -> None:
        self._lp_flush_armed = False
        batch, self._lp_buffer = self._lp_buffer, []
        if not batch:
            return
        for res in self.sched.allocate_low_priority_batch(batch, self.rt.q.now):
            self._account_lp(res)

    def _account_lp(self, res) -> None:
        m = self.rt.metrics
        m.lp_failed_alloc += len(res.failed)
        for alloc in res.allocations:
            m.lp_allocated += 1
            bucket = m.core_alloc_offloaded if alloc.offloaded else m.core_alloc_local
            bucket[alloc.cores] += 1
            if alloc.offloaded:
                m.lp_offloaded += 1
            self._schedule_exec(alloc)

    # -- execution -------------------------------------------------------- #
    def _schedule_exec(self, alloc: Allocation) -> None:
        task = alloc.task

        def start() -> None:
            if task.state != TaskState.ALLOCATED:
                return                      # preempted before execution began
            task.state = TaskState.RUNNING
            dev = self.state.devices[alloc.device]
            busy = max(0, dev.max_usage(alloc.t_start, alloc.t_end) - alloc.cores)
            actual = self.rt.exec_time(task, busy / dev.capacity)
            finish = alloc.t_start + actual
            if finish > alloc.t_end:
                ev = self.rt.q.push(alloc.t_end, lambda: self._violate(task))
            else:
                ev = self.rt.q.push(finish, lambda: self._complete(task))
            self._exec_events[task] = ev

        self._exec_events[task] = self.rt.q.push(alloc.t_start, start)

    def _on_preempt(self, victim: Task) -> None:
        ev = self._exec_events.pop(victim, None)
        if ev is not None:
            ev.cancel()

    def _complete(self, task: Task) -> None:
        now = self.rt.q.now
        self._exec_events.pop(task, None)
        m = self.rt.metrics
        late = now > task.deadline + 1e-9
        dev = self.state.devices[task.device]
        dev.truncate(task, now)        # state update frees remaining slot time
        if task.priority == Priority.HIGH:
            if late:
                task.state = TaskState.FAILED
                m.hp_failed_runtime += 1
                return
            task.state = TaskState.COMPLETED
            m.hp_completed += 1
            if task in self._via_preemption:
                m.hp_completed_via_preemption += 1
            frame = self._frames_by_hp[task]
            if frame.trace_value >= 1:
                self.rt.issue_lp_request(frame)
        else:
            if late:
                task.state = TaskState.FAILED
                return
            task.state = TaskState.COMPLETED
            m.lp_completed += 1
            if task.offloaded:
                m.lp_offloaded_completed += 1

    def _violate(self, task: Task) -> None:
        """Task overran its reserved slot; the device terminates it (§7.3)."""
        self._exec_events.pop(task, None)
        task.state = TaskState.VIOLATED
        self.state.devices[task.device].release(task)
        if task.priority == Priority.HIGH:
            self.rt.metrics.hp_failed_runtime += 1

    def finalize(self) -> None:
        pass


# ====================================================================== #
# Workstealer baselines (processor-sharing execution model)              #
#                                                                        #
# Workstealers perform no admission control: devices rashly execute     #
# whatever they steal (paper §8 "rash task placement decisions").  Cores #
# are therefore *oversubscribed*, which the paper reports as middleware  #
# + concurrent-DNN degradation (11.611 s benchmarked tasks averaging     #
# ~14.5 s).  We model execution as processor sharing: each running task  #
# progresses at rate cores * min(1, capacity/demand); HP tasks addition- #
# ally pay a GIL/middleware interference penalty when the device is      #
# oversubscribed (the Python inference manager competes with TFLite      #
# worker threads).                                                       #
# ====================================================================== #
class _Run:
    __slots__ = ("work", "cores")

    def __init__(self, work: float, cores: int) -> None:
        self.work = work        # remaining core-seconds
        self.cores = cores


class _WSDevice:
    __slots__ = ("idx", "capacity", "running", "queue", "last", "event",
                 "inflight")

    def __init__(self, idx: int, capacity: int = 4) -> None:
        self.idx = idx
        self.capacity = capacity
        self.running: dict[Task, _Run] = {}
        self.queue: deque[Task] = deque()
        self.last = 0.0          # last time `work` values were advanced
        self.event: Optional[Event] = None
        self.inflight = 0        # cores reserved by steals still in transfer

    @property
    def demand(self) -> int:
        return sum(r.cores for r in self.running.values())

    @property
    def lp_cores(self) -> int:
        return sum(r.cores for t, r in self.running.items()
                   if t.priority == Priority.LOW)

    @property
    def committed(self) -> int:
        """Cores running or promised (blocks further steals)."""
        return self.demand + self.inflight

    def share(self) -> float:
        d = self.demand
        return 1.0 if d <= self.capacity else self.capacity / d


class WorkstealerBackend:
    """Centralised (global queue) or decentralised (per-device, random polls)."""

    # HP interference coefficient: rate *= 1/(1 + GIL_COEF * over/capacity)
    # when the device is oversubscribed (see class comment).
    GIL_COEF = 0.6
    # Zombie grace: a late task keeps burning cores for this fraction of a
    # frame period past its deadline before the violation kill lands
    # (detection + violation message + manager teardown are not instant).
    # Calibrated against the paper's Fig 2a workstealer frame counts.
    KILL_GRACE = 1.0

    def __init__(self, rt: Runtime, central: bool) -> None:
        self.rt = rt
        self.central = central
        self.devices = [_WSDevice(d) for d in range(rt.cfg.n_devices)]
        self.global_queue: deque[Task] = deque()
        self._frames_by_hp: dict[Task, Frame] = {}
        self._via_preemption: set[Task] = set()
        self._preempt_pending: set[Task] = set()
        self._polling: set[int] = set()

    # -- processor-sharing core ------------------------------------------- #
    def _hp_penalty(self, dev: _WSDevice) -> float:
        over = max(0, dev.demand - dev.capacity)
        return 1.0 / (1.0 + self.GIL_COEF * over / dev.capacity)

    def _rate(self, dev: _WSDevice, task: Task, run: _Run) -> float:
        rate = run.cores * dev.share()
        if task.priority == Priority.HIGH:
            rate *= self._hp_penalty(dev)
        return rate

    def _advance(self, dev: _WSDevice) -> None:
        """Drain elapsed progress into every running task's `work`."""
        now = self.rt.q.now
        dt = now - dev.last
        if dt > 0:
            for task, run in dev.running.items():
                run.work -= dt * self._rate(dev, task, run)
        dev.last = now

    def _reschedule(self, dev: _WSDevice) -> None:
        """(Re)arm the next-completion event after any demand change."""
        if dev.event is not None:
            dev.event.cancel()
            dev.event = None
        if not dev.running:
            return
        soonest = min(
            run.work / max(self._rate(dev, task, run), 1e-12)
            for task, run in dev.running.items()
        )
        dev.event = self.rt.q.push(
            self.rt.q.now + max(soonest, 0.0), lambda: self._on_finish(dev)
        )

    def _on_finish(self, dev: _WSDevice) -> None:
        dev.event = None
        self._advance(dev)
        done = [t for t, r in dev.running.items() if r.work <= 1e-6]
        for task in done:
            dev.running.pop(task)
            self._complete(dev, task)
        self._kick(dev)
        self._kick_all()
        self._reschedule(dev)

    def _start(self, dev: _WSDevice, task: Task, cores: int) -> None:
        rt = self.rt
        self._advance(dev)
        task.device, task.cores = dev.idx, cores
        task.offloaded = task.offloaded or (
            task.priority == Priority.LOW and dev.idx != task.source_device
        )
        task.state = TaskState.RUNNING
        if task.priority == Priority.HIGH:
            base = rt.net.t_hp
            sigma = self.rt.cfg.hp_noise_sigma
        else:
            base = rt.net.lp_proc_time(cores)
            sigma = self.rt.cfg.lp_noise_sigma
        work = base * cores
        if rt.cfg.exec_noise:
            work = max(0.05, work + rt.rng.gauss(0.0, sigma * cores))
        dev.running[task] = _Run(work, cores)
        # The inference manager terminates tasks that overrun their deadline
        # (paper §7.3 task-violation messages) — partial work is wasted.
        if task.priority == Priority.LOW:
            rt.q.push(task.deadline + self.KILL_GRACE * rt.net.frame_period,
                      lambda: self._kill_if_late(dev, task))
        self._reschedule(dev)

    def _kill_if_late(self, dev: _WSDevice, task: Task) -> None:
        if task not in dev.running:
            return
        self._advance(dev)
        dev.running.pop(task)
        task.state = TaskState.FAILED
        if task in self._preempt_pending:
            self._preempt_pending.discard(task)
            self.rt.metrics.realloc_failure += 1
        self._kick(dev)
        self._kick_all()
        self._reschedule(dev)

    # -- requests --------------------------------------------------------- #
    def hp_request(self, frame: Frame) -> None:
        rt, now = self.rt, self.rt.q.now
        dev = self.devices[frame.device]
        task = Task(
            priority=Priority.HIGH,
            source_device=frame.device,
            deadline=rt.net.hp_deadline(now),
            frame_id=frame.frame_id,
            created_at=now,
        )
        frame.hp_task = task
        self._frames_by_hp[task] = frame
        # Preemption: if starting the HP task would oversubscribe the device,
        # evict the running LP task with the farthest deadline (work lost).
        if rt.cfg.preemption and dev.demand + 1 > dev.capacity:
            victims = [t for t in dev.running if t.priority == Priority.LOW]
            if victims:
                self._preempt(dev, max(victims, key=lambda t: t.deadline))
                self._via_preemption.add(task)
        self._start(dev, task, cores=1)

    def lp_request(self, req: LowPriorityRequest) -> None:
        for t in req.tasks:
            if self.central:
                self.global_queue.append(t)
            else:
                self.devices[req.source_device].queue.append(t)
        self._kick_all()

    # -- preemption ------------------------------------------------------- #
    def _preempt(self, dev: _WSDevice, victim: Task) -> None:
        self._advance(dev)
        run = dev.running.pop(victim)
        victim.state = TaskState.PREEMPTED
        victim.preempt_count += 1
        m = self.rt.metrics
        m.preemptions += 1
        m.preempted_by_cores[run.cores] += 1
        self._preempt_pending.add(victim)
        # re-queue for re-stealing (the workstealer's "reallocation");
        # all partial work is lost.
        if self.central:
            self.global_queue.appendleft(victim)
        else:
            self.devices[victim.source_device].queue.appendleft(victim)
        self._reschedule(dev)

    # -- completion ------------------------------------------------------- #
    def _complete(self, dev: _WSDevice, task: Task) -> None:
        rt, m = self.rt, self.rt.metrics
        late = rt.q.now > task.deadline + 1e-9
        task.state = TaskState.FAILED if late else TaskState.COMPLETED
        if task.priority == Priority.HIGH:
            if late:
                m.hp_failed_runtime += 1
            else:
                m.hp_completed += 1
                if task in self._via_preemption:
                    m.hp_completed_via_preemption += 1
                frame = self._frames_by_hp[task]
                if frame.trace_value >= 1:
                    rt.issue_lp_request(frame)
        elif not late:
            m.lp_completed += 1
            if task.offloaded:
                m.lp_offloaded_completed += 1
            if task in self._preempt_pending:
                self._preempt_pending.discard(task)
                m.realloc_success += 1

    # -- stealing --------------------------------------------------------- #
    def _kick_all(self) -> None:
        for dev in self.devices:
            self._kick(dev)

    def _kick(self, dev: _WSDevice) -> None:
        rt = self.rt
        # Steal while there are >= 2 uncommitted cores (running + in-flight,
        # HP included); stealing is myopic (grab 4 cores when fully idle,
        # else 2) and rash (no completion-feasibility check).
        while dev.committed + 2 <= dev.capacity:
            task, delay = self._acquire(dev)
            if task is None:
                break
            cores = 4 if dev.committed == 0 else 2
            # Rash (paper §8): stealers start tasks with no *completion*
            # feasibility check — a task started with 5 s to its deadline
            # burns cores until the deadline kill. Only tasks already past
            # their deadline are dropped at steal time.
            if rt.q.now + delay > task.deadline:
                task.state = TaskState.FAILED
                if task in self._preempt_pending:
                    self._preempt_pending.discard(task)
                    rt.metrics.realloc_failure += 1
                else:
                    rt.metrics.lp_failed_alloc += 1
                continue
            m = rt.metrics
            m.lp_allocated += 1
            offl = dev.idx != task.source_device
            bucket = m.core_alloc_offloaded if offl else m.core_alloc_local
            bucket[cores] += 1
            if offl:
                m.lp_offloaded += 1
            if delay > 0:
                dev.inflight += cores

                def arrive(d=dev, t=task, c=cores) -> None:
                    d.inflight -= c
                    self._start(d, t, c)

                self.rt.q.push(rt.q.now + delay, arrive)
            else:
                self._start(dev, task, cores)
        if (
            not self.central
            and dev.committed + 2 <= dev.capacity
            and dev.idx not in self._polling
            and any(d.queue for d in self.devices)
        ):
            # decentralised: retry polling while idle
            self._polling.add(dev.idx)

            def poll_again() -> None:
                self._polling.discard(dev.idx)
                self._kick(dev)

            rt.q.push(rt.q.now + 0.25, poll_again)

    def _acquire(self, dev: _WSDevice) -> tuple[Optional[Task], float]:
        net = self.rt.net
        poll = 2 * net.slot(net.msg.state_update)
        if self.central:
            if self.global_queue:
                task = self.global_queue.popleft()
                delay = poll + (
                    net.slot(net.msg.input_transfer)
                    if task.source_device != dev.idx
                    else 0.0
                )
                return task, delay
            return None, 0.0
        # decentralised: own queue first, then random polling order
        if dev.queue:
            return dev.queue.popleft(), 0.0
        order = [d for d in self.devices if d is not dev]
        self.rt.rng.shuffle(order)
        delay = 0.0
        for other in order:
            delay += poll
            if other.queue:
                task = other.queue.popleft()
                return task, delay + net.slot(net.msg.input_transfer)
        return None, delay

    def finalize(self) -> None:
        m = self.rt.metrics
        for task in self._preempt_pending:
            m.realloc_failure += 1
        self._preempt_pending.clear()
        for q in [self.global_queue] + [d.queue for d in self.devices]:
            for task in q:
                if task.state in (TaskState.PENDING, TaskState.PREEMPTED):
                    task.state = TaskState.FAILED
                    m.lp_failed_alloc += 1


def run_scenario(cfg: ScenarioConfig, net: Optional[NetworkConfig] = None) -> Metrics:
    return Runtime(cfg, net).run()
