"""Self-test harness for the ``repro.analysis`` lint plane.

Three layers:

* fixture corpus (tests/data/replint_corpus/): good/bad snippets per rule,
  both polarities, laid out like ``src/`` so path-scoped rules see the
  exact relpaths they scope on;
* pragma/baseline semantics: line-scoped suppression, content-addressed
  occurrence-indexed keys, stale-entry reporting, byte-deterministic JSON;
* seeded injection: copy the real ``src/`` tree, verify the CLI gate
  passes, inject known-bad patterns, verify the gate fails.
"""
import json
import os
import shutil
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (
    DirtyNotifyRule,
    JaxImportRule,
    MirrorWriteRule,
    PallasIndexRule,
    SetIterRule,
    TerminalStateRule,
    UnseededRngRule,
    WallClockRule,
    default_rules,
    run_analysis,
)

REPO = Path(__file__).parent.parent
SRC = REPO / "src"
CORPUS = Path(__file__).parent / "data" / "replint_corpus"
BASELINE = REPO / "replint_baseline.json"


def corpus_report(**kw):
    return run_analysis(CORPUS, root_label="corpus", **kw)


def by_file(report):
    out = {}
    for f, _key in report.findings:
        out.setdefault(f.path, []).append((f.rule, f.line))
    return {path: sorted(rows) for path, rows in out.items()}


# --------------------------------------------------------------------------- #
# Rule polarities over the fixture corpus                                     #
# --------------------------------------------------------------------------- #
EXPECTED = {
    "repro/core/calendar.py": [("dirty-notify", 13), ("dirty-notify", 16)],
    "repro/core/mirror_bad.py": [("mirror-sync", ln) for ln in (5, 6, 7, 8, 9)],
    "repro/core/terminal_bad.py": [("terminal-state", 6), ("terminal-state", 7)],
    "repro/core/policy.py": [("terminal-state", 11)],
    "repro/core/determinism_bad.py": [
        ("determinism-rng", 16), ("determinism-rng", 17),
        ("determinism-rng", 18), ("determinism-rng", 19),
        ("determinism-set-iter", 20), ("determinism-set-iter", 23),
        ("determinism-set-iter", 26),
        ("determinism-wallclock", 14), ("determinism-wallclock", 15),
    ],
    "repro/sim/pragma_cases.py": [("determinism-wallclock", 7)],
    "repro/kernels/pallas_bad.py": [("pallas-index", 6), ("pallas-index", 7)],
    "repro/serving/stream.py": [
        ("jax-free-boundary", 2), ("jax-free-boundary", 3),
        ("jax-free-boundary", 6),
    ],
}

GOOD_FILES = [
    "repro/core/mirror_good.py",
    "repro/core/determinism_good.py",
    "repro/kernels/pallas_good.py",
    "repro/serving/__init__.py",
    "repro/viz/plots.py",
]


def test_corpus_findings_exact():
    report = corpus_report()
    assert by_file(report) == {p: sorted(rows)
                               for p, rows in EXPECTED.items()}
    assert not report.gate_ok


@pytest.mark.parametrize("rel", GOOD_FILES)
def test_good_fixtures_are_clean(rel):
    report = corpus_report(files=[CORPUS / rel])
    assert not report.findings, report.findings


def test_every_rule_fires_in_the_corpus():
    """No shipped rule is vacuous: each one fires somewhere in the corpus.
    (The negative polarity per rule is pinned by the exact-findings test:
    every good fixture — and every good method inside the corpus
    calendar.py for the single-file dirty-notify rule — stays unflagged.)"""
    report = corpus_report()
    fired = {f.rule for f, _ in report.findings} | {
        f.rule for f in report.suppressed}
    assert fired == {r.name for r in default_rules()}


def test_settle_registry_override():
    """The audited registry is constructor-overridable (corpus calendars /
    forks can certify their own settle helpers)."""
    rule = TerminalStateRule(settle={
        "repro/core/terminal_bad.py": frozenset({"leak"}),
    })
    report = corpus_report(rules=[rule])
    assert by_file(report) == {"repro/core/policy.py": [
        ("terminal-state", 8), ("terminal-state", 11)]}


# --------------------------------------------------------------------------- #
# Pragma semantics                                                            #
# --------------------------------------------------------------------------- #
def test_pragma_scopes_to_flagged_line_only():
    report = corpus_report(files=[CORPUS / "repro/sim/pragma_cases.py"],
                           rules=[WallClockRule()])
    assert [(f.rule, f.line) for f, _ in report.findings] == [
        ("determinism-wallclock", 7)]
    assert sorted(f.line for f in report.suppressed) == [6, 12]


def test_pragma_wrong_rule_does_not_suppress(tmp_path):
    mod = tmp_path / "repro" / "core" / "m.py"
    mod.parent.mkdir(parents=True)
    mod.write_text(textwrap.dedent("""\
        import time

        def f():
            return time.time()  # replint: disable=determinism-rng (wrong rule)
    """))
    report = run_analysis(tmp_path, rules=[WallClockRule()])
    assert [f.line for f, _ in report.findings] == [4]
    assert not report.suppressed


# --------------------------------------------------------------------------- #
# Baseline semantics                                                          #
# --------------------------------------------------------------------------- #
def test_baseline_grandfathers_and_gate_passes():
    first = corpus_report()
    baseline = {key: "grandfathered for the corpus round-trip test"
                for _f, key in first.findings}
    second = corpus_report(baseline=baseline)
    assert not second.findings
    assert len(second.baselined) == len(first.findings)
    assert not second.stale_baseline
    assert second.gate_ok


def test_stale_baseline_entry_fails_gate():
    first = corpus_report()
    baseline = {key: "ok" for _f, key in first.findings}
    baseline["determinism-wallclock::repro/core/gone.py::x = time.time()::0"] = \
        "this finding was fixed but the entry was not retired"
    report = corpus_report(baseline=baseline)
    assert report.stale_baseline == [
        "determinism-wallclock::repro/core/gone.py::x = time.time()::0"]
    assert not report.findings
    assert not report.gate_ok


def test_baseline_keys_survive_line_shifts(tmp_path):
    """Content-addressed keys: inserting unrelated lines above a
    grandfathered finding must not invalidate its baseline entry."""
    mod = tmp_path / "repro" / "core" / "m.py"
    mod.parent.mkdir(parents=True)
    body = "import time\n\ndef f():\n    return time.time()\n"
    mod.write_text(body)
    key = run_analysis(tmp_path, rules=[WallClockRule()]).findings[0][1]
    mod.write_text("# an unrelated comment\n# another\n" + body)
    shifted = run_analysis(tmp_path, rules=[WallClockRule()],
                           baseline={key: "attested"})
    assert not shifted.findings
    assert not shifted.stale_baseline
    assert shifted.gate_ok


def test_identical_lines_get_occurrence_indexed_keys(tmp_path):
    mod = tmp_path / "repro" / "core" / "m.py"
    mod.parent.mkdir(parents=True)
    mod.write_text(textwrap.dedent("""\
        import time

        def f():
            t = time.time()
            t = time.time()
            return t
    """))
    report = run_analysis(tmp_path, rules=[WallClockRule()])
    keys = [key for _f, key in report.findings]
    assert len(keys) == 2 and keys[0] != keys[1]
    assert keys[0].endswith("::0") and keys[1].endswith("::1")
    # baselining ONE occurrence leaves the other a live finding
    partial = run_analysis(tmp_path, rules=[WallClockRule()],
                           baseline={keys[0]: "first occurrence attested"})
    assert [key for _f, key in partial.findings] == [keys[1]]
    assert not partial.stale_baseline


def test_parse_error_is_a_finding(tmp_path):
    mod = tmp_path / "repro" / "core" / "broken.py"
    mod.parent.mkdir(parents=True)
    mod.write_text("def f(:\n")
    report = run_analysis(tmp_path)
    assert [f.rule for f, _ in report.findings] == ["parse-error"]
    assert not report.gate_ok


# --------------------------------------------------------------------------- #
# Deterministic report                                                        #
# --------------------------------------------------------------------------- #
def test_json_report_is_byte_deterministic(tmp_path):
    a = corpus_report().to_json()
    b = corpus_report().to_json()
    assert a == b
    # ... and independent of the absolute root the tree is scanned from
    clone = tmp_path / "elsewhere"
    shutil.copytree(CORPUS, clone)
    c = run_analysis(clone, root_label="corpus").to_json()
    assert c == a
    # no absolute paths leak into the report
    assert str(REPO) not in a and str(tmp_path) not in c
    payload = json.loads(a)
    assert payload["gate_ok"] is False
    assert payload["counts"]["findings"] == sum(map(len, EXPECTED.values()))
    assert payload["counts"]["suppressed"] == 3


# --------------------------------------------------------------------------- #
# The real tree: zero unbaselined findings at merge                           #
# --------------------------------------------------------------------------- #
def test_src_gate_is_clean_with_committed_baseline():
    baseline = json.loads(BASELINE.read_text())
    report = run_analysis(SRC, baseline=baseline, root_label="src")
    assert not report.findings, "\n".join(
        f"{f.path}:{f.line}: {f.rule}: {f.message}"
        for f, _ in report.findings)
    assert not report.stale_baseline
    assert report.gate_ok
    # the committed baseline carries ONLY attested timing telemetry
    assert all(f.rule == "determinism-wallclock"
               for f, _k, _j in report.baselined)


# --------------------------------------------------------------------------- #
# CLI + seeded injection                                                      #
# --------------------------------------------------------------------------- #
def _cli(*args, **kw):
    env = dict(os.environ, PYTHONPATH=str(SRC))
    return subprocess.run([sys.executable, "-m", "repro.analysis", *args],
                          capture_output=True, text=True, env=env,
                          cwd=REPO, **kw)


def test_cli_list_rules():
    proc = _cli("--list-rules")
    assert proc.returncode == 0
    listed = {line.split(":", 1)[0] for line in proc.stdout.splitlines()}
    assert listed == {r.name for r in default_rules()}


def test_cli_gate_passes_on_src_within_budget():
    proc = _cli("--gate", "--budget-s", "10")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert " 0 finding(s)" in proc.stdout


def test_cli_budget_exceeded_exits_2():
    proc = _cli("--budget-s", "0")
    assert proc.returncode == 2
    assert "budget exceeded" in proc.stderr


def test_cli_json_report_is_stable_across_runs(tmp_path):
    out1, out2 = tmp_path / "r1.json", tmp_path / "r2.json"
    assert _cli("--json", str(out1)).returncode == 0
    assert _cli("--json", str(out2)).returncode == 0
    assert out1.read_bytes() == out2.read_bytes()


@pytest.fixture()
def src_clone(tmp_path):
    clone = tmp_path / "src"
    shutil.copytree(SRC, clone)
    return clone


def _clone_gate(clone):
    return _cli("--gate", "--root", str(clone),
                "--baseline", str(BASELINE))


def test_injection_clean_clone_passes(src_clone):
    proc = _clone_gate(src_clone)
    assert proc.returncode == 0, proc.stdout + proc.stderr


@pytest.mark.parametrize("rel,snippet,rule", [
    ("repro/core/scheduler.py",
     "\n\ndef _injected_probe():\n    import time\n    return time.time()\n",
     "determinism-wallclock"),
    ("repro/sim/scenarios.py",
     "\n\ndef _injected_clobber(dev):\n    dev._sky.clear()\n",
     "mirror-sync"),
    ("repro/core/task.py",
     "\n\ndef _injected_settle(task):\n"
     "    task.state = TaskState.FAILED\n",
     "terminal-state"),
    ("repro/core/metrics.py",
     "\n\ndef _injected_order(seen):\n    pending = set(seen)\n"
     "    return [s for s in pending]\n",
     "determinism-set-iter"),
    ("repro/serving/stream.py",
     "\nimport jax\n",
     "jax-free-boundary"),
])
def test_injection_gate_fails(src_clone, rel, snippet, rule):
    """Seeded injection: the gate MUST fail when a known-bad pattern is
    introduced anywhere in the scanned tree."""
    target = src_clone / rel
    target.write_text(target.read_text() + snippet)
    proc = _clone_gate(src_clone)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert rule in proc.stdout
