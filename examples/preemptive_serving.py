"""The paper's technique as a TPU-serving feature: priority/deadline-aware
preemptive scheduling of batched inference requests over pod slices.

A stream of interactive HIGH-priority requests (the paper's stage-2
classifier analogue: tight deadline, must run on its home slice) competes
with background LOW-priority batch-decode jobs (the stage-3 DNN analogue:
offloadable to other slices at 2- or 4-way parallel degree).  Token
generation is REAL jax compute on a reduced model; placement, deadlines and
preemption run on the paper's time-slotted calendars.

  PYTHONPATH=src python examples/preemptive_serving.py [--requests 24]
  PYTHONPATH=src python examples/preemptive_serving.py --no-preemption
  PYTHONPATH=src python examples/preemptive_serving.py --resume
        (beyond-paper: preempted jobs keep their KV cache and resume)
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.task import Priority
from repro.models import model as M
from repro.serving.cost_model import measure_cost_model
from repro.serving.engine import (
    PreemptiveServingEngine,
    ServeRequest,
    engine_network_config,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--no-preemption", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="beyond-paper mode: preempted decodes keep their "
                    "KV cache resident and resume instead of restarting")
    ap.add_argument("--lp-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    print(f"[setup] measuring step costs for reduced {args.arch} "
          "(the paper's offline benchmark phase)")
    cost = measure_cost_model(cfg, reps=3)
    net = engine_network_config(cost, args.lp_tokens)

    eng = PreemptiveServingEngine(
        cfg, params, cost,
        n_slices=4, units_per_slice=4,
        preemption=not args.no_preemption,
        lose_work=not args.resume,
        net=net,
    )

    key = jax.random.PRNGKey(1)
    hp_deadline = net.t_hp * 2.0 + 0.05
    lp_exec = cost.lp_exec_time(2, args.lp_tokens)
    rng = jax.random.split(key, args.requests)
    for i in range(args.requests):
        prompt = jax.random.randint(rng[i], (1, 16), 0, cfg.vocab_size)
        hp = i % 3 != 2                       # 2:1 interactive:batch mix
        arrive = 0.02 * i
        req = ServeRequest(
            prompt=prompt,
            max_new_tokens=2 if hp else args.lp_tokens,
            priority=Priority.HIGH if hp else Priority.LOW,
            deadline=arrive + (hp_deadline if hp else lp_exec * 3.0),
            home_slice=i % 4,
        )
        eng.q.push(arrive, lambda r=req: eng.submit(r))

    m = eng.run()
    done = [r for r in eng.done if r.state == "done"]
    hp_done = [r for r in done if r.priority == Priority.HIGH]
    lp_done = [r for r in done if r.priority == Priority.LOW]
    n_hp = sum(1 for r in eng.done if r.priority == Priority.HIGH)
    n_lp = len(eng.done) - n_hp
    print(f"\n[results] preemption={'off' if args.no_preemption else 'on'} "
          f"resume={'on' if args.resume else 'off'}")
    print(f"  HIGH-priority: {len(hp_done)}/{n_hp} done "
          f"({m.preemptions} preemptions invoked, "
          f"{m.realloc_success} victim reallocations)")
    print(f"  LOW-priority:  {len(lp_done)}/{n_lp} done, "
          f"{m.lp_offloaded} offloaded to other slices")
    if lp_done:
        r = lp_done[0]
        print(f"  sample LP generation (req {r.rid}, "
              f"{r.n_preemptions} preemptions): {r.tokens_out[:12]}...")
    lat = [r.completed_at - r.arrival for r in hp_done]
    if lat:
        print(f"  HP latency: mean {1e3*sum(lat)/len(lat):.1f}ms "
              f"max {1e3*max(lat):.1f}ms (deadline {1e3*hp_deadline:.1f}ms)")


if __name__ == "__main__":
    main()
