"""Corpus: determinism violations — clocks, RNG, set iteration."""
import random
import time
from time import perf_counter as pc

import numpy as np


class Plane:
    def __init__(self):
        self._pending: set[int] = set()

    def refresh(self, state):
        stamp = time.time()                         # BAD: wall clock
        tick = pc()                                 # BAD: wall clock via alias
        rng = np.random.default_rng()               # BAD: unseeded generator
        noise = np.random.normal()                  # BAD: global-state draw
        random.shuffle([])                          # BAD: global-state draw
        r = random.Random()                         # BAD: unseeded instance
        for idx in self._pending:                   # BAD: set iter (self attr)
            pass
        pending = {1, 2, 3}
        for p in pending:                           # BAD: set iter (local)
            pass
        d = state._pending
        rows = [i for i in d]                       # BAD: set iter (alias)
        return stamp, tick, rng, noise, r, rows
