"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Reproduces every paper table/figure (full 1296-frame workload by default;
``--fast`` uses 300 frames), runs the scheduler micro-benchmarks, and — if
dry-run artifacts exist under results/ — appends the roofline table.

Output: ``figure,scenario,metric,value[,paper_value]`` CSV on stdout.
"""
from __future__ import annotations

import argparse
import sys
import time

from . import paper_figures, roofline_report, scheduler_micro


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="300 frames instead of the paper's 1296")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()
    n_frames = 300 if args.fast else 1296

    print("figure,scenario,metric,value,paper_value")
    t0 = time.time()
    for fn in paper_figures.ALL_FIGURES:
        for fig, scen, metric, value in fn(n_frames):
            paper = paper_figures.PAPER.get((fig, scen, metric), "")
            print(f"{fig},{scen},{metric},{value:.3f},{paper}")
        sys.stdout.flush()
    for fig, scen, metric, value in scheduler_micro.bench_all(quick=args.fast):
        print(f"{fig},{scen},{metric},{value:.3f},")
        sys.stdout.flush()

    if not args.skip_roofline:
        print()
        roofline_report.print_table()
    print(f"# total bench time: {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
