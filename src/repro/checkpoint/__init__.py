from .store import exists, load_metadata, restore, save  # noqa: F401
