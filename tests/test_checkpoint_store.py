"""Checkpoint store durability + dtype-safety contract (PR 5 bugfixes):

* ``restore`` must refuse a dtype mismatch (naming the leaf) instead of
  silently ``astype``-ing — loading an integer step counter or bool mask
  into a float reference corrupts it; ``cast=True`` opts in explicitly.
* ``save`` must be atomic: an interrupted save can never leave a torn
  checkpoint (new manifest + old arrays, or half-written payload).
"""
import os

import numpy as np
import pytest

from repro.checkpoint import store


def tree():
    return {
        "w": np.arange(6, dtype=np.float32).reshape(2, 3),
        "step": np.asarray(7, dtype=np.int64),
        "mask": np.asarray([True, False, True]),
    }


def test_roundtrip(tmp_path):
    path = str(tmp_path / "ckpt")
    store.save(path, tree(), {"note": "x"})
    assert store.exists(path)
    out = store.restore(path, tree())
    assert out["w"].dtype == np.float32
    np.testing.assert_array_equal(out["w"], tree()["w"])
    np.testing.assert_array_equal(out["mask"], tree()["mask"])
    assert store.load_metadata(path) == {"note": "x"}


def test_restore_refuses_dtype_mismatch_naming_leaf(tmp_path):
    path = str(tmp_path / "ckpt")
    store.save(path, tree())
    ref = tree()
    ref["step"] = np.asarray(0.0, dtype=np.float64)   # int64 -> float64 ref
    with pytest.raises(ValueError, match=r"\['step'\].*int64.*float64"):
        store.restore(path, ref)


def test_restore_cast_opt_in(tmp_path):
    path = str(tmp_path / "ckpt")
    store.save(path, tree())
    ref = tree()
    ref["step"] = np.asarray(0.0, dtype=np.float64)
    out = store.restore(path, ref, cast=True)
    assert out["step"].dtype == np.float64 and out["step"] == 7.0


def test_restore_still_validates_shape(tmp_path):
    path = str(tmp_path / "ckpt")
    store.save(path, tree())
    ref = tree()
    ref["w"] = np.zeros((3, 2), dtype=np.float32)
    with pytest.raises(ValueError, match=r"\['w'\].*shape"):
        store.restore(path, ref)


def test_save_overwrites_atomically(tmp_path):
    path = str(tmp_path / "ckpt")
    store.save(path, tree(), {"v": 1})
    t2 = tree()
    t2["w"] = t2["w"] + 1.0
    store.save(path, t2, {"v": 2})
    out = store.restore(path, tree())
    np.testing.assert_array_equal(out["w"], tree()["w"] + 1.0)
    assert store.load_metadata(path) == {"v": 2}
    # no temp/backup litter left behind
    leftovers = [p for p in os.listdir(tmp_path) if p != "ckpt"]
    assert leftovers == []


def test_failed_swap_rolls_previous_checkpoint_back(tmp_path, monkeypatch):
    """If the final temp-dir -> path rename fails, the previous checkpoint
    must be rolled back into place (path never stays empty on a
    survivable error)."""
    path = str(tmp_path / "ckpt")
    store.save(path, tree(), {"v": 1})
    real_replace = os.replace

    def flaky_replace(src, dst):
        if src.startswith(f"{path}.tmp."):
            raise OSError("no rename for you")
        return real_replace(src, dst)

    monkeypatch.setattr(store.os, "replace", flaky_replace)
    with pytest.raises(OSError, match="no rename"):
        store.save(path, tree(), {"v": 2})
    monkeypatch.undo()
    assert store.exists(path)
    assert store.load_metadata(path) == {"v": 1}
    store.restore(path, tree())
    # the next successful save clears any leftover litter
    store.save(path, tree(), {"v": 3})
    assert store.load_metadata(path) == {"v": 3}
    assert [p for p in os.listdir(tmp_path) if p != "ckpt"] == []


def test_interrupted_save_leaves_previous_checkpoint_intact(tmp_path,
                                                            monkeypatch):
    path = str(tmp_path / "ckpt")
    store.save(path, tree(), {"v": 1})

    def boom(*a, **k):
        raise RuntimeError("disk full")

    monkeypatch.setattr(store.np, "savez", boom)
    with pytest.raises(RuntimeError, match="disk full"):
        store.save(path, tree(), {"v": 2})
    monkeypatch.undo()
    # the previous checkpoint is fully readable; nothing torn, no litter
    assert store.exists(path)
    out = store.restore(path, tree())
    np.testing.assert_array_equal(out["w"], tree()["w"])
    assert store.load_metadata(path) == {"v": 1}
    assert [p for p in os.listdir(tmp_path) if p != "ckpt"] == []
